"""GraSp sparse serving walkthrough (DESIGN.md §10): auto backend
selection flipping dense → grasp as graph density drops.

GraSp is the paper's Step-2 sparsity bitmap: real adjacencies are >99%
zero, so the accelerator can skip all-zero 128×128 blocks of Â entirely.
GraphServe makes that a per-graph DISPATCH decision rather than a build
flag:

  register — `agg_backend="auto"` turns on the backend rule for a model;
             plans exist in BOTH backends after warmup, so however the
             rule routes, nothing recompiles;
  route    — each graph's block bitmap feeds the modelled density/cost
             rule (`select_agg_backend`): scattered/dense graphs keep the
             dense matmul, clustered sparse graphs take the batched
             `bitmap_spmm` block-skip kernel;
  derive   — the block structure is DERIVED device-side from the cached
             fp32 Â once per structure version (zero extra host→device
             bytes), cached next to the CacheG operands, invalidated by
             update(), released by detach();
  observe  — summary() reports `agg_backends`, `grasp_batches`, and
             `backend_fallbacks` (a sparse dispatch that quietly ran
             dense — e.g. on a CPU host, where the skip grid cannot run);
  correct  — once BOTH backends hold measured batch latencies at a
             (model, bucket), the §14 latency bank overrides the
             roofline RANKING (never eligibility): on this CPU host the
             ref grasp path skips nothing, so late sweep entries can
             route dense where the cold model said grasp — the measured
             column below shows what the engine actually consulted.

  PYTHONPATH=src python examples/sparse_serving.py
"""
from repro.core.graph import BucketLadder
from repro.core.models import GNNConfig
from repro.core.sparsity import block_stats, grasp_max_nnz, select_agg_backend
from repro.data.graphs import clustered_like
from repro.runtime.gnn_server import GraphServe, GraphServeConfig


def main():
    cap, in_feats, classes, hidden = 1024, 16, 5, 16
    n = 896

    eng = GraphServe(GraphServeConfig(ladder=BucketLadder(buckets=(cap,)),
                                      batch_slots=2), seed=0)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=in_feats,
                                        hidden=hidden, num_classes=classes),
                       agg_backend="auto")
    blobs = eng.warmup()      # dense AND grasp plans + the block compactor
    print(f"warm: {blobs} compiled blobs (both backends pre-traced), "
          f"bucket budget grasp_max_nnz({cap}) = {grasp_max_nnz(cap)}\n")

    # Same community structure, falling density: cross-community edges
    # fill the block bitmap at high density; at low density the adjacency
    # is block-diagonal — exactly what the 128x128 skip targets.
    sweep = [("dense-ish", 0.50, 0.30), ("medium", 0.10, 0.05),
             ("sparse", 0.03, 0.0), ("very sparse", 0.01, 0.0)]
    print(f"{'graph':>12} {'elem dens':>10} {'block dens':>10} "
          f"{'model dense':>12} {'model grasp':>12} {'backend':>8}")
    for name, within, cross in sweep:
        g = clustered_like(num_nodes=n, num_feats=in_feats,
                           num_classes=classes, within_density=within,
                           cross_frac=cross, seed=3)
        pg = eng.sc.ladder.pad(g)
        st = block_stats(pg.norm_adj)
        # the engine's rule, verbatim: modelled costs, overridden by the
        # latency bank's measured pair once both backends have served here
        measured = eng._measured_agg_pair("gcn", cap)
        choice, dense_s, grasp_s = select_agg_backend(
            cap, hidden, nnz_blocks=st["nnz_blocks"],
            max_row_nnz=st["max_row_nnz"], measured=measured)
        gid = eng.attach(g, model="gcn")
        eng.query(gid)
        eng.query(gid)        # same (model, bucket, tier, backend) key:
        eng.run()             # one BATCHED dispatch of 2
        served = eng.finished[-1].backend
        assert served == choice
        both = all(m is not None for m in measured)
        print(f"{name:>12} {g.num_edges / n**2:>10.4f} "
              f"{st['block_density']:>10.2f} {dense_s * 1e6:>10.1f}us "
              f"{grasp_s * 1e6:>10.1f}us {served:>8}"
              f"{'  (measured override live)' if both else ''}")
        eng.detach(gid)

    eng.assert_warm()         # the flip cost zero recompiles
    s = eng.summary()
    print(f"\nagg_backends={s['agg_backends']} "
          f"grasp_batches={s['grasp_batches']} "
          f"backend_fallbacks={s['backend_fallbacks']} "
          f"(fallbacks > 0 on CPU hosts: the ref routing has no skip grid)")


if __name__ == "__main__":
    main()
