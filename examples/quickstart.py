"""Quickstart: GraNNite GNN inference on a Cora-shaped graph.

Trains the paper's 2-layer GCN, then runs the same parameters through
 (a) the baseline edge-list path (out-of-the-box mapping: gather/scatter),
 (b) the GraNNite dense path (StaGr + PreG + GraphSplit), and
 (c) the full stack with QuantGr INT8,
reporting accuracy and wall-clock for each — a miniature of paper Fig. 20/22.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.gnn import gcn
from repro.core.graph import add_self_loops, pad_graph
from repro.core.layers import Techniques
from repro.core.models import (build_operands, calibrate_quant, evaluate,
                               forward_baseline, forward_grannite,
                               train_node_classifier)
from repro.data.graphs import cora_like


def timed(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / n


def main():
    print("== GraNNite quickstart: GCN on a Cora-shaped graph ==")
    g = cora_like()
    pg = pad_graph(g)           # NodePad: 2708 -> 2816 (22 x 128 MXU tiles)
    cfg = gcn("cora")
    print(f"graph: {g.num_nodes} nodes / {g.num_edges} edges, "
          f"padded to {pg.capacity}")

    ops_ = build_operands(pg, cfg)    # GraphSplit: host-side PreG/StaGr masks

    def fwd_dense(p, x):
        return forward_grannite(p, cfg, x, ops_, Techniques(stagr=True))

    print("training 2-layer GCN (100 epochs, lr 0.01, wd 5e-4)...")
    params = train_node_classifier(jax.random.PRNGKey(0), cfg, pg, fwd_dense)
    acc = evaluate(cfg, params, pg, fwd_dense)
    print(f"test accuracy (fp32 dense path): {acc:.3f}")

    x = jnp.asarray(pg.features)
    ei = jnp.asarray(add_self_loops(g.edge_index, g.num_nodes))

    base = jax.jit(lambda p, xx: forward_baseline(p, cfg, xx, ei, pg.capacity))
    dense = jax.jit(lambda p, xx: fwd_dense(p, xx))
    ops_q = dataclasses.replace(ops_, quant=calibrate_quant(params, cfg, x, ops_))
    quant = jax.jit(lambda p, xx: forward_grannite(
        p, cfg, xx, ops_q, Techniques(stagr=True, quantgr=True)))

    tb = timed(base, params, x)
    td = timed(dense, params, x)
    tq = timed(quant, params, x)
    acc_q = evaluate(cfg, params, pg,
                     lambda p, xx: forward_grannite(
                         p, cfg, xx, ops_q, Techniques(stagr=True, quantgr=True)))
    print(f"baseline (gather/scatter): {tb*1e3:7.2f} ms   1.00x")
    print(f"GraNNite (StaGr dense)   : {td*1e3:7.2f} ms   {tb/td:.2f}x")
    print(f"+ QuantGr INT8           : {tq*1e3:7.2f} ms   {tb/tq:.2f}x "
          f"(accuracy {acc_q:.3f}, delta {acc_q-acc:+.3f})")


if __name__ == "__main__":
    main()
