"""GrAd + NodePad: serve a GNN over an EVOLVING graph with zero recompiles.

Models the paper's Fig. 10 scenario (on-device knowledge graph): nodes and
edges stream in; the norm-adjacency mask is rebuilt on the host (GraphSplit)
and fed to ONE compiled blob as a runtime argument (GrAd), with the node
count padded to a fixed NodePad bucket.

  PYTHONPATH=src python examples/dynamic_graph_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.gnn import gcn
from repro.core.graph import pad_features, pad_graph, update_edges
from repro.core.layers import Techniques
from repro.core.models import GranniteOperands, forward_grannite, init_params
from repro.data.graphs import dynamic_graph_stream, planetoid_like


def main():
    base = planetoid_like(num_nodes=2000, num_edges=4000, num_feats=256,
                          num_classes=7, seed=0)
    cfg = gcn("cora")
    cfg = type(cfg)(kind="gcn", in_feats=256, hidden=64, num_classes=7)
    params = init_params(jax.random.PRNGKey(0), cfg)
    # NodePad: 50% headroom so the stream never outgrows the bucket
    pg = pad_graph(base, slack=0.5)
    print(f"NodePad bucket: {pg.capacity} (graph starts at {base.num_nodes})")

    traces = {"n": 0}

    @jax.jit
    def serve(p, x, norm_adj):
        traces["n"] += 1
        z = jnp.zeros_like(norm_adj)
        ops_ = GranniteOperands(norm_adj=norm_adj, mask_mult=z, bias_add=z,
                                sample_mask=z, mean_mask=z)
        logits = forward_grannite(p, cfg, x, ops_,
                                  Techniques(stagr=True, grad_dynamic=True))
        return jnp.argmax(logits, axis=-1)

    stream = dynamic_graph_stream(base, steps=10, edges_per_step=64,
                                  nodes_per_step=20)
    t0 = time.perf_counter()
    for i, (ei, n, feats) in enumerate(stream):
        th = time.perf_counter()
        pg = update_edges(pg, ei, n)            # host: GraphSplit preprocessing
        x = jnp.asarray(pad_features(feats, pg.capacity))
        host_ms = (time.perf_counter() - th) * 1e3
        td = time.perf_counter()
        preds = serve(params, x, jnp.asarray(pg.norm_adj))
        preds.block_until_ready()
        dev_ms = (time.perf_counter() - td) * 1e3
        print(f"step {i}: {n} nodes, {ei.shape[1]} edges | host "
              f"{host_ms:6.1f} ms, device {dev_ms:6.1f} ms, "
              f"retraces so far: {traces['n']}")
    total = time.perf_counter() - t0
    print(f"\n10 graph updates in {total:.2f}s, compiled EXACTLY "
          f"{traces['n']} blob(s) — GrAd/NodePad recompile-free serving")
    assert traces["n"] == 1


if __name__ == "__main__":
    main()
