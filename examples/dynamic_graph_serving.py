"""GrAd + NodePad on the GraphServe engine: serve an EVOLVING graph with
zero recompiles.

Models the paper's Fig. 10 scenario (on-device knowledge graph): nodes and
edges stream in; the engine rebuilds the norm-adjacency operands on the host
(GraphSplit) and feeds ONE compiled blob per (model, bucket) with runtime
arguments (GrAd), the node count padded to a NodePad bucket drawn from the
engine's ladder. If the stream outgrew its bucket, the engine would move the
graph up the ladder (one counted recompile) — here the ladder's admission
slack gives enough headroom that the whole run stays recompile-free.

  PYTHONPATH=src python examples/dynamic_graph_serving.py
"""
import dataclasses
import time

from repro.configs.gnn import gcn
from repro.core.graph import BucketLadder
from repro.data.graphs import dynamic_graph_stream, planetoid_like
from repro.runtime.gnn_server import GraphServe, GraphServeConfig


def main():
    base = planetoid_like(num_nodes=2000, num_edges=4000, num_feats=256,
                          num_classes=7, seed=0)
    cfg = dataclasses.replace(gcn("cora"), in_feats=256)

    # NodePad ladder with 25% admission slack: the stream adds 200 nodes to a
    # 2000-node graph, so the 2560 rung absorbs every update without moving.
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(1024, 2560),
                                              slack=0.25),
                          batch_slots=1)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", cfg)
    eng.warmup()

    gid = eng.attach(base, model="gcn")
    _, pg = eng.graphs[gid]
    print(f"NodePad bucket: {pg.capacity} (graph starts at {base.num_nodes} "
          f"nodes, {eng.compiled_blobs} blobs warm)")

    stream = dynamic_graph_stream(base, steps=10, edges_per_step=64,
                                  nodes_per_step=20)
    t0 = time.perf_counter()
    for i, (ei, n, feats) in enumerate(stream):
        th = time.perf_counter()
        rebucketed = eng.update(gid, ei, n, feats)   # host: GraphSplit
        eng.query(gid)
        host_ms = (time.perf_counter() - th) * 1e3
        td = time.perf_counter()
        eng.run()                                    # device: one dense blob
        dev_ms = (time.perf_counter() - td) * 1e3
        print(f"step {i}: {n} nodes, {ei.shape[1]} edges | host "
              f"{host_ms:6.1f} ms, device {dev_ms:6.1f} ms, "
              f"rebucketed: {rebucketed}, blobs: {eng.compiled_blobs}")
    total = time.perf_counter() - t0

    eng.assert_warm()
    s = eng.summary()
    print(f"\n{s['requests']} graph updates in {total:.2f}s, compiled "
          f"EXACTLY {s['compiled_blobs']} blob(s), "
          f"{s['rebucket_events']} rebucket(s), p50 "
          f"{s['p50_latency_ms']:.1f} ms — GrAd/NodePad recompile-free "
          f"serving")
    assert s["rebucket_events"] == 0


if __name__ == "__main__":
    main()
