"""Quality-tier serving walkthrough (DESIGN.md §8): one trained GCN served
at three quality tiers through a single warm GraphServe engine.

The paper's Step 3 trades accuracy for efficiency (QuantGr INT8, GrAx
approximations). In production that trade is a PER-REQUEST decision — a
free-tier request takes int8, a paying tenant takes fp32 — so GraphServe
models tiers as first-class serving state:

  register  — a model carries a tier registry: fp32 / int8 / int8+grax,
              each a Techniques variant with its own ExecutionPlan;
  warm      — warmup() compiles every (model, bucket, tier) plan, QuantGr
              tiers against a placeholder calibration (same pytree shape
              as any real one), so NOTHING traces after this point;
  calibrate — the first attach() runs the once-per-(model, tier) QuantGr
              calibration and measures accuracy_delta_vs_fp32 on the
              held-out split of the calibration graph;
  query     — query(gid, tier=...) picks the tier per request; an
              uncalibrated quant tier falls back to fp32 (counted, never
              an error); all tiers share ONE CacheG operand-cache entry;
  metrics   — summary() reports per-tier p50/p99/throughput and the
              accuracy deltas.

  PYTHONPATH=src python examples/quality_tiers.py
"""
import jax

from repro.core.graph import BucketLadder, pad_graph
from repro.core.models import (GNNConfig, build_operands, forward_grannite,
                               train_node_classifier)
from repro.data.graphs import planetoid_like
from repro.runtime.gnn_server import (STANDARD_TIERS, GraphServe,
                                      GraphServeConfig, tier_techniques)


def main():
    in_feats, classes, n = 64, 7, 200
    g = planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=in_feats,
                       num_classes=classes, seed=0, train_per_class=5)
    cfg = GNNConfig(kind="gcn", in_feats=in_feats, hidden=64,
                    num_classes=classes)

    # --- train once (fp32 dense path); every tier serves the SAME params
    pg = pad_graph(g, capacity=256)
    ops = build_operands(pg, cfg, lean=True)
    t_fp32 = tier_techniques("gcn")["fp32"]
    params = train_node_classifier(
        jax.random.PRNGKey(0), cfg, pg,
        lambda p, x: forward_grannite(p, cfg, x, ops, t_fp32), epochs=40)

    # --- register + warm: every (model, bucket, tier) plan compiles NOW
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(256,)), batch_slots=2)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", cfg, params, tiers=STANDARD_TIERS)
    eng.warmup()
    print(f"warm: {eng.compiled_blobs} blobs (fp32 + int8 plans — int8+grax "
          f"aliases int8 for GCN — + CacheG materializer + int8-Â deriver)")

    # --- a quant tier BEFORE calibration: served via fp32, counted
    uid = eng.submit(g, model="gcn", tier="int8")
    eng.run()
    served = [r for r in eng.finished if r.uid == uid][0]
    print(f"pre-calibration int8 request served as tier={served.tier!r} "
          f"(tier_fallbacks={eng.metrics['tier_fallbacks']})")

    # --- attach: runs the once-per-(model, tier) calibration + quality audit
    gid = eng.attach(g, model="gcn")
    deltas = eng.models["gcn"].accuracy_delta
    print("accuracy_delta_vs_fp32 (pts, held-out):",
          {k: round(v, 3) for k, v in deltas.items()})

    # --- mixed-tier traffic over ONE attached graph, one operand entry
    for i in range(12):
        eng.query(gid, tier=STANDARD_TIERS[i % 3])
    eng.run()
    eng.assert_warm()          # zero recompiles across all of the above

    s = eng.summary()
    print(f"\noperand cache: {s['operand_cache_misses']} miss / "
          f"{s['operand_cache_hits']} hits (all tiers share one fp32 entry; "
          f"the int8 Â is derived once per structure version)")
    for tier, st in s["tiers"].items():
        print(f"  {tier:10s} {st['requests']:2d} req  "
              f"p50={st['p50_latency_ms']:6.1f} ms  "
              f"p99={st['p99_latency_ms']:6.1f} ms  "
              f"{st['throughput_rps']:6.1f} req/s")
    assert s["tier_fallbacks"] == 1          # only the pre-calibration one


if __name__ == "__main__":
    main()
