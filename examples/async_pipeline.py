"""Async pipelined serving (DESIGN.md §9): overlap host preprocessing with
device execution on an online request stream.

The sync path serializes GraphSplit's two halves — each `submit()` pays
padding + operand packing on the host, then `run()` blocks on the device
batch before the next request is touched. The pipeline scheduler runs the
same engine with host worker threads feeding a batching dispatcher: while
the device executes request N, workers prepare N+1 and N+2, and the batch
window coalesces same-(model, bucket, tier) arrivals into fuller batches.

  PYTHONPATH=src python examples/async_pipeline.py
"""
import time

import numpy as np

from repro.core.graph import BucketLadder
from repro.core.models import GNNConfig
from repro.data.graphs import planetoid_like
from repro.runtime.gnn_server import GraphServe, GraphServeConfig
from repro.runtime.scheduler import PipelineConfig

IN_FEATS, CLASSES, N_REQ = 64, 7, 16


def build_engine():
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(512, 1024)),
                          batch_slots=4)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        hidden=16, num_classes=CLASSES),
                       tiers=("fp32", "int8"))
    eng.register_model("gat", GNNConfig(kind="gat", in_feats=IN_FEATS,
                                        hidden=16, num_classes=CLASSES,
                                        heads=4))
    eng.warmup()
    eng.calibrate("gcn", planetoid_like(num_nodes=200, num_edges=600,
                                        num_feats=IN_FEATS,
                                        num_classes=CLASSES, seed=99,
                                        train_per_class=5))
    return eng


def traffic():
    rng = np.random.default_rng(0)
    out = []
    for i in range(N_REQ):
        kind = "gcn" if i % 2 == 0 else "gat"
        n = int(rng.integers(300, 900))
        tier = ("fp32", "int8")[int(rng.integers(2))] if kind == "gcn" else None
        out.append((kind, tier, planetoid_like(
            num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
            num_classes=CLASSES, seed=i, train_per_class=2)))
    return out


def main():
    stream = traffic()

    # --- online sync baseline: drain after every arrival
    eng = build_engine()
    t0 = time.perf_counter()
    for kind, tier, g in stream:
        eng.submit(g, model=kind, tier=tier)
        eng.run()
    sync_s = time.perf_counter() - t0
    s = eng.summary()
    print(f"sync  run(): {N_REQ / sync_s:5.1f} req/s  "
          f"device_idle={s['device_idle_fraction']:.2f}  "
          f"occupancy={s['batch_occupancy']:.2f}")

    # --- async pipeline: same arrivals, host workers + batching dispatcher
    eng = build_engine()
    pc = PipelineConfig(host_workers=2, window_ms=25.0,
                        max_pending=N_REQ, max_ready=N_REQ)
    t0 = time.perf_counter()
    with eng.scheduler(pc) as sched:
        for kind, tier, g in stream:
            sched.submit(g, model=kind, tier=tier)
        done = sched.drain()
    async_s = time.perf_counter() - t0
    eng.assert_warm()                 # overlap won, zero recompiles paid
    s = eng.summary()
    print(f"async pipe : {N_REQ / async_s:5.1f} req/s  "
          f"device_idle={s['device_idle_fraction']:.2f}  "
          f"occupancy={s['batch_occupancy']:.2f}  "
          f"(host workers={pc.host_workers}, window={pc.window_ms}ms)")
    print(f"\n{sync_s / async_s:.2f}x async vs sync; "
          f"{len(done)} requests completed, "
          f"blocked={sched.metrics['blocked']} "
          f"rejected={sched.metrics['rejected']}")
    assert len(done) == N_REQ and all(r.done for r in done)


if __name__ == "__main__":
    main()
