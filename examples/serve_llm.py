"""End-to-end serving driver: batched requests against a small LM through
the NodePad-bucketed server (mixed prompt lengths, zero recompiles).

  PYTHONPATH=src python examples/serve_llm.py [--arch qwen3-4b] [--requests 12]
"""
import argparse
import json

import numpy as np

from repro.configs import ARCHS, reduced
from repro.runtime.server import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    sc = ServeConfig(buckets=(32, 64, 128), max_len=256, batch_slots=4)
    server = Server(cfg, sc, seed=0)
    print(f"serving reduced {cfg.name}: buckets={sc.buckets} "
          f"slots={sc.batch_slots} mode={server.sc.mode}")

    rng = np.random.default_rng(1)
    for i in range(args.requests):
        n = int(rng.integers(4, 120))
        uid = server.submit(rng.integers(0, cfg.vocab_size, size=n),
                            max_new_tokens=args.max_new)
        print(f"  submitted request {uid}: prompt_len={n}")

    done = server.run()
    s = server.summary()
    print(json.dumps(s, indent=2))
    assert s["compiled_blobs"] <= len(sc.buckets) + 1, \
        "NodePad guarantee violated: more blobs than buckets+decode"
    for r in done[:3]:
        print(f"request {r.uid}: output tokens {r.output.tolist()}")


if __name__ == "__main__":
    main()
