"""End-to-end LM training driver: a ~100M-class model for a few hundred
steps with checkpoint/restart, microbatching and straggler monitoring.

Default is CPU-friendly (reduced smollm, 200 steps, < ~3 min). Pass
``--full`` on real accelerators to train the actual smollm-135m config.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import json

import jax

from repro.configs import ARCHS, reduced
from repro.runtime.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ARCHS["smollm-135m"]
    if not args.full:
        cfg = reduced(cfg, layers=6)
    print(f"arch={cfg.name} layers={cfg.num_layers} "
          f"params={cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    tc = TrainConfig(steps=args.steps, seq_len=128, global_batch=8,
                     microbatches=2, lr=1e-3, warmup_steps=20,
                     ckpt_dir=args.ckpt_dir, ckpt_every=50)
    tr = Trainer(cfg, tc)
    tr.run()
    s = tr.summary()
    print(json.dumps(s, indent=2))
    assert s["last_loss"] < s["first_loss"], "training must reduce loss"
    print(f"loss: {s['first_loss']:.3f} -> {s['last_loss']:.3f} over "
          f"{s['steps']} steps ({s['stragglers']} straggler steps flagged)")


if __name__ == "__main__":
    main()
