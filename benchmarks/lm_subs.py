"""Beyond-paper benchmarks: GraNNite's rewrites applied to the LM substrate.

  * SSD chunked-matmul vs sequential recurrence (the mamba2 'EffOp' — the
    DSP->DPU rewrite story on the SSM family);
  * MoE EffOp one-hot dispatch vs gather/scatter reference;
  * serving: NodePad bucket reuse (zero recompiles across request shapes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod

from .common import record, time_fn

KEY = jax.random.PRNGKey(11)


def ssd_vs_sequential() -> List[Dict]:
    cfg = reduced(ARCHS["mamba2-2.7b"], layers=1)
    cfg = dataclasses.replace(cfg, d_model=512,
                              ssm=dataclasses.replace(cfg.ssm, d_state=64,
                                                      headdim=64, chunk=64))
    p = ssm_mod.ssm_init(KEY, cfg)
    x = jax.random.normal(KEY, (4, 1024, cfg.d_model))
    fast = jax.jit(lambda pp, xx: ssm_mod.ssm_forward(pp, cfg, xx))
    slow = jax.jit(lambda pp, xx: ssm_mod.ssm_reference(pp, cfg, xx))
    tf = time_fn(fast, p, x)
    ts = time_fn(slow, p, x)
    return [record("lm/ssd/sequential_scan", ts, "1.00x"),
            record("lm/ssd/chunked_matmul", tf, f"{ts/tf:.2f}x")]


def moe_dispatch_paths() -> List[Dict]:
    cfg = reduced(ARCHS["olmoe-1b-7b"], layers=1)
    p = moe_mod.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (4, 256, cfg.d_model), jnp.float32)
    m = cfg.moe

    def gather_ref(pp, xx):
        """Reference gather/scatter MoE (the control-heavy form)."""
        b, s, d = xx.shape
        toks = xx.reshape(b * s, d)
        logits = toks @ pp.w_router.value
        gates, idx, _ = moe_mod._route(m, logits)
        out = jnp.zeros_like(toks)
        for kk in range(m.top_k):
            e_idx = idx[:, kk]                                  # (T,)
            w_in = pp.w_in.value[e_idx]                         # gather (T,d,ff)
            w_up = pp.w_up.value[e_idx]
            w_out = pp.w_out.value[e_idx]
            h = jnp.einsum("td,tdf->tf", toks, w_in)
            h = jax.nn.silu(h) * jnp.einsum("td,tdf->tf", toks, w_up)
            y = jnp.einsum("tf,tfd->td", h, w_out)
            out = out + y * gates[:, kk:kk + 1]
        return out.reshape(b, s, d)

    dense = jax.jit(lambda pp, xx: moe_mod.moe_forward(pp, cfg, xx)[0])
    ref = jax.jit(gather_ref)
    y1 = dense(p, x)
    y2 = ref(p, x)
    # correctness first: same result up to capacity drops (generous cap)
    close = float(jnp.abs(y1 - y2).max())
    td = time_fn(dense, p, x)
    tr = time_fn(ref, p, x)
    return [record("lm/moe/gather_dispatch", tr, "1.00x"),
            record("lm/moe/effop_dense_dispatch", td,
                   f"{tr/td:.2f}x maxdiff={close:.2e}")]


def serving_bucket_reuse() -> List[Dict]:
    from repro.runtime.server import ServeConfig, Server
    cfg = reduced(ARCHS["smollm-135m"])
    sv = Server(cfg, ServeConfig(buckets=(16, 32), max_len=64, batch_slots=2))
    rng = np.random.default_rng(0)
    for n in (5, 9, 17, 30, 12, 3, 8, 25):
        sv.submit(rng.integers(0, cfg.vocab_size, size=n), max_new_tokens=4)
    import time
    t0 = time.perf_counter()
    sv.run()
    dt = time.perf_counter() - t0
    s = sv.summary()
    return [record("lm/serve/8_requests_wall", dt,
                   f"blobs={s['compiled_blobs']} tokens={s['tokens_out']}")]
