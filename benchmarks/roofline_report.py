"""Merge results/dryrun_*.jsonl into the §Dry-run / §Roofline markdown
tables for EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir results]

Later rows override earlier ones per (arch, shape, mesh) — re-run fix files
supersede the first attempt. The roofline table is single-pod only (the
multi-pod rows prove compile/fit; their cost columns are deployment-raw).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = ["gemma2-27b", "chatglm3-6b", "qwen3-4b", "smollm-135m",
              "mamba2-2.7b", "olmoe-1b-7b", "llama4-scout-17b-a16e",
              "jamba-v0.1-52b", "phi-3-vision-4.2b", "whisper-base"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(directory: str) -> Dict[tuple, dict]:
    rows: Dict[tuple, dict] = {}
    files = sorted(glob.glob(os.path.join(directory, "dryrun_*.jsonl")),
                   key=os.path.getmtime)
    for f in files:
        for line in open(f):
            r = json.loads(line)
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def fmt_t(t) -> str:
    if t is None:
        return "-"
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t*1e3:.1f}ms"
    return f"{t*1e6:.0f}us"


def fmt_b(b) -> str:
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def dryrun_table(rows: Dict[tuple, dict]) -> str:
    out = ["| arch | shape | mesh | status | compile | args GiB/dev | "
           "temp GiB/dev | collectives |",
           "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                r = rows.get((a, s, m))
                if r is None:
                    out.append(f"| {a} | {s} | {m} | MISSING | | | | |")
                    continue
                if r["status"] == "skipped":
                    out.append(f"| {a} | {s} | {m} | skipped¹ | | | | |")
                    continue
                if r["status"] != "ok":
                    out.append(f"| {a} | {s} | {m} | **FAILED** | | | | "
                               f"{r.get('error','')[:60]} |")
                    continue
                ma = r.get("memory_analysis", {})
                coll = r.get("coll_breakdown", {}) or {}
                ck = "+".join(sorted(k.replace("all-", "a")
                                     .replace("reduce-scatter", "rs")
                                     .replace("collective-permute", "cp")
                                     for k in coll)) or "none"
                out.append(
                    f"| {a} | {s} | {m} | ok | {r.get('compile_s','')}s | "
                    f"{fmt_b(ma.get('argument_bytes'))} | "
                    f"{fmt_b(ma.get('temp_bytes'))} | {ck} |")
    return "\n".join(out)


def roofline_table(rows: Dict[tuple, dict]) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | bound | "
           "MODEL_FLOPS | useful | roofline |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = rows.get((a, s, "single"))
            if r is None or r.get("status") != "ok":
                continue
            out.append(
                f"| {a} | {s} | {fmt_t(r['t_compute_s'])} | "
                f"{fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} | "
                f"{r['bottleneck']} | {r['model_flops']:.2e} | "
                f"{r['useful_fraction']*100:.1f}% | "
                f"{r['roofline_fraction']*100:.1f}% |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results")
    args = ap.parse_args()
    rows = load(args.dir)
    n_ok = sum(r.get("status") == "ok" for r in rows.values())
    n_skip = sum(r.get("status") == "skipped" for r in rows.values())
    n_fail = len(rows) - n_ok - n_skip
    print(f"## Dry-run matrix ({n_ok} ok / {n_skip} skipped / "
          f"{n_fail} failed of {len(rows)} cells)\n")
    print(dryrun_table(rows))
    print("\n¹ long_500k is decode at 524288 with quadratic attention — "
          "skipped for pure full-attention archs per the assignment.\n")
    print("## Roofline (single-pod 16x16, per-chip terms)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
