"""Benchmark utilities: wall-clock timing of jitted callables on CPU.

CPU wall-time preserves the paper's RELATIVE comparisons (dense-MXU-path vs
gather-DSP-path) even though absolute numbers differ from the NPU: both
backends execute gathers/selects on scalar units and matmuls on wide units.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

ROWS: List[Dict] = []


def time_fn(fn: Callable, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median seconds per call (blocks on device results)."""
    for _ in range(warmup):
        out = fn(*args)
        _block(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _block(out):
    for leaf in _leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _leaves(x):
    import jax
    return jax.tree_util.tree_leaves(x)


def record(name: str, seconds: float, derived: str = "",
           modelled_s: float = None) -> Dict:
    """Append one benchmark row (and print it as CSV).

    `modelled_s` — optional modelled accelerator latency for the same
    callable (benchmarks.tpu_model). When given, the row carries
    `measured_vs_modelled` = measured CPU seconds / modelled seconds, so
    BENCH_gnn.json trends show whether measured wall-clock is drifting
    relative to the analytic roofline (schema in benchmarks/README.md);
    rows without a model carry None.
    """
    ratio = (seconds / modelled_s) if modelled_s else None
    row = {"name": name, "us_per_call": seconds * 1e6, "derived": derived,
           "measured_vs_modelled": ratio}
    ROWS.append(row)
    print(f"{name},{row['us_per_call']:.1f},{derived}")
    return row
