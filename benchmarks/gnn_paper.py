"""Paper-table reproductions (one function per table/figure).

Fig. 20 — progressive technique speedups per model (GCN / GAT / SAGE-max).
Fig. 21 — Series-1 vs Series-2 NPU: analytic MXU-tile-count scaling.
Fig. 22 — CPU vs GPU vs NPU: gather-path vs dense-path on one backend.
Fig. 23 / energy — bytes-moved proxy (no power rails on CPU).
Accuracy table — FP32 vs QuantGr vs GrAx accuracies per model.
Serving — GraphServe engine throughput over mixed-size multi-graph traffic.
CacheG — `operand_pipeline`: host→device operand bytes + per-query latency,
eager dense uploads vs the device-resident operand cache (DESIGN.md §7).
Tiers — `quality_tiers`: per-tier (fp32 / int8 / int8+grax) latency, operand
bytes, and accuracy delta through GraphServe (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn import GNN_MODELS
from repro.core.graph import add_self_loops, pad_graph
from repro.core.layers import Techniques
from repro.core.models import (GNNConfig, build_operands, calibrate_quant,
                               derive_tier_operands, evaluate,
                               forward_baseline, forward_grannite,
                               init_params, train_node_classifier)
from repro.core.sparsity import sparsity_report
from repro.data.graphs import cora_like, citeseer_like

from .common import record, time_fn
from .tpu_model import analyze as tpu_analyze

KEY = jax.random.PRNGKey(0)


def _setup(kind: str, dataset: str = "cora", **cfg_kw):
    g = cora_like() if dataset == "cora" else citeseer_like()
    pg = pad_graph(g)
    cfg = GNN_MODELS[kind](dataset)
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    params = init_params(KEY, cfg)
    return g, pg, cfg, params


# ------------------------------------------------------------------ Fig 20


def fig20_progressive(dataset: str = "cora") -> List[Dict]:
    """Cumulative technique stacks per model; speedup over the baseline
    (out-of-the-box gather/scatter mapping).

    Two columns per stack:
      * cpu  — measured CPU wall-clock (honest, but CPUs are GOOD at gathers
               — the paper's own Fig. 8 premise — so the ordering inverts);
      * tpu  — modelled accelerator latency from the compiled HLO
               (benchmarks.tpu_model): MXU-rate dense FLOPs, full-HBM bytes,
               gather/scatter bytes at the serialized DSP-analogue rate.
               This column carries the paper's comparison.
    """
    rows = []

    def _bench(label, fn, *args, base=None):
        ti = time_fn(fn, *args)
        mi = tpu_analyze(fn, *args)["t_model_s"]
        if base is None:
            rows.append(record(label, ti, f"tpu_model={mi*1e6:.0f}us 1.00x"))
        else:
            t0, m0 = base
            rows.append(record(
                label, ti,
                f"cpu {t0/ti:.2f}x | tpu_model {m0/mi:.2f}x"))
        return ti, mi

    # --- GCN: baseline -> +StaGr/GraphSplit -> +GrAd/NodePad -> +GraSp ->
    #          +QuantGr  (paper: 1.51x -> 1.4x -> 1.1x -> 2.7x)
    g, pg, cfg, params = _setup("gcn", dataset)
    x = jnp.asarray(pg.features)
    ei = jnp.asarray(add_self_loops(g.edge_index, g.num_nodes))
    ops_ = build_operands(pg, cfg, grasp=True)
    ops_q = dataclasses.replace(ops_, quant=calibrate_quant(params, cfg, x, ops_))

    base = jax.jit(lambda p, xx: forward_baseline(p, cfg, xx, ei, pg.capacity))
    b = _bench(f"fig20/gcn/{dataset}/baseline", base, params, x)

    stacks = [
        ("stagr+graphsplit", ops_, Techniques(stagr=True, graphsplit=True)),
        ("grad+nodepad", ops_, Techniques(stagr=True, graphsplit=True,
                                          grad_dynamic=True)),
        ("grasp", ops_, Techniques(stagr=True, graphsplit=True,
                                   grad_dynamic=True, grasp=True)),
        ("quantgr", ops_q, Techniques(stagr=True, graphsplit=True,
                                      grad_dynamic=True, quantgr=True)),
    ]
    for name, op, t in stacks:
        if t.grad_dynamic:
            # GrAd: Â is an argument (runtime input)
            fn = jax.jit(lambda p, xx, na: forward_grannite(
                p, cfg, xx, dataclasses.replace(op, norm_adj=na), t))
            _bench(f"fig20/gcn/{dataset}/{name}", fn, params, x, op.norm_adj,
                   base=b)
        else:
            fn = jax.jit(lambda p, xx: forward_grannite(p, cfg, xx, op, t))
            _bench(f"fig20/gcn/{dataset}/{name}", fn, params, x, base=b)

    # --- GAT: baseline -> EffOp -> +GrAx1 -> +GrAx2  (paper: 3x -> 7.6x)
    g, pg, cfg, params = _setup("gat", dataset)
    x = jnp.asarray(pg.features)
    ei = jnp.asarray(add_self_loops(g.edge_index, g.num_nodes))
    ops_ = build_operands(pg, cfg)
    base = jax.jit(lambda p, xx: forward_baseline(p, cfg, xx, ei, pg.capacity))
    b = _bench(f"fig20/gat/{dataset}/baseline", base, params, x)
    for name, t in [
            ("effop", Techniques(effop=True)),
            ("effop+grax1", Techniques(effop=True, grax1=True)),
            ("effop+grax1+grax2", Techniques(effop=True, grax1=True,
                                             grax2=True))]:
        fn = jax.jit(lambda p, xx: forward_grannite(p, cfg, xx, ops_, t))
        _bench(f"fig20/gat/{dataset}/{name}", fn, params, x, base=b)

    # --- SAGE-max: baseline -> EffOp -> GrAx3 (paper: 2x -> 3.2x; NOT cumulative)
    g, pg, cfg, params = _setup("sage-max", dataset)
    x = jnp.asarray(pg.features)
    ei = jnp.asarray(g.edge_index)
    ops_ = build_operands(pg, cfg)
    base = jax.jit(lambda p, xx: forward_baseline(p, cfg, xx, ei, pg.capacity))
    b = _bench(f"fig20/sage-max/{dataset}/baseline", base, params, x)
    for name, t in [("effop", Techniques(effop=True)),
                    ("grax3", Techniques(effop=True, grax3=True))]:
        fn = jax.jit(lambda p, xx: forward_grannite(p, cfg, xx, ops_, t))
        _bench(f"fig20/sage-max/{dataset}/{name}", fn, params, x, base=b)
    return rows


# ------------------------------------------------------------------ Fig 22


def fig22_path_comparison(dataset: str = "cora") -> List[Dict]:
    """Gather/scatter path (the CPU/DSP-analogue) vs GraNNite dense path
    (the NPU/MXU-analogue) for all three layer types. The `cpu` column is
    the measured host wall-clock of each path (the paper's CPU bar); the
    `tpu_model` column prices the same compiled HLOs with accelerator
    constants (the paper's NPU bar)."""
    rows = []
    for kind in ("gcn", "gat", "sage-mean"):
        g, pg, cfg, params = _setup(kind, dataset)
        x = jnp.asarray(pg.features)
        ei = jnp.asarray(add_self_loops(g.edge_index, g.num_nodes)
                         if kind != "sage-mean" else g.edge_index)
        ops_ = build_operands(pg, cfg)
        t = {"gcn": Techniques(stagr=True),
             "gat": Techniques.full_gat(),
             "sage-mean": Techniques.full_sage()}[kind]
        slow = jax.jit(lambda p, xx: forward_baseline(p, cfg, xx, ei,
                                                      pg.capacity))
        fast = jax.jit(lambda p, xx: forward_grannite(p, cfg, xx, ops_, t))
        ts = time_fn(slow, params, x)              # CPU executes the model
        mf = tpu_analyze(fast, params, x)["t_model_s"]   # NPU-analogue
        ms = tpu_analyze(slow, params, x)["t_model_s"]
        rows.append(record(f"fig22/{kind}/{dataset}/cpu_gather_path", ts,
                           "1.00x (measured host)"))
        rows.append(record(
            f"fig22/{kind}/{dataset}/tpu_dense_path", mf,
            f"{ts/mf:.2f}x vs cpu | {ms/mf:.2f}x vs gather-on-accel"))
    return rows


# ------------------------------------------------------------------ Fig 21


def fig21_tile_scaling(dataset: str = "cora") -> List[Dict]:
    """Series-1 (2 NPU tiles) vs Series-2 (4 tiles): analytic roofline-model
    throughput scaling for GCN under the full GraNNite stack. CacheG keeps
    Â and the weights SRAM-resident (the paper's own technique — implemented
    on the serving path by the operand pipeline, DESIGN.md §7 and
    `operand_pipeline` below), so DRAM traffic is activations only; compute
    scales with tile count. The paper
    observes 1.7x (not the ideal 2x) because the small graph leaves the
    wider part partially idle — reproduced here as the memory-bound floor
    that does NOT scale with tiles."""
    rows = []
    g = cora_like() if dataset == "cora" else citeseer_like()
    pg = pad_graph(g)
    n, f, h = pg.capacity, g.features.shape[1], 64
    flops = 2.0 * n * f * h + 2.0 * n * n * h          # combine + aggregate
    # QuantGr int8 datapath; CacheG: only activations cross DRAM
    bytes_dram = n * f + n * h * 2                     # x in, h/out streams
    for name, tiles in (("series1", 2), ("series2", 4)):
        peak = 8e12 * tiles            # int8 MAC/s per tile (FlexNN-class)
        bw = 68e9                      # LPDDR5x-class shared bandwidth
        t_comp = flops / peak
        t_mem = bytes_dram / bw
        t = max(t_comp, t_mem)
        rows.append(record(f"fig21/gcn/{dataset}/{name}", t,
                           f"tiles={tiles} comp={t_comp*1e6:.0f}us "
                           f"mem={t_mem*1e6:.0f}us"))
    r = rows[0]["us_per_call"] / rows[1]["us_per_call"]
    record(f"fig21/gcn/{dataset}/scaling", 0.0,
           f"{r:.2f}x (paper: 1.7x; ideal 2x broken by the mem floor)")
    return rows


# ------------------------------------------------------------- accuracy


def accuracy_table(dataset: str = "cora", epochs: int = 100) -> List[Dict]:
    """Top-1 accuracy per model: FP32 dense path, QuantGr INT8 (GCN), and
    GrAx approximations (GAT / SAGE-max). Paper baselines: GCN 80.8, GAT
    81.3, SAGE-max 79.3, SAGE-mean 75.5 (real Cora; ours is shape-matched
    synthetic, so ABSOLUTE numbers differ — the DELTAS are the claim)."""
    rows = []
    for kind in ("gcn", "gat", "sage-max", "sage-mean"):
        g, pg, cfg, _ = _setup(kind, dataset)
        ops_ = build_operands(pg, cfg)
        t_plain = {"gcn": Techniques(stagr=True),
                   "gat": Techniques(effop=True),
                   "sage-max": Techniques(effop=True),
                   "sage-mean": Techniques(stagr=True)}[kind]

        def fwd(p, x, _t=t_plain, _c=cfg, _o=ops_):
            return forward_grannite(p, _c, x, _o, _t)

        if kind.startswith("sage"):
            # train on the edge-list path (the paper trains in PyG and
            # deploys; the dense F=1433 masked-max is an inference form —
            # training it on one CPU core is prohibitive), evaluate the
            # DEPLOYED dense path: the deltas are the paper's claim
            ei = jnp.asarray(g.edge_index)

            def fwd_train(p, x, _c=cfg, _e=ei, _n=pg.capacity):
                return forward_baseline(p, _c, x, _e, _n)
        else:
            fwd_train = fwd

        params = train_node_classifier(KEY, cfg, pg, fwd_train, epochs=epochs)
        acc = evaluate(cfg, params, pg, fwd)
        rows.append(record(f"accuracy/{kind}/{dataset}/fp32", 0.0,
                           f"{acc:.4f}"))

        if kind == "gcn":
            x = jnp.asarray(pg.features)
            ops_q = dataclasses.replace(
                ops_, quant=calibrate_quant(params, cfg, x, ops_))
            t_q = Techniques(stagr=True, quantgr=True)

            def fwd_q(p, xx):
                return forward_grannite(p, cfg, xx, ops_q, t_q)

            acc_q = evaluate(cfg, params, pg, fwd_q)
            rows.append(record(f"accuracy/{kind}/{dataset}/quantgr_int8", 0.0,
                               f"{acc_q:.4f} (delta {acc_q-acc:+.4f})"))
        if kind in ("gat", "sage-max"):
            t_x = (Techniques(effop=True, grax1=True, grax2=True)
                   if kind == "gat" else Techniques(effop=True, grax3=True))

            def fwd_x(p, xx):
                return forward_grannite(p, cfg, xx, ops_, t_x)

            acc_x = evaluate(cfg, params, pg, fwd_x)
            rows.append(record(f"accuracy/{kind}/{dataset}/grax", 0.0,
                               f"{acc_x:.4f} (delta {acc_x-acc:+.4f})"))
    return rows


def fig22_density_crossover() -> List[Dict]:
    """Where the dense-masked rewrite wins even at BYTES granularity.

    At Cora's sparsity (≈0.14% density) the dense GAT multiplies FLOPs
    ~500× over the edge-list form, so a bandwidth-only accelerator model
    shows an inversion — the paper's GAT win comes from the DSP being
    *latency*-bound on serialized gathers, which a bytes-rate model is too
    conservative to capture. This sweep raises edge density and shows the
    crossover where the dense path wins under our conservative model too —
    the technique's win condition (E·F comparable to N²)."""
    import numpy as np
    from repro.data.graphs import planetoid_like
    rows = []
    n = 1024
    for avg_deg in (4, 32, 128):
        g = planetoid_like(num_nodes=n, num_edges=n * avg_deg // 2,
                           num_feats=64, num_classes=5, seed=3)
        pg = pad_graph(g)
        cfg = GNNConfig(kind="gat", in_feats=64, hidden=32, num_classes=5,
                        heads=4)
        params = init_params(KEY, cfg)
        x = jnp.asarray(pg.features)
        ei = jnp.asarray(add_self_loops(g.edge_index, g.num_nodes))
        ops_ = build_operands(pg, cfg)
        slow = jax.jit(lambda p, xx: forward_baseline(p, cfg, xx, ei,
                                                      pg.capacity))
        fast = jax.jit(lambda p, xx: forward_grannite(
            p, cfg, xx, ops_, Techniques.full_gat()))
        ms = tpu_analyze(slow, params, x)["t_model_s"]
        mf = tpu_analyze(fast, params, x)["t_model_s"]
        rows.append(record(
            f"fig22x/gat/deg{avg_deg}/dense_vs_gather", mf,
            f"{ms/mf:.2f}x (gather path {ms*1e6:.0f}us)"))
    return rows


# ------------------------------------------------------------- serving


def serving_throughput(dataset: str = "cora", *, n_requests: int = 12,
                       seed: int = 0) -> List[Dict]:
    """GraphServe engine under mixed-size multi-tenant traffic.

    Submits `n_requests` graphs of varied sizes across a 3-rung NodePad
    ladder for two model kinds, warms the (kind, bucket) plan cache, then
    drains the queue batched; reports requests/s, p50/p99 latency, the
    compiled-blob count, and batch occupancy. The zero-recompile contract
    (`assert_warm`) is enforced, not just measured.
    """
    from repro.core.graph import BucketLadder
    from repro.data.graphs import planetoid_like
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig

    rng = np.random.default_rng(seed)
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(128, 256, 384)),
                          batch_slots=4)
    eng = GraphServe(sc, seed=seed)
    in_feats, classes = 64, 7
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=in_feats,
                                        hidden=64, num_classes=classes))
    eng.register_model("gat", GNNConfig(kind="gat", in_feats=in_feats,
                                        hidden=64, num_classes=classes,
                                        heads=8))
    eng.warmup()

    for i in range(n_requests):
        n = int(rng.integers(48, 380))
        g = planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=in_feats,
                           num_classes=classes, seed=seed + i,
                           train_per_class=2)
        eng.submit(g, model="gcn" if i % 2 == 0 else "gat")
    eng.run()
    eng.assert_warm()

    s = eng.summary()
    rows = [
        record(f"serve/gnn/{dataset}/throughput_rps", 0.0,
               f"{s['throughput_rps']:.1f} requests/s over "
               f"{s['requests']} mixed-size graphs"),
        record(f"serve/gnn/{dataset}/latency", s["p50_latency_ms"] * 1e-3,
               f"p50={s['p50_latency_ms']:.1f}ms p99="
               f"{s['p99_latency_ms']:.1f}ms"),
        record(f"serve/gnn/{dataset}/compiled_blobs", 0.0,
               f"{s['compiled_blobs']} (= kinds x buckets x (2 fusion-mode "
               f"plans + CacheG materializer + GrAd delta patcher), zero "
               f"recompiles after warmup)"),
        record(f"serve/gnn/{dataset}/batch_occupancy", 0.0,
               f"{s['batch_occupancy']:.2f} of {sc.batch_slots} slots"),
        record(f"serve/gnn/{dataset}/operand_bytes_h2d", 0.0,
               f"{s['operand_bytes_h2d']} B (CacheG compact transfer, "
               f"{s['cacheg_fallbacks']} fallbacks)"),
    ]
    return rows


def operand_pipeline(dataset: str = "cora", *, cap: int = 2048,
                     n_queries: int = 6, seed: int = 0) -> List[Dict]:
    """CacheG operand pipeline vs eager host-built operands (DESIGN.md §7).

    Attaches ONE undirected graph at a `cap`-capacity rung and queries it
    repeatedly with GAT — the worst eager case: every request rebuilds and
    re-uploads two dense (cap, cap) float32 masks (2 x 16 MB at cap=2048).
    CacheG uploads one SymG bit-packed adjacency on the first query (the
    structure miss), materializes the masks on device, and serves every
    later query from the device-resident cache: zero host operand builds,
    zero operand bytes over the link. Reports bytes moved, hit/miss counts,
    and per-query wall-clock for both paths; the paper's Fig. 21 scaling
    argument (only activations cross DRAM) rests on exactly this pipeline.
    """
    import time as _time

    from repro.core.graph import BucketLadder
    from repro.data.graphs import planetoid_like
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig

    n = int(cap * 3 / 4)
    g = planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=16,
                       num_classes=5, seed=seed, train_per_class=2)
    rows, stats = [], {}
    for mode in ("eager", "cacheg"):
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(cap,)),
                              batch_slots=2, use_cacheg=(mode == "cacheg"))
        eng = GraphServe(sc, seed=seed)
        eng.register_model("gat", GNNConfig(kind="gat", in_feats=16,
                                            hidden=16, num_classes=5,
                                            heads=4))
        eng.warmup()
        gid = eng.attach(g, model="gat")
        t0 = _time.perf_counter()
        for _ in range(n_queries):
            eng.query(gid)
            eng.run()
        wall = _time.perf_counter() - t0
        eng.assert_warm()
        s = eng.summary()
        stats[mode] = s
        rows.append(record(
            f"operand_pipeline/{mode}/cap{cap}/query", wall / n_queries,
            f"{s['operand_bytes_h2d']} operand B h2d over {n_queries} "
            f"queries (hits={s['operand_cache_hits']} "
            f"misses={s['operand_cache_misses']})"))
    ratio = (stats["eager"]["operand_bytes_h2d"]
             / max(stats["cacheg"]["operand_bytes_h2d"], 1))
    rows.append(record(
        f"operand_pipeline/cap{cap}/bytes_reduction", 0.0,
        f"{ratio:.0f}x fewer host->device operand bytes with CacheG"))
    return rows


def quality_tiers(dataset: str = "cora", *, epochs: int = 60,
                  n_queries: int = 6, seed: int = 0) -> List[Dict]:
    """Quality-tier serving table (DESIGN.md §8): per-tier latency, operand
    bytes, and accuracy delta vs fp32 for GCN / GAT / SAGE-max through one
    warm GraphServe engine — the latency/quality frontier Step 3 trades on.

    Columns: `us_per_call` is the ACCELERATOR-MODEL per-forward latency of
    the tier's compiled plan (benchmarks.tpu_model: int8 dots at the 2x MXU
    rate, s8 operand bytes — QuantGr's claim; same convention as the
    analytic fig21 rows), because that is the latency column the tier
    frontier is judged on. The measured host wall-clock rides in `derived`
    as `host_p50=`: CPUs have no int8 GEMM path (XLA widens s8 dots to
    s32), so the measured int8 rows invert on CPU — the same caveat as
    fig20, where the tpu_model column also carries the comparison.
    `derived` further reports `acc_delta` (percentage points vs the fp32
    tier on the held-out split) and `bytes_h2d` (operand bytes this tier's
    queries moved — 0 after the shared CacheG entry materializes, whichever
    tier paid the miss).
    """
    from repro.core.graph import BucketLadder
    from repro.data.graphs import planetoid_like
    from repro.runtime.gnn_server import (STANDARD_TIERS, GraphServe,
                                          GraphServeConfig, tier_techniques)

    in_feats, classes, n = 64, 7, 200
    g = planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=in_feats,
                       num_classes=classes, seed=seed, train_per_class=5)
    rows = []
    for kind in ("gcn", "gat", "sage"):
        cfg = GNNConfig(kind=kind, in_feats=in_feats, hidden=64,
                        num_classes=classes, heads=8,
                        aggregator="max" if kind == "sage" else "mean")
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(256,)),
                              batch_slots=1)
        eng = GraphServe(sc, seed=seed)

        # train the fp32 dense path, then serve the SAME params per tier
        pg = eng.sc.ladder.pad(g)
        ops_ = build_operands(pg, cfg, lean=True)
        t_fp32 = tier_techniques(kind)["fp32"]

        def fwd(p, x, _c=cfg, _o=ops_, _t=t_fp32):
            return forward_grannite(p, _c, x, _o, _t)

        params = train_node_classifier(KEY, cfg, pg, fwd, epochs=epochs)

        eng.register_model(kind, cfg, params, tiers=STANDARD_TIERS)
        eng.warmup()
        gid = eng.attach(g, model=kind)         # calibrate + quality audit
        e = eng.models[kind]
        x1 = jnp.asarray(pg.features)

        base_model_s = None
        for tier in STANDARD_TIERS:
            t = e.tiers[tier]
            cal = e.calibrations.get(tier)
            # price the forward the engine actually serves: for QuantGr GCN
            # tiers the cached int8 Â enters as a runtime INPUT (1-byte
            # rows), exactly like the device-resident tier cache feeds it
            tops = (derive_tier_operands(jnp.asarray(ops_.norm_adj))
                    if (kind == "gcn" and t.quantgr) else None)
            mi = tpu_analyze(
                lambda xx, _t=t, _q=cal, _to=tops: forward_grannite(
                    params, cfg, xx, ops_, _t, quant=_q, tier_ops=_to),
                x1)["t_model_s"]
            if base_model_s is None:
                base_model_s = mi
            b0 = eng.metrics["operand_bytes_h2d"]
            for _ in range(n_queries):
                eng.query(gid, tier=tier)
                eng.run()
            db = eng.metrics["operand_bytes_h2d"] - b0
            p50_s = eng.summary()["tiers"][tier]["p50_latency_ms"] * 1e-3
            delta = e.accuracy_delta.get(tier, 0.0)
            rows.append(record(
                f"quality_tiers/{kind}/{dataset}/{tier}", mi,
                f"{base_model_s/mi:.2f}x vs fp32 (tpu_model) "
                f"host_p50={p50_s*1e6:.0f}us (CPU, no int8 GEMM) "
                f"acc_delta={delta:+.2f}pts bytes_h2d={db}"))
        eng.assert_warm()
    return rows


def pipeline_overlap(dataset: str = "cora", *, n_requests: int = 24,
                     batch_slots: int = 4, seed: int = 0) -> List[Dict]:
    """Async two-stage pipeline scheduler vs synchronous `run()` (DESIGN.md
    §9) under an ONLINE stream of mixed kind/bucket/tier requests.

    Arrival model: requests become visible one at a time (an online server
    cannot peek at future traffic). The sync driver is what bare
    `submit()+run()` gives such a server — it pads, builds/packs operands,
    then blocks on the device batch before touching the next request, so
    (a) the device idles through every request's host work and (b) each
    dispatch is a 1-of-`batch_slots` batch whose junk slots still pay full
    width. The scheduler sees the SAME arrival order but overlaps host
    workers with the device stage and lets the batch window coalesce
    arrivals into fuller batches. Three rows: the online sync baseline,
    the async pipeline, and an offline submit-all `run()` (the batching
    upper bound no online scheduler can beat). Two claims, each against
    the sync driver where it is meaningful: THROUGHPUT — async beats the
    online `run()` baseline (batch window + overlap vs 1-of-N junk-width
    batches); DEVICE IDLE — async's `device_idle_fraction` lands far
    below the offline `run()`'s, whose device sits provably idle through
    the entire host submit loop (the online driver's junk-slot batches
    keep its device busy on WASTED width, so its idle fraction measures
    waste, not overlap). Fresh identically-warmed engines each mode;
    `assert_warm()` is enforced, so the win is scheduling, never
    recompilation differences.
    """
    import time as _time

    from repro.core.graph import BucketLadder
    from repro.data.graphs import planetoid_like
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig
    from repro.runtime.scheduler import PipelineConfig

    in_feats, classes = 64, 7
    rng = np.random.default_rng(seed)
    cal = planetoid_like(num_nodes=200, num_edges=600, num_feats=in_feats,
                         num_classes=classes, seed=seed + 10_000,
                         train_per_class=5)
    traffic = []
    for i in range(n_requests):
        kind = "gcn" if i % 2 == 0 else "gat"
        n = int(rng.integers(300, 900))
        tier = ("fp32", "int8")[int(rng.integers(2))] if kind == "gcn" else None
        traffic.append((kind, tier, planetoid_like(
            num_nodes=n, num_edges=3 * n, num_feats=in_feats,
            num_classes=classes, seed=seed + i, train_per_class=2)))

    def build():
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(512, 1024)),
                              batch_slots=batch_slots)
        eng = GraphServe(sc, seed=seed)
        eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=in_feats,
                                            hidden=16, num_classes=classes),
                           tiers=("fp32", "int8"))
        eng.register_model("gat", GNNConfig(kind="gat", in_feats=in_feats,
                                            hidden=16, num_classes=classes,
                                            heads=4))
        eng.warmup()
        eng.calibrate("gcn", cal)               # int8 tier serves for real
        return eng

    def run_mode(eng, mode):
        """One timed pass of the whole stream; returns (wall, idle, occ).
        The engine stays warm across passes — only metric DELTAS over this
        pass are read, so repeated passes measure scheduling, not state."""
        m0 = (eng.metrics["device_busy_s"], eng.metrics["slots_filled"],
              eng.metrics["slots_total"])
        t0 = _time.perf_counter()
        if mode == "sync":
            for kind, tier, g in traffic:       # online: drain per arrival
                eng.submit(g, model=kind, tier=tier)
                eng.run()
        elif mode == "offline":
            for kind, tier, g in traffic:       # oracle: full future known
                eng.submit(g, model=kind, tier=tier)
            eng.run()
        else:
            pc = PipelineConfig(host_workers=2, window_ms=25.0,
                                max_pending=n_requests,
                                max_ready=n_requests)
            with eng.scheduler(pc) as sched:
                for kind, tier, g in traffic:
                    sched.submit(g, model=kind, tier=tier)
                sched.drain()
        wall = _time.perf_counter() - t0
        eng.assert_warm()                       # overlap, not recompiles
        busy = eng.metrics["device_busy_s"] - m0[0]
        occ = ((eng.metrics["slots_filled"] - m0[1])
               / max(eng.metrics["slots_total"] - m0[2], 1))
        return wall, max(0.0, 1.0 - busy / wall), occ

    # this box is shared/noisy: interleave 3 reps across modes on
    # persistently-warm engines and keep each mode's best pass, so one bad
    # scheduling patch cannot decide the comparison
    engines = {mode: build() for mode in ("sync", "async", "offline")}
    stats = {}
    for _ in range(3):
        for mode, eng in engines.items():
            res = run_mode(eng, mode)
            if mode not in stats or res[0] < stats[mode][0]:
                stats[mode] = res
    rows = []
    for mode, (wall, idle, occ) in stats.items():
        rows.append(record(
            f"pipeline_overlap/{mode}/{dataset}/throughput",
            wall / n_requests,
            f"{n_requests / wall:.1f} req/s over {n_requests} mixed "
            f"kind/bucket/tier requests, device_idle={idle:.2f} "
            f"occupancy={occ:.2f} (best of 3 interleaved passes)"))
    (ws, _, _), (wa, ai, _) = stats["sync"], stats["async"]
    (wo, oi, _) = stats["offline"]
    rows.append(record(
        f"pipeline_overlap/{dataset}/speedup", 0.0,
        f"{ws / wa:.2f}x async vs online run(); device_idle "
        f"{oi:.2f} (submit-all run()) -> {ai:.2f} (pipelined); "
        f"offline oracle wall at {wo / wa:.2f}x of async"))
    return rows


def grasp_serving(dataset: str = "cora", *, cap: int = 1024,
                  n_queries: int = 4, batch_slots: int = 2,
                  seed: int = 0) -> List[Dict]:
    """GraSp aggregation backend vs dense through GraphServe (DESIGN.md
    §10): dense-vs-grasp latency and operand bytes per graph density.

    Serves community-clustered GCN graphs of falling density (high-density
    graphs scatter cross-community edges until the block bitmap fills) at
    one `cap` rung through two identically-warmed engines — `dense` forced
    and `auto` — with batched queries (batch >= 2, the bitmap_spmm path in
    a vmapped plan). Columns: `us_per_call` is the measured per-query
    wall-clock (CPU caveat: the ref/interpret kernel cannot skip blocks,
    so the MEASURED column may invert, exactly like fig20's gather rows);
    `derived` carries the backend the rule picked, the MODELLED
    aggregation costs (`select_agg_backend` — the same constants as the
    fig21 analytic rows; this column carries the claim: grasp beats dense
    at low density), the block stats, and the operand bytes each mode
    moved."""
    import time as _time

    from repro.core.graph import BucketLadder
    from repro.core.sparsity import block_stats, select_agg_backend
    from repro.data.graphs import clustered_like
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig

    in_feats, classes, hidden = 16, 5, 16
    n = int(cap * 7 / 8)
    cases = [("dense02", 0.5, 0.30), ("mid01", 0.10, 0.05),
             ("sparse003", 0.03, 0.0)]
    rows = []

    def build(mode):
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(cap,)),
                              batch_slots=batch_slots)
        eng = GraphServe(sc, seed=seed)
        eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=in_feats,
                                            hidden=hidden,
                                            num_classes=classes),
                           agg_backend=mode)
        eng.warmup()
        return eng

    engines = {mode: build(mode) for mode in ("dense", "auto")}
    for label, density, cross in cases:
        g = clustered_like(num_nodes=n, num_feats=in_feats,
                           num_classes=classes, within_density=density,
                           cross_frac=cross, seed=seed)
        pg = engines["auto"].sc.ladder.pad(g)
        st = block_stats(pg.norm_adj)
        choice, dense_s, grasp_s = select_agg_backend(
            cap, hidden, nnz_blocks=st["nnz_blocks"],
            max_row_nnz=st["max_row_nnz"])
        elem_density = g.num_edges / (n * n)
        for mode, eng in engines.items():
            gid = eng.attach(g, model="gcn")
            b0 = eng.metrics["operand_bytes_h2d"]
            # one untimed dispatch first: the once-per-(graph, version)
            # work (operand materialization, block compaction, backend
            # rule) belongs to attach-time, not to the steady-state
            # per-query latency this row claims to measure
            eng.query(gid)
            eng.run()
            t0 = _time.perf_counter()
            for _ in range(n_queries):
                for _ in range(batch_slots):    # full batches per dispatch
                    eng.query(gid)
                eng.run()
            wall = (_time.perf_counter() - t0) / (n_queries * batch_slots)
            eng.assert_warm()
            db = eng.metrics["operand_bytes_h2d"] - b0
            backend = ("dense" if mode == "dense" else choice)
            rows.append(record(
                f"grasp_serving/{mode}/{dataset}/{label}", wall,
                f"backend={backend} model dense={dense_s*1e6:.1f}us "
                f"grasp={grasp_s*1e6:.1f}us ({dense_s/grasp_s:.2f}x) "
                f"elem_density={elem_density:.4f} "
                f"block_density={st['block_density']:.2f} bytes_h2d={db}"))
            eng.detach(gid)
    s = engines["auto"].summary()
    rows.append(record(
        f"grasp_serving/{dataset}/dispatch", 0.0,
        f"grasp_batches={s['grasp_batches']} "
        f"backend_fallbacks={s['backend_fallbacks']} over mixed-density "
        f"auto traffic, zero recompiles (batched bitmap_spmm plan, "
        f"batch={batch_slots}; on a CPU host the kernel routing is 'ref', "
        f"so every grasp REQUEST also counts a backend_fallback — the "
        f"skip grid only runs on TPU/interpret)"))
    return rows


def sharded_serving(dataset: str = "synthetic", *, quick: bool = True,
                    n_queries: Optional[int] = None,
                    seed: int = 0) -> List[Dict]:
    """Sharded serving of a partitioned giant graph (DESIGN.md §12):
    throughput vs device count with compressed halo exchange.

    One community-clustered GCN graph larger than any single ladder rung
    is served at shard counts 1/2/4/8 — count 1 through the ordinary
    unsharded engine at the full-capacity bucket (the baseline), counts
    >= 2 through engines configured to auto-shard (`shard_counts=(s,)`),
    each warmed before traffic. `us_per_call` is the measured per-query
    wall-clock; `modelled_s` (hence `measured_vs_modelled`) is
    `core.partition.modelled_sharded_latency` — per-shard compute at the
    derated MXU roofline plus one compressed-halo collective per
    exchanged layer width at the host-link bandwidth. The scaling CLAIM
    lives in the modelled column: per-shard compute falls ~1/S while the
    int8 wire term grows slowly, so modelled throughput is monotone in
    the device count. The measured column shows what this host actually
    did — on a 1-CPU box every shard computes serially under a
    vmap-simulated axis (placement is recorded per row), so measured
    throughput only follows the model on the CI multi-device leg
    (XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    import time as _time

    import jax as _jax

    from repro.core.graph import BucketLadder
    from repro.core.partition import (modelled_sharded_latency,
                                      partition_graph)
    from repro.core.models import sharded_exchange_widths
    from repro.data.graphs import clustered_like
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig

    in_feats, hidden, classes = 16, 256, 5
    all_buckets = (128, 256, 512, 1024, 2048)
    # n is picked so every doubling of the shard count halves the ladder
    # bucket (1800 -> loads 1800/900/450/225 -> buckets 2048/1024/512/256):
    # the full sharded capacity S x bucket stays constant, so the modelled
    # per-shard aggregation cost genuinely falls ~1/S instead of being
    # masked by bucket-floor over-padding at high shard counts. hidden=256
    # keeps that aggregation term above the collective latency floor —
    # smaller widths would make the model (correctly) report that sharding
    # a trivial graph is all wire and no win.
    n = 1800
    cfg = GNNConfig(kind="gcn", in_feats=in_feats, hidden=hidden,
                    num_classes=classes)
    n_queries = n_queries if n_queries is not None else (2 if quick else 4)
    g = clustered_like(num_nodes=n, num_feats=in_feats,
                       num_classes=classes, within_density=0.02,
                       cross_frac=0.05, seed=seed)
    full_ladder = BucketLadder(buckets=all_buckets)
    rows, modelled_rps = [], []
    for shards in (1, 2, 4, 8):
        load = -(-n // shards)
        bucket = full_ladder.bucket_for(load)
        if shards == 1:
            sc = GraphServeConfig(ladder=BucketLadder(buckets=(bucket,)),
                                  batch_slots=1)
            part = partition_graph(g.edge_index, n, 1, shard_cap=bucket)
        else:
            # a one-rung ladder the graph EXCEEDS, so attach() must take
            # the sharded path at exactly this shard count
            sc = GraphServeConfig(ladder=BucketLadder(buckets=(bucket,)),
                                  batch_slots=1, shard_counts=(shards,))
        eng = GraphServe(sc, seed=seed)
        eng.register_model("gcn", cfg)
        eng.warmup()
        gid = eng.attach(g, model="gcn", calibrate=False)
        if shards > 1:
            part = eng._sharded[gid][0]
        # untimed first query: once-per-(graph, version) work (operand /
        # shard-slice build) is attach-time cost, not steady-state latency
        eng.query(gid)
        eng.run()
        t0 = _time.perf_counter()
        for _ in range(n_queries):
            eng.query(gid)
            eng.run()
        wall = (_time.perf_counter() - t0) / n_queries
        eng.assert_warm()
        modelled = modelled_sharded_latency(
            part, in_feats=in_feats, hidden=hidden, classes=classes,
            exchange_widths=sharded_exchange_widths(cfg))
        modelled_rps.append(1.0 / modelled)
        s = eng.summary()
        placement = ("shard_map" if 1 < shards <= len(_jax.devices())
                     else ("vmap" if shards > 1 else "unsharded"))
        rows.append(record(
            f"sharded_serving/gcn/{dataset}/shards{shards}", wall,
            f"devices={min(shards, len(_jax.devices()))} "
            f"placement={placement} bucket={bucket} "
            f"modelled_rps={1.0 / modelled:.0f} "
            f"halo_bytes={s['halo_bytes_exchanged']} "
            f"exact_bytes={s['collective_bytes_exact']} "
            f"cut_edges={part.cut_edges}",
            modelled_s=modelled))
        eng.detach(gid)
    mono = all(b >= a for a, b in zip(modelled_rps, modelled_rps[1:]))
    rows.append(record(
        f"sharded_serving/gcn/{dataset}/scaling", 0.0,
        f"modelled_rps={'/'.join(f'{r:.0f}' for r in modelled_rps)} over "
        f"1/2/4/8 shards monotone={mono} (per-shard aggregation ~1/S, "
        f"int8 halo wire grows ~2(S-1)/S; compressed wire is 4x cheaper "
        f"than exact fp32)"))
    return rows


def partition_quality(dataset: str = "synthetic", *, quick: bool = True,
                      seed: int = 0) -> List[Dict]:
    """Partitioner quality and §15 serving wins (DESIGN.md §15): multilevel
    coarsen+refine vs the §12 greedy streaming cut, replica-group
    throughput scaling, and delta-halo vs full-halo exchange bytes under
    edge churn.

    Three row groups, each carrying its acceptance assert IN the benchmark
    (a regression fails the CI leg, not just a dashboard):

      * cut/ — greedy vs multilevel on the community-clustered serving
        graph at 4 and 8 shards: cut_edges, halo rows, and the bytes a
        SPARSE per-layer halo gather would move (4 B x halo rows x
        exchanged widths; the dense full-row psum the plan ships is
        partition-independent, so halo rows are where cut quality turns
        into wire). Asserts the multilevel cut is STRICTLY below greedy.
        Also one measured serving row per method — same graph, same
        queries, engines differing only in `partition_method`.
      * replica/ — one 2-shard layout dispatched at replica_groups R =
        1/2/4 over the same query stream: measured per-query wall,
        dispatch count (must be ceil(N/R) — the §15 packing claim), and
        modelled rps R/modelled_latency (replica rows share no
        collectives, so an R x S mesh runs them concurrently at the
        single-replica latency). Asserts modelled rps monotone in R and
        the measured dispatch counts exactly ceil(N/R).
      * delta/ — a churn loop of one-pair GrAd deltas against a sharded
        graph: `delta_halo_bytes_exchanged` (dirty boundary rows through
        `compressed_psum_delta`'s pricing) vs `delta_halo_bytes_full`
        (re-exchanging every operand row). Asserts delta < full.
    """
    import time as _time

    from repro.core.graph import BucketLadder
    from repro.core.partition import (modelled_sharded_latency,
                                      partition_graph)
    from repro.core.models import sharded_exchange_widths
    from repro.data.graphs import clustered_like
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig

    in_feats, hidden, classes = 16, 64, 5
    n = 1800
    cfg = GNNConfig(kind="gcn", in_feats=in_feats, hidden=hidden,
                    num_classes=classes)
    g = clustered_like(num_nodes=n, num_feats=in_feats, num_classes=classes,
                       within_density=0.02, cross_frac=0.05, seed=seed)
    widths = sharded_exchange_widths(cfg)
    rows = []

    # ---- cut quality: greedy vs multilevel ---------------------------
    for shards, bucket in ((4, 512),) if quick else ((4, 512), (8, 256)):
        parts = {m: partition_graph(g.edge_index, n, shards,
                                    shard_cap=bucket, method=m)
                 for m in ("greedy", "multilevel")}
        assert parts["multilevel"].cut_edges < parts["greedy"].cut_edges, (
            "multilevel refinement must strictly beat the greedy "
            "streaming cut on a community-clustered graph",
            parts["multilevel"].cut_edges, parts["greedy"].cut_edges)
        for m, p in parts.items():
            halo_rows = sum(len(h) for h in p.halo)
            sparse_bytes = 4 * halo_rows * sum(widths)
            rows.append(record(
                f"partition_quality/cut/{dataset}/shards{shards}/{m}", 0.0,
                f"cut_edges={p.cut_edges} halo_rows={halo_rows} "
                f"sparse_halo_bytes={sparse_bytes} "
                f"loads={'/'.join(str(int(x)) for x in p.loads)}"))
    # measured serving, same traffic, partition_method the only knob
    n_q = 2 if quick else 4
    for m in ("greedy", "multilevel"):
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(512,)),
                              batch_slots=1, shard_counts=(4,),
                              partition_method=m)
        eng = GraphServe(sc, seed=seed)
        eng.register_model("gcn", cfg)
        eng.warmup()
        gid = eng.attach(g, model="gcn", calibrate=False)
        part = eng._sharded[gid][0]
        eng.query(gid)
        eng.run()                  # untimed: slice-build is attach cost
        t0 = _time.perf_counter()
        for _ in range(n_q):
            eng.query(gid)
            eng.run()
        wall = (_time.perf_counter() - t0) / n_q
        eng.assert_warm()
        rows.append(record(
            f"partition_quality/serve/{dataset}/{m}", wall,
            f"cut_edges={part.cut_edges} shards=4 bucket=512",
            modelled_s=modelled_sharded_latency(
                part, in_feats=in_feats, hidden=hidden, classes=classes,
                exchange_widths=widths)))
        eng.detach(gid)

    # ---- replica-group scaling --------------------------------------
    small = clustered_like(num_nodes=200, num_feats=in_feats,
                           num_classes=classes, within_density=0.05,
                           cross_frac=0.1, seed=seed + 1)
    n_q = 4 if quick else 8
    modelled_rps = []
    for r in (1, 2, 4):
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(128,)),
                              batch_slots=1, shard_counts=(2,),
                              replica_groups=r)
        eng = GraphServe(sc, seed=seed)
        eng.register_model("gcn", cfg)
        eng.warmup()
        gid = eng.attach(small, model="gcn", calibrate=False)
        eng.query(gid)
        eng.run()
        part = eng._sharded[gid][0]
        before = eng.metrics["sharded_batches"]
        t0 = _time.perf_counter()
        for _ in range(n_q):
            eng.query(gid)
        eng.run()
        wall = (_time.perf_counter() - t0) / n_q
        eng.assert_warm()
        dispatches = eng.metrics["sharded_batches"] - before
        assert dispatches == -(-n_q // r), (
            "replica packing must dispatch ceil(N/R) sharded batches",
            dispatches, n_q, r)
        lat = modelled_sharded_latency(part, in_feats=in_feats,
                                       hidden=hidden, classes=classes,
                                       exchange_widths=widths)
        modelled_rps.append(r / lat)
        rows.append(record(
            f"partition_quality/replica/{dataset}/r{r}", wall,
            f"dispatches={dispatches} queries={n_q} "
            f"occupancy={eng.summary()['batch_occupancy']:.2f} "
            f"modelled_rps={r / lat:.0f}", modelled_s=lat))
        eng.detach(gid)
    assert all(b > a for a, b in zip(modelled_rps, modelled_rps[1:])), (
        "replica rows share no collectives: modelled throughput must "
        "rise monotonically with R", modelled_rps)

    # ---- delta-halo vs full-halo bytes under churn ------------------
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(128,)),
                          batch_slots=1, shard_counts=(2,))
    eng = GraphServe(sc, seed=seed)
    eng.register_model("gcn", cfg)
    eng.warmup()
    gid = eng.attach(small, model="gcn", calibrate=False)
    eng.query(gid)
    eng.run()
    part = eng._sharded[gid][0]
    rng = np.random.default_rng(seed)
    churn = 4 if quick else 12
    done = 0
    while done < churn:
        u, v = rng.integers(0, 200, size=2)
        if u == v:
            continue
        adj = eng.graphs[gid][1].adj
        pair = [(int(u), int(v))]
        ok = eng.update_delta(
            gid, add_edges=pair if adj[u, v] == 0 else None,
            remove_edges=pair if adj[u, v] != 0 else None)
        done += bool(ok)
    s = eng.summary()
    assert 0 < s["delta_halo_bytes_exchanged"] < s["delta_halo_bytes_full"], (
        "dirty-row exchange must move strictly fewer bytes than full "
        "halo re-exchange", s["delta_halo_bytes_exchanged"],
        s["delta_halo_bytes_full"])
    eng.query(gid)
    eng.run()
    eng.assert_warm()           # churn never left the warm patch traces
    rows.append(record(
        f"partition_quality/delta/{dataset}/churn{churn}", 0.0,
        f"delta_bytes={s['delta_halo_bytes_exchanged']} "
        f"full_bytes={s['delta_halo_bytes_full']} "
        f"dirty_rows={s['delta_dirty_rows']} "
        f"saving={s['delta_halo_bytes_full'] / max(s['delta_halo_bytes_exchanged'], 1):.0f}x "
        f"shards={part.shards}"))
    eng.detach(gid)
    return rows


# ------------------------------------------------------- energy / GraSp


def energy_proxy(dataset: str = "cora") -> List[Dict]:
    """Fig. 23 proxy: bytes moved per inference (dominant energy driver on
    edge parts). Dense vs ZVC/GraSp-compressed operand traffic."""
    g = cora_like() if dataset == "cora" else citeseer_like()
    pg = pad_graph(g)
    rep = sparsity_report(pg.norm_adj)
    rows = [
        record(f"energy/{dataset}/adj_dense_bytes", 0.0,
               str(rep["dense_bytes"])),
        record(f"energy/{dataset}/adj_zvc_bytes", 0.0, str(rep["zvc_bytes"])),
        record(f"energy/{dataset}/adj_block_bytes", 0.0,
               str(rep["block_compacted_bytes"])),
        record(f"energy/{dataset}/flop_skip_fraction", 0.0,
               f"{rep['flop_skip_fraction']:.3f}"),
        record(f"energy/{dataset}/zvc_saving", 0.0,
               f"{rep['dense_bytes']/max(rep['zvc_bytes'],1):.1f}x"),
    ]
    return rows


# ------------------------------------------------- fused layers (§11)


def fused_layers(quick: bool = True) -> List[Dict]:
    """Fused per-layer kernels vs unfused per-op dispatch (DESIGN.md §11).

    Two rows per (kind, tier, backend) hot combination. The `unfused` row
    is the per-op forward (`fusion="none"`); the `fused` row is the same
    tier math as one fused kernel pass per layer (`fusion="layer"`).
    Columns:

      * us_per_call — measured CPU wall-clock. On CPU both modes lower to
        near-identical XLA (the fused ref twins ARE the unfused math), so
        this column is a sanity check, not the claim.
      * tpu_model speedup (in `derived`) — the claim. The unfused forward
        is priced from its compiled HLO (`benchmarks.tpu_model`); the
        fused forward reuses the SAME MXU/VPU terms (fusion never changes
        FLOPs) with the HBM term re-priced to the bytes the fused kernels
        actually move: per layer, kernel operands + output only — every
        intermediate (H strips, attention logits, re-quantized H) lives in
        VMEM scratch across grid steps and never crosses HBM. The fused
        cost carries NO serialized-gather term: every fused-kernel load is
        a block-granular pipelined DMA (BlockSpec index maps /
        scalar-prefetch descriptors), which is exactly the row-granularity
        serialization the GATHER_BW term models — eliminating it is the
        GraSp/EffOp dispatch win.
      * interp_grid (in `derived`) — measured wall-clock of the fused
        forward with the REAL Pallas grids on the interpret backend
        (REPRO_KERNEL_MODE=interpret). Orders slower than XLA by design;
        recorded so CI trends catch grid-structure regressions, never
        compared against the XLA columns.

    `measured_vs_modelled` lands on both rows (see benchmarks/common.record).
    """
    import os

    from repro.core.graph import Graph
    from repro.core.models import calibrate_tier
    from repro.runtime.gnn_server import tier_techniques

    from .tpu_model import HBM_BW

    rows: List[Dict] = []
    reps = 2 if quick else 5
    f32, i8 = 4, 1

    def _graph(n, fin, *, band=None, seed=0):
        rng = np.random.default_rng(seed)
        if band is None:
            m = n * 6
            ei = rng.integers(0, n, size=(2, m)).astype(np.int32)
            ei = np.concatenate([ei, ei[::-1]], axis=1)
        else:
            # banded ring: block-sparse-friendly clustered structure
            src = np.repeat(np.arange(n, dtype=np.int32), band)
            dst = (src + np.tile(np.arange(1, band + 1, dtype=np.int32), n)
                   ) % n
            ei = np.concatenate([np.stack([src, dst]),
                                 np.stack([dst, src])], axis=1)
        feats = rng.standard_normal((n, fin)).astype(np.float32)
        return Graph(edge_index=ei, num_nodes=n, features=feats)

    def _bench(label, cfg, params, x, ops_, t, quant, tops, fused_bytes):
        kw = dict(quant=quant, tier_ops=tops)
        unfused = jax.jit(lambda p, xx: forward_grannite(
            p, cfg, xx, ops_, t, fusion="none", **kw))
        fused = jax.jit(lambda p, xx: forward_grannite(
            p, cfg, xx, ops_, t, fusion="layer", **kw))
        tu = time_fn(unfused, params, x, warmup=1, repeats=reps)
        tf = time_fn(fused, params, x, warmup=1, repeats=reps)
        a = tpu_analyze(unfused, params, x)
        t_unf = a["t_model_s"]
        t_fus = max(a["t_mxu_s"] + a["t_vpu_s"], fused_bytes / HBM_BW)
        # interpret-grid timing: fresh jits trace through the REAL Pallas
        # grids (the kernel mode is read at trace time — kernels/ops.py)
        prev = os.environ.get("REPRO_KERNEL_MODE")
        os.environ["REPRO_KERNEL_MODE"] = "interpret"
        try:
            igrid = jax.jit(lambda p, xx: forward_grannite(
                p, cfg, xx, ops_, t, fusion="layer", **kw))
            ti = time_fn(igrid, params, x, warmup=1, repeats=2)
        finally:
            if prev is None:
                os.environ.pop("REPRO_KERNEL_MODE", None)
            else:
                os.environ["REPRO_KERNEL_MODE"] = prev
        rows.append(record(
            f"fused_layers/{label}/unfused", tu,
            f"tpu_model={t_unf * 1e6:.2f}us hbm_bytes={a['bytes']:.0f}",
            modelled_s=t_unf))
        rows.append(record(
            f"fused_layers/{label}/fused", tf,
            f"tpu_model={t_fus * 1e6:.2f}us "
            f"speedup={t_unf / t_fus:.2f}x "
            f"hbm_bytes={fused_bytes} interp_grid={ti * 1e6:.0f}us",
            modelled_s=t_fus))

    # serving-bucket shapes: hidden-heavy enough that the eliminated
    # inter-op intermediates dominate the (shared) Â / mask reads
    fin, hidden, classes, heads = 128, 512, 16, 4
    cap = 256

    # --- GCN dense: fp32 + int8 tiers --------------------------------
    pg = pad_graph(_graph(230, fin), capacity=cap)
    cfg = GNNConfig(kind="gcn", in_feats=fin, hidden=hidden,
                    num_classes=classes)
    params = init_params(KEY, cfg)
    ops_ = build_operands(pg, cfg)
    x = jnp.asarray(pg.features)
    tt = tier_techniques("gcn")
    nb_adj = cap * cap * f32
    fb = ((nb_adj + cap * fin * f32 + fin * hidden * f32 + hidden * f32
           + cap * hidden * f32)
          + (nb_adj + cap * hidden * f32 + hidden * classes * f32
             + classes * f32 + cap * classes * f32))
    _bench("gcn/fp32/dense", cfg, params, x, ops_, tt["fp32"],
           None, None, fb)

    quant = calibrate_tier(params, cfg, x, ops_)
    tops = derive_tier_operands(ops_.norm_adj)
    nb_aq = cap * cap * i8 + cap * f32        # int8 Â + row scales
    fb8 = ((nb_aq + cap * fin * f32 + fin * hidden * i8 + hidden * f32
            + cap * hidden * f32)
           + (nb_aq + cap * hidden * f32 + hidden * classes * i8
              + classes * f32 + cap * classes * f32))
    _bench("gcn/int8/dense", cfg, params, x, ops_, tt["int8"],
           quant, tops, fb8)

    # --- GCN grasp: banded structure at a paper-scale rung -----------
    capg, hg = 1024, 128
    pgb = pad_graph(_graph(1000, fin, band=3, seed=1), capacity=capg)
    cfgb = GNNConfig(kind="gcn", in_feats=fin, hidden=hg,
                     num_classes=classes)
    paramsb = init_params(KEY, cfgb)
    opsb = build_operands(pgb, cfgb, grasp=True)
    bsp = opsb.block_sparse
    nb_bsp = sum(int(np.asarray(a).nbytes)
                 for a in (bsp.blocks, bsp.block_cols, bsp.counts))
    fbg = ((nb_bsp + capg * fin * f32 + fin * hg * f32 + hg * f32
            + capg * hg * f32)
           + (nb_bsp + capg * hg * f32 + hg * classes * f32
              + classes * f32 + capg * classes * f32))
    _bench("gcn/fp32/grasp", cfgb, paramsb, jnp.asarray(pgb.features),
           opsb, dataclasses.replace(tt["fp32"], grasp=True),
           None, None, fbg)

    # --- GAT dense: fp32 + int8 tiers --------------------------------
    cfg_g = GNNConfig(kind="gat", in_feats=fin, hidden=hidden,
                      num_classes=classes, heads=heads)
    params_g = init_params(KEY, cfg_g)
    ops_g = build_operands(pg, cfg_g)
    tt_g = tier_techniques("gat")
    nb_bias = cap * cap * f32
    fb_gat = ((cap * fin * f32 + fin * hidden * f32 + 2 * hidden * f32
               + nb_bias + hidden * f32 + cap * hidden * f32)
              + (cap * hidden * f32 + hidden * classes * f32
                 + 2 * classes * f32 + nb_bias + classes * f32
                 + cap * classes * f32))
    _bench("gat/fp32/dense", cfg_g, params_g, x, ops_g, tt_g["fp32"],
           None, None, fb_gat)

    quant_g = calibrate_tier(params_g, cfg_g, x, ops_g)
    # precombined fusion: the int8 combine runs unfused (x + wq read, H
    # written), then the fused attention grid re-reads H (twice: alpha
    # reductions + the combine matmul stream) — only the N^2-per-head
    # attention intermediates fuse away
    fb_gat8 = ((cap * fin * f32 + fin * hidden * i8
                + 3 * cap * hidden * f32 + nb_bias + hidden * f32
                + cap * hidden * f32)
               + (cap * hidden * f32 + hidden * classes * i8
                  + 3 * cap * classes * f32 + nb_bias + classes * f32
                  + cap * classes * f32))
    _bench("gat/int8/dense", cfg_g, params_g, x, ops_g, tt_g["int8"],
           quant_g, None, fb_gat8)

    # --- SAGE mean: fp32 ---------------------------------------------
    cfg_s = GNNConfig(kind="sage", in_feats=fin, hidden=hidden,
                      num_classes=classes, aggregator="mean")
    params_s = init_params(KEY, cfg_s)
    ops_s = build_operands(pg, cfg_s)
    fb_sage = ((nb_adj + cap * fin * f32 + 2 * fin * hidden * f32
                + hidden * f32 + cap * hidden * f32)
               + (nb_adj + cap * hidden * f32 + 2 * hidden * classes * f32
                  + classes * f32 + cap * classes * f32))
    _bench("sage/fp32/dense", cfg_s, params_s, x, ops_s,
           tier_techniques("sage")["fp32"], None, None, fb_sage)
    return rows


# --------------------------------------------- cache pressure (§13)


def cache_pressure(dataset: str = "synthetic", *, quick: bool = True,
                   seed: int = 0) -> List[Dict]:
    """Bounded CacheG memory hierarchy under churn (DESIGN.md §13).

    Three claims, one row each:

      * churn — attach/query cycles over more tenants than the byte
        budget admits. The derived column reports the peak
        `cache_resident_bytes` seen after EVERY step against the budget
        (the §13 invariant — also enforced, bit-level, by
        tests/test_cache_pressure.py), plus eviction/spill-fault counts.
        `assert_warm` holds throughout: eviction and re-materialization
        replay warm blobs, they never trace.
      * spill_fault vs warm_hit — per-query wall-clock when the operands
        must re-materialize from the host-RAM spill form vs when they
        are device-resident. The gap is the fault penalty: one compact
        SymG transfer + on-device materialization, zero host repacking.
      * delta_update vs full_rebuild — end-to-end (update + next query)
        for a single undirected edge flip via `update_delta` (GrAd
        device-side patch; the next query HITS the patched entry) vs
        `update()` (invalidates; the next query rebuilds from scratch).
        The differential suite proves both end bit-identical; this row
        reports what the equivalence costs.
    """
    import time as _time

    from repro.core.graph import BucketLadder
    from repro.data.graphs import planetoid_like
    from repro.runtime.cache import estimate_dense_entry_bytes
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig

    cap, fin, classes = 128, 32, 5
    entry = estimate_dense_entry_bytes(1, cap)      # gcn: one Â field
    budget = 3 * entry + entry // 2                 # ~3 resident tenants

    def _g(i):
        n = 48 + (i * 13) % 70
        return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=fin,
                              num_classes=classes, seed=seed + i,
                              train_per_class=2)

    def _engine(**kw):
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(cap,)),
                              batch_slots=1, return_logits=True, **kw)
        eng = GraphServe(sc, seed=seed)
        eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=fin,
                                            hidden=16, num_classes=classes))
        eng.warmup()
        return eng

    rows: List[Dict] = []

    # --- churn: more tenants than the budget admits ------------------
    n_graphs = 6 if quick else 12
    n_cycles = 30 if quick else 120
    eng = _engine(device_cache_budget_bytes=budget)
    gids = [eng.attach(_g(i), model="gcn") for i in range(n_graphs)]
    rng = np.random.default_rng(seed)
    peak = 0
    t0 = _time.perf_counter()
    for _ in range(n_cycles):
        eng.query(gids[int(rng.integers(n_graphs))])
        eng.run()
        peak = max(peak, eng.summary()["cache_resident_bytes"])
    wall = _time.perf_counter() - t0
    eng.assert_warm()
    s = eng.summary()
    assert peak <= budget, (peak, budget)
    rows.append(record(
        f"cache_pressure/{dataset}/churn", wall / n_cycles,
        f"peak_resident={peak}B <= budget={budget}B over {n_cycles} "
        f"cycles x {n_graphs} tenants, evictions={s['cache_evictions']} "
        f"spill_hits={s['cache_spill_hits']}, zero recompiles"))

    # --- spill fault vs warm hit -------------------------------------
    eng = _engine(device_cache_budget_bytes=2 * entry + entry // 2)
    a, b, c = (eng.attach(_g(i), model="gcn") for i in range(3))
    for gid in (a, b, c):                           # first-touch misses
        eng.query(gid)
        eng.run()
    reps = 3 if quick else 10
    t_fault = t_hit = 0.0
    for _ in range(reps):
        for gid in (b, c):                          # evicts `a` (budget=2)
            eng.query(gid)
            eng.run()
        t0 = _time.perf_counter()
        eng.query(a)                                # faults on the spill form
        eng.run()
        t_fault += _time.perf_counter() - t0
        t0 = _time.perf_counter()
        eng.query(a)                                # device-resident now
        eng.run()
        t_hit += _time.perf_counter() - t0
    eng.assert_warm()
    s = eng.summary()
    rows.append(record(
        f"cache_pressure/{dataset}/spill_fault", t_fault / reps,
        f"{t_fault / max(t_hit, 1e-9):.2f}x the warm hit "
        f"({s['cache_spill_hits']} faults served from the host spill "
        f"form, {s['operand_bytes_h2d']} compact B h2d)"))
    rows.append(record(
        f"cache_pressure/{dataset}/warm_hit", t_hit / reps,
        f"device-resident query ({s['operand_cache_hits']} hits)"))

    # --- GrAd delta patch vs full rebuild ----------------------------
    # at a paper-scale rung: the full path re-normalizes the whole
    # (cap, cap) Â on the host and re-uploads it; the delta path renorms
    # only the touched rows device-side
    cap_d = 512 if quick else 1024
    nd = int(cap_d * 3 / 4)
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(cap_d,)),
                          batch_slots=1, return_logits=True)
    eng = GraphServe(sc, seed=seed)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=fin,
                                        hidden=16, num_classes=classes))
    eng.warmup()
    g = planetoid_like(num_nodes=nd, num_edges=3 * nd, num_feats=fin,
                       num_classes=classes, seed=seed + 1,
                       train_per_class=2)
    gid = eng.attach(g, model="gcn")
    eng.query(gid)
    eng.run()
    adj = eng.graphs[gid][1].adj
    j = int(np.flatnonzero(adj[0] == 0)[1])         # absent pair (0, j)
    pair = (0, j)
    t0 = _time.perf_counter()
    for _ in range(reps):
        eng.update_delta(gid, add_edges=[pair])
        eng.query(gid)
        eng.run()
        eng.update_delta(gid, remove_edges=[pair])
        eng.query(gid)
        eng.run()
    t_delta = (_time.perf_counter() - t0) / (2 * reps)
    cols = np.array([[0, j], [j, 0]], dtype=g.edge_index.dtype).T
    ei_plus = np.concatenate([g.edge_index, cols], axis=1)
    t0 = _time.perf_counter()
    for _ in range(reps):
        eng.update(gid, ei_plus, g.num_nodes, g.features)
        eng.query(gid)
        eng.run()
        eng.update(gid, g.edge_index, g.num_nodes, g.features)
        eng.query(gid)
        eng.run()
    t_full = (_time.perf_counter() - t0) / (2 * reps)
    eng.assert_warm()
    s = eng.summary()
    rows.append(record(
        f"cache_pressure/{dataset}/delta_update", t_delta,
        f"{t_full / max(t_delta, 1e-9):.2f}x vs full rebuild "
        f"({s['delta_updates']} patched, {s['delta_fallbacks']} fallbacks; "
        f"next query hits the patched entry)"))
    rows.append(record(
        f"cache_pressure/{dataset}/full_rebuild", t_full,
        "update() baseline: invalidate + rebuild on the next query"))
    return rows


def slo_serving(dataset: str = "synthetic", *, quick: bool = True,
                seed: int = 0) -> List[Dict]:
    """SLO-aware serving (DESIGN.md §14): deadline hit-rate under load for
    static-tier vs governed serving, and measured-EWMA vs roofline-only
    agg-backend routing.

    Three row groups:

      * static / governed — the SAME bursty deadline-carrying stream served
        by a fixed-fp32 engine and by one with an `SLOGovernor`. Each
        request's `deadline_ms` is set from a short calibration pass (a
        multiple of the measured fp32 batch latency), so queue wait inside
        a burst is what blows budgets. The derived column reports the
        deadline hit rate, rolling p99, tier downgrades taken, and how the
        served-tier mix shifted — on this CPU box int8's QuantGr kernels
        are not guaranteed faster, so the row reports what trading quality
        for latency actually bought rather than asserting it.
      * backend_routing — an `agg_backend="auto"` engine serving a mixed
        sparse/dense stream. The first sparse request routes on the
        roofline alone (cold bank); once BOTH backends hold measured
        samples at the bucket, the same probe re-routes on measured EWMA
        (`select_agg_backend(measured=...)`). The derived column reports
        both choices, both measured latencies, and `ewma_vs_model` — the
        ratio that exposes how far the analytic model sits from this
        box's reality (the BENCH grasp-regression guard, as a trend row).
    """
    import time as _time

    from repro.core.graph import BucketLadder
    from repro.data.graphs import planetoid_like
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig
    from repro.runtime.slo import SLOConfig

    rows: List[Dict] = []
    in_feats, classes = 32, 5
    cal = planetoid_like(num_nodes=200, num_edges=600, num_feats=in_feats,
                         num_classes=classes, seed=seed + 10_000,
                         train_per_class=5)

    def _engine(slo=None):
        # 2-slot batches: an 8-deep burst takes 4 dispatches, so the tail
        # of each burst pays real queue wait — that is the load knob
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(256,)),
                              batch_slots=2)
        eng = GraphServe(sc, seed=seed, slo=slo)
        eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=in_feats,
                                            hidden=32, num_classes=classes),
                           tiers=("fp32", "int8"))
        eng.warmup()
        eng.calibrate("gcn", cal)
        return eng

    n_requests = 24 if quick else 64
    burst = 8
    rng = np.random.default_rng(seed)
    traffic = [planetoid_like(num_nodes=int(rng.integers(100, 240)),
                              num_edges=600, num_feats=in_feats,
                              num_classes=classes, seed=seed + i,
                              train_per_class=2)
               for i in range(n_requests)]

    # calibration pass: measured fp32 latency sets the deadline scale, so
    # the SAME relative pressure applies whatever box runs this; 2.5x one
    # batch means roughly the back half of each 4-dispatch burst is at risk
    probe = _engine()
    l0 = len(probe.metrics["latency_s"])
    for i in range(4):
        probe.submit(traffic[i], model="gcn", tier="fp32")
        probe.run()
    base_s = float(np.median(probe.metrics["latency_s"][l0:]))
    deadline_ms = 2.5 * base_s * 1e3

    slo = SLOConfig(target_p99_ms=deadline_ms, window=16, min_samples=4,
                    breach_checks=2, clear_checks=4,
                    max_queue_depth=4 * burst, ladder=("fp32", "int8"))
    for mode, eng in (("static", _engine()),
                      ("governed", _engine(slo=slo))):
        m0 = (eng.metrics["deadline_misses"], len(eng.metrics["latency_s"]),
              len(eng.finished))
        t0 = _time.perf_counter()
        for i in range(0, n_requests, burst):
            for g in traffic[i:i + burst]:      # burst arrival: queue wait
                eng.submit(g, model="gcn", deadline_ms=deadline_ms)
            eng.run()
        wall = _time.perf_counter() - t0
        eng.assert_warm()
        misses = eng.metrics["deadline_misses"] - m0[0]
        lats = np.asarray(eng.metrics["latency_s"][m0[1]:])
        tiers = [r.tier for r in eng.finished[m0[2]:]]
        s = eng.summary()
        rows.append(record(
            f"slo_serving/{mode}/{dataset}/hit_rate", wall / n_requests,
            f"hit_rate={1 - misses / n_requests:.2f} "
            f"({n_requests - misses}/{n_requests} under "
            f"{deadline_ms:.1f}ms), p99={np.percentile(lats, 99) * 1e3:.1f}ms, "
            f"downgrades={s['slo_downgrades']}, "
            f"int8_served={sum(t == 'int8' for t in tiers)}"))

    # --- EWMA-measured vs roofline-only backend routing ------------------
    from repro.data.graphs import clustered_like

    cap, fin = 512, 64
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(cap,)), batch_slots=2)
    eng = GraphServe(sc, seed=seed)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=fin, hidden=64,
                                        num_classes=classes),
                       agg_backend="auto")
    eng.warmup()
    n = cap - 64

    def _sparse(i):       # community-clustered: block-sparse, roofline grasp
        return clustered_like(num_nodes=n, num_feats=fin,
                              num_classes=classes, within_density=0.03,
                              cross_frac=0.0, seed=seed + 100 + i)

    def _dense(i):        # cross-community scatter fills the bitmap: dense
        return clustered_like(num_nodes=n, num_feats=fin,
                              num_classes=classes, within_density=0.5,
                              cross_frac=0.3, seed=seed + 200 + i)

    uid = eng.submit(_sparse(0), model="gcn")
    eng.run()
    roofline_pick = next(r for r in eng.finished if r.uid == uid).backend
    for i in range(4 if quick else 8):          # measure BOTH backends
        eng.submit(_sparse(i + 1), model="gcn")
        eng.submit(_dense(i), model="gcn")
        eng.run()
    pair = eng._measured_agg_pair("gcn", cap)
    uid = eng.submit(_sparse(99), model="gcn")
    eng.run()
    measured_pick = next(r for r in eng.finished if r.uid == uid).backend
    eng.assert_warm()
    s = eng.summary()
    d_ms = f"{pair[0] * 1e3:.2f}" if pair[0] is not None else "n/a"
    g_ms = f"{pair[1] * 1e3:.2f}" if pair[1] is not None else "n/a"
    rows.append(record(
        f"slo_serving/{dataset}/backend_routing",
        pair[0] if pair[0] is not None else 0.0,
        f"roofline_pick={roofline_pick} measured_pick={measured_pick} "
        f"(dense={d_ms}ms grasp={g_ms}ms measured; "
        f"flipped={measured_pick != roofline_pick}), "
        f"ewma_vs_model={s['ewma_vs_model']:.1f}x"))
    return rows
