"""TPU-model latency from compiled HLO — the device-side analogue of the
paper's NPU measurements.

CPU wall-clock CANNOT reproduce the paper's relative claims: a CPU executes
gathers well (the paper says exactly this — control-heavy work belongs on
CPUs) and pays O(N^2) for the dense rewrites, so the comparison inverts.
The paper's speedups come from the accelerator's asymmetry: MXU-class dense
throughput vs DSP-class serialized gather/scatter.

We therefore derive a modelled latency from each path's ACTUAL compiled
artifact (same methodology as launch/roofline.py): HLO FLOPs at MXU rate,
HBM bytes at full bandwidth, EXCEPT bytes moved by gather / scatter /
dynamic-slice ops, which are priced at GATHER_BW — the serialized
row-granularity DMA rate that models the NPU's DSP path (and the TPU's own
poor gather throughput). INT8 dots get the 2x MXU rate (QuantGr's claim).

One backend artifact is repriced: the CPU emitter lowers s8 dots by
materializing s32 COPIES of the int8 operands (convert(s8)->s32 feeding the
dot), where an MXU/NPU int8 datapath reads the 1-byte operands natively.
`analyze` subtracts the excess: 3 B/element for every s32 operand of every
s32-accumulating dot (4B artifact read vs 1B native — counted per dot, so
an operand converted once but read by several dots, like the cached int8 Â
feeding both GCN layers, is repriced at every use), plus 5 B/element per
widening convert (its 1B read + 4B write simply don't exist natively).
Without this, QuantGr's operand-byte shrink — the entire point of shipping
int8 Â (DESIGN.md §8) — would be invisible to the model. The repricing
assumes s32 dots ARE quantized-int8 dots, which holds for every path in
this repo (nothing dots genuine int32 data).

The GNN paths contain no scans (heads unroll), so HLO cost analysis is
exact here — no two-point correction needed.
"""
from __future__ import annotations

import re
from typing import Callable, Dict

import jax

from repro.core.costs import GATHER_BW, HBM_BW, PEAK_BF16  # noqa: F401

PEAK_INT8 = 2 * PEAK_BF16     # int8 dots at the 2x MXU rate (QuantGr)
VPU_RATE = PEAK_BF16 / 8      # elementwise/transcendental fallback

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "f64": 8}

_GATHER_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\b(gather|scatter|dynamic-slice|"
    r"dynamic-update-slice)\(", )

_INT8_DOT_RE = re.compile(r"=\s*s32\[[\d,]*\][^=]*?\bdot\(")

# CPU lowering artifact: s8 dot operands widened to s32 copies (see module
# docstring) — native int8 datapaths read the 1-byte form directly.
_S8_WIDEN_RE = re.compile(r"=\s*s32\[([\d,]*)\][^=]*?\bconvert\(s8\[")
_S32_DOT_OPERANDS_RE = re.compile(       # operands carry {layout} commas
    r"=\s*s32\[[\d,]*\][^=]*?\bdot\(s32\[([\d,]*)\]\S*\s+%[^,]+,"
    r"\s+s32\[([\d,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def analyze(fn: Callable, *args) -> Dict[str, float]:
    """Compile fn(*args) and derive the TPU-model latency terms."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # older jax: one dict per device
        ca = ca[0] if ca else {}
    txt = compiled.as_text()

    gather_bytes = 0
    for m in _GATHER_RE.finditer(txt):
        gather_bytes += _bytes_of(m.group(1), m.group(2))
    has_int8_dot = bool(_INT8_DOT_RE.search(txt))

    flops = float(ca.get("flops", 0.0))
    trans = float(ca.get("transcendentals", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    if has_int8_dot:
        excess = sum(5.0 * _bytes_of("s8", m.group(1))  # element counts
                     for m in _S8_WIDEN_RE.finditer(txt))
        excess += sum(3.0 * (_bytes_of("s8", m.group(1))
                             + _bytes_of("s8", m.group(2)))
                      for m in _S32_DOT_OPERANDS_RE.finditer(txt))
        byts = max(byts - excess, 0.0)

    t_mxu = flops / (PEAK_INT8 if has_int8_dot else PEAK_BF16)
    t_vpu = trans / VPU_RATE
    t_hbm = max(byts - gather_bytes, 0.0) / HBM_BW
    t_gather = gather_bytes / GATHER_BW
    # dense terms overlap (roofline max); the serialized gather path does not
    t_model = max(t_mxu + t_vpu, t_hbm) + t_gather
    return {"t_model_s": t_model, "t_mxu_s": t_mxu, "t_vpu_s": t_vpu,
            "t_hbm_s": t_hbm, "t_gather_s": t_gather,
            "gather_bytes": gather_bytes,
            "flops": flops, "bytes": byts, "int8": has_int8_dot}
