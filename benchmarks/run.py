"""Benchmark harness entry point — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--dataset cora]
                                          [--bench-json BENCH_gnn.json]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.record) and
writes benchmarks/results.json. ``--bench-json`` additionally writes the
serving-throughput, CacheG operand-bytes, quality-tier, and
pipeline-overlap rows to a standalone file (CI uploads it as the
``BENCH_gnn`` artifact per push to track the perf trajectory; the
repo-root BENCH_gnn.json is a committed point-in-time snapshot — schema
in benchmarks/README.md). The roofline report
(§Roofline) is generated separately by launch/dryrun.py (needs the
512-device placeholder env).
"""
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow accuracy-table training runs")
    ap.add_argument("--dataset", default="cora",
                    choices=["cora", "citeseer", "both"])
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "results.json"))
    ap.add_argument("--bench-json", default=None, metavar="BENCH_gnn.json",
                    help="also write the serving-throughput and CacheG "
                         "operand-bytes rows to this path (repo-root "
                         "BENCH_gnn.json in CI) for perf-trajectory tracking")
    ap.add_argument("--only", default=None, choices=["fused_layers"],
                    help="run a single benchmark family (CI's interpret "
                         "leg runs `--only fused_layers` so the fused-grid "
                         "rows land without the full suite)")
    args = ap.parse_args()

    from . import gnn_paper, lm_subs
    from .common import ROWS

    datasets = (["cora", "citeseer"] if args.dataset == "both"
                else [args.dataset])
    print("name,us_per_call,derived")
    if args.only == "fused_layers":
        gnn_paper.fused_layers(quick=args.quick)
        _write(args, ROWS)
        return
    for ds in datasets:
        gnn_paper.fig20_progressive(ds)
        gnn_paper.fig22_path_comparison(ds)
        gnn_paper.fig21_tile_scaling(ds)
        gnn_paper.energy_proxy(ds)
        if not args.quick:
            gnn_paper.accuracy_table(ds)
    gnn_paper.fig22_density_crossover()
    gnn_paper.serving_throughput()
    # --quick drops to a 1024 rung so CI stays fast; the full run exercises
    # the paper-scale cap-2048 GAT case (2 x 16 MB eager masks per query)
    gnn_paper.operand_pipeline(cap=1024 if args.quick else 2048,
                               n_queries=4 if args.quick else 6)
    # quality tiers (DESIGN.md §8): short training in --quick mode — the
    # per-tier latency/bytes/accuracy-delta rows still land in BENCH_gnn.json
    gnn_paper.quality_tiers(epochs=12 if args.quick else 60,
                            n_queries=3 if args.quick else 6)
    # async pipeline scheduler vs sync run() (DESIGN.md §9): online mixed
    # kind/bucket/tier stream; fewer requests in --quick keeps CI ~fast
    gnn_paper.pipeline_overlap(n_requests=16 if args.quick else 24)
    # GraSp agg backend vs dense per density (DESIGN.md §10); the smaller
    # --quick rung still exercises the batched bitmap_spmm dispatch
    gnn_paper.grasp_serving(cap=512 if args.quick else 1024,
                            n_queries=2 if args.quick else 4)
    # fused per-layer kernels vs per-op dispatch (DESIGN.md §11)
    gnn_paper.fused_layers(quick=args.quick)
    lm_subs.ssd_vs_sequential()
    lm_subs.moe_dispatch_paths()
    lm_subs.serving_bucket_reuse()
    _write(args, ROWS)


def _write(args, rows) -> None:
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows -> {args.out}")

    if args.bench_json:
        perf = [r for r in rows
                if r["name"].startswith(("serve/", "operand_pipeline/",
                                         "quality_tiers/",
                                         "pipeline_overlap/",
                                         "grasp_serving/",
                                         "fused_layers/"))]
        with open(args.bench_json, "w") as f:
            json.dump({"rows": perf}, f, indent=1)
        print(f"# wrote {len(perf)} perf rows -> {args.bench_json}")


if __name__ == "__main__":
    main()
