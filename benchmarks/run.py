"""Benchmark harness entry point — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--dataset cora]
                                          [--bench-json BENCH_gnn.json]
                                          [--only FAMILY]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.record) and
writes benchmarks/results.json. ``--bench-json`` additionally writes the
serving-throughput, CacheG operand-bytes, quality-tier, pipeline-overlap,
grasp, fused-layer, sharded-serving, cache-pressure, and SLO-serving rows
to a standalone file (CI
uploads it as the ``BENCH_gnn`` artifact per push to track the perf
trajectory; the repo-root BENCH_gnn.json is a committed point-in-time
snapshot — schema in benchmarks/README.md). ``--only`` runs a single
benchmark family from the registry below (any family, not just the CI
legs); an unknown name lists the known ones. The roofline report
(§Roofline) is generated separately by launch/dryrun.py (needs the
512-device placeholder env).
"""
from __future__ import annotations

import argparse
import json
import os


def _families(args, datasets, gnn_paper, lm_subs):
    """`--only` registry: family name -> thunk running it with the SAME
    arguments the full suite would use (so an `--only` row is comparable
    to the corresponding full-run row). One entry per independent
    benchmark family; dataset-parameterized families honor --dataset."""
    q = args.quick
    return {
        "fig20": lambda: [gnn_paper.fig20_progressive(d) for d in datasets],
        "fig21": lambda: [gnn_paper.fig21_tile_scaling(d) for d in datasets],
        "fig22": lambda: [gnn_paper.fig22_path_comparison(d)
                          for d in datasets],
        "density_crossover": gnn_paper.fig22_density_crossover,
        "energy": lambda: [gnn_paper.energy_proxy(d) for d in datasets],
        "accuracy": lambda: [gnn_paper.accuracy_table(d) for d in datasets],
        "serving": gnn_paper.serving_throughput,
        "operand_pipeline": lambda: gnn_paper.operand_pipeline(
            cap=1024 if q else 2048, n_queries=4 if q else 6),
        "quality_tiers": lambda: gnn_paper.quality_tiers(
            epochs=12 if q else 60, n_queries=3 if q else 6),
        "pipeline_overlap": lambda: gnn_paper.pipeline_overlap(
            n_requests=16 if q else 24),
        "grasp_serving": lambda: gnn_paper.grasp_serving(
            cap=512 if q else 1024, n_queries=2 if q else 4),
        "fused_layers": lambda: gnn_paper.fused_layers(quick=q),
        "sharded_serving": lambda: gnn_paper.sharded_serving(quick=q),
        "partition_quality": lambda: gnn_paper.partition_quality(quick=q),
        "cache_pressure": lambda: gnn_paper.cache_pressure(quick=q),
        "slo_serving": lambda: gnn_paper.slo_serving(quick=q),
        "lm_subs": lambda: (lm_subs.ssd_vs_sequential(),
                            lm_subs.moe_dispatch_paths(),
                            lm_subs.serving_bucket_reuse()),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow accuracy-table training runs")
    ap.add_argument("--dataset", default="cora",
                    choices=["cora", "citeseer", "both"])
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "results.json"))
    ap.add_argument("--bench-json", default=None, metavar="BENCH_gnn.json",
                    help="also write the serving-throughput and CacheG "
                         "operand-bytes rows to this path (repo-root "
                         "BENCH_gnn.json in CI) for perf-trajectory tracking")
    ap.add_argument("--only", default=None, metavar="FAMILY",
                    help="run a single benchmark family (e.g. CI's "
                         "interpret leg runs `--only fused_layers`, the "
                         "multi-device leg `--only sharded_serving`); an "
                         "unknown name lists the registry")
    args = ap.parse_args()

    from . import gnn_paper, lm_subs
    from .common import ROWS

    datasets = (["cora", "citeseer"] if args.dataset == "both"
                else [args.dataset])
    families = _families(args, datasets, gnn_paper, lm_subs)
    if args.only is not None and args.only not in families:
        ap.error(f"unknown benchmark family {args.only!r}; known families: "
                 f"{', '.join(sorted(families))}")
    print("name,us_per_call,derived")
    if args.only is not None:
        families[args.only]()
        _write(args, ROWS)
        return
    for ds in datasets:
        gnn_paper.fig20_progressive(ds)
        gnn_paper.fig22_path_comparison(ds)
        gnn_paper.fig21_tile_scaling(ds)
        gnn_paper.energy_proxy(ds)
        if not args.quick:
            gnn_paper.accuracy_table(ds)
    gnn_paper.fig22_density_crossover()
    gnn_paper.serving_throughput()
    # --quick drops to a 1024 rung so CI stays fast; the full run exercises
    # the paper-scale cap-2048 GAT case (2 x 16 MB eager masks per query)
    families["operand_pipeline"]()
    # quality tiers (DESIGN.md §8): short training in --quick mode — the
    # per-tier latency/bytes/accuracy-delta rows still land in BENCH_gnn.json
    families["quality_tiers"]()
    # async pipeline scheduler vs sync run() (DESIGN.md §9): online mixed
    # kind/bucket/tier stream; fewer requests in --quick keeps CI ~fast
    families["pipeline_overlap"]()
    # GraSp agg backend vs dense per density (DESIGN.md §10); the smaller
    # --quick rung still exercises the batched bitmap_spmm dispatch
    families["grasp_serving"]()
    # fused per-layer kernels vs per-op dispatch (DESIGN.md §11)
    families["fused_layers"]()
    # sharded serving of a partitioned giant graph (DESIGN.md §12):
    # throughput vs shard count with compressed halo exchange
    families["sharded_serving"]()
    # §15 partitioner quality, replica-group scaling, delta-halo bytes —
    # the acceptance asserts run IN the benchmark
    families["partition_quality"]()
    # bounded cache hierarchy under churn + GrAd delta updates
    # (DESIGN.md §13): eviction/spill-fault costs and delta-vs-rebuild
    families["cache_pressure"]()
    # SLO-aware serving (DESIGN.md §14): deadline hit-rate static vs
    # governed + measured-EWMA vs roofline-only backend routing
    families["slo_serving"]()
    families["lm_subs"]()
    _write(args, ROWS)


def _write(args, rows) -> None:
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows -> {args.out}")

    if args.bench_json:
        perf = [r for r in rows
                if r["name"].startswith(("serve/", "operand_pipeline/",
                                         "quality_tiers/",
                                         "pipeline_overlap/",
                                         "grasp_serving/",
                                         "fused_layers/",
                                         "sharded_serving/",
                                         "partition_quality/",
                                         "cache_pressure/",
                                         "slo_serving/"))]
        with open(args.bench_json, "w") as f:
            json.dump({"rows": perf}, f, indent=1)
        print(f"# wrote {len(perf)} perf rows -> {args.bench_json}")


if __name__ == "__main__":
    main()
