"""Quality-tier serving (DESIGN.md §8): tier registry, QuantGr calibration,
fp32 fallback, zero recompiles over mixed-tier traffic, CacheG sharing
across tiers, and the GrAx3 exactness condition."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import BucketLadder, pad_graph
from repro.core.layers import Techniques
from repro.core.models import (GNNConfig, build_operands, calibrate_tier,
                               forward_grannite, train_node_classifier)
from repro.data.graphs import planetoid_like
from repro.runtime.gnn_server import (STANDARD_TIERS, GraphServe,
                                      GraphServeConfig, tier_techniques)

IN_FEATS, CLASSES = 16, 4


def _graph(n, seed=0):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=4)


def _cfg(kind, **kw):
    return GNNConfig(kind=kind, in_feats=IN_FEATS, hidden=16,
                     num_classes=CLASSES, heads=4, **kw)


def _engine(kind, *, tiers=STANDARD_TIERS, buckets=(128,), batch_slots=2,
            params=None, **cfg_kw):
    sc = GraphServeConfig(ladder=BucketLadder(buckets=buckets),
                          batch_slots=batch_slots, return_logits=True)
    eng = GraphServe(sc, seed=0)
    eng.register_model(kind, _cfg(kind, **cfg_kw), params, tiers=tiers)
    eng.warmup()
    return eng


def _trained_gcn_params(pg, cfg, epochs=40):
    ops = build_operands(pg, cfg, lean=True)
    t = tier_techniques("gcn")["fp32"]

    def fwd(p, x):
        return forward_grannite(p, cfg, x, ops, t)

    return train_node_classifier(jax.random.PRNGKey(0), cfg, pg, fwd,
                                 epochs=epochs)


# ----------------------------------------------------- int8 vs fp32 quality


def test_int8_tier_matches_fp32_on_trained_model():
    """On a TRAINED model (well-separated logits, realistic activation
    ranges) the int8 tier serves logits within quantization tolerance of
    fp32 and an accuracy delta within the paper's ~1-point envelope."""
    g = _graph(100, seed=3)
    cfg = _cfg("gcn")
    pg = pad_graph(g, capacity=128)
    params = _trained_gcn_params(pg, cfg)

    eng = _engine("gcn", params=params)
    gid = eng.attach(g, model="gcn")        # runs calibration + quality audit
    eng.query(gid, tier="fp32")
    eng.query(gid, tier="int8")
    eng.run()
    eng.assert_warm()

    out = {r.tier: r.logits for r in eng.finished}
    rel = (np.linalg.norm(out["int8"] - out["fp32"])
           / np.linalg.norm(out["fp32"]))
    assert rel < 0.05                       # INT8 round-trip error envelope
    agree = (out["int8"].argmax(-1) == out["fp32"].argmax(-1)).mean()
    assert agree > 0.95
    delta = eng.summary()["accuracy_delta_vs_fp32"]["gcn"]["int8"]
    assert abs(delta) <= 1.5                # percentage points (held-out)


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
def test_every_kind_serves_all_standard_tiers(kind):
    eng = _engine(kind, aggregator="max" if kind == "sage" else "mean")
    gid = eng.attach(_graph(100), model=kind)
    for tier in STANDARD_TIERS:
        eng.query(gid, tier=tier)
    eng.run()
    eng.assert_warm()
    assert {r.tier for r in eng.finished} == set(STANDARD_TIERS)
    deltas = eng.summary()["accuracy_delta_vs_fp32"][kind]
    assert set(deltas) == {"int8", "int8+grax"}


# -------------------------------------------------- plan / blob accounting


def test_tier_plans_counted_in_compiled_blobs():
    """Warmup compiles one plan per DISTINCT tier Techniques per bucket
    AND per fusion mode (both pre-traced, DESIGN.md §11) — GCN's int8+grax
    aliases int8 (no GrAx variant), so 3 named tiers cost 2×2 plan traces —
    plus the shared CacheG materializer trace and, for QuantGr GCN tiers,
    the per-bucket tier-operand deriver (int8 Â), all inside the
    zero-recompile contract."""
    eng = _engine("gcn")
    # (fp32 + int8(=int8+grax)) × 2 fusion modes, materializer, int8-Â
    # deriver, plus the §13 GrAd delta patcher AND its tier row-requant
    # trace (a QuantGr GCN tier keeps a derived int8 Â to patch)
    assert eng.compiled_blobs == 2 * 2 + 1 + 1 + 1 + 1
    eng = _engine("gat")
    # no deriver (model-level quant), patcher only — no tier form to patch
    assert eng.compiled_blobs == 3 * 2 + 1 + 1
    # untier'd registration stays a single-plan engine (back-compat):
    # fp32-only means no int8 Â, so the patcher warms without the requant
    eng = _engine("gcn", tiers=None)
    assert eng.compiled_blobs == 1 * 2 + 1 + 1


def test_zero_recompiles_across_mixed_tier_traffic():
    """Mixed sizes AND mixed tiers: after warmup, no request sequence may
    trace anything new — the tier registry is pre-compiled, calibration is
    pure value work, and fallback reuses the warm fp32 plan."""
    eng = _engine("gat", buckets=(128, 256), batch_slots=2)
    blobs = eng.compiled_blobs
    gid = eng.attach(_graph(100, seed=1), model="gat")
    rng = np.random.default_rng(0)
    for i in range(10):
        tier = STANDARD_TIERS[int(rng.integers(3))]
        if i % 3 == 2:
            eng.submit(_graph(int(rng.integers(60, 250)), seed=i),
                       model="gat", tier=tier)
        else:
            eng.query(gid, tier=tier)
    eng.run()
    eng.assert_warm()
    assert eng.compiled_blobs == blobs
    assert len(eng.finished) == 10
    # every request kept its resolved tier and its own output length
    for r in eng.finished:
        assert r.tier in STANDARD_TIERS
        assert r.preds.shape == (r.pg.num_nodes,)


def test_mixed_tier_requests_never_share_a_batch():
    """Tier is part of the batch key — a dispatch can't mix compiled
    variants, so 4 alternating-tier requests at batch_slots=4 must run as
    two partial batches, not one full one."""
    eng = _engine("gcn", batch_slots=4)
    gid = eng.attach(_graph(100), model="gcn")
    for tier in ("fp32", "int8", "fp32", "int8"):
        eng.query(gid, tier=tier)
    eng.run()
    eng.assert_warm()
    assert eng.metrics["batches"] == 2
    assert eng.metrics["slots_filled"] == 4


# ------------------------------------------------ CacheG shared across tiers


def test_operand_cache_shared_across_tiers():
    """The operand cache key carries no tier: fp32 and int8 queries of one
    attached graph share ONE device-resident fp32 entry, and the derived
    int8 Â is quantized once per structure version (one tier-cache entry
    reused by both QuantGr tier names), never per query."""
    eng = _engine("gcn")
    gid = eng.attach(_graph(100), model="gcn")
    eng.query(gid, tier="fp32")             # structure miss
    eng.query(gid, tier="int8")             # HIT — same fp32 operands
    eng.query(gid, tier="int8+grax")        # HIT — and reuses the int8 Â
    eng.query(gid, tier="int8")             # HIT
    eng.run()
    eng.assert_warm()
    s = eng.summary()
    assert s["operand_cache_misses"] == 1
    assert s["operand_cache_hits"] == 3
    assert len(eng._operand_cache) == 1
    assert len(eng._tier_operand_cache) == 1
    # update() invalidates BOTH caches under the same version key
    g2 = _graph(110, seed=9)
    eng.update(gid, g2.edge_index, g2.num_nodes, g2.features)
    assert len(eng._operand_cache) == 0
    assert len(eng._tier_operand_cache) == 0
    eng.query(gid, tier="int8")
    eng.run()
    eng.assert_warm()
    assert len(eng._tier_operand_cache) == 1


# ------------------------------------------------- fallback-before-calibrate


def test_uncalibrated_quant_tier_falls_back_to_fp32():
    """submit() never calibrates; an int8 request on an uncalibrated model
    must serve through the fp32 plan (counted, not an error) and flip to
    real int8 once calibrate() runs."""
    g = _graph(100)
    eng = _engine("gcn")
    eng.submit(g, model="gcn", tier="int8")
    eng.run()
    eng.assert_warm()
    assert eng.finished[-1].tier == "fp32"
    assert eng.summary()["tier_fallbacks"] == 1

    eng.calibrate("gcn", g)
    eng.submit(g, model="gcn", tier="int8")
    eng.run()
    eng.assert_warm()                       # calibration added NO traces
    assert eng.finished[-1].tier == "int8"
    assert eng.summary()["tier_fallbacks"] == 1     # no new fallback


def test_attach_calibrates_once_per_model_tier():
    eng = _engine("gcn")
    e = eng.models["gcn"]
    assert e.calibrations == {}
    eng.attach(_graph(100, seed=1), model="gcn")
    cal = e.calibrations["int8"]
    # alias tiers (identical Techniques) share ONE calibration pytree and
    # one audit, exactly like they share a compiled plan
    assert e.calibrations["int8+grax"] is cal
    deltas = dict(e.accuracy_delta)
    eng.attach(_graph(90, seed=2), model="gcn")     # second attach: no-op
    assert e.calibrations["int8"] is cal
    # ...including the quality audit: advertised deltas keep their first
    # calibration graph instead of silently drifting to a new one
    assert e.accuracy_delta == deltas
    eng.calibrate("gcn", _graph(90, seed=2))        # explicit, non-forced
    assert e.calibrations["int8"] is cal
    assert e.accuracy_delta == deltas
    # deferred mode leaves the model uncalibrated
    eng2 = _engine("gat")
    eng2.attach(_graph(100), model="gat", calibrate=False)
    assert eng2.models["gat"].calibrations == {}


def test_unknown_tier_and_missing_fp32_are_errors():
    eng = _engine("gcn")
    gid = eng.attach(_graph(100), model="gcn")
    with pytest.raises(KeyError):
        eng.query(gid, tier="bf16")
    with pytest.raises(ValueError):
        eng.register_model("bad", _cfg("gcn"),
                           tiers={"int8": tier_techniques("gcn")["int8"]})
    # the fallback tier must be servable uncalibrated: a QuantGr 'fp32'
    # would fall back to itself and run its plan with quant=None,
    # recompiling a trace warmup compiled against a calibration pytree
    with pytest.raises(ValueError):
        eng.register_model("bad2", _cfg("gcn"),
                           tiers={"fp32": tier_techniques("gcn")["int8"]})
    with pytest.raises(ValueError):
        eng.register_model(
            "bad3", _cfg("gcn"),
            techniques=dataclasses.replace(tier_techniques("gcn")["fp32"],
                                           quantgr=True))


# ------------------------------------------------------- custom tier registry


def test_custom_grax_only_tier_needs_no_calibration():
    """A non-QuantGr tier (pure GrAx approximation) serves immediately —
    no calibration, no fallback — through its own compiled plan."""
    std = tier_techniques("sage")
    tiers = {"fp32": std["fp32"],
             "grax": dataclasses.replace(std["fp32"], grax3=True)}
    eng = _engine("sage", tiers=tiers, aggregator="max")
    gid = eng.attach(_graph(100), model="sage")
    eng.query(gid, tier="grax")
    eng.run()
    eng.assert_warm()
    assert eng.finished[-1].tier == "grax"
    assert eng.summary()["tier_fallbacks"] == 0


# ----------------------------------------------------------- GrAx3 exactness


def test_grax3_sage_max_equivalence_small_graphs():
    """GrAx3 (mask-mul + maxpool) equals the exact additive-mask max
    whenever aggregated features are >= 0 — the paper's stated condition,
    guaranteed here by the ReLU'd pooling layer. Checked on several small
    graphs through the full forward."""
    cfg = _cfg("sage", aggregator="max")
    key = jax.random.PRNGKey(1)
    from repro.core.models import init_params
    params = init_params(key, cfg)
    for seed, n in ((0, 40), (1, 60), (2, 100)):
        pg = pad_graph(_graph(n, seed=seed), capacity=128)
        ops = build_operands(pg, cfg, lean=True)
        x = jnp.asarray(pg.features)
        exact = forward_grannite(params, cfg, x, ops, Techniques(effop=True))
        grax = forward_grannite(params, cfg, x, ops,
                                Techniques(effop=True, grax3=True))
        np.testing.assert_allclose(np.asarray(grax), np.asarray(exact),
                                   atol=1e-5)


def test_grax3_tier_logits_match_fp32_tier_through_engine():
    """End-to-end: the int8+grax SAGE-max tier differs from fp32 only by
    quantization (GrAx3 itself is exact post-ReLU), so tier logits stay
    within the INT8 envelope."""
    eng = _engine("sage", aggregator="max")
    gid = eng.attach(_graph(80), model="sage")
    eng.query(gid, tier="fp32")
    eng.query(gid, tier="int8+grax")
    eng.run()
    out = {r.tier: r.logits for r in eng.finished}
    rel = (np.linalg.norm(out["int8+grax"] - out["fp32"])
           / np.linalg.norm(out["fp32"]))
    assert rel < 0.05


# ----------------------------------------------------- calibration invariance


def test_calibration_pytree_structure_is_graph_independent():
    """The warmup contract: calibrate_tier's pytree structure must be a
    function of the model config alone, so a plan warmed on a placeholder
    calibration replays warm on the real one."""
    cfg = _cfg("sage", aggregator="max")
    from repro.core.models import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    structs = []
    for n, cap in ((30, 128), (100, 128), (200, 256)):
        pg = pad_graph(_graph(n, seed=n), capacity=cap)
        ops = build_operands(pg, cfg, lean=True)
        cal = calibrate_tier(params, cfg, jnp.asarray(pg.features), ops)
        structs.append(jax.tree_util.tree_structure(cal))
        shapes = [leaf.shape for leaf in jax.tree_util.tree_leaves(cal)]
        assert all(cap not in s for s in shapes)    # model-shaped only
    assert structs[0] == structs[1] == structs[2]


def test_per_tier_latency_metrics_reported():
    eng = _engine("gcn")
    gid = eng.attach(_graph(100), model="gcn")
    for tier in ("fp32", "int8", "int8", "fp32"):
        eng.query(gid, tier=tier)
    eng.run()
    tiers = eng.summary()["tiers"]
    assert set(tiers) == {"fp32", "int8"}
    for st in tiers.values():
        assert st["requests"] == 2
        assert st["p50_latency_ms"] > 0
        assert st["p99_latency_ms"] >= st["p50_latency_ms"]
        assert st["throughput_rps"] > 0
