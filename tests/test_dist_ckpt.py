"""Distribution rules, checkpointing (atomic/keep-k/elastic), compression."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.configs import ARCHS, reduced
from repro.dist import sharding as shd
from repro.nn import lm
from repro.nn.common import Param

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------- sharding


def _mesh11():
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_spec_divisibility_fallback():
    mesh = _mesh11()
    # with model axis size 1 everything divides; simulate a bigger axis by
    # constructing specs directly
    p = Param(jnp.zeros((9, 64)), ("heads", "embed"))
    spec = shd.spec_for_axes(p.axes, p.value.shape, mesh)
    assert isinstance(spec, jax.sharding.PartitionSpec)


def test_param_specs_cover_every_leaf():
    cfg = reduced(ARCHS["olmoe-1b-7b"])
    params = jax.eval_shape(lambda: lm.lm_init(jax.random.PRNGKey(0), cfg))
    mesh = _mesh11()
    specs = shd.param_specs(params, mesh)
    n_params = len(jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, Param)))
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    assert n_params == n_specs > 0


def test_production_mesh_rules_subprocess():
    """Full 512-device rule check runs in a subprocess (XLA_FLAGS isolation):
    every assigned arch must produce valid, divisible PartitionSpecs on both
    production meshes."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax, json
        from repro.configs import ARCHS
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_production_mesh
        from repro.launch import specs as S
        out = {}
        for multi in (False, True):
            mesh = make_production_mesh(multi_pod=multi)
            for name, cfg in ARCHS.items():
                params = S.abstract_params(cfg)
                specs = shd.param_specs(params, mesh)
                flat = jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))
                pflat = jax.tree_util.tree_leaves(
                    params, is_leaf=lambda x: hasattr(x, "axes"))
                for p, s in zip(pflat, flat):
                    for dim, entry in zip(p.value.shape, tuple(s)):
                        if entry is None: continue
                        axes = entry if isinstance(entry, tuple) else (entry,)
                        k = 1
                        for a in axes: k *= mesh.shape[a]
                        assert dim % k == 0, (name, p.value.shape, s)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                       "PYTHONPATH": f"{REPO}/src"})
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_compressed_psum_subprocess():
    """int8-compressed gradient all-reduce == exact mean within quant error
    (8 fake devices, shard_map)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.compress import compressed_psum_mean, exact_psum_mean
        try:
            shard_map = jax.shard_map
        except AttributeError:   # pre-0.5 jax: experimental namespace
            from jax.experimental.shard_map import shard_map
        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))

        def body(xs):
            g = xs[0]
            mean, resid = compressed_psum_mean(g, ("data",))
            exact = exact_psum_mean(g, ("data",))
            return mean[None], exact[None], resid[None]

        f = shard_map(body, mesh=mesh, in_specs=(P("data"),),
                      out_specs=(P("data"), P("data"), P("data")))
        mean, exact, resid = f(x)
        mean, exact = np.asarray(mean[0]), np.asarray(exact[0])
        scale = np.abs(x).max() / 127.0
        err = np.abs(mean - exact).max()
        assert err <= scale + 1e-7, (err, scale)
        # error feedback residual should reconstruct: g = represented + resid
        print("OK", err / (np.abs(exact).max() + 1e-9))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                       "PYTHONPATH": f"{REPO}/src"})
    assert "OK" in r.stdout, (r.stdout, r.stderr[-2000:])


# ---------------------------------------------------------------- ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (32, 16)),
                       "b": jnp.zeros((16,))},
            "opt": {"m": jnp.ones((32, 16)), "count": jnp.asarray(7)},
            "step": jnp.asarray(123, jnp.int32)}


def test_ckpt_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    step, restored = restore_checkpoint(str(tmp_path), tree)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_keep_k(tmp_path):
    tree = _tree()
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(str(tmp_path)) == 5


def test_ckpt_atomicity_tmp_never_visible(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    assert not any(d.startswith("tmp.") for d in os.listdir(tmp_path))


def test_ckpt_symg_packs_symmetric(tmp_path, padded_graph):
    """The GNN norm adjacency (symmetric) must be stored triangular."""
    tree = {"norm_adj": jnp.asarray(padded_graph.norm_adj)}
    save_checkpoint(str(tmp_path), 0, tree)
    with open(os.path.join(tmp_path, "step_0000000000/manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["symg"], "symmetric matrix should be SymG-packed"
    _, restored = restore_checkpoint(str(tmp_path), tree)
    np.testing.assert_allclose(np.asarray(restored["norm_adj"]),
                               padded_graph.norm_adj, atol=1e-6)


def test_ckpt_elastic_reshard(tmp_path):
    """Restore under a different sharding (elastic restart)."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 9, tree)
    mesh = _mesh11()
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        tree)
    step, restored = restore_checkpoint(str(tmp_path), tree, shardings=sh)
    assert step == 9
    w = restored["params"]["w"]
    assert w.sharding.mesh.shape == {"data": 1, "model": 1}
