"""Fused per-layer kernel suite (DESIGN.md §11).

Four layers of coverage:

* kernel-mode routing: the explicit REPRO_KERNEL_MODE override beats
  backend autodetect and REPRO_PALLAS_INTERPRET (kernels/ops.py docstring
  precedence), and an unknown value is a loud error;
* interpret-grid differentials: every fused kernel variant vs its exact
  jnp ref twin, including tile-remainder shapes (N and F not multiples of
  128 — the wrappers pad, the kernels mask, the strips strip);
* the plan-dimension contract: `forward_grannite(..., fusion="layer")`
  equals `fusion="none"` across kinds x tiers x agg backends (same tier
  math, different execution schedule);
* serving: mixed fused/unfused traffic through GraphServe under the
  deterministic async scheduler replays warm (zero recompiles) and every
  fused request's logits match the unfused forward.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import BucketLadder, Graph, pad_graph
from repro.core.models import (FUSION_MODES, GNNConfig, build_operands,
                               build_plan, calibrate_tier,
                               derive_tier_operands, forward_grannite,
                               init_params)
from repro.core.sparsity import to_block_sparse
from repro.kernels import ops as kops


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------- mode routing


class TestKernelModeRouting:
    def test_explicit_override_beats_autodetect(self, monkeypatch):
        # even with the interpret CI flag set, the explicit mode wins
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        for mode in ("pallas", "interpret", "ref"):
            monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
            assert kops._mode() == mode

    def test_unknown_override_raises(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_MODE", "magic")
        with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
            kops._mode()

    def test_autodetect_fallback_order(self, monkeypatch):
        monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        if jax.default_backend() != "tpu":
            assert kops._mode() == "interpret"
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
        expect = "pallas" if jax.default_backend() == "tpu" else "ref"
        assert kops._mode() == expect


# ------------------------------------------- kernel vs ref twins


def _norm_adj(rng, n, density=0.1):
    adj = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(adj, 1.0)
    return jnp.asarray(adj / np.maximum(adj.sum(1, keepdims=True), 1.0))


def _both_modes(monkeypatch, fn):
    """Run fn() under forced ref then forced interpret kernel routing."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
    want = fn()
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    got = fn()
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    return np.asarray(want), np.asarray(got)


SHAPES = [(64, 32, 48), (130, 70, 90)]     # second: tile remainders


@pytest.mark.parametrize("n,fin,o", SHAPES)
@pytest.mark.parametrize("act", ["none", "relu", "elu"])
def test_fused_gcn_dense_kernel(monkeypatch, n, fin, o, act):
    rng = _rng(1)
    na = _norm_adj(rng, n)
    x = jnp.asarray(rng.standard_normal((n, fin)).astype(np.float32))
    w = jnp.asarray(0.2 * rng.standard_normal((fin, o)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((o,)).astype(np.float32))
    want, got = _both_modes(monkeypatch, lambda: kops.fused_gcn_layer(
        x, w, b, norm_adj=na, activation=act))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,fin,o", SHAPES)
def test_fused_gcn_int8_kernel(monkeypatch, n, fin, o):
    rng = _rng(2)
    x = jnp.asarray(rng.standard_normal((n, fin)).astype(np.float32))
    quant = (jnp.asarray(rng.integers(-127, 128, (fin, o)).astype(np.int8)),
             jnp.asarray((0.01 + 0.02 * rng.random(o)).astype(np.float32)),
             jnp.float32(0.05), jnp.float32(0.1),
             jnp.asarray(rng.integers(-127, 128, (n, n)).astype(np.int8)),
             jnp.asarray((0.005 + 0.01 * rng.random((n, 1))
                          ).astype(np.float32)))
    b = jnp.asarray(rng.standard_normal((o,)).astype(np.float32))
    want, got = _both_modes(monkeypatch, lambda: kops.fused_gcn_layer(
        x, None, b, quant=quant, activation="relu"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_gcn_grasp_kernel(monkeypatch):
    n, fin, o = 256, 70, 90
    rng = _rng(3)
    adj = np.zeros((n, n), np.float32)
    idx = np.arange(n)
    for d in (0, 1, 2):                      # banded: some blocks all-zero
        adj[idx, (idx + d) % n] = 1.0
        adj[(idx + d) % n, idx] = 1.0
    na = jnp.asarray(adj / adj.sum(1, keepdims=True))
    bsp = to_block_sparse(np.asarray(na))
    x = jnp.asarray(rng.standard_normal((n, fin)).astype(np.float32))
    w = jnp.asarray(0.2 * rng.standard_normal((fin, o)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((o,)).astype(np.float32))
    want, got = _both_modes(monkeypatch, lambda: kops.fused_gcn_layer(
        x, w, b, block_sparse=bsp, activation="relu"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # the block-skip form must also equal the dense fused layer
    monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
    dense = kops.fused_gcn_layer(x, w, b, norm_adj=na, activation="relu")
    np.testing.assert_allclose(got, np.asarray(dense), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,fin,heads,f", [(64, 32, 2, 16), (130, 45, 3, 20)])
def test_fused_gat_full_kernel(monkeypatch, n, fin, heads, f):
    rng = _rng(4)
    adj = (rng.random((n, n)) < 0.15)
    np.fill_diagonal(adj, True)
    bias = jnp.asarray(np.where(adj, 0.0, -1e9).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, fin)).astype(np.float32))
    w = jnp.asarray(0.2 * rng.standard_normal((fin, heads, f)
                                              ).astype(np.float32))
    a_src = jnp.asarray(0.3 * rng.standard_normal((heads, f)
                                                  ).astype(np.float32))
    a_dst = jnp.asarray(0.3 * rng.standard_normal((heads, f)
                                                  ).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((heads, f)).astype(np.float32))
    want, got = _both_modes(monkeypatch, lambda: kops.fused_gat_layer(
        x, w, a_src, a_dst, bias, b, activation="elu"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_gat_precombined_kernel(monkeypatch):
    n, heads, f = 130, 3, 20
    rng = _rng(5)
    adj = (rng.random((n, n)) < 0.15)
    np.fill_diagonal(adj, True)
    bias = jnp.asarray(np.where(adj, 0.0, -1e9).astype(np.float32))
    h = jnp.asarray(rng.standard_normal((n, heads, f)).astype(np.float32))
    a_src = jnp.asarray(0.3 * rng.standard_normal((heads, f)
                                                  ).astype(np.float32))
    a_dst = jnp.asarray(0.3 * rng.standard_normal((heads, f)
                                                  ).astype(np.float32))
    alpha_src = jnp.einsum("nhf,hf->nh", h, a_src)
    alpha_dst = jnp.einsum("nhf,hf->nh", h, a_dst)
    b = jnp.asarray(rng.standard_normal((heads, f)).astype(np.float32))
    want, got = _both_modes(monkeypatch, lambda: kops.fused_gat_layer(
        None, None, a_src, a_dst, bias, b, activation="none",
        precombined=(h, alpha_dst, alpha_src)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("aggregator", ["mean", "max"])
@pytest.mark.parametrize("n,fin,o", SHAPES)
def test_fused_sage_kernel(monkeypatch, aggregator, n, fin, o):
    rng = _rng(6)
    mask = (rng.random((n, n)) < 0.2).astype(np.float32)
    np.fill_diagonal(mask, 1.0)
    x = jnp.asarray(rng.standard_normal((n, fin)).astype(np.float32))
    ws = jnp.asarray(0.2 * rng.standard_normal((fin, o)).astype(np.float32))
    wn = jnp.asarray(0.2 * rng.standard_normal((fin, o)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((o,)).astype(np.float32))
    if aggregator == "mean":
        mm = jnp.asarray(mask / np.maximum(mask.sum(1, keepdims=True), 1.0))
        fn = lambda: kops.fused_sage_layer(
            x, ws, wn, b, mean_mask=mm, activation="relu")
    else:
        pooled = jnp.asarray(np.abs(rng.standard_normal((n, fin))
                                    ).astype(np.float32))
        fn = lambda: kops.fused_sage_layer(
            x, ws, wn, b, sample_mask=jnp.asarray(mask), pooled=pooled,
            activation="relu")
    want, got = _both_modes(monkeypatch, fn)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------- fusion as a plan dimension


def _setup(kind, *, n=100, cap=128, fin=12, hidden=16, classes=5, heads=2,
           grasp=False, seed=7):
    rng = _rng(seed)
    if grasp:
        src = np.repeat(np.arange(n, dtype=np.int32), 3)
        dst = (src + np.tile(np.arange(1, 4, dtype=np.int32), n)) % n
        ei = np.concatenate([np.stack([src, dst]),
                             np.stack([dst, src])], axis=1)
    else:
        m = n * 4
        ei = rng.integers(0, n, size=(2, m)).astype(np.int32)
        ei = np.concatenate([ei, ei[::-1]], axis=1)
    feats = rng.standard_normal((n, fin)).astype(np.float32)
    pg = pad_graph(Graph(edge_index=ei, num_nodes=n, features=feats),
                   capacity=cap)
    cfg = GNNConfig(kind=kind, in_feats=fin, hidden=hidden,
                    num_classes=classes, heads=heads,
                    aggregator="max" if kind == "sage" else "mean")
    params = init_params(jax.random.PRNGKey(seed), cfg)
    ops_ = build_operands(pg, cfg, grasp=grasp)
    return pg, cfg, params, ops_


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
@pytest.mark.parametrize("tier", ["fp32", "int8", "int8+grax"])
def test_forward_fused_matches_unfused(kind, tier):
    from repro.runtime.gnn_server import tier_techniques
    t = tier_techniques(kind)[tier]
    pg, cfg, params, ops_ = _setup(kind)
    x = jnp.asarray(pg.features)
    quant = calibrate_tier(params, cfg, x, ops_) if t.quantgr else None
    tops = (derive_tier_operands(ops_.norm_adj)
            if kind == "gcn" and t.quantgr else None)
    want = forward_grannite(params, cfg, x, ops_, t, quant=quant,
                            tier_ops=tops, fusion="none")
    got = forward_grannite(params, cfg, x, ops_, t, quant=quant,
                           tier_ops=tops, fusion="layer")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_forward_fused_matches_unfused_grasp():
    from repro.runtime.gnn_server import tier_techniques
    t = dataclasses.replace(tier_techniques("gcn")["fp32"], grasp=True)
    pg, cfg, params, ops_ = _setup("gcn", n=120, cap=256, grasp=True)
    x = jnp.asarray(pg.features)
    want = forward_grannite(params, cfg, x, ops_, t, fusion="none")
    got = forward_grannite(params, cfg, x, ops_, t, fusion="layer")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_unknown_fusion_mode_rejected():
    from repro.core.layers import Techniques
    pg, cfg, params, ops_ = _setup("gcn")
    x = jnp.asarray(pg.features)
    t = Techniques(stagr=True, graphsplit=True)
    with pytest.raises(ValueError, match="fusion"):
        forward_grannite(params, cfg, x, ops_, t, fusion="bogus")
    with pytest.raises(ValueError, match="fusion"):
        build_plan(cfg, pg.capacity, t, fusion="bogus")


def test_plan_key_carries_fusion():
    pg, cfg, params, ops_ = _setup("gcn")
    from repro.core.layers import Techniques
    t = Techniques(stagr=True, graphsplit=True)
    p_none = build_plan(cfg, pg.capacity, t, fusion="none")
    p_layer = build_plan(cfg, pg.capacity, t, fusion="layer")
    assert p_none.key != p_layer.key
    # fusion is key[5]; key[6] is the §12 shard count (0 = unsharded)
    assert p_none.key[:5] == p_layer.key[:5]
    assert p_none.key[6] == p_layer.key[6] == 0
    assert set(FUSION_MODES) == {p_none.key[5], p_layer.key[5]}


# --------------------------------------------------- serving level


def _traffic_graph(n, seed, fin=12, classes=4):
    rng = _rng(seed)
    m = max(1, n * 3)
    ei = rng.integers(0, n, size=(2, m)).astype(np.int32)
    ei = np.concatenate([ei, ei[::-1]], axis=1)
    feats = rng.standard_normal((n, fin)).astype(np.float32)
    labels = rng.integers(0, classes, size=(n,)).astype(np.int32)
    return Graph(edge_index=ei, num_nodes=n, features=feats, labels=labels)


def test_serving_mixed_fusion_zero_recompile_async():
    """Mixed fused/unfused/mixed-tier traffic through the deterministic
    async scheduler: zero recompiles after warmup, fused logits equal the
    unfused forward, and fused/unfused requests never share a batch."""
    from repro.runtime.gnn_server import (GraphServe, GraphServeConfig,
                                          STANDARD_TIERS)
    from repro.runtime.scheduler import PipelineConfig

    eng = GraphServe(GraphServeConfig(ladder=BucketLadder(buckets=(128,)),
                                      batch_slots=3, return_logits=True),
                     seed=0)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=12, hidden=8,
                                        num_classes=4),
                       tiers=STANDARD_TIERS, agg_backend="auto")
    eng.warmup()
    eng.calibrate("gcn", _traffic_graph(64, seed=999))

    traffic = [(40, "fp32", "layer"), (60, "fp32", "none"),
               (80, "int8", "layer"), (50, "fp32", "layer"),
               (70, "int8", "none"), (90, None, None),
               (30, "int8+grax", "layer")]
    with eng.scheduler(PipelineConfig(deterministic=True)) as sched:
        for i, (n, tier, fusion) in enumerate(traffic):
            sched.submit(_traffic_graph(n, seed=i), model="gcn", tier=tier,
                         fusion=fusion)
        done = sched.drain()
    eng.assert_warm()                       # THE zero-recompile contract

    assert len(done) == len(traffic)
    assert {r.fusion for r in done} == {"none", "layer"}
    e = eng.models["gcn"]
    for r in done:
        want = forward_grannite(e.params, e.cfg,
                                jnp.asarray(r.pg.features), r.ops,
                                e.tiers[r.tier],
                                quant=e.calibrations.get(r.tier),
                                tier_ops=r.tier_ops, fusion="none")
        np.testing.assert_allclose(
            r.logits, np.asarray(want)[:r.pg.num_nodes],
            rtol=2e-4, atol=2e-4)

    # a dispatch never mixes fusion modes: replay the composition check
    # through the engine's own batch-key fold
    from repro.runtime.gnn_server import pending_stats
    stats = pending_stats(done)
    # 6-element batch key: (model, bucket, tier, backend, fusion, shards)
    assert all(len(k) == 6 and k[5] == 0 for k in stats)


def test_register_model_fusion_default_and_validation():
    from repro.runtime.gnn_server import GraphServe, GraphServeConfig

    eng = GraphServe(GraphServeConfig(ladder=BucketLadder(buckets=(128,)),
                                      batch_slots=2, return_logits=True),
                     seed=0)
    cfg = GNNConfig(kind="gcn", in_feats=12, hidden=8, num_classes=4)
    with pytest.raises(ValueError, match="fusion"):
        eng.register_model("bad", cfg, fusion="bogus")
    eng.register_model("gcn", cfg, fusion="layer")
    eng.warmup()
    # the model default routes requests to fused plans without a per-call
    # override; an explicit "none" still serves unfused
    eng.submit(_traffic_graph(40, seed=0), model="gcn")
    eng.submit(_traffic_graph(40, seed=1), model="gcn", fusion="none")
    done = eng.run()
    eng.assert_warm()
    assert [r.fusion for r in sorted(done, key=lambda r: r.uid)] == \
        ["layer", "none"]
    with pytest.raises(ValueError, match="fusion"):
        eng.submit(_traffic_graph(10, seed=2), model="gcn", fusion="bogus")
