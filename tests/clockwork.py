"""FakeClock: deterministic virtual time for the §14 SLO serving tests.

Injected into `GraphServe(clock=...)`, it replaces every timestamp,
deadline comparison, and latency sample in the serving path with virtual
time that only moves when a test says so:

  * `advance(seconds)` — move time forward manually (e.g. "the request
    sat in the queue for 40 ms").
  * scripted per-batch latencies — `script(key_match, seconds)` registers
    what a dispatch under a batch key "costs"; the engine calls
    `on_batch(key)` between its dispatch timestamps, and the fake clock
    advances by the scripted figure, so measured batch latency becomes a
    test INPUT. `default_batch_s` covers unscripted keys.

No real sleeping happens anywhere: tests drive the engine's sync path or
the scheduler's deterministic inline mode, and assertions compare virtual
timestamps. That is the zero-`time.sleep` contract ISSUE 9 pins.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.runtime.clock import Clock  # noqa: E402


class FakeClock(Clock):
    def __init__(self, start: float = 0.0, default_batch_s: float = 0.0):
        self._now = float(start)
        self.default_batch_s = float(default_batch_s)
        # (predicate over BatchKey, seconds) — first match wins
        self._scripts: List[Tuple[Callable, float]] = []
        self.batch_log: List[Tuple[tuple, float]] = []   # (key, cost) seen

    # -------------------------------------------------------------- Clock
    def now(self) -> float:
        return self._now

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def on_batch(self, key, span=None) -> None:
        cost = self.default_batch_s
        for pred, seconds in self._scripts:
            if pred(key):
                cost = seconds
                break
        self.batch_log.append((tuple(key), cost))
        self._now += cost

    # ----------------------------------------------------------- controls
    def advance(self, seconds: float) -> None:
        assert seconds >= 0, "virtual time cannot rewind"
        self._now += float(seconds)

    def script(self, match, seconds: float) -> None:
        """Register a per-batch latency. `match` is a predicate over the
        BatchKey tuple, or a dict of {index: value} the key must agree
        with (e.g. {2: "int8"} scripts every int8 dispatch)."""
        if isinstance(match, dict):
            items = tuple(match.items())

            def pred(key, _items=items):
                return all(key[i] == v for i, v in _items)
        else:
            pred = match
        # newest script wins: tests re-script a key mid-run to model a
        # path getting slower/faster
        self._scripts.insert(0, (pred, float(seconds)))
