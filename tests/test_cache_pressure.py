"""Cache-churn soak + §13 bounded-hierarchy invariants (tier-1, no deps).

The DESIGN.md §13 contract under sustained multi-tenant churn: with a
`device_cache_budget_bytes` sized for a handful of graphs, hundreds of
attach/query/detach cycles must (a) never push `cache_resident_bytes` past
the budget at ANY step, (b) keep the per-entry byte ledger consistent
(sum of entry costs == resident bytes), (c) conserve eviction outcomes
(evictions == spilled + dropped), (d) answer every evicted graph
BIT-IDENTICALLY after re-materialization from its host-RAM spill form, and
(e) trace nothing after warmup — eviction, spill, re-materialization, and
GrAd delta patching all replay warm blobs. Plus the update-before-first-
query counter regression and the sharded delta differential.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.graph import BucketLadder, Graph, edge_index_from_adjacency
from repro.core.models import (GNNConfig, OPERAND_FIELDS,
                               build_sharded_operands)
from repro.data.graphs import planetoid_like
from repro.runtime.cache import (CacheAdmissionError, DeviceCacheManager,
                                 estimate_dense_entry_bytes)
from repro.runtime.gnn_server import GraphServe, GraphServeConfig

IN_FEATS, CLASSES = 12, 4
BUCKET = 128
# one gcn fp32 operand entry at bucket 128 (1 field + 4 holes)
ENTRY = estimate_dense_entry_bytes(1, BUCKET)


def _graph(n, seed):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=1)


def _engine(budget, *, spill=True, admission="evict", tiers=None,
            shard_counts=()):
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(BUCKET,)),
                          batch_slots=2, return_logits=True,
                          device_cache_budget_bytes=budget,
                          spill_to_host=spill, admission=admission,
                          shard_counts=shard_counts)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        hidden=8, num_classes=CLASSES),
                       tiers=tiers)
    eng.warmup()
    return eng


def _assert_invariants(eng):
    cm = eng._cache
    with eng._lock:
        sizes = cm.entry_sizes()
        resident = cm.resident_bytes
        ev, sp, dr = cm.evictions, cm.spilled, cm.dropped
    assert sum(sizes.values()) == resident
    if eng.sc.device_cache_budget_bytes is not None:
        assert resident <= eng.sc.device_cache_budget_bytes
    assert ev == sp + dr


# ------------------------------------------------------------- churn soak


def test_churn_soak_respects_budget_at_every_step():
    """200 attach/query/detach cycles under a budget fitting ~8 graphs:
    the §13 invariants hold after EVERY step, and nothing traces."""
    budget = 8 * ENTRY + 8 * ENTRY // 4          # ~8 primaries + derived room
    eng = _engine(budget, tiers=("fp32", "int8"))
    eng.calibrate("gcn", _graph(64, seed=999))
    blobs = eng.compiled_blobs
    live = []
    for i in range(200):
        gid = eng.attach(_graph(20 + (i % 40), seed=i), model="gcn")
        live.append(gid)
        _assert_invariants(eng)
        eng.query(gid, tier="int8" if i % 3 else "fp32")
        eng.run()
        _assert_invariants(eng)
        if i % 5 == 4:                           # churn: detach the oldest
            eng.detach(live.pop(0))
            _assert_invariants(eng)
    cm = eng._cache
    assert cm.evictions > 0                      # the soak exercised pressure
    assert eng.compiled_blobs == blobs
    eng.assert_warm()
    for gid in live:
        eng.detach(gid)
    _assert_invariants(eng)


def test_evicted_graph_answers_bit_identically_via_spill():
    """Budget fits 2 graphs; 5 attach+query. Re-querying the evicted ones
    must fault into the host-RAM spill store, re-materialize, and return
    logits BIT-identical to the first (pre-eviction) answer — warm."""
    eng = _engine(2 * ENTRY + ENTRY // 2)
    gids, first = [], {}
    for i in range(5):
        gid = eng.attach(_graph(30 + i, seed=100 + i), model="gcn")
        gids.append(gid)
        eng.query(gid)
        first[gid] = np.asarray(eng.run()[-1].logits)
    cm = eng._cache
    assert cm.evictions >= 3 and cm.spilled >= 3
    for gid in gids:
        eng.query(gid)
        np.testing.assert_array_equal(np.asarray(eng.run()[-1].logits),
                                      first[gid])
    assert eng.metrics["cache_spill_hits"] >= 3
    # a spill fault is NOT a structure miss: one miss per (graph, version)
    assert eng.metrics["operand_cache_misses"] == len(gids)
    eng.assert_warm()
    _assert_invariants(eng)


def test_spill_disabled_drops_and_rebuilds():
    """spill_to_host=False: every capacity eviction drops (conservation
    pins evictions == dropped), the spill store stays empty, and the next
    query is an honest full-rebuild miss."""
    eng = _engine(ENTRY + ENTRY // 2, spill=False)
    g1 = eng.attach(_graph(30, seed=1), model="gcn")
    g2 = eng.attach(_graph(31, seed=2), model="gcn")
    eng.query(g1)
    lg1 = np.asarray(eng.run()[-1].logits)
    eng.query(g2)
    eng.run()
    cm = eng._cache
    assert cm.evictions == cm.dropped >= 1 and cm.spilled == 0
    assert cm.spill_entries == 0
    misses = eng.metrics["operand_cache_misses"]
    eng.query(g1)
    np.testing.assert_array_equal(np.asarray(eng.run()[-1].logits), lg1)
    assert eng.metrics["operand_cache_misses"] == misses + 1
    assert eng.metrics["cache_spill_hits"] == 0
    eng.assert_warm()


# -------------------------------------------------------------- admission


def test_admission_rejects_entry_that_can_never_fit():
    """A graph whose projected primary entry exceeds the WHOLE budget is
    rejected at attach() under either policy — caching it is impossible."""
    for policy in ("evict", "reject"):
        eng = _engine(ENTRY // 2, admission=policy)
        with pytest.raises(CacheAdmissionError):
            eng.attach(_graph(30, seed=1), model="gcn")
        assert eng.metrics["cache_admission_rejects"] == 1
        assert eng.graphs == {}


def test_admission_reject_policy_refuses_overflow_evict_admits():
    """Same pressure, two policies: "evict" admits and lets insert-time
    eviction make room; "reject" refuses an attach that would overflow the
    CURRENT residency."""
    evict = _engine(ENTRY + ENTRY // 2, admission="evict")
    a = evict.attach(_graph(30, seed=1), model="gcn")
    evict.query(a)
    evict.run()
    b = evict.attach(_graph(31, seed=2), model="gcn")   # admitted
    evict.query(b)
    evict.run()
    assert evict._cache.evictions >= 1

    reject = _engine(ENTRY + ENTRY // 2, admission="reject")
    a = reject.attach(_graph(30, seed=1), model="gcn")
    reject.query(a)
    reject.run()
    with pytest.raises(CacheAdmissionError):
        reject.attach(_graph(31, seed=2), model="gcn")
    assert reject.metrics["cache_admission_rejects"] == 1
    reject.detach(a)                                    # frees residency
    c = reject.attach(_graph(32, seed=3), model="gcn")
    reject.query(c)
    reject.run()
    assert reject._cache.evictions == 0


def test_unbudgeted_engine_never_evicts():
    """No budget configured: the manager is pure bookkeeping — residency
    grows, nothing evicts, attach never rejects (the pre-§13 behavior)."""
    eng = _engine(None)
    for i in range(6):
        gid = eng.attach(_graph(25 + i, seed=i), model="gcn")
        eng.query(gid)
        eng.run()
    cm = eng._cache
    assert cm.evictions == 0 and cm.resident_bytes >= 6 * ENTRY
    _assert_invariants(eng)


# ----------------------------------------- update-before-first-query (fix)


def test_update_before_first_query_pins_counters():
    """Regression: update() on an attached-but-never-queried graph retires
    cache keys that were never populated. That must be a counter no-op —
    no eviction/spill/drop movement, no phantom hit — and the first query
    after the update is exactly ONE miss, the second exactly one hit."""
    eng = _engine(8 * ENTRY)
    gid = eng.attach(_graph(30, seed=7), model="gcn")
    g2 = _graph(34, seed=8)
    eng.update(gid, g2.edge_index, g2.num_nodes, g2.features)
    cm = eng._cache
    assert (cm.evictions, cm.spilled, cm.dropped) == (0, 0, 0)
    assert eng.metrics["operand_cache_misses"] == 0
    assert eng.metrics["operand_cache_hits"] == 0
    eng.query(gid)
    eng.run()
    assert eng.metrics["operand_cache_misses"] == 1
    assert eng.metrics["operand_cache_hits"] == 0
    eng.query(gid)
    eng.run()
    assert eng.metrics["operand_cache_misses"] == 1
    assert eng.metrics["operand_cache_hits"] == 1
    assert (cm.evictions, cm.spilled, cm.dropped) == (0, 0, 0)
    # update_delta with a RESIDENT entry patches it under the new key: the
    # next query is a HIT — no rebuild, no phantom miss, no counter drift
    adj = eng.graphs[gid][1].adj
    iu, ju = np.nonzero(np.triu(adj[:34, :34], 1))
    assert eng.update_delta(gid, remove_edges=[(int(iu[0]), int(ju[0]))])
    assert (cm.evictions, cm.spilled, cm.dropped) == (0, 0, 0)
    eng.query(gid)
    eng.run()
    assert eng.metrics["operand_cache_misses"] == 1
    assert eng.metrics["operand_cache_hits"] == 2
    assert eng.metrics["delta_updates"] == 1
    # and update_delta BEFORE any query of the new version is the no-op
    # counter case: nothing resident to patch, nothing counted as evicted
    g3 = _graph(36, seed=9)
    eng.update(gid, g3.edge_index, g3.num_nodes, g3.features)
    iu, ju = np.nonzero(np.triu(eng.graphs[gid][1].adj[:36, :36], 1))
    assert eng.update_delta(gid, remove_edges=[(int(iu[0]), int(ju[0]))])
    assert (cm.evictions, cm.spilled, cm.dropped) == (0, 0, 0)
    eng.query(gid)
    eng.run()
    assert eng.metrics["operand_cache_misses"] == 2
    eng.assert_warm()


# --------------------------------------------------------- sharded deltas


def test_sharded_delta_patches_slices_and_halo():
    """§13 on the §12 path: update_delta over an auto-sharded graph keeps
    the partition, patches the cached slice tuple device-side, and the
    patched blocks are BIT-identical to a fresh `build_sharded_operands`
    over the same partition — plus the halo sets track the new edges."""
    eng = _engine(None, shard_counts=(2,))
    g = _graph(200, seed=3)                      # > bucket 128: auto-shards
    gid = eng.attach(g, model="gcn")
    eng.query(gid)
    eng.run()
    part0 = eng._sharded[gid][0]
    pg = eng.graphs[gid][1]
    adj = pg.adj
    iu, ju = np.nonzero(np.triu(adj[:200, :200], 1))
    add = [(0, 150)] if adj[0, 150] == 0 else [(1, 151)]
    rem = [(int(iu[0]), int(ju[0]))]
    assert eng.update_delta(gid, add_edges=add, remove_edges=rem)
    assert eng.metrics["delta_updates"] == 1
    part1, g1 = eng._sharded[gid]
    assert np.array_equal(part1.perm, part0.perm)     # partition KEPT
    ver = eng._graph_version[gid]
    patched = eng._shard_cache[(gid, ver)]
    cfg = eng.models["gcn"].cfg
    ref = build_sharded_operands(g1, part1, cfg)
    for s, r in zip(patched, ref):
        for f in OPERAND_FIELDS["gcn"]:
            np.testing.assert_array_equal(np.asarray(getattr(s.ops, f)),
                                          np.asarray(getattr(r.ops, f)))
    # halo observability patched host-side: matches a from-scratch halo
    # computation over the SAME assignment and the NEW edges
    new_ei = edge_index_from_adjacency(eng.graphs[gid][1].adj, 200)
    assert sorted(map(tuple, new_ei.T.tolist())) == sorted(
        map(tuple, g1.edge_index.T.tolist()))
    eng.query(gid)
    eng.run()
    eng.assert_warm()


# -------------------------------------------------- manager unit behavior


def test_manager_rejects_oversized_entry_without_breaking_budget():
    cm = DeviceCacheManager(budget_bytes=100)
    assert not cm.put("operand", (0, 0), "big", nbytes=101)
    assert cm.resident_bytes == 0
    assert cm.put("operand", (0, 0), "ok", nbytes=60)
    assert cm.put("operand", (1, 0), "ok2", nbytes=60)  # evicts (0, 0)
    assert cm.resident_bytes == 60
    assert cm.evictions == 1 and cm.dropped == 1        # no spill_fn
    assert cm.get("operand", (0, 0)) is None
    assert cm.get("operand", (1, 0)) == "ok2"


def test_manager_derived_evicts_before_primary_and_lru_groups():
    """Eviction order: least-recently-used GRAPH first; within the victim
    graph, derived forms before the primary they hang off."""
    cm = DeviceCacheManager(budget_bytes=100)
    cm.put("operand", (0, 0), "p0", nbytes=40)
    cm.put("tier", (0, 0), "d0", nbytes=10)
    cm.put("operand", (1, 0), "p1", nbytes=40)
    cm.put("operand", (2, 0), "p2", nbytes=15)   # needs 5 bytes freed
    # graph 0 is the coldest GROUP; its DERIVED form is the first victim —
    # the 10-byte tier entry covers the need, the primary stays resident
    assert cm.get("tier", (0, 0)) is None
    assert cm.get("operand", (0, 0)) == "p0"
    assert cm.get("operand", (1, 0)) == "p1"
    # group-LRU across graphs: graph 0 was just touched, so the next
    # squeeze takes graph 1's primary even though it was inserted later
    cm.get("operand", (0, 0))
    cm.put("operand", (3, 0), "p3", nbytes=40)
    assert cm.get("operand", (1, 0)) is None
    assert cm.get("operand", (0, 0)) == "p0"


def test_manager_invalidate_is_not_an_eviction():
    cm = DeviceCacheManager(budget_bytes=100)
    cm.put("operand", (0, 0), "p", nbytes=40,
           spill_fn=lambda: "packed")
    cm.put("tier", (0, 0), "d", nbytes=10)
    assert cm.invalidate((0, 0)) == 2
    assert cm.resident_bytes == 0
    assert (cm.evictions, cm.spilled, cm.dropped) == (0, 0, 0)
    assert cm.invalidate((0, 0)) == 0            # idempotent no-op


def test_manager_spill_roundtrip_and_conservation():
    cm = DeviceCacheManager(budget_bytes=50)
    cm.put("operand", (0, 0), "p0", nbytes=40, spill_fn=lambda: "packed0")
    cm.put("operand", (1, 0), "p1", nbytes=40)   # evicts+spills (0, 0)
    assert cm.spilled == 1 and cm.spill_entries == 1
    assert cm.spill_get("operand", (0, 0)) == "packed0"
    assert cm.spill_hits == 1
    # non-destructive: a re-insert + re-eviction reuses the stored form
    cm.put("operand", (0, 0), "p0", nbytes=40, spill_fn=lambda: "packed0")
    cm.put("operand", (1, 0), "p1", nbytes=40)
    assert cm.evictions == cm.spilled + cm.dropped
    assert cm.spill_get("operand", (0, 0)) == "packed0"


def test_manager_budget_validation():
    with pytest.raises(ValueError):
        DeviceCacheManager(budget_bytes=0)
    with pytest.raises(ValueError):
        DeviceCacheManager(budget_bytes=-5)
