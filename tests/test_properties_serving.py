"""Property-based differential serving suite (DESIGN.md §9).

Hypothesis-driven randomized properties over the whole serving stack:
random graphs × kinds (GCN/GAT/SAGE) × quality tiers served through the
deterministic pipeline scheduler must equal the sequential single-request
forward; the CacheG/SymG pack→unpack transfer forms must round-trip
losslessly; NodePad's admission rule must be monotone. Skipped without
hypothesis (tier-1 stays dependency-light); CI installs requirements-dev
so these EXECUTE there, and the scheduled nightly job deepens
`max_examples` via the `nightly` profile registered in conftest.py. Tests
here deliberately carry no per-test `max_examples` so the active profile
controls depth; determinism comes from hypothesis' own seeding plus the
engine's deterministic scheduler mode.

The seeded SymG round-trip sweep formerly in test_gnn_serving.py was
promoted into `test_symg_roundtrip_lossless` here.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core.graph import (BucketLadder, node_bucket, pad_graph,  # noqa: E402
                              required_capacity, symg_pack, symg_unpack)
from repro.core.models import (GNNConfig, _unpack_adjacency,  # noqa: E402
                               compact_operands, forward_grannite)
from repro.data.graphs import planetoid_like  # noqa: E402
from repro.runtime.gnn_server import (STANDARD_TIERS, GraphServe,  # noqa: E402
                                      GraphServeConfig)
from repro.runtime.scheduler import PipelineConfig  # noqa: E402

IN_FEATS, CLASSES = 12, 4
BUCKETS = (128, 256)
KINDS = ("gcn", "gat", "sage")


def _graph(n, seed):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=1)


# Warm engines are expensive (one compile sweep per kind) and hypothesis
# runs many examples: build each kind's engine once at module scope and let
# every example serve on it — examples only ever REPLAY warm plans, which
# assert_warm re-checks at the end of each one.
_ENGINES = {}


def _engine(kind):
    if kind not in _ENGINES:
        sc = GraphServeConfig(ladder=BucketLadder(buckets=BUCKETS),
                              batch_slots=3, return_logits=True)
        eng = GraphServe(sc, seed=0)
        eng.register_model(kind, GNNConfig(
            kind=kind, in_feats=IN_FEATS, hidden=8, num_classes=CLASSES,
            heads=2, aggregator="max" if kind == "sage" else "mean"),
            tiers=STANDARD_TIERS)
        eng.warmup()
        eng.calibrate(kind, _graph(64, seed=999))   # quant tiers live
        _ENGINES[kind] = eng
    return _ENGINES[kind]


# ------------------------------------------- differential: async == single


@st.composite
def traffic(draw):
    kind = draw(st.sampled_from(KINDS))
    k = draw(st.integers(1, 5))
    reqs = [(draw(st.integers(10, 200)),             # num_nodes
             draw(st.integers(0, 2 ** 16)),          # graph seed
             draw(st.sampled_from((None,) + STANDARD_TIERS)))
            for _ in range(k)]
    return kind, reqs


@given(traffic())
def test_async_batched_logits_equal_sequential(case):
    """The tentpole differential: ANY mix of graph sizes and tiers served
    batched through the deterministic pipeline scheduler equals the
    sequential single-request forward, and replays entirely warm."""
    kind, reqs = case
    eng = _engine(kind)
    with eng.scheduler(PipelineConfig(deterministic=True)) as sched:
        for n, seed, tier in reqs:
            sched.submit(_graph(n, seed), model=kind, tier=tier)
        out = sched.drain()
    assert len(out) == len(reqs) and all(r.done for r in out)
    e = eng.models[kind]
    for r in out:
        ref = forward_grannite(e.params, e.cfg, jnp.asarray(r.pg.features),
                               r.ops, e.tiers[r.tier],
                               quant=e.calibrations.get(r.tier),
                               tier_ops=r.tier_ops)
        np.testing.assert_allclose(r.logits,
                                   np.asarray(ref)[: r.pg.num_nodes],
                                   atol=2e-5)
        np.testing.assert_array_equal(
            r.preds, np.asarray(ref)[: r.pg.num_nodes].argmax(-1))
    eng.assert_warm()


# --------------------------------------------------- pack/unpack round-trips


@given(st.integers(2, 60), st.integers(0, 2 ** 16))
def test_symg_roundtrip_lossless(n, seed):
    """SymG pack/unpack is lossless and stores exactly the n(n+1)/2 upper
    triangle (promoted from the seeded sweep in test_gnn_serving.py)."""
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)).astype(np.float32)
    sym = (m + m.T) / 2
    packed, nn = symg_pack(sym)
    assert packed.size == n * (n + 1) // 2
    np.testing.assert_allclose(symg_unpack(packed, nn), sym, atol=1e-6)


@given(st.integers(10, 150), st.integers(0, 2 ** 16))
def test_compact_transfer_bits_roundtrip(n, seed):
    """CacheG's bit-packed transfer form reproduces the exact 0/1 adjacency
    through the device-side unpack, padding included."""
    g = _graph(n, seed)
    pg = pad_graph(g, capacity=node_bucket(n))
    co = compact_operands(pg, GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        num_classes=CLASSES))
    np.testing.assert_array_equal(np.asarray(_unpack_adjacency(co)), pg.adj)


# -------------------------------------------------- NodePad admission rule


@given(st.integers(1, 4000), st.integers(0, 4000),
       st.floats(0.0, 0.5, allow_nan=False), st.floats(0.0, 0.5,
                                                       allow_nan=False))
def test_required_capacity_monotone(n, dn, s1, s2):
    """`required_capacity` is monotone in BOTH arguments (more nodes or more
    slack can never need less room), always admits the graph itself, and
    `node_bucket` rounds it to a tile multiple without undershooting."""
    lo_s, hi_s = sorted((s1, s2))
    assert required_capacity(n, lo_s) >= n
    assert required_capacity(n + dn, lo_s) >= required_capacity(n, lo_s)
    assert required_capacity(n, hi_s) >= required_capacity(n, lo_s)
    b = node_bucket(n, slack=lo_s)
    assert b % 128 == 0 and b >= required_capacity(n, lo_s)


@given(st.integers(1, 384), st.integers(1, 384))
def test_ladder_admission_monotone(a, b):
    """A bigger graph never lands in a smaller rung, and every rung covers
    the slack-adjusted requirement."""
    lad = BucketLadder(buckets=(128, 256, 384))
    lo, hi = min(a, b), max(a, b)
    assert lad.bucket_for(lo) <= lad.bucket_for(hi)
    assert lad.bucket_for(lo) >= required_capacity(lo, lad.slack)
