"""Property-based differential serving suite (DESIGN.md §9 / §10).

Hypothesis-driven randomized properties over the whole serving stack:
random graphs × kinds (GCN/GAT/SAGE) × quality tiers served through the
deterministic pipeline scheduler must equal the sequential single-request
forward; the `grasp` aggregation backend must match the `dense` backend
across kinds × edge densities × tiers; fused per-layer serving
(`fusion="layer"`, DESIGN.md §11) must equal unfused serving over the same
traffic; the N-way sharded forward (DESIGN.md §12) must equal the
single-device forward across kinds × tiers × shard counts × halo wire
formats; the CacheG/SymG pack→unpack
transfer forms (including the budget-padded GraSp block form) must
round-trip losslessly; NodePad's admission rule and the per-bucket
`grasp_max_nnz` budget must be monotone. Skipped without hypothesis
(tier-1 stays dependency-light); CI installs requirements-dev so these
EXECUTE there, and the scheduled nightly job deepens `max_examples` via
the `nightly` profile registered in conftest.py. Tests here deliberately
carry no per-test `max_examples` so the active profile controls depth;
determinism comes from hypothesis' own seeding plus the engine's
deterministic scheduler mode.

The seeded SymG round-trip sweep formerly in test_gnn_serving.py was
promoted into `test_symg_roundtrip_lossless` here.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.graph import (BucketLadder, Graph,  # noqa: E402
                              edge_index_from_adjacency, node_bucket,
                              pad_graph, required_capacity, symg_pack,
                              symg_unpack)
from repro.core.models import (OPERAND_FIELDS, GNNConfig,  # noqa: E402
                               _unpack_adjacency,
                               build_operands, build_sharded_operands,
                               build_sharded_plan, calibrate_tier,
                               compact_operands, forward_grannite,
                               init_params, stack_shard_slices,
                               unshard_logits)
from repro.core.partition import partition_graph  # noqa: E402
from repro.core.sparsity import (from_block_sparse, grasp_max_nnz,  # noqa: E402
                                 pad_block_sparse, stack_block_sparse,
                                 to_block_sparse)
from repro.data.graphs import planetoid_like  # noqa: E402
from repro.runtime.gnn_server import (STANDARD_TIERS, GraphServe,  # noqa: E402
                                      GraphServeConfig, tier_techniques)
from repro.runtime.scheduler import PipelineConfig  # noqa: E402

IN_FEATS, CLASSES = 12, 4
BUCKETS = (128, 256)
KINDS = ("gcn", "gat", "sage")


def _graph(n, seed):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=1)


# Warm engines are expensive (one compile sweep per kind) and hypothesis
# runs many examples: build each kind's engine once at module scope and let
# every example serve on it — examples only ever REPLAY warm plans, which
# assert_warm re-checks at the end of each one.
_ENGINES = {}


def _engine(kind, agg_backend="dense"):
    key = (kind, agg_backend)
    if key not in _ENGINES:
        sc = GraphServeConfig(ladder=BucketLadder(buckets=BUCKETS),
                              batch_slots=3, return_logits=True)
        eng = GraphServe(sc, seed=0)
        eng.register_model(kind, GNNConfig(
            kind=kind, in_feats=IN_FEATS, hidden=8, num_classes=CLASSES,
            heads=2, aggregator="max" if kind == "sage" else "mean"),
            tiers=STANDARD_TIERS, agg_backend=agg_backend)
        eng.warmup()
        eng.calibrate(kind, _graph(64, seed=999))   # quant tiers live
        _ENGINES[key] = eng
    return _ENGINES[key]


# ------------------------------------------- differential: async == single


@st.composite
def traffic(draw):
    kind = draw(st.sampled_from(KINDS))
    k = draw(st.integers(1, 5))
    reqs = [(draw(st.integers(10, 200)),             # num_nodes
             draw(st.integers(0, 2 ** 16)),          # graph seed
             draw(st.sampled_from((None,) + STANDARD_TIERS)))
            for _ in range(k)]
    return kind, reqs


@given(traffic())
def test_async_batched_logits_equal_sequential(case):
    """The tentpole differential: ANY mix of graph sizes and tiers served
    batched through the deterministic pipeline scheduler equals the
    sequential single-request forward, and replays entirely warm."""
    kind, reqs = case
    eng = _engine(kind)
    with eng.scheduler(PipelineConfig(deterministic=True)) as sched:
        for n, seed, tier in reqs:
            sched.submit(_graph(n, seed), model=kind, tier=tier)
        out = sched.drain()
    assert len(out) == len(reqs) and all(r.done for r in out)
    e = eng.models[kind]
    for r in out:
        ref = forward_grannite(e.params, e.cfg, jnp.asarray(r.pg.features),
                               r.ops, e.tiers[r.tier],
                               quant=e.calibrations.get(r.tier),
                               tier_ops=r.tier_ops)
        np.testing.assert_allclose(r.logits,
                                   np.asarray(ref)[: r.pg.num_nodes],
                                   atol=2e-5)
        np.testing.assert_array_equal(
            r.preds, np.asarray(ref)[: r.pg.num_nodes].argmax(-1))
    eng.assert_warm()


# ------------------------------------------- differential: grasp == dense


@st.composite
def backend_traffic(draw):
    kind = draw(st.sampled_from(KINDS))
    k = draw(st.integers(1, 4))
    reqs = []
    for _ in range(k):
        n = draw(st.integers(10, 200))
        density = draw(st.floats(0.01, 0.5))
        edges = max(int(density * n * n), 1)
        reqs.append((n, edges, draw(st.integers(0, 2 ** 16)),
                     draw(st.sampled_from((None,) + STANDARD_TIERS))))
    return kind, reqs


@given(backend_traffic())
def test_grasp_backend_logits_equal_dense(case):
    """DESIGN.md §10 differential: ANY mix of graph sizes, edge densities
    (0.01–0.5) and tiers served through the forced-grasp engine's
    deterministic pipeline equals the dense engine's sequential forward
    within fp32 tolerance (block-sum accumulation order differs, so this
    is allclose, not bit-equality), and both engines replay entirely warm.
    Non-GCN kinds and QuantGr tiers resolve dense on the grasp engine too
    — the rule, not an error path."""
    kind, reqs = case
    eng_g = _engine(kind, "grasp")
    eng_d = _engine(kind, "dense")
    graphs = [planetoid_like(num_nodes=n, num_edges=e, num_feats=IN_FEATS,
                             num_classes=CLASSES, seed=seed,
                             train_per_class=1)
              for n, e, seed, _ in reqs]
    with eng_g.scheduler(PipelineConfig(deterministic=True)) as sched:
        for g, (_, _, _, tier) in zip(graphs, reqs):
            sched.submit(g, model=kind, tier=tier)
        out = sched.drain()
    eng_g.assert_warm()
    uids = [eng_d.submit(g, model=kind, tier=tier)
            for g, (_, _, _, tier) in zip(graphs, reqs)]
    eng_d.run()
    eng_d.assert_warm()
    ref = {r.uid: r for r in eng_d.finished}
    for r, uid in zip(out, uids):
        assert ref[uid].backend == "dense"
        if kind != "gcn" or eng_g.models[kind].tiers[r.tier].quantgr:
            assert r.backend == "dense"
        np.testing.assert_allclose(r.logits, ref[uid].logits,
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_array_equal(r.preds, ref[uid].preds)


# ------------------------------------------- differential: fused == unfused


@st.composite
def fusion_traffic(draw):
    kind = draw(st.sampled_from(KINDS))
    k = draw(st.integers(1, 4))
    reqs = [(draw(st.integers(10, 200)),             # num_nodes
             draw(st.integers(0, 2 ** 16)),          # graph seed
             draw(st.sampled_from((None,) + STANDARD_TIERS)),
             draw(st.sampled_from((None, "none", "layer"))))
            for _ in range(k)]
    return kind, reqs


@given(fusion_traffic())
def test_fused_serving_logits_equal_unfused(case):
    """DESIGN.md §11 differential: ANY mix of graph sizes, tiers and fusion
    modes served through the deterministic pipeline equals the UNFUSED
    sequential forward, and the engine replays entirely warm — fusion is a
    pre-traced plan dimension, never a recompile. Tolerance is looser than
    the unfused differential (2e-4 vs 2e-5) because the fused GAT kernel
    folds the attention mask additively before softmax instead of applying
    an exact where-mask after."""
    kind, reqs = case
    eng = _engine(kind)
    with eng.scheduler(PipelineConfig(deterministic=True)) as sched:
        for n, seed, tier, fusion in reqs:
            sched.submit(_graph(n, seed), model=kind, tier=tier,
                         fusion=fusion)
        out = sched.drain()
    assert len(out) == len(reqs) and all(r.done for r in out)
    e = eng.models[kind]
    for r, (_, _, _, fusion) in zip(out, reqs):
        assert r.fusion == (fusion or "none")
        ref = forward_grannite(e.params, e.cfg, jnp.asarray(r.pg.features),
                               r.ops, e.tiers[r.tier],
                               quant=e.calibrations.get(r.tier),
                               tier_ops=r.tier_ops, fusion="none")
        np.testing.assert_allclose(r.logits,
                                   np.asarray(ref)[: r.pg.num_nodes],
                                   rtol=2e-4, atol=2e-4)
    eng.assert_warm()


# ------------------------------------------ differential: sharded == single


SHARD_CAP = 128

# One compiled sharded plan + jitted reference per (kind, tier, shards,
# compress): shapes are fixed by the key, so every hypothesis example
# replays warm traces (same economics as the module-scope engines above).
_SHARDED = {}


def _sharded_setup(kind, tier, shards, compress):
    key = (kind, tier, shards, compress)
    if key not in _SHARDED:
        cfg = GNNConfig(kind=kind, in_feats=IN_FEATS, hidden=8,
                        num_classes=CLASSES, heads=2,
                        aggregator="max" if kind == "sage" else "mean")
        t = tier_techniques(kind)[tier]
        plan = build_sharded_plan(cfg, SHARD_CAP, shards, t,
                                  compress=compress)
        ref = jax.jit(lambda p, x, o, q: forward_grannite(p, cfg, x, o, t,
                                                          quant=q))
        params = init_params(jax.random.PRNGKey(0), cfg)
        _SHARDED[key] = (cfg, t, plan, ref, params)
    return _SHARDED[key]


@st.composite
def sharded_case(draw):
    kind = draw(st.sampled_from(KINDS))
    shards = draw(st.sampled_from((2, 4)))
    return (kind,
            draw(st.sampled_from(STANDARD_TIERS)),
            shards,
            draw(st.integers(20, SHARD_CAP * shards)),  # num_nodes
            draw(st.integers(0, 2 ** 16)),              # graph seed
            draw(st.booleans()))                        # compressed halos


@given(sharded_case())
def test_sharded_forward_equals_single_device(case):
    """DESIGN.md §12 differential: ANY (kind, tier, shard count, graph,
    halo wire format) partitioned through the greedy edge-cut and run under
    the sharded plan equals the jitted single-device forward at the
    partition's full capacity. Both sides jitted (the discipline from the
    QuantGr suites: XLA's reciprocal-multiply lowering shifts int8 round()
    boundaries between jitted and eager code). Uncompressed halos are
    numerically tight — the exchange is a psum of disjoint blocks;
    compressed halos admit the documented int8 wire error."""
    kind, tier, shards, n, seed, compress = case
    cfg, t, plan, ref, params = _sharded_setup(kind, tier, shards, compress)
    g = _graph(n, seed)
    part = partition_graph(g.edge_index, n, shards, shard_cap=SHARD_CAP)
    slices = build_sharded_operands(g, part, cfg,
                                    rng=np.random.default_rng(seed))
    x, ops, mask = stack_shard_slices(slices)
    pg = pad_graph(g, capacity=part.full_rows)
    rops = build_operands(pg, cfg, lean=True,
                          rng=np.random.default_rng(seed))
    quant = (calibrate_tier(params, cfg, jnp.asarray(pg.features), rops)
             if t.quantgr else None)
    got = unshard_logits(
        np.asarray(plan(params, x, ops, quant, node_mask=mask)), part)
    want = np.asarray(ref(params, jnp.asarray(pg.features), rops, quant))[:n]
    tol = 0.05 if compress else (2e-5 if tier == "fp32" else 2e-3)
    np.testing.assert_allclose(got, want, atol=tol)


# cache replica plans per (kind, tier, shards, compress, R) — same warm-
# trace economics as _SHARDED
_REPLICA = {}


def _replica_plan(kind, tier, shards, compress, replicas):
    key = (kind, tier, shards, compress, replicas)
    if key not in _REPLICA:
        cfg, t, _, _, params = _sharded_setup(kind, tier, shards, compress)
        _REPLICA[key] = (build_sharded_plan(cfg, SHARD_CAP, shards, t,
                                            compress=compress,
                                            replicas=replicas),
                         cfg, t, params)
    return _REPLICA[key]


@st.composite
def replica_case(draw):
    kind = draw(st.sampled_from(KINDS))
    return (kind,
            draw(st.sampled_from(STANDARD_TIERS)),
            draw(st.sampled_from((2, 3))),              # replicas
            draw(st.integers(20, SHARD_CAP * 2)),       # num_nodes
            draw(st.integers(0, 2 ** 16)),              # graph seed
            draw(st.booleans()))                        # compressed halos


@given(replica_case())
def test_replica_dispatch_bit_identical_to_single(case):
    """DESIGN.md §15: replica-group dispatch is a WIDTH concern, never a
    numerics concern — each replica row of an R-wide sharded plan returns
    the BIT-identical logits of the single-replica plan on the same
    operands (the replica axis carries no collectives; halo psums name
    only the shard axis). Holds for every kind, tier, and wire format, on
    DIFFERENT graphs per row."""
    kind, tier, replicas, n, seed, compress = case
    shards = 2
    cfg, t, plan1, _, params = _sharded_setup(kind, tier, shards, compress)
    planr, _, _, _ = _replica_plan(kind, tier, shards, compress, replicas)
    rows = []
    for r in range(replicas):
        nr = 20 + (n + 17 * r) % (SHARD_CAP * shards - 20)
        g = _graph(nr, seed + r)
        part = partition_graph(g.edge_index, nr, shards,
                               shard_cap=SHARD_CAP)
        slices = build_sharded_operands(g, part, cfg,
                                        rng=np.random.default_rng(seed + r))
        rows.append(stack_shard_slices(slices))
    quant = None
    if t.quantgr:
        g0 = _graph(n, seed)
        pg = pad_graph(g0, capacity=shards * SHARD_CAP)
        rops = build_operands(pg, cfg, lean=True,
                              rng=np.random.default_rng(seed))
        quant = calibrate_tier(params, cfg, jnp.asarray(pg.features), rops)
    xs = jnp.stack([r[0] for r in rows])
    ops = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                 *[r[1] for r in rows])
    masks = jnp.stack([r[2] for r in rows])
    wide = np.asarray(planr(params, xs, ops, quant, node_mask=masks))
    for r, (x1, o1, m1) in enumerate(rows):
        single = np.asarray(plan1(params, x1, o1, quant, node_mask=m1))
        np.testing.assert_array_equal(wide[r], single)


# --------------------------------------------------- pack/unpack round-trips


@given(st.integers(1, 3), st.floats(0.0, 0.3), st.integers(0, 2 ** 16))
def test_block_sparse_pad_stack_roundtrip(cb, density, seed):
    """Budget-padding and batch-stacking the GraSp block form is lossless:
    every padded structure densifies back to its source matrix, and the
    stacked form is the same pytree with a leading batch dim."""
    rng = np.random.default_rng(seed)
    n = cb * 128
    mats = [((rng.random((n, n)) < density) * rng.random((n, n))
             ).astype(np.float32) for _ in range(2)]
    budget = max(grasp_max_nnz(n),
                 *(to_block_sparse(a).max_nnz for a in mats))
    sps = [pad_block_sparse(to_block_sparse(a), budget) for a in mats]
    for a, sp in zip(mats, sps):
        assert sp.max_nnz == budget
        np.testing.assert_array_equal(from_block_sparse(sp), a)
    stacked = stack_block_sparse(sps)
    assert stacked.blocks.shape[0] == 2
    assert stacked.block_cols.shape == (2, cb, budget)


@given(st.integers(2, 60), st.integers(0, 2 ** 16))
def test_symg_roundtrip_lossless(n, seed):
    """SymG pack/unpack is lossless and stores exactly the n(n+1)/2 upper
    triangle (promoted from the seeded sweep in test_gnn_serving.py)."""
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)).astype(np.float32)
    sym = (m + m.T) / 2
    packed, nn = symg_pack(sym)
    assert packed.size == n * (n + 1) // 2
    np.testing.assert_allclose(symg_unpack(packed, nn), sym, atol=1e-6)


@given(st.integers(10, 150), st.integers(0, 2 ** 16))
def test_compact_transfer_bits_roundtrip(n, seed):
    """CacheG's bit-packed transfer form reproduces the exact 0/1 adjacency
    through the device-side unpack, padding included."""
    g = _graph(n, seed)
    pg = pad_graph(g, capacity=node_bucket(n))
    co = compact_operands(pg, GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        num_classes=CLASSES))
    np.testing.assert_array_equal(np.asarray(_unpack_adjacency(co)), pg.adj)


# -------------------------------------------------- NodePad admission rule


@given(st.integers(1, 4000), st.integers(0, 4000),
       st.floats(0.0, 0.5, allow_nan=False), st.floats(0.0, 0.5,
                                                       allow_nan=False))
def test_required_capacity_monotone(n, dn, s1, s2):
    """`required_capacity` is monotone in BOTH arguments (more nodes or more
    slack can never need less room), always admits the graph itself, and
    `node_bucket` rounds it to a tile multiple without undershooting."""
    lo_s, hi_s = sorted((s1, s2))
    assert required_capacity(n, lo_s) >= n
    assert required_capacity(n + dn, lo_s) >= required_capacity(n, lo_s)
    assert required_capacity(n, hi_s) >= required_capacity(n, lo_s)
    b = node_bucket(n, slack=lo_s)
    assert b % 128 == 0 and b >= required_capacity(n, lo_s)


@given(st.integers(1, 384), st.integers(1, 384))
def test_ladder_admission_monotone(a, b):
    """A bigger graph never lands in a smaller rung, and every rung covers
    the slack-adjusted requirement."""
    lad = BucketLadder(buckets=(128, 256, 384))
    lo, hi = min(a, b), max(a, b)
    assert lad.bucket_for(lo) <= lad.bucket_for(hi)
    assert lad.bucket_for(lo) >= required_capacity(lo, lad.slack)


@given(st.integers(1, 64), st.integers(0, 64))
def test_grasp_budget_monotone(cb, dcb):
    """The per-bucket GraSp block-list budget never shrinks as capacity
    grows (a graph eligible at one rung stays eligible after a re-bucket)
    and never exceeds the bucket's column-block count."""
    lo, hi = cb * 128, (cb + dcb) * 128
    assert grasp_max_nnz(lo) <= grasp_max_nnz(hi)
    assert 1 <= grasp_max_nnz(lo) <= cb


# ------------------------------------- GrAd delta-update differential (§13)


@given(st.sampled_from(("gcn", "gat", "sage")),
       st.sampled_from(("fp32", "int8")),
       st.integers(20, 100), st.integers(0, 2 ** 16),
       st.integers(0, 5), st.integers(0, 5))
def test_delta_update_equals_full_rebuild(kind, tier, n, seed, n_add, n_rm):
    """update_delta ≡ full rebuild, across kinds × tiers × delta shapes:
    after a random symmetric edge delta, the patched device operands (and
    for GCN int8, the patched int8 Â — bit-for-bit) and the served logits
    must equal a FRESH attach of the post-delta structure. SAGE exercises
    the documented fallback (sampled mask: `update()` under the hood), and
    the zero-recompile contract holds through patch and fallback alike."""
    eng = _engine(kind)
    rng = np.random.default_rng(seed)
    g = _graph(n, seed)
    gid = eng.attach(g, model=kind)
    gid2 = None
    try:
        eng.query(gid, tier=tier)
        eng.run()
        pg = eng.graphs[gid][1]
        iu, ju = np.triu_indices(n, 1)
        on = pg.adj[iu, ju] != 0
        absent = np.flatnonzero(~on)
        present = np.flatnonzero(on)
        add = [(int(iu[k]), int(ju[k])) for k in
               rng.choice(absent, size=min(n_add, len(absent)),
                          replace=False)] if len(absent) else []
        rm = [(int(iu[k]), int(ju[k])) for k in
              rng.choice(present, size=min(n_rm, len(present)),
                         replace=False)] if len(present) else []
        if not add and not rm:
            assert eng.update_delta(gid) is True        # vacuous no-op
            return
        applied = eng.update_delta(gid, add_edges=add, remove_edges=rm)
        assert applied is (kind != "sage")
        eng.query(gid, tier=tier)
        r1 = eng.run()[-1]
        eng.assert_warm()
        pg1 = eng.graphs[gid][1]
        gid2 = eng.attach(Graph(
            edge_index=edge_index_from_adjacency(pg1.adj, n),
            num_nodes=n, features=g.features), model=kind)
        eng.query(gid2, tier=tier)
        r2 = eng.run()[-1]
        eng.assert_warm()
        k1 = (gid, eng._graph_version[gid])
        k2 = (gid2, eng._graph_version[gid2])
        o1, o2 = eng._operand_cache[k1], eng._operand_cache[k2]
        for f in OPERAND_FIELDS[kind]:
            np.testing.assert_array_equal(np.asarray(getattr(o1, f)),
                                          np.asarray(getattr(o2, f)))
        if kind == "gcn" and tier == "int8":
            t1 = eng._tier_operand_cache[k1]
            t2 = eng._tier_operand_cache[k2]
            np.testing.assert_array_equal(np.asarray(t1.agg_aq),
                                          np.asarray(t2.agg_aq))
            np.testing.assert_array_equal(np.asarray(t1.agg_a_scale),
                                          np.asarray(t2.agg_a_scale))
        np.testing.assert_array_equal(np.asarray(r1.logits),
                                      np.asarray(r2.logits))
    finally:
        eng.detach(gid)
        if gid2 is not None:
            eng.detach(gid2)


# --------------------------------- §13 byte-accounting under interleavings


_BUDGETED = {}


def _budgeted_engine():
    """One budgeted module-scope engine (warm engines are expensive): a
    budget fitting ~2 bucket-128 GCN primaries, so random interleavings
    exercise eviction and spill constantly."""
    if "eng" not in _BUDGETED:
        from repro.runtime.cache import estimate_dense_entry_bytes
        entry = estimate_dense_entry_bytes(1, 128)
        sc = GraphServeConfig(ladder=BucketLadder(buckets=(128,)),
                              batch_slots=2, return_logits=True,
                              device_cache_budget_bytes=2 * entry + 40_000)
        eng = GraphServe(sc, seed=0)
        eng.register_model("gcn", GNNConfig(
            kind="gcn", in_feats=IN_FEATS, hidden=8, num_classes=CLASSES),
            tiers=("fp32", "int8"))
        eng.warmup()
        eng.calibrate("gcn", _graph(64, seed=999))
        _BUDGETED["eng"] = eng
    return _BUDGETED["eng"]


# ------------------------------------- §14 SLO serving under a fake clock


@st.composite
def slo_traffic(draw):
    k = draw(st.integers(1, 6))
    return [(draw(st.integers(10, 200)),                 # num_nodes
             draw(st.integers(0, 2 ** 16)),              # graph seed
             draw(st.sampled_from((None, 0.0, 5.0, 1e6))),  # deadline_ms
             draw(st.floats(0.0, 0.02)))                 # inter-arrival gap s
            for _ in range(k)]


@given(slo_traffic())
def test_every_request_completes_exactly_once_with_deadline_flag(reqs):
    """§14 liveness: under ANY mix of deadlines (none / already-expired /
    tight / loose) and arrival gaps on a virtual clock, every accepted
    request completes EXACTLY once, `deadline_missed` is always a bool,
    dropped answers happen only on missed deadlines, deadline-free requests
    can never be flagged, and nothing recompiles."""
    from clockwork import FakeClock
    eng = _engine("gcn")
    clk = FakeClock(default_batch_s=1e-3)
    eng.clock = clk
    with eng.scheduler(PipelineConfig(deterministic=True)) as sched:
        for n, seed, deadline_ms, gap in reqs:
            sched.submit(_graph(n, seed), model="gcn",
                         deadline_ms=deadline_ms)
            clk.advance(gap)
        out = sched.drain()
    assert len(out) == len(reqs)
    assert len({r.uid for r in out}) == len(reqs)        # exactly once
    for r, (_, _, deadline_ms, _) in zip(out, reqs):
        assert r.done and r.deadline_missed in (True, False)
        if r.preds is None:
            assert r.deadline_missed                     # drops ⇒ missed
        if deadline_ms is None:
            assert not r.deadline_missed and r.preds is not None
        if deadline_ms == 1e6:
            assert not r.deadline_missed                 # loose never misses
    eng.assert_warm()


@given(st.lists(st.floats(1e-6, 1e3), min_size=1, max_size=30),
       st.floats(0.01, 0.99),
       st.one_of(st.none(), st.floats(1e-9, 1e6)))
def test_latency_bank_prediction_bounded_by_samples(xs, alpha, seed):
    """§14 bank invariant: however wrong the roofline seed is, once a key
    has samples its prediction is a convex combination of them — always
    within [min, max] of what was observed, seed excluded by construction."""
    from repro.runtime.ewma import LatencyBank
    bank = LatencyBank(alpha=alpha)
    key = ("m", 128, "fp32", "dense", "none", 0)
    if seed is not None:
        bank.seed(key, seed)
        assert bank.predict(key) == seed                 # cold: seed verbatim
    for i, x in enumerate(xs):
        bank.observe(key, x)
        p = bank.predict(key)
        assert min(xs[: i + 1]) <= p <= max(xs[: i + 1])
    assert bank.samples(key) == len(xs)


_ROUTER = {}


@st.composite
def router_state(draw):
    tiers = [t for t in STANDARD_TIERS if t != "fp32"]
    return (draw(st.floats(0.0, 10.0)),                  # tolerance
            {t: draw(st.one_of(st.none(), st.floats(-8.0, 8.0)))
             for t in tiers},                            # accuracy deltas
            {t: draw(st.booleans()) for t in tiers},     # calibrated?
            [(draw(st.sampled_from(STANDARD_TIERS)),
              draw(st.floats(1e-7, 1e-2)),
              draw(st.booleans()))                       # measured vs seed
             for _ in range(draw(st.integers(0, 6)))])


@given(router_state())
def test_tier_router_never_selects_unservable_tier(state):
    """§14 router safety: whatever the (delta, calibration, bank) state,
    the tolerance router returns a tier that is servable RIGHT NOW — its
    measured delta fits the tolerance and QuantGr tiers are calibrated —
    so `_resolve_tier` passes it through without the fp32 fallback."""
    tolerance, deltas, calibrated, costs = state
    if "eng" not in _ROUTER:
        # router-only engine: never warmed, never dispatched — the router
        # reads registry + bank state only, so no compile sweep is needed
        eng = GraphServe(GraphServeConfig(
            ladder=BucketLadder(buckets=BUCKETS), batch_slots=3))
        eng.register_model("gcn", GNNConfig(
            kind="gcn", in_feats=IN_FEATS, hidden=8, num_classes=CLASSES),
            tiers=STANDARD_TIERS)
        _ROUTER["eng"] = eng
    eng = _ROUTER["eng"]
    from repro.runtime.ewma import LatencyBank
    e = eng.models["gcn"]
    e.accuracy_delta.clear()
    e.calibrations.clear()
    eng.bank = LatencyBank()
    for t, d in deltas.items():
        if d is not None:
            e.accuracy_delta[t] = d
    for t, c in calibrated.items():
        if c:
            e.calibrations[t] = {}
    for t, cost, measured in costs:
        key = ("gcn", 128, t, "dense", "none", 0)
        if measured:
            eng.bank.observe(key, cost)
        else:
            eng.bank.seed(key, cost)
    pick = eng._tier_for_tolerance("gcn", tolerance, 128)
    if pick != "fp32":
        assert abs(e.accuracy_delta[pick]) <= tolerance
        if e.tiers[pick].quantgr:
            assert pick in e.calibrations
    fallbacks = eng.metrics["tier_fallbacks"]
    assert eng._resolve_tier("gcn", pick) == pick        # servable as-is
    assert eng.metrics["tier_fallbacks"] == fallbacks


# --------------------------------- §13 byte-accounting under interleavings


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                          st.integers(0, 2 ** 10)),
                min_size=1, max_size=12))
def test_cache_byte_accounting_under_random_interleavings(ops):
    """After EVERY attach/query/update_delta/detach in a random sequence:
    the per-entry ledger sums to `cache_resident_bytes`, residency never
    exceeds the budget, and evictions == spilled + dropped. Nothing ever
    traces (eviction/spill/patch replay warm blobs)."""
    eng = _budgeted_engine()
    cm = eng._cache
    slots = {}
    try:
        for op, slot, seed in ops:
            if op == 0 and slot not in slots:
                slots[slot] = eng.attach(_graph(30 + slot, seed % 7),
                                         model="gcn")
            elif op == 1 and slot in slots:
                eng.query(slots[slot], tier="int8" if seed % 2 else "fp32")
                eng.run()
            elif op == 2 and slot in slots:
                gid = slots[slot]
                pg = eng.graphs[gid][1]
                rng = np.random.default_rng(seed)
                i, j = rng.choice(pg.num_nodes, size=2, replace=False)
                pair = [(int(min(i, j)), int(max(i, j)))]
                if pg.adj[i, j]:
                    eng.update_delta(gid, remove_edges=pair)
                else:
                    eng.update_delta(gid, add_edges=pair)
            elif op == 3 and slot in slots:
                eng.detach(slots.pop(slot))
            with eng._lock:
                sizes = cm.entry_sizes()
                resident = cm.resident_bytes
                ev, sp, dr = cm.evictions, cm.spilled, cm.dropped
            assert sum(sizes.values()) == resident
            assert resident <= eng.sc.device_cache_budget_bytes
            assert ev == sp + dr
    finally:
        for gid in slots.values():
            eng.detach(gid)
    eng.assert_warm()
