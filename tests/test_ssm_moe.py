"""SSD (Mamba2) and MoE invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.configs import ARCHS, reduced
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod

KEY = jax.random.PRNGKey(3)


def _ssm_cfg(chunk=32):
    cfg = reduced(ARCHS["mamba2-2.7b"])
    return dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, chunk=chunk))


# ------------------------------------------------------------------- SSD


@pytest.mark.parametrize("s,chunk", [(64, 32), (128, 32), (96, 96), (64, 16)])
def test_ssd_chunked_equals_sequential(s, chunk):
    """The paper-spirit check: the dense chunked (MXU) form must equal the
    sequential recurrence (DSP form) exactly."""
    cfg = _ssm_cfg(chunk)
    p = ssm_mod.ssm_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, s), (2, s, cfg.d_model))
    y_ssd = ssm_mod.ssm_forward(p, cfg, x)
    y_seq = ssm_mod.ssm_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_ssd), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)


def test_ssd_chunk_size_invariance():
    """Output must not depend on the chunking (pure reformulation)."""
    x = jax.random.normal(KEY, (1, 64, 128))
    outs = []
    for chunk in (16, 32, 64):
        cfg = _ssm_cfg(chunk)
        p = ssm_mod.ssm_init(jax.random.PRNGKey(1), cfg)
        outs.append(np.asarray(ssm_mod.ssm_forward(p, cfg, x)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-5)


def test_ssm_decode_chain_matches_forward():
    """Prefill state + N decode steps == forward over the whole sequence."""
    cfg = _ssm_cfg(16)
    p = ssm_mod.ssm_init(KEY, cfg)
    s, extra = 32, 4
    x = jax.random.normal(jax.random.fold_in(KEY, 9),
                          (1, s + extra, cfg.d_model))
    y_full = ssm_mod.ssm_forward(p, cfg, x)
    y_pre, cache = ssm_mod.ssm_forward(p, cfg, x[:, :s], return_state=True)
    ys = [y_pre]
    for t in range(extra):
        yt, cache = ssm_mod.ssm_decode(p, cfg, x[:, s + t: s + t + 1], cache)
        ys.append(yt)
    y_chain = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_chain),
                               rtol=2e-3, atol=2e-3)


def test_ssd_state_decay_property():
    """A(h) < 0 => with zero input the state decays monotonically."""
    cfg = _ssm_cfg(16)
    p = ssm_mod.ssm_init(KEY, cfg)
    cache = ssm_mod.ssm_init_cache(cfg, 1)
    cache = ssm_mod.SSMCache(conv=cache.conv,
                             state=jnp.ones_like(cache.state))
    x = jnp.zeros((1, 1, cfg.d_model))
    _, c1 = ssm_mod.ssm_decode(p, cfg, x, cache)
    _, c2 = ssm_mod.ssm_decode(p, cfg, x, c1)
    n0 = float(jnp.abs(cache.state).sum())
    n1 = float(jnp.abs(c1.state).sum())
    n2 = float(jnp.abs(c2.state).sum())
    assert n1 < n0 and n2 < n1


# ------------------------------------------------------------------- MoE


def _moe_cfg():
    return reduced(ARCHS["olmoe-1b-7b"])


def test_moe_dispatch_mask_properties():
    """EffOp dispatch invariants: <=1 slot per (token, expert), capacity
    respected, combine gates bounded by dispatch support."""
    cfg = _moe_cfg()
    m = cfg.moe
    g = 64
    logits = jax.random.normal(KEY, (g, m.num_experts))
    gates, idx, probs = moe_mod._route(m, logits)
    cap = moe_mod.capacity(m, g)
    dispatch, combine = moe_mod._dispatch_masks(m, gates, idx, cap)
    d = np.asarray(dispatch)
    assert d.shape == (g, m.num_experts, cap)
    assert set(np.unique(d)).issubset({0.0, 1.0})
    # each (expert, slot) holds at most one token
    assert d.sum(axis=0).max() <= 1.0 + 1e-6
    # each token occupies at most top_k slots
    assert d.sum(axis=(1, 2)).max() <= m.top_k + 1e-6
    # combine is supported only where dispatch is
    c = np.asarray(combine)
    assert (c[d == 0] == 0).all()
    assert c.min() >= 0


def test_moe_forward_finite_and_aux_positive():
    cfg = _moe_cfg()
    p = moe_mod.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), cfg.dtype)
    y, aux = moe_mod.moe_forward(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) > 0


def test_moe_grouping_invariance():
    """vmap'd group dispatch: result must not depend on group size as long
    as capacity doesn't truncate (generous capacity_factor)."""
    cfg = _moe_cfg()
    big_cf = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    outs = []
    for gs in (32, 64, 128):
        c = dataclasses.replace(cfg, moe=dataclasses.replace(big_cf,
                                                             group_size=gs))
        p = moe_mod.moe_init(jax.random.PRNGKey(5), c)
        x = jax.random.normal(KEY, (1, 128, c.d_model), jnp.float32)
        y, _ = moe_mod.moe_forward(p, c, x)
        outs.append(np.asarray(y, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_overflow():
    """With capacity_factor << 1 some tokens must drop to zero output —
    NodePad semantics: dropped tokens produce exactly 0 (no edge)."""
    cfg = _moe_cfg()
    tiny = dataclasses.replace(cfg.moe, capacity_factor=0.05, top_k=1)
    c = dataclasses.replace(cfg, moe=tiny)
    p = moe_mod.moe_init(KEY, c)
    # zero the shared path so drops are visible (olmoe has none anyway)
    x = jax.random.normal(KEY, (1, 64, c.d_model), jnp.float32)
    y, _ = moe_mod.moe_forward(p, c, x)
    token_norms = np.asarray(jnp.abs(y[0]).sum(-1))
    assert (token_norms < 1e-7).sum() > 0      # some tokens dropped


@given(st.integers(0, 2 ** 16))
def test_moe_router_gates_normalized(seed):
    cfg = _moe_cfg()
    logits = jax.random.normal(jax.random.PRNGKey(seed), (32, cfg.moe.num_experts))
    gates, idx, probs = moe_mod._route(cfg.moe, logits)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert np.asarray(probs).min() >= 0
