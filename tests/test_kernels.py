"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes as the brief requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def k(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize("m,kk,n", [(128, 128, 128), (256, 128, 384),
                                    (384, 256, 128), (512, 384, 256),
                                    (130, 70, 90)])   # ragged: wrapper pads
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, kk, n, dtype):
    a = jax.random.normal(k(1), (m, kk), dtype)
    b = jax.random.normal(k(2), (kk, n), dtype)
    got = ops.matmul(a, b)
    want = ref.matmul_ref(a, b)
    # f32 tolerance allows k-block accumulation-order differences
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


# ------------------------------------------------------------ int8 matmul


@pytest.mark.parametrize("m,kk,n", [(128, 128, 128), (256, 256, 128),
                                    (100, 60, 130)])
def test_int8_matmul_sweep(m, kk, n):
    xq = jax.random.randint(k(3), (m, kk), -127, 128, jnp.int8)
    wq = jax.random.randint(k(4), (kk, n), -127, 128, jnp.int8)
    sx = jnp.float32(0.013)
    sw = jax.random.uniform(k(5), (n,), jnp.float32, 0.001, 0.05)
    got = ops.int8_matmul(xq, wq, sx, sw)
    want = ref.int8_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ------------------------------------------------------------ bitmap spmm


@pytest.mark.parametrize("n,f,density", [(256, 128, 0.02), (384, 64, 0.1),
                                         (512, 96, 0.0)])
def test_bitmap_spmm_sweep(n, f, density, rng):
    from repro.core.sparsity import to_block_sparse
    a = (rng.random((n, n)) < density) * rng.random((n, n))
    a = a.astype(np.float32)
    h = rng.standard_normal((n, f)).astype(np.float32)
    got = ops.bitmap_spmm(to_block_sparse(a), jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(got), a @ h, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ gat kernel


@pytest.mark.parametrize("n,heads,f", [(256, 4, 128), (128, 8, 64),
                                       (384, 1, 32)])
def test_gat_attention_sweep(n, heads, f, rng):
    h = jax.random.normal(k(6), (n, heads, f))
    a_dst = jax.random.normal(k(7), (n, heads))
    a_src = jax.random.normal(k(8), (n, heads))
    adj = (rng.random((n, n)) < 0.03).astype(np.float32)
    np.fill_diagonal(adj, 1.0)
    bias = np.where(adj > 0, 0.0, -1e9).astype(np.float32)
    got = ops.gat_attention(h, a_dst, a_src, jnp.asarray(bias))
    want = ref.gat_attention_ref(h, a_dst, a_src, jnp.asarray(bias))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------ sage max


@pytest.mark.parametrize("n,f", [(256, 128), (128, 200), (384, 64)])
def test_sage_max_sweep(n, f, rng):
    mask = (rng.random((n, n)) < 0.05).astype(np.float32)
    h = jnp.abs(jax.random.normal(k(9), (n, f)))   # GrAx3 precondition: h >= 0
    got = ops.sage_max(jnp.asarray(mask), h)
    want = ref.sage_max_ref(jnp.asarray(mask), h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ------------------------------------------------------- flash attention


@pytest.mark.parametrize("s,hh,kv,d", [(256, 4, 2, 64), (512, 8, 8, 64),
                                       (256, 9, 3, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(s, hh, kv, d, causal):
    q = jax.random.normal(k(10), (2, s, hh, d))
    kk_ = jax.random.normal(k(11), (2, s, kv, d))
    v = jax.random.normal(k(12), (2, s, kv, d))
    got = ops.flash_attention(q, kk_, v, causal=causal)
    want = ref.flash_attention_ref(q, kk_, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_window_softcap():
    q = jax.random.normal(k(13), (1, 256, 4, 64))
    kk_ = jax.random.normal(k(14), (1, 256, 4, 64))
    v = jax.random.normal(k(15), (1, 256, 4, 64))
    got = ops.flash_attention(q, kk_, v, causal=True, window=64, softcap=50.0)
    want = ref.flash_attention_ref(q, kk_, v, causal=True, window=64,
                                   softcap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# -------------------------------------------- chunked-jax oracle vs flash


def test_chunked_attention_matches_flash_ref():
    from repro.nn.attention import chunked_attention
    q = jax.random.normal(k(16), (2, 256, 8, 64))
    kk_ = jax.random.normal(k(17), (2, 256, 2, 64))
    v = jax.random.normal(k(18), (2, 256, 2, 64))
    got = chunked_attention(q, kk_, v, causal=True, q_chunk=64, kv_chunk=128)
    want = ref.flash_attention_ref(q, kk_, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [None, 100])
def test_chunked_attention_block_skip_exact(window):
    """§Perf block-skip must be EXACT (skipped blocks are fully masked)."""
    from repro.nn.attention import chunked_attention
    q = jax.random.normal(k(30), (2, 256, 8, 64))
    kk_ = jax.random.normal(k(31), (2, 256, 2, 64))
    v = jax.random.normal(k(32), (2, 256, 2, 64))
    want = ref.flash_attention_ref(q, kk_, v, causal=True, window=window)
    got = chunked_attention(q, kk_, v, causal=True, window=window,
                            q_chunk=64, kv_chunk=64, block_skip=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_block_skip_differentiable():
    from repro.nn.attention import chunked_attention
    q = jax.random.normal(k(33), (1, 128, 4, 32))
    kk_ = jax.random.normal(k(34), (1, 128, 4, 32))
    v = jax.random.normal(k(35), (1, 128, 4, 32))

    def loss(qq):
        return chunked_attention(qq, kk_, v, causal=True, q_chunk=32,
                                 kv_chunk=32, block_skip=True).sum()

    g = jax.grad(loss)(q)
    assert bool(jnp.isfinite(g).all())


def test_chunked_attention_bf16_scores_quality():
    """QuantGr-on-scores (§Perf iter 3): bf16 score buffers lose < 2e-2."""
    from repro.nn.attention import chunked_attention
    q = jax.random.normal(k(36), (2, 256, 4, 64))
    kk_ = jax.random.normal(k(37), (2, 256, 4, 64))
    v = jax.random.normal(k(38), (2, 256, 4, 64))
    want = ref.flash_attention_ref(q, kk_, v, causal=True)
    got = chunked_attention(q, kk_, v, causal=True, q_chunk=64, kv_chunk=64,
                            logits_bf16=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_chunked_attention_ragged_and_kvlen():
    from repro.nn.attention import chunked_attention
    q = jax.random.normal(k(19), (1, 80, 4, 32))     # 80 % 64 != 0: pads
    kk_ = jax.random.normal(k(20), (1, 80, 4, 32))
    v = jax.random.normal(k(21), (1, 80, 4, 32))
    got = chunked_attention(q, kk_, v, causal=True, q_chunk=64, kv_chunk=64,
                            kv_len=jnp.asarray(50))
    # oracle: mask keys >= 50
    want = ref.flash_attention_ref(q[:, :, :, :], kk_[:, :50], v[:, :50],
                                   causal=True)
    np.testing.assert_allclose(np.asarray(got[:, :50], np.float32),
                               np.asarray(want[:, :50], np.float32),
                               rtol=2e-3, atol=2e-3)
