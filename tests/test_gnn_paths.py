"""System behaviour: baseline (edge-list) vs GraNNite (dense-masked) paths
must agree; GrAx approximations must stay within the paper's quality bounds;
NodePad/GrAd must be shape-stable (zero recompiles)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import add_self_loops, node_bucket, pad_graph, update_edges
from repro.core.layers import Techniques
from repro.core.models import (GNNConfig, build_operands, calibrate_quant,
                               forward_baseline, forward_grannite, init_params)

KEY = jax.random.PRNGKey(0)


def _cfg(graph, kind, **kw):
    return GNNConfig(kind=kind, in_feats=graph.features.shape[1],
                     num_classes=5, **kw)


# ----------------------------------------------------- path equivalence


def test_gcn_baseline_equals_stagr(small_graph, padded_graph):
    cfg = _cfg(small_graph, "gcn")
    params = init_params(KEY, cfg)
    x = jnp.asarray(padded_graph.features)
    ops_ = build_operands(padded_graph, cfg)
    ei = jnp.asarray(add_self_loops(small_graph.edge_index,
                                    small_graph.num_nodes))
    base = forward_baseline(params, cfg, x, ei, padded_graph.capacity)
    dense = forward_grannite(params, cfg, x, ops_, Techniques(stagr=True))
    n = small_graph.num_nodes
    np.testing.assert_allclose(np.asarray(base[:n]), np.asarray(dense[:n]),
                               rtol=1e-4, atol=1e-5)


def test_gat_baseline_equals_effop_exact(small_graph, padded_graph):
    """EffOp with exact masking (no GrAx1) must equal the edge-list GAT."""
    cfg = _cfg(small_graph, "gat", heads=4, hidden=32)
    params = init_params(KEY, cfg)
    x = jnp.asarray(padded_graph.features)
    ops_ = build_operands(padded_graph, cfg)
    # baseline needs self-loop edges to match mask built with self-loops
    ei = jnp.asarray(add_self_loops(small_graph.edge_index,
                                    small_graph.num_nodes))
    base = forward_baseline(params, cfg, x, ei, padded_graph.capacity)
    eff = forward_grannite(params, cfg, x, ops_,
                           Techniques(effop=True, grax1=False, grax2=True))
    n = small_graph.num_nodes
    np.testing.assert_allclose(np.asarray(base[:n]), np.asarray(eff[:n]),
                               rtol=5e-3, atol=5e-4)


def test_gat_grax1_negligible_quality_delta(small_graph, padded_graph):
    """GrAx1 (additive mask) vs exact mask: paper claims negligible loss."""
    cfg = _cfg(small_graph, "gat", heads=4, hidden=32)
    params = init_params(KEY, cfg)
    x = jnp.asarray(padded_graph.features)
    ops_ = build_operands(padded_graph, cfg)
    exact = forward_grannite(params, cfg, x, ops_,
                             Techniques(effop=True, grax1=False))
    approx = forward_grannite(params, cfg, x, ops_,
                              Techniques(effop=True, grax1=True, grax2=True))
    n = small_graph.num_nodes
    # predictions (argmax) must agree on > 99% of nodes
    agree = (jnp.argmax(exact[:n], -1) == jnp.argmax(approx[:n], -1)).mean()
    assert agree > 0.99, float(agree)


def test_grax2_is_numerically_identical():
    """GrAx2 reorders broadcast-add; results must be bit-comparable."""
    from repro.core.effop import broadcast_add_scores
    src = jax.random.normal(KEY, (100,))
    dst = jax.random.normal(jax.random.fold_in(KEY, 1), (100,))
    a = broadcast_add_scores(src, dst, grax2=True)
    b = broadcast_add_scores(src, dst, grax2=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sage_mean_baseline_equals_dense(small_graph, padded_graph):
    cfg = _cfg(small_graph, "sage", aggregator="mean", max_neighbors=10 ** 6)
    params = init_params(KEY, cfg)
    x = jnp.asarray(padded_graph.features)
    # no sampling cap -> sampled adjacency == full adjacency (incl self)
    ops_ = build_operands(padded_graph, cfg)
    ei = jnp.asarray(add_self_loops(small_graph.edge_index,
                                    small_graph.num_nodes))
    base = forward_baseline(params, cfg, x, ei, padded_graph.capacity)
    dense = forward_grannite(params, cfg, x, ops_, Techniques(stagr=True))
    n = small_graph.num_nodes
    np.testing.assert_allclose(np.asarray(base[:n]), np.asarray(dense[:n]),
                               rtol=5e-3, atol=5e-4)


def test_sage_max_grax3_matches_exact_for_nonneg(small_graph, padded_graph):
    """GrAx3 == exact masked-max whenever features >= 0 (paper's condition)."""
    from repro.core.effop import masked_max_aggregate
    mask = jnp.asarray(
        (np.random.default_rng(0).random((64, 64)) < 0.1).astype(np.float32))
    h = jnp.abs(jax.random.normal(KEY, (64, 16)))
    a = masked_max_aggregate(h, mask, grax3=True)
    b = masked_max_aggregate(h, mask, grax3=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ----------------------------------------------------------- NodePad/GrAd


def test_nodepad_padding_is_inert(small_graph):
    """Same graph, two capacities: real-node outputs must be identical —
    the '0 = no edge' convention makes padding semantically inert."""
    cfg = _cfg(small_graph, "gcn")
    params = init_params(KEY, cfg)
    pg1 = pad_graph(small_graph)                       # tight bucket
    pg2 = pad_graph(small_graph, capacity=pg1.capacity + 256)
    o1 = build_operands(pg1, cfg)
    o2 = build_operands(pg2, cfg)
    y1 = forward_grannite(params, cfg, jnp.asarray(pg1.features), o1,
                          Techniques(stagr=True))
    y2 = forward_grannite(params, cfg, jnp.asarray(pg2.features), o2,
                          Techniques(stagr=True))
    n = small_graph.num_nodes
    np.testing.assert_allclose(np.asarray(y1[:n]), np.asarray(y2[:n]),
                               rtol=1e-5, atol=1e-6)


def test_grad_dynamic_updates_zero_recompile(small_graph):
    """GrAd: evolving edges = new mask VALUES, same shapes -> jit cache hit."""
    from repro.data.graphs import dynamic_graph_stream
    cfg = _cfg(small_graph, "gcn")
    params = init_params(KEY, cfg)
    pg = pad_graph(small_graph, slack=0.5)     # headroom for added nodes

    traces = 0

    @jax.jit
    def step(p, x, norm_adj):
        nonlocal traces
        traces += 1
        return forward_grannite(p, cfg, x, _ops_like(norm_adj),
                                Techniques(stagr=True, grad_dynamic=True))

    def _ops_like(na):
        import dataclasses as dc
        from repro.core.models import GranniteOperands
        z = jnp.zeros_like(na)
        return GranniteOperands(norm_adj=na, mask_mult=z, bias_add=z,
                                sample_mask=z, mean_mask=z)

    for ei, n, feats in dynamic_graph_stream(small_graph, steps=4,
                                             nodes_per_step=4):
        pg = update_edges(pg, ei, n)
        from repro.core.graph import pad_features
        x = jnp.asarray(pad_features(feats, pg.capacity))
        y = step(params, x, jnp.asarray(pg.norm_adj))
        assert bool(jnp.isfinite(y[:n]).all())
    assert traces == 1, f"GrAd must not retrace; traced {traces}x"


def test_node_bucket_alignment():
    assert node_bucket(2708) == 2816            # Cora -> 22 * 128
    assert node_bucket(2708, slack=0.108) == 3072   # paper pads to ~3000
    assert node_bucket(128) == 128
    assert node_bucket(129) == 256


# --------------------------------------------------------------- QuantGr


def test_quantgr_accuracy_within_bound(small_graph, padded_graph):
    """INT8 logits must keep argmax agreement high (paper: 'negligible')."""
    cfg = _cfg(small_graph, "gcn")
    params = init_params(KEY, cfg)
    x = jnp.asarray(padded_graph.features)
    ops_ = build_operands(padded_graph, cfg)
    fp = forward_grannite(params, cfg, x, ops_, Techniques(stagr=True))
    ops_q = dataclasses.replace(
        ops_, quant=calibrate_quant(params, cfg, x, ops_))
    q = forward_grannite(params, cfg, x, ops_q,
                         Techniques(stagr=True, quantgr=True))
    n = small_graph.num_nodes
    agree = (jnp.argmax(fp[:n], -1) == jnp.argmax(q[:n], -1)).mean()
    assert agree > 0.97, float(agree)


# ---------------------------------------------------------- training e2e


def test_gcn_trains_to_usable_accuracy(small_graph, padded_graph):
    """End-to-end: train on synthetic Cora-like labels, eval > random."""
    from repro.core.models import evaluate, train_node_classifier
    cfg = _cfg(small_graph, "gcn")
    ops_ = build_operands(padded_graph, cfg)

    def fwd(p, x):
        return forward_grannite(p, cfg, x, ops_, Techniques(stagr=True))

    params = train_node_classifier(KEY, cfg, padded_graph, fwd, epochs=60)
    acc = evaluate(cfg, params, padded_graph, fwd)
    assert acc > 0.55, acc       # 5 classes -> random is 0.2
