"""Unit + hypothesis property tests for the GraNNite core substrates."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.core import effop, masks
from repro.core.graph import (dense_adjacency, gcn_norm_adjacency,
                              mean_adjacency, symg_pack, symg_unpack)
from repro.core.partition import (Stage, default_gnn_stages, graphsplit,
                                  transfer_cost)
from repro.core.quant import (calibrate_absmax, dequantize, quant_error,
                              quantize)
from repro.core.sparsity import (from_block_sparse, sparsity_report,
                                 to_block_sparse, zvc_compressed_bytes,
                                 zvc_pack, zvc_unpack)

# ------------------------------------------------------------------ graphs


@st.composite
def graphs(draw):
    n = draw(st.integers(5, 60))
    e = draw(st.integers(1, 150))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    ei = rng.integers(0, n, size=(2, e)).astype(np.int32)
    return ei, n


@given(graphs())
def test_gcn_norm_rows_bounded(g):
    """Property: Â = D^-1/2 (A+I) D^-1/2 is symmetric-ish w/ bounded rows."""
    ei, n = g
    cap = ((n + 127) // 128) * 128
    a = gcn_norm_adjacency(ei, n, cap)
    assert a.shape == (cap, cap)
    assert np.all(a >= 0)
    assert np.all(a[n:, :] == 0) and np.all(a[:, n:] == 0)  # padding inert
    # row sums of the normalized adjacency are <= sqrt(deg) bounded; all
    # finite and no NaN from zero-degree nodes
    assert np.isfinite(a).all()


@given(graphs())
def test_mean_adjacency_rows_sum_to_one_or_zero(g):
    ei, n = g
    cap = ((n + 127) // 128) * 128
    a = mean_adjacency(ei, n, cap)
    rs = a.sum(axis=1)
    ok = np.isclose(rs, 1.0, atol=1e-5) | np.isclose(rs, 0.0)
    assert ok.all()


@given(st.integers(2, 50), st.integers(0, 2 ** 16))
def test_symg_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)).astype(np.float32)
    sym = (m + m.T) / 2
    packed, nn = symg_pack(sym)
    assert packed.size == n * (n + 1) // 2    # the paper's ~2x storage claim
    np.testing.assert_allclose(symg_unpack(packed, nn), sym, atol=1e-6)


def test_symg_rejects_asymmetric():
    with pytest.raises(ValueError):
        symg_pack(np.arange(9, dtype=np.float32).reshape(3, 3))


# ------------------------------------------------------------------ GraSp


@given(st.integers(1, 3), st.integers(1, 3), st.floats(0.0, 0.3),
       st.integers(0, 2 ** 16))
def test_block_sparse_roundtrip(rb, cb, density, seed):
    rng = np.random.default_rng(seed)
    n, m = rb * 128, cb * 128
    a = ((rng.random((n, m)) < density) * rng.random((n, m))).astype(np.float32)
    sp = to_block_sparse(a)
    np.testing.assert_array_equal(from_block_sparse(sp), a)
    assert 0.0 <= sp.density <= 1.0


@given(st.integers(10, 400), st.floats(0.0, 0.5), st.integers(0, 2 ** 16))
def test_zvc_roundtrip_and_size(n, density, seed):
    rng = np.random.default_rng(seed)
    x = ((rng.random(n) < density) * rng.standard_normal(n)).astype(np.float32)
    vals, bitmap, shape = zvc_pack(x)
    np.testing.assert_array_equal(zvc_unpack(vals, bitmap, shape), x)
    # compressed size formula consistent
    assert zvc_compressed_bytes(x) == vals.nbytes + (x.size + 7) // 8


def test_sparsity_report_cora_like():
    # the paper's claim is about REAL graph scale: use the Cora-shaped graph
    from repro.core.graph import pad_graph
    from repro.core.sparsity import apply_reorder, bfs_reorder
    from repro.data.graphs import cora_like
    pg = pad_graph(cora_like())
    rep = sparsity_report(pg.norm_adj)
    assert rep["element_density"] < 0.01       # paper: >99% zeros
    assert rep["zvc_bytes"] < rep["dense_bytes"] / 5
    # element-level ZVC skips are huge; 128x128 BLOCK skips need locality:
    # BFS reordering (beyond-paper, DESIGN.md §6) must densify blocks
    perm = bfs_reorder(pg.adj, pg.num_nodes)
    rep2 = sparsity_report(apply_reorder(pg.norm_adj, perm))
    assert rep2["flop_skip_fraction"] > rep["flop_skip_fraction"]
    assert rep2["flop_skip_fraction"] > 0.4


def test_bfs_reorder_is_permutation_and_preserves_matmul():
    from repro.core.graph import pad_graph
    from repro.core.sparsity import apply_reorder, bfs_reorder
    from repro.data.graphs import planetoid_like
    g = planetoid_like(num_nodes=150, num_edges=300, num_feats=8,
                       num_classes=3, seed=2)
    pg = pad_graph(g)
    perm = bfs_reorder(pg.adj, pg.num_nodes)
    assert sorted(perm.tolist()) == list(range(pg.capacity))
    # aggregation in permuted space == permuted aggregation
    h = np.random.default_rng(0).standard_normal(
        (pg.capacity, 8)).astype(np.float32)
    a = pg.norm_adj
    lhs = apply_reorder(a, perm) @ h[perm]
    rhs = (a @ h)[perm]
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)


# ------------------------------------------------------------------ EffOp


@given(st.integers(2, 40), st.integers(1, 12), st.integers(0, 2 ** 16))
def test_one_hot_gather_equals_gather(n, f, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, size=17))
    np.testing.assert_allclose(np.asarray(effop.one_hot_gather(h, idx)),
                               np.asarray(h[idx]), rtol=1e-6)


@given(st.integers(2, 30), st.integers(0, 2 ** 16))
def test_segment_softmax_dense_rows_sum_to_one(n, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    adj = (rng.random((n, n)) < 0.4).astype(np.float32)
    np.fill_diagonal(adj, 1.0)
    bias = jnp.asarray(np.where(adj > 0, 0.0, masks.NEG_INF).astype(np.float32))
    p = effop.segment_softmax_dense(logits, bias)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)
    # probability mass only on edges (skip when the graph is complete)
    off_edge = np.asarray(p)[adj == 0]
    assert off_edge.size == 0 or float(off_edge.max()) < 1e-6


# ---------------------------------------------------------------- QuantGr


@given(st.integers(4, 200), st.integers(0, 2 ** 16))
def test_quant_roundtrip_error_bounded(n, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    err = quant_error(x)
    assert err < 0.02   # int8 symmetric: ~0.4% typical, 2% safe bound


def test_quant_symmetric_zero_point():
    import jax.numpy as jnp
    x = jnp.asarray(np.array([-1.0, 0.0, 1.0], np.float32))
    q = calibrate_absmax(x)
    xq = quantize(x, q)
    assert int(xq[1]) == 0                      # symmetric: zero -> 0
    assert int(xq[0]) == -int(xq[2])


# -------------------------------------------------------------- GraphSplit


def test_graphsplit_prefers_host_preprocessing():
    """The paper's core finding: control-heavy preprocessing belongs on the
    CPU, dense compute on the accelerator — the cost model must discover
    this from the latency/transfer structure alone."""
    stages = default_gnn_stages(3000, 10000, 1433, 64, capacity=3072)
    plan = graphsplit(stages)
    placement = plan.placement(stages)
    assert placement[0] == "host"               # build_adjacency
    assert placement[1] == "host"               # degree/norm (PreG)
    assert placement[2] == "device"             # combine matmul
    assert placement[3] == "device"             # aggregate matmul


def test_graphsplit_degenerate_cases():
    fast_host = [Stage("a", 1e-6, 1.0, output_bytes=100)]
    assert graphsplit(fast_host).cut == 1       # everything on host
    fast_dev = [Stage("a", 1.0, 1e-6, output_bytes=100)]
    assert graphsplit(fast_dev).cut == 0        # everything on device


def test_transfer_cost_monotone():
    assert transfer_cost(10 ** 6) < transfer_cost(10 ** 9)
