"""Runtime behaviour: trainer fault tolerance, stragglers, data determinism,
server bucketing / zero-recompile / correctness."""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data.synthetic import TokenStream
from repro.runtime.server import ServeConfig, Server
from repro.runtime.trainer import TrainConfig, Trainer


def _cfg():
    return reduced(ARCHS["smollm-135m"])


# ---------------------------------------------------------------- data


def test_tokenstream_deterministic_and_host_sharded():
    s = TokenStream(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    a = s.batch_at(5)
    b = s.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions the global batch deterministically
    h0 = TokenStream(vocab_size=128, seq_len=16, global_batch=8, seed=3,
                     num_hosts=2, host_id=0).batch_at(5)
    h1 = TokenStream(vocab_size=128, seq_len=16, global_batch=8, seed=3,
                     num_hosts=2, host_id=1).batch_at(5)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    assert (a["labels"] == np.roll(np.concatenate(
        [a["tokens"], a["labels"][:, -1:]], 1), -1, 1)[:, :-1]).all()


# -------------------------------------------------------------- trainer


def test_trainer_loss_decreases(tmp_path):
    tc = TrainConfig(steps=8, seq_len=32, global_batch=4, lr=3e-3,
                     ckpt_dir=None)
    tr = Trainer(_cfg(), tc)
    tr.run()
    s = tr.summary()
    assert s["steps"] == 8
    assert s["last_loss"] < s["first_loss"]


def test_trainer_restart_resumes_from_checkpoint(tmp_path):
    """Chaos drill: injected crash at step 5; supervisor must restore the
    step-4 checkpoint and complete the run with restarts == 1."""
    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    tc = TrainConfig(steps=8, seq_len=32, global_batch=4,
                     ckpt_dir=str(tmp_path), ckpt_every=2)
    tr = Trainer(_cfg(), tc, failure_injector=injector)
    tr.run()
    assert tr.restarts == 1
    assert tr.step == 8
    # deterministic data: the re-run of steps 4..5 used identical batches —
    # loss history after restart must continue sanely (finite)
    assert all(np.isfinite(r.loss) for r in tr.history)


def test_trainer_gives_up_after_max_failures(tmp_path):
    def injector(step):
        raise RuntimeError("persistent failure")

    tc = TrainConfig(steps=4, seq_len=32, global_batch=4,
                     ckpt_dir=str(tmp_path), ckpt_every=1)
    tr = Trainer(_cfg(), tc, failure_injector=injector)
    with pytest.raises(RuntimeError):
        tr.run(max_failures=2)


def test_trainer_microbatch_equivalence():
    """Gradient accumulation must match the single-batch gradient step."""
    import jax
    cfg = _cfg()
    t1 = TrainConfig(steps=1, seq_len=32, global_batch=4, microbatches=1,
                     clip_norm=1e9)
    t2 = TrainConfig(steps=1, seq_len=32, global_batch=4, microbatches=4,
                     clip_norm=1e9)
    tr1 = Trainer(cfg, t1)
    tr2 = Trainer(cfg, t2)
    tr1.run()
    tr2.run()
    l1 = jax.tree_util.tree_leaves(tr1.params)
    l2 = jax.tree_util.tree_leaves(tr2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_straggler_detection():
    import time as _time
    tc = TrainConfig(steps=6, seq_len=32, global_batch=2,
                     straggler_factor=2.0)
    tr = Trainer(_cfg(), tc)
    orig = tr.train_step

    calls = {"n": 0}

    def slow_step(*a, **k):
        calls["n"] += 1
        if calls["n"] == 5:
            # sleep relative to the observed EWMA so the drill works no
            # matter how slow compilation made the first steps
            base = tr._straggler.baseline or 0.0
            _time.sleep(max(0.2, 4.0 * base))
        return orig(*a, **k)

    tr.train_step = slow_step
    tr.run()
    assert sum(r.straggler for r in tr.history) >= 1


# --------------------------------------------------------------- server


def test_server_bucketing_and_zero_recompile():
    cfg = _cfg()
    sv = Server(cfg, ServeConfig(buckets=(16, 32), max_len=64, batch_slots=2))
    rng = np.random.default_rng(0)
    for n in (5, 9, 17, 30, 12, 3):
        sv.submit(rng.integers(0, cfg.vocab_size, size=n), max_new_tokens=3)
    sv.run()
    s = sv.summary()
    assert s["requests"] == 6
    # <= one prefill blob per bucket + one decode blob (NodePad guarantee)
    assert s["compiled_blobs"] <= len(sv.sc.buckets) + 1
    assert s["tokens_out"] == 18


def test_server_rejects_oversized_prompt():
    cfg = _cfg()
    sv = Server(cfg, ServeConfig(buckets=(16,), max_len=32, batch_slots=1))
    sv.submit(np.zeros(17, np.int32))
    with pytest.raises(ValueError):
        sv.run()


def test_server_wave_mode_for_ssm():
    cfg = reduced(ARCHS["mamba2-2.7b"])
    sv = Server(cfg, ServeConfig(buckets=(16,), max_len=32, batch_slots=2,
                                 mode="continuous"))
    assert sv.sc.mode == "wave"      # forced: recurrent state needs waves
    sv.submit(np.arange(8) % cfg.vocab_size, max_new_tokens=2)
    sv.run()
    assert sv.summary()["requests"] == 1


def test_server_greedy_matches_reference():
    """Wave decode (same-length prompts) must equal lm.greedy_generate."""
    import jax
    import jax.numpy as jnp
    from repro.nn import lm
    cfg = _cfg()
    sv = Server(cfg, ServeConfig(buckets=(16,), max_len=32, batch_slots=2),
                seed=0)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, size=(2, 16))
    for i in range(2):
        sv.submit(prompts[i], max_new_tokens=4)
    done = sorted(sv.run(), key=lambda r: r.uid)
    ref = lm.greedy_generate(sv.params, cfg, jnp.asarray(prompts),
                             steps=3, max_len=32)
    got = np.stack([r.output for r in done])
    np.testing.assert_array_equal(got, np.asarray(ref))
