"""GraphServe engine: bucket ladder, zero-recompile contract, batched
correctness, GrAd re-bucket policy, and the serving benchmark row."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import BucketLadder, Graph, pad_graph, stack_padded
from repro.core.models import (GNNConfig, build_operands, build_plan,
                               forward_grannite, stack_operands)
from repro.data.graphs import dynamic_graph_stream, planetoid_like
from repro.runtime.gnn_server import (DEFAULT_TECHNIQUES, GraphServe,
                                      GraphServeConfig)

BUCKETS = (128, 256, 384)                   # >= 3 bucket sizes
SIZES = [50, 120, 200, 300, 130, 60, 250, 380, 90]   # >= 8 mixed requests
IN_FEATS, CLASSES = 32, 5


def _graph(n, seed):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=2)


@pytest.fixture(scope="module")
def engine():
    sc = GraphServeConfig(ladder=BucketLadder(buckets=BUCKETS),
                          batch_slots=3, return_logits=True)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        hidden=16, num_classes=CLASSES))
    eng.register_model("gat", GNNConfig(kind="gat", in_feats=IN_FEATS,
                                        hidden=16, num_classes=CLASSES,
                                        heads=4))
    eng.warmup()
    for i, n in enumerate(SIZES):
        eng.submit(_graph(n, i), model="gcn" if i % 2 == 0 else "gat")
    eng.run()
    return eng


# ------------------------------------------------------------- bucket ladder


def test_ladder_selects_smallest_fitting_bucket():
    lad = BucketLadder(buckets=BUCKETS)
    assert lad.bucket_for(1) == 128
    assert lad.bucket_for(128) == 128
    assert lad.bucket_for(129) == 256
    assert lad.bucket_for(384) == 384
    with pytest.raises(ValueError):
        lad.bucket_for(385)
    with pytest.raises(ValueError):
        BucketLadder(buckets=(100,))        # not tile-aligned


def test_ladder_slack_reserves_headroom():
    lad = BucketLadder(buckets=BUCKETS, slack=0.5)
    assert lad.bucket_for(100) == 256       # 100 * 1.5 -> next rung
    # slack is headroom, not a hard cap: the top rung still admits
    assert lad.bucket_for(380) == 384


def test_stack_padded_rejects_mixed_buckets():
    a = pad_graph(_graph(50, 0), capacity=128)
    b = pad_graph(_graph(200, 1), capacity=256)
    with pytest.raises(ValueError):
        stack_padded([a, b])
    st = stack_padded([a, a])
    assert st.features.shape == (2, 128, IN_FEATS)
    assert st.norm_adj.shape == (2, 128, 128)


# ----------------------------------------------------- zero-recompile serving


def test_compiled_blobs_equal_distinct_plans(engine):
    # after warmup: one plan trace per (kind, bucket, fusion mode) — warmup
    # pre-traces BOTH fusion modes (DESIGN.md §11) — plus one CacheG
    # materializer trace and one GrAd delta-patcher trace (§13) per
    # (kind, bucket); the 9 mixed-size requests all replayed warm blobs
    assert engine.compiled_blobs == len(engine.models) * len(BUCKETS) * 4
    engine.assert_warm()
    s = engine.summary()
    assert s["requests"] == len(SIZES)
    assert s["compiled_blobs"] == len(engine.models) * len(BUCKETS) * 4


def test_requests_span_all_buckets(engine):
    assert {r.bucket for r in engine.finished} == set(BUCKETS)


def test_batched_logits_match_single_graph(engine):
    """Engine (vmapped, batched) output == single-graph forward_grannite."""
    for r in engine.finished:
        e = engine.models[r.model]
        ref = forward_grannite(e.params, e.cfg, jnp.asarray(r.pg.features),
                               r.ops, e.techniques)
        np.testing.assert_allclose(
            r.logits, np.asarray(ref)[: r.pg.num_nodes], atol=1e-5)
        np.testing.assert_array_equal(
            r.preds, np.asarray(ref)[: r.pg.num_nodes].argmax(-1))


def test_junk_slot_padding_never_leaks(engine):
    # 9 requests over (kind, bucket) groups with batch_slots=3 means at
    # least one partial batch ran with repeated junk slots; every finished
    # request must still carry its own prediction length
    for r in engine.finished:
        assert r.preds.shape == (r.pg.num_nodes,)
        assert r.done


# ------------------------------------------------------------ GrAd re-bucket


def test_dynamic_stream_rebuckets_exactly_once():
    base = _graph(100, 7)
    sc = GraphServeConfig(ladder=BucketLadder(buckets=BUCKETS),
                          batch_slots=1, return_logits=True)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        hidden=16, num_classes=CLASSES))
    eng.warmup(buckets=(128,))              # only the starting rung is warm
    gid = eng.attach(base, model="gcn")
    assert eng.graphs[gid][1].capacity == 128

    blobs_before = eng.compiled_blobs
    # 100 -> 160 nodes: crosses the 128-bucket boundary exactly once
    for ei, n, feats in dynamic_graph_stream(base, steps=6,
                                             edges_per_step=32,
                                             nodes_per_step=10, seed=3):
        eng.update(gid, ei, n, feats)
        eng.query(gid)
    eng.run()

    s = eng.summary()
    assert s["rebucket_events"] == 1
    assert eng.graphs[gid][1].capacity == 256
    # exactly one new compile: the (gcn, 256) plan the graph grew into.
    # (The stream adds DIRECTED edges, so CacheG's SymG transfer falls back
    # to the eager dense upload — no new materializer trace at 256.)
    assert s["cacheg_fallbacks"] > 0
    assert eng.compiled_blobs == blobs_before + 1

    # predictions after the re-bucket must equal a fresh pad_graph at the
    # new capacity (value-identical GrAd state, no drift through the move)
    final = eng.finished[-1]
    fresh = pad_graph(Graph(edge_index=ei, num_nodes=n, features=feats),
                      capacity=256)
    e = eng.models["gcn"]
    ref = forward_grannite(e.params, e.cfg, jnp.asarray(fresh.features),
                           build_operands(fresh, e.cfg, lean=True),
                           e.techniques)
    np.testing.assert_allclose(final.logits, np.asarray(ref)[:n], atol=1e-5)
    np.testing.assert_array_equal(final.preds,
                                  np.asarray(ref)[:n].argmax(-1))


# ----------------------------------------------------------- plan / operands


def test_plan_trace_count_tracks_compiles():
    cfg = GNNConfig(kind="gcn", in_feats=8, hidden=8, num_classes=3)
    plan = build_plan(cfg, 128, DEFAULT_TECHNIQUES["gcn"], batch_size=2)
    params = {"l1": {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))},
              "l2": {"w": jnp.zeros((8, 3)), "b": jnp.zeros((3,))}}
    pg = pad_graph(_graph(50, 0), capacity=128)
    ops = stack_operands([build_operands(pg, cfg, lean=True)] * 2)
    x = jnp.zeros((2, 128, 8))
    assert plan.trace_count == 0
    plan(params, x, ops)
    assert plan.trace_count == 1
    plan(params, x, ops)                    # warm replay: no new trace
    assert plan.trace_count == 1
    # params are runtime args, so the plan's identity is the full config —
    # models sharing (cfg, capacity, batch, techniques, backend, fusion,
    # shards) share one blob; "dense" is the default aggregation backend
    # (DESIGN.md §10), "none" the default fusion mode (§11), and 0 shards
    # the unsharded path (§12)
    assert plan.key == (cfg, 128, 2, DEFAULT_TECHNIQUES["gcn"], "dense",
                        "none", 0)


def test_identical_models_share_one_blob():
    """Params are runtime args: two tenants with the same (cfg, techniques)
    must share a compiled plan per bucket, not double the jit cache."""
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(128,)), batch_slots=2)
    eng = GraphServe(sc, seed=0)
    cfg = GNNConfig(kind="gcn", in_feats=IN_FEATS, hidden=16,
                    num_classes=CLASSES)
    eng.register_model("tenant_a", cfg)
    eng.register_model("tenant_b", cfg)
    eng.warmup()
    # one shared plan trace per fusion mode (warmup pre-traces both,
    # DESIGN.md §11) + one CacheG materializer trace + one GrAd
    # delta-patcher trace (§13) for the bucket — shared across tenants too
    assert eng.compiled_blobs == 4
    eng.submit(_graph(50, 0), model="tenant_a")
    eng.submit(_graph(60, 1), model="tenant_b")
    eng.run()
    eng.assert_warm()
    assert len(eng.finished) == 2


def test_stack_operands_batches_grasp_rejects_offline_quant():
    """GraSp structures batch (DESIGN.md §10: leaves gain a leading B);
    only the per-graph OFFLINE QuantGr form stays un-batchable, with an
    error naming its source (`calibrate_quant`)."""
    pg = pad_graph(_graph(50, 0), capacity=128)
    cfg = GNNConfig(kind="gcn", in_feats=IN_FEATS, hidden=16,
                    num_classes=CLASSES)
    ops = build_operands(pg, cfg, grasp=True)
    stacked = stack_operands([ops, ops])
    assert stacked.block_sparse is not None
    assert stacked.block_sparse.blocks.shape == \
        (2,) + tuple(ops.block_sparse.blocks.shape)
    assert stacked.norm_adj.shape == (2, 128, 128)
    bad = dataclasses.replace(ops, block_sparse=None, quant={"l1": object()})
    with pytest.raises(ValueError, match="calibrate_quant"):
        stack_operands([bad, bad])
    # mixed grasp/dense sets cannot share one vmapped dispatch
    with pytest.raises(ValueError, match="mix"):
        stack_operands([ops, dataclasses.replace(ops, block_sparse=None)])


# The seeded SymG round-trip sweep that lived here was promoted to a real
# hypothesis property: tests/test_properties_serving.py::
# test_symg_roundtrip_lossless (runs in CI; deepened by the nightly profile).


# -------------------------------------------------------- benchmark output


def test_serving_benchmark_emits_throughput_rows():
    from benchmarks import gnn_paper
    rows = gnn_paper.serving_throughput(n_requests=8, seed=1)
    names = [r["name"] for r in rows]
    assert any("throughput_rps" in n for n in names)
    assert any("requests/s" in r["derived"] for r in rows)
    lat = [r for r in rows if n_matches(r["name"], "latency")][0]
    assert "p50=" in lat["derived"] and "p99=" in lat["derived"]
    blobs = [r for r in rows if n_matches(r["name"], "compiled_blobs")][0]
    # 2 kinds x 3 buckets x (2 fusion-mode plans + CacheG materializer
    # + GrAd delta patcher, §13)
    assert blobs["derived"].startswith("24 ")


def n_matches(name, suffix):
    return name.endswith(suffix)
