"""Multilevel partitioner invariants (DESIGN.md §15).

The §15 partitioner — HEM coarsening, greedy weighted cut on the coarsest
graph, KL/FM boundary refinement on uncoarsening — must honor the exact
`GraphShards` contract the §12 greedy streaming cut established: the
serving engine keys partitions by structure version and assumes
determinism, the NodePad admission chain assumes the load cap is HARD, and
the GrAd delta path assumes `patch_halo` round-trips. On top of that it
claims a QUALITY win: refined cut <= greedy cut on clustered graphs (the
workload whose community structure a one-pass stream cannot see).
"""
import numpy as np
import pytest

from repro.core.graph import BucketLadder, apply_edge_delta, dense_adjacency
from repro.core.partition import (CoarseHierarchy, PARTITION_METHODS,
                                  coarsen_graph, partition_for_ladder,
                                  partition_graph, patch_halo)
from repro.data.graphs import clustered_like

IN_FEATS, CLASSES = 8, 4


def _graph(n, seed, *, within=0.05, cross=0.05, cluster=64):
    return clustered_like(num_nodes=n, num_feats=IN_FEATS,
                          num_classes=CLASSES, within_density=within,
                          cross_frac=cross, cluster=cluster, seed=seed)


# ----------------------------------------------------------- contract


@pytest.mark.parametrize("shards,cap", [(2, 200), (3, 130), (4, 100),
                                        (5, 80)])
def test_multilevel_respects_hard_cap_and_contract(shards, cap):
    """The balanced load cap survives coarsen + refine at every shard
    count, and the emitted GraphShards satisfies the full §12 contract:
    perm permutes the slot space, each shard's slot range holds only its
    own nodes, halo sets are exactly the remote in-neighbors."""
    g = _graph(390, seed=1)
    part = partition_graph(g.edge_index, 390, shards, shard_cap=cap)
    assert part.loads.sum() == 390
    assert part.loads.max() <= -(-390 // shards) <= cap
    np.testing.assert_array_equal(np.sort(part.perm),
                                  np.arange(shards * cap))
    for s in range(shards):
        own = part.perm[s * cap: s * cap + int(part.loads[s])]
        assert (part.assignment[own] == s).all()
        # halo = exact remote in-neighbor set of shard s
        src, dst = g.edge_index
        expect = np.unique(src[(part.assignment[src] != s)
                               & (part.assignment[dst] == s)])
        np.testing.assert_array_equal(part.halo[s], expect)


def test_multilevel_deterministic():
    g = _graph(600, seed=2)
    a = partition_graph(g.edge_index, 600, 4, shard_cap=150)
    b = partition_graph(g.edge_index, 600, 4, shard_cap=150)
    np.testing.assert_array_equal(a.assignment, b.assignment)
    np.testing.assert_array_equal(a.perm, b.perm)
    assert a.cut_edges == b.cut_edges


def test_unknown_method_rejected():
    g = _graph(64, seed=0)
    with pytest.raises(ValueError, match="unknown partition method"):
        partition_graph(g.edge_index, 64, 2, shard_cap=32, method="metis")
    assert set(PARTITION_METHODS) == {"multilevel", "greedy"}


def test_single_shard_trivial_both_methods():
    g = _graph(100, seed=3)
    for method in PARTITION_METHODS:
        p = partition_graph(g.edge_index, 100, 1, shard_cap=128,
                            method=method)
        assert p.cut_edges == 0 and len(p.halo[0]) == 0
        assert (p.assignment == 0).all()


# ------------------------------------------------------------- quality


@pytest.mark.parametrize("n,shards", [(768, 4), (1024, 4), (1024, 8)])
def test_refined_cut_beats_greedy_on_clustered(n, shards):
    """The §15 acceptance claim: on community-structured graphs the
    multilevel cut is STRICTLY below the greedy streaming cut (which
    chases degree order across community boundaries), and the halo —
    hence the compressed-halo wire — shrinks with it."""
    g = _graph(n, seed=4, within=0.03, cross=0.05)
    cap = -(-n // shards)
    greedy = partition_graph(g.edge_index, n, shards, shard_cap=cap,
                             method="greedy")
    multi = partition_graph(g.edge_index, n, shards, shard_cap=cap,
                            method="multilevel")
    assert multi.cut_edges < greedy.cut_edges
    assert sum(len(h) for h in multi.halo) <= sum(len(h) for h in
                                                  greedy.halo)


# ---------------------------------------------------- hierarchy reuse


def test_coarsen_once_recut_matches_direct():
    """`partition_for_ladder`'s coarsen-once optimization is exact: a
    hierarchy built at the LARGEST candidate count re-cuts every smaller
    count to the same assignment a fresh per-count hierarchy at that
    max_shards would give (the hierarchy is shard-count-independent)."""
    g = _graph(700, seed=5)
    hier = coarsen_graph(g.edge_index, 700, max_shards=4)
    assert isinstance(hier, CoarseHierarchy)
    assert hier.levels[0].n == 700
    assert hier.levels[-1].n < 700
    # node weights are conserved through every contraction
    for lvl in hier.levels:
        assert int(lvl.nw.sum()) == 700
    for s in (2, 3, 4):
        via_hier = partition_graph(g.edge_index, 700, s,
                                   shard_cap=-(-700 // s), hierarchy=hier)
        direct = partition_graph(
            g.edge_index, 700, s, shard_cap=-(-700 // s),
            hierarchy=coarsen_graph(g.edge_index, 700, max_shards=4))
        np.testing.assert_array_equal(via_hier.assignment,
                                      direct.assignment)


def test_partition_for_ladder_methods():
    ladder = BucketLadder(buckets=(128, 256))
    g = _graph(300, seed=6)
    for method in PARTITION_METHODS:
        p = partition_for_ladder(g.edge_index, 300, ladder, (2, 4),
                                 method=method)
        # smallest admissible count wins: 300/2=150 -> bucket 256
        assert (p.shards, p.shard_cap) == (2, 256)
        assert p.loads.max() <= 150


# ------------------------------------------------- GrAd compatibility


def test_patch_halo_consistent_after_refinement():
    """`patch_halo` with the SAME edge list reproduces the partitioner's
    own halo/cut exactly (the §13 delta path recomputes, never drifts),
    and with an evolved list matches a from-scratch halo build against
    the KEPT assignment."""
    g = _graph(500, seed=7)
    part = partition_graph(g.edge_index, 500, 4, shard_cap=125)
    same = patch_halo(part, g.edge_index)
    assert same.cut_edges == part.cut_edges
    for a, b in zip(same.halo, part.halo):
        np.testing.assert_array_equal(a, b)
    # evolve: drop half the edges
    keep = g.edge_index[:, ::2]
    evolved = patch_halo(part, keep)
    src, dst = keep
    cross = part.assignment[src] != part.assignment[dst]
    assert evolved.cut_edges == int(cross.sum())
    for s in range(4):
        expect = np.unique(src[cross & (part.assignment[dst] == s)])
        np.testing.assert_array_equal(evolved.halo[s], expect)
    np.testing.assert_array_equal(evolved.assignment, part.assignment)


def test_boundary_rows_identifies_cross_shard_touched_nodes():
    """`EdgeDelta.boundary_rows` (§15): exactly the touched nodes with a
    cross-shard neighbor in the PATCHED adjacency — the rows whose remote
    copies a halo-delta exchange must refresh. Interior deltas are
    wire-free."""
    n = 200
    g = _graph(n, seed=8)
    part = partition_graph(g.edge_index, n, 2, shard_cap=100)
    cap = n
    adj = dense_adjacency(g.edge_index, cap, self_loops=False)
    from repro.core.graph import gcn_norm_adjacency
    na = gcn_norm_adjacency(g.edge_index, n, cap)
    # a cross-shard pair and an interior pair of shard 0
    s0 = np.flatnonzero(part.assignment == 0)
    s1 = np.flatnonzero(part.assignment == 1)
    inter0 = [u for u in s0
              if not (adj[u, :n] != 0)[part.assignment != 0].any()]
    cross_pair = (int(s0[0]), int(s1[0]))
    delta = apply_edge_delta(
        adj, na, n,
        add_edges=[cross_pair] if adj[cross_pair] == 0 else None,
        remove_edges=[cross_pair] if adj[cross_pair] != 0 else None)
    dirty = delta.boundary_rows(part.assignment, n)
    # both endpoints of a cross-shard flip are boundary-dirty
    assert set(cross_pair) <= set(dirty.tolist())
    # brute force: touched nodes with any patched cross-shard neighbor
    expect = [int(u) for u in delta.touched
              if (delta.adj[u, :n] != 0)[
                  part.assignment != part.assignment[u]].any()]
    assert sorted(dirty.tolist()) == sorted(expect)
    if len(inter0) >= 2:
        u, v = int(inter0[0]), int(inter0[1])
        d2 = apply_edge_delta(
            adj, na, n,
            add_edges=[(u, v)] if adj[u, v] == 0 else None,
            remove_edges=[(u, v)] if adj[u, v] != 0 else None)
        # an interior flip between nodes with no cross-shard neighbors
        # dirties nothing
        assert d2.boundary_rows(part.assignment, n).size == 0
