"""CacheG operand pipeline (DESIGN.md §7): SymG bit-packed transfer, device
materialization, the device-resident operand cache, byte accounting, and the
satellite fixes that ride along (grow() supervision carry, vectorized SAGE
sampling, bucket-rule dedup)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.models as models_mod
import repro.runtime.gnn_server as server_mod
from repro.core.graph import (BucketLadder, Graph, is_symmetric_adjacency,
                              node_bucket, pack_adjacency_bits, pad_graph,
                              required_capacity, symg_pack_adjacency_bits,
                              triangular_nbits)
from repro.core.masks import sage_sample_adjacency
from repro.core.models import (GNNConfig, build_operands, compact_operands,
                               forward_grannite, materialize_operands,
                               operand_nbytes, _unpack_adjacency)
from repro.data.graphs import planetoid_like
from repro.runtime.gnn_server import GraphServe, GraphServeConfig

IN_FEATS, CLASSES = 16, 4


def _graph(n, seed=0):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=2)


def _cfg(kind):
    return GNNConfig(kind=kind, in_feats=IN_FEATS, hidden=16,
                     num_classes=CLASSES, heads=4)


def _engine(*kinds, use_cacheg=True, buckets=(128,), batch_slots=2):
    sc = GraphServeConfig(ladder=BucketLadder(buckets=buckets),
                          batch_slots=batch_slots, return_logits=True,
                          use_cacheg=use_cacheg)
    eng = GraphServe(sc, seed=0)
    for kind in kinds:
        eng.register_model(kind, _cfg(kind))
    eng.warmup()
    return eng


# ------------------------------------------------- SymG bit-pack round trip


@pytest.mark.parametrize("cap", [128, 256])
def test_symg_bits_device_roundtrip(cap):
    """pack (host, triangular bits) -> upload -> unpack (device) is lossless
    for an undirected 0/1 adjacency, at exactly cap(cap+1)/2 bits."""
    pg = pad_graph(_graph(cap - 30, seed=1), capacity=cap)
    assert is_symmetric_adjacency(pg.adj)
    packed = symg_pack_adjacency_bits(pg.adj)
    assert packed.nbytes == triangular_nbits(cap) // 8
    co = compact_operands(pg, _cfg("gcn"))
    np.testing.assert_array_equal(np.asarray(co.packed), packed)
    np.testing.assert_array_equal(np.asarray(_unpack_adjacency(co)), pg.adj)


def test_full_bitpack_device_roundtrip():
    """The non-SymG (directed / SAGE-sample) row-major packing round-trips."""
    rng = np.random.default_rng(3)
    adj = (rng.random((128, 128)) < 0.05).astype(np.float32)
    co = models_mod.CompactOperands(
        packed=jnp.asarray(pack_adjacency_bits(adj)),
        degree=jnp.zeros((128,), jnp.float32),
        num_nodes=jnp.asarray(128, jnp.int32),
        capacity=128, fields=("sample_mask",), triangular=False)
    np.testing.assert_array_equal(np.asarray(_unpack_adjacency(co)), adj)


def test_symg_pack_rejects_directed():
    adj = np.zeros((128, 128), np.float32)
    adj[3, 7] = 1.0                         # no reverse edge
    with pytest.raises(ValueError):
        symg_pack_adjacency_bits(adj)


# ------------------------------------- compact == eager operand equivalence


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
def test_materialized_operands_match_eager(kind):
    pg = pad_graph(_graph(100), capacity=128)
    cfg = _cfg(kind)
    eager = build_operands(pg, cfg, lean=True)
    mat = materialize_operands(compact_operands(pg, cfg))
    for f in ("norm_adj", "mask_mult", "bias_add", "sample_mask",
              "mean_mask"):
        a, b = np.asarray(getattr(eager, f)), np.asarray(getattr(mat, f))
        assert a.shape == b.shape, (kind, f)
        np.testing.assert_allclose(a, b, atol=1e-6, err_msg=f"{kind}/{f}")


def test_materialized_gcn_matches_eager_with_explicit_self_loop():
    """An explicit (i, i) edge in edge_index must not double-count in the
    CacheG degree: both paths add self loops idempotently."""
    g = _graph(100)
    loops = np.array([[0, 5], [0, 5]], np.int32)
    pg = pad_graph(Graph(edge_index=np.concatenate([g.edge_index, loops],
                                                   axis=1),
                         num_nodes=g.num_nodes, features=g.features),
                   capacity=128)
    cfg = _cfg("gcn")
    eager = build_operands(pg, cfg, lean=True)
    mat = materialize_operands(compact_operands(pg, cfg))
    np.testing.assert_allclose(np.asarray(eager.norm_adj),
                               np.asarray(mat.norm_adj), atol=1e-6)


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
def test_batched_cacheg_matches_eager_path(kind):
    """Same params, same graphs: the CacheG engine's batched logits equal the
    eager-operand engine's within fp32 tolerance."""
    graphs = [_graph(n, seed=i) for i, n in enumerate([60, 110, 90])]
    outs = {}
    for mode in (True, False):
        eng = _engine(kind, use_cacheg=mode)
        for g in graphs:
            eng.submit(g, model=kind)
        eng.run()
        eng.assert_warm()
        outs[mode] = {r.uid: r.logits for r in eng.finished}
    assert outs[True].keys() == outs[False].keys()
    for uid in outs[True]:
        np.testing.assert_allclose(outs[True][uid], outs[False][uid],
                                   atol=1e-5)


# --------------------------------------------- device-resident operand cache


def test_repeated_query_skips_host_operand_build(monkeypatch):
    """After the first query of an attached graph, later queries perform ZERO
    host-side operand construction (neither eager nor compact) and move zero
    operand bytes."""
    eng = _engine("gat")
    gid = eng.attach(_graph(100), model="gat")

    calls = {"eager": 0, "compact": 0}
    real_build, real_compact = models_mod.build_operands, models_mod.compact_operands

    def count_build(*a, **k):
        calls["eager"] += 1
        return real_build(*a, **k)

    def count_compact(*a, **k):
        calls["compact"] += 1
        return real_compact(*a, **k)

    # the host stage lives in core.models.prepare_host_operands (the
    # pipeline split, DESIGN.md §9), so the build fns are intercepted there
    monkeypatch.setattr(models_mod, "build_operands", count_build)
    monkeypatch.setattr(models_mod, "compact_operands", count_compact)

    eng.query(gid)                          # structure miss: one compact build
    eng.run()
    assert calls == {"eager": 0, "compact": 1}
    bytes_after_miss = eng.metrics["operand_bytes_h2d"]

    for _ in range(4):                      # warm hits: no host work at all
        eng.query(gid)
    eng.run()
    eng.assert_warm()
    assert calls == {"eager": 0, "compact": 1}
    assert eng.metrics["operand_bytes_h2d"] == bytes_after_miss
    s = eng.summary()
    assert s["operand_cache_misses"] == 1
    assert s["operand_cache_hits"] == 4


def test_update_invalidates_operand_cache():
    """update() bumps the structure version: the next query re-materializes
    exactly once and serves the NEW structure, never the stale cache."""
    eng = _engine("gcn")
    g = _graph(100)
    gid = eng.attach(g, model="gcn")
    eng.query(gid)
    eng.run()
    assert eng.summary()["operand_cache_misses"] == 1

    # add undirected edges (keeps the SymG path live) between real nodes
    extra = np.array([[0, 1, 2, 3], [5, 6, 7, 8]], np.int32)
    ei = np.concatenate([g.edge_index, extra, extra[::-1]], axis=1)
    eng.update(gid, ei, g.num_nodes, g.features)
    eng.query(gid)
    eng.query(gid)                          # second query hits the new entry
    eng.run()
    eng.assert_warm()
    s = eng.summary()
    assert s["operand_cache_misses"] == 2
    assert s["operand_cache_hits"] == 1
    assert s["cacheg_fallbacks"] == 0

    # the served logits reflect the updated structure
    e = eng.models["gcn"]
    fresh = pad_graph(Graph(edge_index=ei, num_nodes=g.num_nodes,
                            features=g.features), capacity=128)
    ref = forward_grannite(e.params, e.cfg, jnp.asarray(fresh.features),
                           build_operands(fresh, e.cfg, lean=True),
                           e.techniques)
    np.testing.assert_allclose(eng.finished[-1].logits,
                               np.asarray(ref)[: g.num_nodes], atol=1e-5)


def test_directed_graph_falls_back_to_eager_upload():
    """A directed GCN graph cannot take the SymG transfer; the engine serves
    it through the eager dense upload (counted) without breaking warmth."""
    g = _graph(100)
    adj = np.zeros((g.num_nodes, g.num_nodes), bool)
    adj[g.edge_index[1], g.edge_index[0]] = True
    pairs = np.argwhere(~adj & ~adj.T & ~np.eye(g.num_nodes, dtype=bool))
    i, j = pairs[0]                          # guaranteed-absent pair
    ei = np.concatenate([g.edge_index,
                         np.array([[i], [j]], np.int32)], axis=1)
    directed = Graph(edge_index=ei, num_nodes=g.num_nodes,
                     features=g.features)
    eng = _engine("gcn")
    gid = eng.attach(directed, model="gcn")
    eng.query(gid)
    eng.query(gid)                          # fallback ops still cache-hit
    eng.run()
    eng.assert_warm()
    s = eng.summary()
    assert s["cacheg_fallbacks"] == 1
    assert s["operand_cache_hits"] == 1


def test_detach_releases_cache_and_graph():
    eng = _engine("gcn")
    gid = eng.attach(_graph(100), model="gcn")
    eng.query(gid)
    eng.run()
    assert len(eng._operand_cache) == 1
    eng.detach(gid)
    assert eng._operand_cache == {} and gid not in eng.graphs
    eng.detach(gid)                         # idempotent


# ------------------------------------------------------ h2d byte accounting


def test_operand_bytes_accounting_matches_array_sizes():
    """operand_bytes_h2d is exactly the nbytes of what each path uploads:
    packed bits + degree + num_nodes for CacheG, the five dense fields for
    the eager path."""
    cap, n_q = 128, 3
    g = _graph(100)

    eng = _engine("gat", use_cacheg=True)
    gid = eng.attach(g, model="gat")
    for _ in range(n_q):
        eng.query(gid)
    eng.run()
    compact_expected = (triangular_nbits(cap) // 8    # SymG bit-packed adj
                        + cap * 4                     # degree float32
                        + 4)                          # num_nodes int32
    assert eng.summary()["operand_bytes_h2d"] == compact_expected

    eng = _engine("gat", use_cacheg=False)
    gid = eng.attach(g, model="gat")
    for _ in range(n_q):
        eng.query(gid)
    eng.run()
    pg = pad_graph(g, capacity=cap)
    per_request = operand_nbytes(build_operands(pg, _cfg("gat"), lean=True))
    assert per_request == 2 * 4 * cap * cap + 3 * 4   # 2 masks + 3 holes
    assert eng.summary()["operand_bytes_h2d"] == n_q * per_request
    # the compact transfer beats the eager upload by far more than the
    # acceptance floor even on a single cold miss
    assert per_request / compact_expected > 10


# ------------------------------------------------------- satellite: grow()


def test_grow_preserves_supervision_arrays():
    """Re-bucketing an attached graph must carry labels/train/test masks;
    new nodes come up unlabeled (-1 / False)."""
    lad = BucketLadder(buckets=(128, 256))
    g = _graph(100)
    pg = lad.pad(g)
    assert pg.capacity == 128

    n_new = 150                             # outgrows 128 -> re-bucket to 256
    feats = np.zeros((n_new, IN_FEATS), np.float32)
    feats[: g.num_nodes] = g.features
    ei = g.edge_index
    grown, rebucketed = lad.grow(pg, ei, n_new, feats)
    assert rebucketed and grown.capacity == 256
    np.testing.assert_array_equal(grown.labels[: g.num_nodes],
                                  g.labels)
    assert (grown.labels[g.num_nodes:] == -1).all()
    np.testing.assert_array_equal(grown.train_mask[: g.num_nodes],
                                  g.train_mask)
    np.testing.assert_array_equal(grown.test_mask[: g.num_nodes],
                                  g.test_mask)
    assert not grown.train_mask[g.num_nodes:].any()
    assert not grown.test_mask[g.num_nodes:].any()


# ---------------------------------------------- satellite: SAGE vectorized


def test_sage_sampler_vectorized_semantics():
    rng_adj = np.random.default_rng(5)
    cap, n, k = 128, 100, 6
    adj = (rng_adj.random((cap, cap)) < 0.15).astype(np.float32)
    np.fill_diagonal(adj, 0.0)

    s1 = sage_sample_adjacency(adj, n, max_neighbors=k,
                               rng=np.random.default_rng(7))
    s2 = sage_sample_adjacency(adj, n, max_neighbors=k,
                               rng=np.random.default_rng(7))
    np.testing.assert_array_equal(s1, s2)   # seeded-rng determinism

    off_diag = s1 - np.diag(np.diag(s1))
    assert (off_diag.sum(axis=1) <= k).all()          # cap respected
    assert (off_diag <= adj).all()                    # sampled ⊆ neighbors
    assert (np.diag(s1)[:n] == 1.0).all()             # include_self
    assert (s1[n:] == 0).all()                        # padded rows inert
    # rows with <= k neighbors keep every neighbor
    few = adj[:n].sum(axis=1) <= k
    np.testing.assert_array_equal(off_diag[:n][few], adj[:n][few])


def test_sage_sampler_no_neighbors_and_zero_k():
    adj = np.zeros((128, 128), np.float32)
    out = sage_sample_adjacency(adj, 10, max_neighbors=4)
    assert (np.diag(out)[:10] == 1.0).all() and out.sum() == 10
    out = sage_sample_adjacency(adj, 10, max_neighbors=0, include_self=False)
    assert out.sum() == 0


# ------------------------------------------ satellite: bucket-rule dedup


def test_bucket_rules_share_required_capacity():
    """node_bucket and BucketLadder.bucket_for both round up the same
    admission target (the slack rule lives in ONE place)."""
    lad = BucketLadder(buckets=(128, 256, 512, 1024, 2048, 4096), slack=0.5)
    for n in (10, 100, 170, 300, 683, 1365):
        want = required_capacity(n, lad.slack)
        nb = node_bucket(n, slack=lad.slack)
        assert nb >= want and nb % 128 == 0
        assert lad.bucket_for(n) >= want
        # whenever the free-form tile multiple is itself a rung, the two
        # rules agree exactly — the admission target is computed once
        if nb in lad.buckets:
            assert lad.bucket_for(n) == nb
