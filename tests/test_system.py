"""End-to-end behaviour of the paper's system: the full GraNNite pipeline
(preprocess -> enable -> optimize -> trade accuracy) on a Cora-shaped graph,
and the technique-stacking used by benchmarks (Fig. 20)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import pad_graph
from repro.core.layers import Techniques
from repro.core.models import (GNNConfig, build_operands, calibrate_quant,
                               evaluate, forward_grannite, init_params,
                               train_node_classifier)

KEY = jax.random.PRNGKey(0)


def test_full_grannite_pipeline(small_graph):
    """Train FP32 -> apply full GraNNite stack -> accuracy preserved."""
    pg = pad_graph(small_graph)
    cfg = GNNConfig(kind="gcn", in_feats=small_graph.features.shape[1],
                    num_classes=5)
    ops_ = build_operands(pg, cfg, grasp=True)

    def fwd_plain(p, x):
        return forward_grannite(p, cfg, x, ops_, Techniques(stagr=True))

    params = train_node_classifier(KEY, cfg, pg, fwd_plain, epochs=60)
    acc_fp32 = evaluate(cfg, params, pg, fwd_plain)

    # full stack: StaGr + GraphSplit + GrAd + GraSp + QuantGr
    x = jnp.asarray(pg.features)
    ops_q = dataclasses.replace(ops_, quant=calibrate_quant(params, cfg, x, ops_))
    t_full = Techniques.full_gcn()

    def fwd_full(p, xx):
        return forward_grannite(p, cfg, xx, ops_q, t_full)

    acc_full = evaluate(cfg, params, pg, fwd_full)
    assert acc_fp32 > 0.55
    assert acc_full > acc_fp32 - 0.03      # paper: negligible quality loss


def test_every_paper_model_runs_all_techniques(small_graph):
    pg = pad_graph(small_graph)
    f = small_graph.features.shape[1]
    x = jnp.asarray(pg.features)
    combos = [
        (GNNConfig(kind="gcn", in_feats=f, num_classes=5),
         Techniques.full_gcn()),
        (GNNConfig(kind="gat", in_feats=f, num_classes=5, heads=4),
         Techniques.full_gat()),
        (GNNConfig(kind="sage", in_feats=f, num_classes=5, aggregator="mean"),
         Techniques.full_sage()),
        (GNNConfig(kind="sage", in_feats=f, num_classes=5, aggregator="max"),
         Techniques.full_sage()),
    ]
    for cfg, t in combos:
        params = init_params(KEY, cfg)
        ops_ = build_operands(pg, cfg, grasp=t.grasp)
        if t.quantgr:
            ops_ = dataclasses.replace(
                ops_, quant=calibrate_quant(params, cfg, x, ops_))
        y = forward_grannite(params, cfg, x, ops_, t)
        assert y.shape == (pg.capacity, cfg.num_classes)
        assert bool(jnp.isfinite(y).all()), cfg.kind
