"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device override belongs exclusively to launch/dryrun.py)."""
import os

import numpy as np
import pytest

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")  # kernels: interpret mode

# Hypothesis depth is profile-driven: the default `ci` profile keeps the
# PR-gate suite fast, the `nightly` profile (selected by the scheduled CI
# job via HYPOTHESIS_PROFILE=nightly) runs an order of magnitude more
# examples. No property test pins its own max_examples — a per-test
# @settings would silently override the profile and opt out of the
# nightly deepening.
try:
    from hypothesis import HealthCheck, settings

    _COMMON = dict(deadline=None,
                   suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("ci", max_examples=25, **_COMMON)
    settings.register_profile("nightly", max_examples=300, **_COMMON)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:                               # tier-1 runs without it
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (training loops, LLM serving); "
        "deselect with -m 'not slow'")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_graph():
    from repro.data.graphs import planetoid_like
    return planetoid_like(num_nodes=220, num_edges=500, num_feats=48,
                          num_classes=5, seed=1)


@pytest.fixture(scope="session")
def padded_graph(small_graph):
    from repro.core.graph import pad_graph
    return pad_graph(small_graph)
