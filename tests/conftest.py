"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device override belongs exclusively to launch/dryrun.py)."""
import os

import numpy as np
import pytest

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")  # kernels: interpret mode


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_graph():
    from repro.data.graphs import planetoid_like
    return planetoid_like(num_nodes=220, num_edges=500, num_feats=48,
                          num_classes=5, seed=1)


@pytest.fixture(scope="session")
def padded_graph(small_graph):
    from repro.core.graph import pad_graph
    return pad_graph(small_graph)
