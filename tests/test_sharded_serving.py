"""Multi-device GraphSplit (DESIGN.md §12): N-way partitioner invariants,
sharded-vs-single-device differentials, auto-shard serving, and the
compressed-halo cost model.

Differential discipline: BOTH sides of every comparison are jitted — XLA's
CPU backend strength-reduces divisions to reciprocal multiplies, which
shifts int8 round() boundaries between jitted and eager runs, so a
jitted-vs-eager comparison tests the compiler, not the sharding. The
sharded plans here run vmap-simulated (1 CPU device); the CI multi-device
leg re-runs this file under XLA_FLAGS=--xla_force_host_platform_device_count=8
where the same plans place under shard_map.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.graph import BucketLadder, Graph, pad_graph
from repro.core.models import (GNNConfig, build_operands, build_plan,
                               build_sharded_operands, build_sharded_plan,
                               calibrate_tier, forward_grannite, init_params,
                               sharded_exchange_widths, stack_shard_slices,
                               unshard_logits)
from repro.core.partition import (GraphShards, modelled_sharded_latency,
                                  partition_for_ladder, partition_graph)
from repro.data.graphs import clustered_like
from repro.runtime.gnn_server import (GraphServe, GraphServeConfig,
                                      tier_techniques)

IN_FEATS, CLASSES = 12, 4


def _graph(n, seed):
    return clustered_like(num_nodes=n, num_feats=IN_FEATS,
                          num_classes=CLASSES, within_density=0.05,
                          cross_frac=0.1, seed=seed)


def _cfg(kind, **kw):
    base = dict(in_feats=IN_FEATS, hidden=16, num_classes=CLASSES)
    if kind == "gat":
        base["heads"] = 4
    base.update(kw)
    return GNNConfig(kind=kind, **base)


# --------------------------------------------------------------- partitioner


def test_partition_invariants():
    g = _graph(300, 0)
    part = partition_graph(g.edge_index, 300, 3, shard_cap=128)
    assert part.full_rows == 384
    assert part.loads.sum() == 300 and (part.loads <= 128).all()
    # perm is a permutation of the slot space
    np.testing.assert_array_equal(np.sort(part.perm), np.arange(384))
    # shard s's slot range holds only its own nodes (padding aside)
    for s in range(3):
        rows = part.perm[s * 128:(s + 1) * 128]
        own = rows[rows < 300]
        assert len(own) == part.loads[s]
        assert (part.assignment[own] == s).all()
    # halo sets are exactly the remote in-neighbors of each shard
    src, dst = g.edge_index
    for s in range(3):
        expect = np.unique(src[(part.assignment[dst] == s)
                               & (part.assignment[src] != s)])
        np.testing.assert_array_equal(part.halo[s], expect)
    assert part.cut_edges == int(
        (part.assignment[src] != part.assignment[dst]).sum())
    # deterministic: the serving cache keys partitions by structure version
    again = partition_graph(g.edge_index, 300, 3, shard_cap=128)
    np.testing.assert_array_equal(part.perm, again.perm)
    assert part.cut_edges == again.cut_edges


def test_partition_cut_beats_random_split():
    """The greedy affinity placement must beat a round-robin strawman on a
    community-structured graph — otherwise it isn't an edge-cut heuristic."""
    g = _graph(256, 1)           # two 128-node communities, 10% cross edges
    part = partition_graph(g.edge_index, 256, 2, shard_cap=128)
    src, dst = g.edge_index
    rr = (np.arange(256) % 2)
    rr_cut = int((rr[src] != rr[dst]).sum())
    assert part.cut_edges < rr_cut


def test_partition_cap_errors():
    g = _graph(64, 2)
    with pytest.raises(ValueError, match="exceeds the shard bucket"):
        partition_graph(g.edge_index, 64, 2, shard_cap=128, max_load=200)
    with pytest.raises(ValueError, match="cannot hold"):
        partition_graph(g.edge_index, 64, 2, shard_cap=128, max_load=16)
    with pytest.raises(ValueError, match="shards must be"):
        partition_graph(g.edge_index, 64, 0, shard_cap=128)


def test_partition_for_ladder_picks_smallest_fitting_count():
    lad = BucketLadder(buckets=(128, 256))
    g = _graph(300, 3)
    # 2 shards -> load 150 -> bucket 256 (fits): chosen over 4
    part = partition_for_ladder(g.edge_index, 300, lad, (4, 2))
    assert (part.shards, part.shard_cap) == (2, 256)
    # only 8 configured -> load 38 -> bucket 128
    part = partition_for_ladder(g.edge_index, 300, lad, (8,))
    assert (part.shards, part.shard_cap) == (8, 128)
    # nothing fits
    with pytest.raises(ValueError, match="fits no configured shard count"):
        partition_for_ladder(g.edge_index, 3000, lad, (2,))
    # shard count 1 is the unsharded path, never a partition
    with pytest.raises(ValueError, match="fits no configured shard count"):
        partition_for_ladder(g.edge_index, 300, lad, (1,))


# ------------------------------------------------- plan-level differentials


def _sharded_logits(cfg, t, params, g, part, *, compress, quant=None,
                    rng_seed=7):
    slices = build_sharded_operands(g, part, cfg,
                                    rng=np.random.default_rng(rng_seed))
    x, ops, mask = stack_shard_slices(slices)
    plan = build_sharded_plan(cfg, part.shard_cap, part.shards, t,
                              compress=compress)
    out = plan(params, x, ops, quant, node_mask=mask)
    return unshard_logits(np.asarray(out), part)


def _reference_logits(cfg, t, params, g, capacity, *, quant=None,
                      rng_seed=7):
    pg = pad_graph(g, capacity=capacity)
    ops = build_operands(pg, cfg, lean=True,
                         rng=np.random.default_rng(rng_seed))
    fwd = jax.jit(lambda p, x, o, q: forward_grannite(p, cfg, x, o, t,
                                                      quant=q))
    return np.asarray(fwd(params, jnp.asarray(pg.features), ops, quant))


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
@pytest.mark.parametrize("compress", [False, True])
def test_sharded_matches_single_device_fp32(kind, compress):
    """2-shard forward == jitted full-capacity forward, per kind.

    compress=False is numerically tight (same math, reassociated adds);
    compress=True adds only the int8 wire error (<= scale/2 per halo
    element, amplified once through layer 2) — the documented tolerance."""
    cfg = _cfg(kind)
    t = tier_techniques(kind)["fp32"]
    g = _graph(200, 4)
    part = partition_graph(g.edge_index, 200, 2, shard_cap=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    got = _sharded_logits(cfg, t, params, g, part, compress=compress)
    ref = _reference_logits(cfg, t, params, g, part.full_rows)[:200]
    tol = 0.05 if compress else 5e-6
    np.testing.assert_allclose(got, ref, atol=tol)


@pytest.mark.parametrize("tier", ["int8", "int8+grax"])
def test_sharded_gcn_int8_exact_vs_unsharded(tier):
    """QuantGr GCN: row blocks carry COMPLETE Â rows, so per-row scales —
    and hence every int8 rounding decision — match the single-device trace
    bit-for-bit. With the wire uncompressed the sharded int8 forward is
    EXACTLY the unsharded one (0.0), not merely close."""
    cfg = _cfg("gcn")
    t = tier_techniques("gcn")[tier]
    g = _graph(200, 5)
    part = partition_graph(g.edge_index, 200, 2, shard_cap=128)
    params = init_params(jax.random.PRNGKey(1), cfg)
    pg = pad_graph(g, capacity=part.full_rows)
    ops = build_operands(pg, cfg, lean=True)
    cal = calibrate_tier(params, cfg, jnp.asarray(pg.features), ops)
    got = _sharded_logits(cfg, t, params, g, part, compress=False, quant=cal)
    ref = _reference_logits(cfg, t, params, g, part.full_rows,
                            quant=cal)[:200]
    np.testing.assert_array_equal(got, ref)


def test_sharded_sage_max_pooling_matches():
    cfg = _cfg("sage", aggregator="max")
    t = tier_techniques("sage")["fp32"]
    g = _graph(150, 6)
    part = partition_graph(g.edge_index, 150, 2, shard_cap=128)
    params = init_params(jax.random.PRNGKey(2), cfg)
    got = _sharded_logits(cfg, t, params, g, part, compress=False)
    ref = _reference_logits(cfg, t, params, g, part.full_rows)[:150]
    np.testing.assert_allclose(got, ref, atol=5e-6)


def test_four_shards_match_two_shards():
    """Shard count is a placement choice, not a numerics choice."""
    cfg = _cfg("gcn")
    t = tier_techniques("gcn")["fp32"]
    g = _graph(400, 8)
    params = init_params(jax.random.PRNGKey(3), cfg)
    p2 = partition_graph(g.edge_index, 400, 2, shard_cap=256)
    p4 = partition_graph(g.edge_index, 400, 4, shard_cap=128)
    assert p2.full_rows == p4.full_rows == 512
    a = _sharded_logits(cfg, t, params, g, p2, compress=False)
    b = _sharded_logits(cfg, t, params, g, p4, compress=False)
    np.testing.assert_allclose(a, b, atol=5e-6)


# ---------------------------------------------------------------- cost model


def _fake_part(shards, shard_cap):
    n = shards * shard_cap
    return GraphShards(shards=shards, shard_cap=shard_cap, num_nodes=n,
                       assignment=np.zeros(n, np.int32),
                       perm=np.arange(n), halo=(), loads=np.array([n]),
                       cut_edges=0)


def test_modelled_latency_monotone_when_compute_dominates():
    """At constant full capacity, doubling shards halves the dominant
    O(C x full) aggregation; with compressed halos the wire term stays
    small enough that modelled throughput rises monotonically 1->8 — the
    scaling claim the sharded_serving benchmark asserts on real rows."""
    widths = sharded_exchange_widths(_cfg("gcn", hidden=256, num_classes=5))
    lat = [modelled_sharded_latency(_fake_part(s, 2048 // s), in_feats=16,
                                    hidden=256, classes=5,
                                    exchange_widths=widths)
           for s in (1, 2, 4, 8)]
    assert all(b < a for a, b in zip(lat, lat[1:])), lat
    # 1-shard partitions pay no wire: compressed == exact at S=1
    one_c = modelled_sharded_latency(_fake_part(1, 2048), in_feats=16,
                                     hidden=256, classes=5,
                                     exchange_widths=widths, compress=True)
    one_e = modelled_sharded_latency(_fake_part(1, 2048), in_feats=16,
                                     hidden=256, classes=5,
                                     exchange_widths=widths, compress=False)
    assert one_c == one_e


def test_modelled_latency_compression_wins_on_wire():
    p = _fake_part(4, 512)
    kw = dict(in_feats=16, hidden=256, classes=5,
              exchange_widths=(256, 5))
    assert modelled_sharded_latency(p, compress=True, **kw) < \
        modelled_sharded_latency(p, compress=False, **kw)


# ------------------------------------------------------------ serving engine


BUCKET = 128


@pytest.fixture(scope="module")
def engine():
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(BUCKET,)),
                          batch_slots=2, shard_counts=(2, 4),
                          return_logits=True)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", _cfg("gcn"), tiers=("fp32", "int8"))
    eng.warmup()
    return eng


def test_auto_shard_attach_and_query(engine):
    """A graph over the top bucket auto-shards on attach; its logits match
    a jitted single-device forward at the partition's full capacity within
    the compressed-halo tolerance; serving it recompiles nothing."""
    g = _graph(200, 10)
    gid = engine.attach(g, model="gcn")
    part = engine._sharded[gid][0]
    assert (part.shards, part.shard_cap) == (2, BUCKET)
    assert engine.summary()["shard_counts"] == {gid: 2}
    uid = engine.query(gid)
    engine.run()
    engine.assert_warm()
    r = [f for f in engine.finished if f.uid == uid][0]
    assert r.shards == 2 and r.bucket == BUCKET
    e = engine.models["gcn"]
    ref = _reference_logits(e.cfg, e.tiers["fp32"], e.params, g,
                            part.full_rows)[:200]
    np.testing.assert_allclose(r.logits, ref, atol=0.05)
    np.testing.assert_array_equal(r.preds, r.logits.argmax(-1))
    engine.detach(gid)


def test_mixed_traffic_soak_zero_recompile(engine):
    """Sharded + unsharded + both tiers interleaved: every dispatch replays
    a warm blob (the §12 acceptance soak), shard slices serve from cache
    after the first query, and halo byte accounting is exact."""
    big = engine.attach(_graph(260, 11), model="gcn")    # 4 x 128
    small = engine.attach(_graph(60, 12), model="gcn")   # unsharded
    part = engine._sharded[big][0]
    assert part.shards == 4
    before = {k: engine.metrics[k] for k in
              ("sharded_batches", "halo_bytes_exchanged",
               "collective_bytes_compressed", "collective_bytes_exact")}
    n_big = 0
    for i in range(8):
        tier = "int8" if i % 2 else "fp32"
        gid = big if i % 3 else small
        n_big += gid == big
        engine.query(gid, tier=tier)
    engine.run()
    engine.assert_warm()
    s = engine.summary()
    assert s["sharded_batches"] == before["sharded_batches"] + n_big
    # every sharded dispatch moves the same compressed halo volume:
    # 2(S-1)/S of (full_rows x width) int8 elements per exchanged layer
    e = engine.models["gcn"]
    elems = sum(part.full_rows * w for w in sharded_exchange_widths(e.cfg))
    comp = int(2 * (part.shards - 1) / part.shards * elems)
    assert s["halo_bytes_exchanged"] == \
        before["halo_bytes_exchanged"] + n_big * comp
    assert s["collective_bytes_compressed"] == \
        before["collective_bytes_compressed"] + n_big * comp
    assert s["collective_bytes_exact"] == \
        before["collective_bytes_exact"] + n_big * 4 * comp
    # shard slices were cut once and replayed from the CacheG shard cache
    assert engine.metrics["operand_cache_hits"] > 0
    engine.detach(big)
    engine.detach(small)


def test_sharded_rejects_fused_dispatch(engine):
    gid = engine.attach(_graph(200, 13), model="gcn")
    with pytest.raises(ValueError, match="fus"):
        engine.query(gid, fusion="layer")
    engine.detach(gid)


def test_update_crosses_the_sharding_boundary_both_ways():
    """GrAd on a sharded graph: shrink back into the ladder (leaves the
    sharded path), grow past it again (re-enters at a new shard count) —
    each crossing is one rebucket event and queries stay correct."""
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(BUCKET,)),
                          batch_slots=1, shard_counts=(2, 4),
                          return_logits=True)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", _cfg("gcn"))
    eng.warmup()
    g0 = _graph(200, 14)
    gid = eng.attach(g0, model="gcn")
    assert eng._sharded[gid][0].shards == 2
    blobs = eng.compiled_blobs

    g1 = _graph(90, 15)          # shrink: back into the 128 ladder
    assert eng.update(gid, g1.edge_index, 90, g1.features) is True
    assert eng.summary()["shard_counts"] == {}
    eng.query(gid)

    g2 = _graph(300, 16)         # grow: off the top again, now 4 shards
    assert eng.update(gid, g2.edge_index, 300, g2.features) is True
    part = eng._sharded[gid][0]
    assert (part.shards, part.shard_cap) == (4, BUCKET)
    eng.query(gid)
    eng.run()
    eng.assert_warm()
    assert eng.compiled_blobs == blobs       # every bucket/shard pre-traced
    assert eng.summary()["rebucket_events"] == 2

    e = eng.models["gcn"]
    final = eng.finished[-1]
    ref = _reference_logits(e.cfg, e.tiers["fp32"], e.params, g2,
                            part.full_rows)[:300]
    np.testing.assert_allclose(final.logits, ref, atol=0.05)

    # same (shards, shard_cap) after a pure value update: no rebucket
    g3 = _graph(290, 17)
    assert eng.update(gid, g3.edge_index, 290, g3.features) is False
    eng.detach(gid)
    assert eng.summary()["shard_counts"] == {}


def test_oversized_graph_without_shard_counts_still_raises():
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(BUCKET,)))
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", _cfg("gcn"))
    with pytest.raises(ValueError):
        eng.attach(_graph(200, 18), model="gcn")


def test_summary_exposes_shard_observability(engine):
    s = engine.summary()
    for k in ("shard_counts", "sharded_batches", "halo_bytes_exchanged",
              "collective_bytes_compressed", "collective_bytes_exact",
              "delta_halo_bytes_exchanged", "delta_halo_bytes_full",
              "delta_dirty_rows"):
        assert k in s, k


# ---------------------------------------------------------- replica groups


def _replica_engine(replicas):
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(BUCKET,)),
                          batch_slots=2, shard_counts=(2, 4),
                          return_logits=True, replica_groups=replicas)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", _cfg("gcn"), tiers=("fp32",))
    eng.warmup()
    return eng

def test_replica_groups_widen_sharded_dispatch():
    """§15 replica groups: with replica_groups=R the engine packs up to R
    same-key sharded requests into ONE plan call — N queries run in
    ceil(N/R) sharded batches instead of N — and every request's logits
    are BIT-identical to the width-1 engine's (the replica axis carries no
    collectives, so width is a throughput knob, never a numerics knob)."""
    e1, e2 = _replica_engine(1), _replica_engine(2)
    g = _graph(200, 20)
    want = {}
    for eng in (e1, e2):
        gid = eng.attach(g, model="gcn")
        before = eng.metrics["sharded_batches"]
        uids = [eng.query(gid) for _ in range(5)]
        eng.run()
        eng.assert_warm()
        done = {r.uid: r.logits for r in eng.finished}
        got = [done[u] for u in uids]
        if eng is e1:
            assert eng.metrics["sharded_batches"] - before == 5
            want = got
        else:
            # ceil(5 / 2) = 3 dispatches; the odd batch pads its replica
            # slot (2/6 + padded slot counted against occupancy)
            assert eng.metrics["sharded_batches"] - before == 3
            assert eng.metrics["slots_filled"] == 5
            assert eng.metrics["slots_total"] == 6
            for a, b in zip(want, got):
                np.testing.assert_array_equal(a, b)
        eng.detach(gid)


def test_partition_method_config_reaches_attach():
    """`GraphServeConfig.partition_method` selects the attach()-time
    partitioner: "greedy" reproduces the §12 streaming cut verbatim,
    the default reproduces the §15 multilevel cut."""
    g = _graph(260, 21)
    lad = BucketLadder(buckets=(BUCKET,))
    for method in ("multilevel", "greedy"):
        sc = GraphServeConfig(ladder=lad, shard_counts=(2, 4),
                              partition_method=method)
        eng = GraphServe(sc, seed=0)
        eng.register_model("gcn", _cfg("gcn"))
        gid = eng.attach(g, model="gcn")
        direct = partition_for_ladder(g.edge_index, g.num_nodes, lad,
                                      (2, 4), method=method)
        np.testing.assert_array_equal(eng._sharded[gid][0].assignment,
                                      direct.assignment)
        assert eng._sharded[gid][0].cut_edges == direct.cut_edges


# ------------------------------------------------------- halo-delta bytes


def test_sharded_delta_halo_byte_accounting():
    """§15 halo-delta exchange accounting: a one-pair cross-shard GrAd
    delta dirties exactly its boundary rows, the summary prices the dirty
    exchange STRICTLY below a full halo re-exchange (both through
    `ring_psum_nbytes` at the exact-fp32 rate the rebuild-exact operand
    patch requires), and the patched graph still serves correct logits."""
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(BUCKET,)),
                          batch_slots=1, shard_counts=(2, 4),
                          return_logits=True)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", _cfg("gcn"))
    eng.warmup()
    g = _graph(200, 22)
    gid = eng.attach(g, model="gcn")
    eng.query(gid)
    eng.run()                     # cut + cache the shard slices
    part = eng._sharded[gid][0]
    s0 = np.flatnonzero(part.assignment == 0)
    s1 = np.flatnonzero(part.assignment == 1)
    adj = eng.graphs[gid][1].adj
    pair = next((int(u), int(v)) for u in s0[:20] for v in s1[:20]
                if adj[u, v] == 0)
    assert eng.update_delta(gid, add_edges=[pair]) is True
    s = eng.summary()
    assert s["delta_dirty_rows"] >= 2          # both endpoints now boundary
    assert 0 < s["delta_halo_bytes_exchanged"] < s["delta_halo_bytes_full"]
    # exact ratio: k dirty rows of the (full x full) operand matrices plus
    # k entries of D^-1/2, so delta/full == k/full_rows (int truncation)
    want = s["delta_halo_bytes_full"] * s["delta_dirty_rows"] / part.full_rows
    assert abs(s["delta_halo_bytes_exchanged"] - want) <= 2
    uid = eng.query(gid)
    eng.run()
    eng.assert_warm()
    r = [f for f in eng.finished if f.uid == uid][0]
    e = eng.models["gcn"]
    g2 = eng._sharded[gid][0], eng._sharded[gid][1]
    ref = _reference_logits(e.cfg, e.tiers["fp32"], e.params, g2[1],
                            part.full_rows)[:200]
    np.testing.assert_allclose(r.logits, ref, atol=0.05)
    # an interior flip (both endpoints shard 0, no cross-shard neighbors
    # gained) moves NO delta bytes
    inter = [u for u in s0
             if not (adj[u, :200] != 0)[part.assignment != 0].any()]
    if len(inter) >= 2:
        u, v = int(inter[0]), int(inter[1])
        before = eng.summary()["delta_halo_bytes_exchanged"]
        assert eng.update_delta(
            gid, add_edges=[(u, v)] if adj[u, v] == 0 else None,
            remove_edges=[(u, v)] if adj[u, v] != 0 else None) is True
        after = eng.summary()
        assert after["delta_halo_bytes_exchanged"] == before
    eng.detach(gid)
