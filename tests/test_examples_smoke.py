"""Every example script must run end-to-end as a subprocess.

Examples are the repo's executable documentation: they rot silently when
an API they demo changes shape (the library's own tests keep passing).
Each script here runs under the same interpreter with PYTHONPATH=src,
exactly as README.md tells a user to invoke it. The two training-scale
scripts (serve_llm.py, train_lm.py) are marked `slow` AND skip unless
REPRO_RUN_SLOW=1 (train_lm trains for minutes — too slow for the bare
tier-1 `pytest -x -q` gate); the nightly CI job opts in. The five
serving examples finish in seconds on CPU and gate every PR.
"""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
EXAMPLES = REPO / "examples"

FAST = [
    "quickstart.py",
    "quality_tiers.py",
    "sparse_serving.py",
    "dynamic_graph_serving.py",
    "async_pipeline.py",
]
SLOW = ["serve_llm.py", "train_lm.py"]


def _run(name, timeout):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    # examples must not depend on accelerator hardware in CI
    env.setdefault("REPRO_PALLAS_INTERPRET", "1")
    proc = subprocess.run(
        [sys.executable, str(EXAMPLES / name)],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(REPO))
    assert proc.returncode == 0, (
        f"{name} exited {proc.returncode}\n"
        f"--- stdout ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr ---\n{proc.stderr[-2000:]}")
    return proc


def test_every_example_is_covered():
    """A new examples/*.py must be added to FAST or SLOW — no silent gaps."""
    on_disk = sorted(p.name for p in EXAMPLES.glob("*.py"))
    assert on_disk == sorted(FAST + SLOW)


@pytest.mark.parametrize("name", FAST)
def test_fast_example_runs(name):
    proc = _run(name, timeout=300)
    # every serving example prints *something* (a summary, a table…);
    # empty stdout means the demo silently did nothing
    assert proc.stdout.strip(), f"{name} produced no output"


@pytest.mark.slow
@pytest.mark.skipif(os.environ.get("REPRO_RUN_SLOW") != "1",
                    reason="training-scale example; set REPRO_RUN_SLOW=1")
@pytest.mark.parametrize("name", SLOW)
def test_slow_example_runs(name):
    proc = _run(name, timeout=1800)
    assert proc.stdout.strip(), f"{name} produced no output"
