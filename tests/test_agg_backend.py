"""GraSp aggregation backend dispatch (DESIGN.md §10).

The serving-reachable block-sparse path: per-(graph, bucket) backend
selection by the density/cost rule, batched `bitmap_spmm` plans, the
device-derived structure cache next to CacheG, forced-mode fallbacks, and
the `backend_fallbacks` observability contract. The Pallas grid itself is
exercised because conftest routes kernels through interpret mode; a
dedicated CI leg re-runs this file with `REPRO_PALLAS_INTERPRET=1` set
explicitly so the routing never silently regresses to the ref fallback.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import BucketLadder, pad_graph
from repro.core.models import GNNConfig, build_plan, prepare_host_operands
from repro.core.sparsity import (agg_cost_model, block_stats,
                                 compact_block_sparse, from_block_sparse,
                                 grasp_max_nnz, pad_block_sparse,
                                 select_agg_backend, stack_block_sparse,
                                 to_block_sparse)
from repro.data.graphs import clustered_like, planetoid_like
from repro.runtime.gnn_server import GraphServe, GraphServeConfig

IN_FEATS, CLASSES = 16, 4


def _engine(mode, *, buckets=(1024,), batch_slots=2, use_cacheg=True,
            hidden=8, seed=0):
    sc = GraphServeConfig(ladder=BucketLadder(buckets=buckets),
                          batch_slots=batch_slots, return_logits=True,
                          use_cacheg=use_cacheg)
    eng = GraphServe(sc, seed=seed)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        hidden=hidden, num_classes=CLASSES),
                       agg_backend=mode)
    eng.warmup()
    return eng


def _sparse_graph(seed=1, n=900, density=0.05):
    return clustered_like(num_nodes=n, num_feats=IN_FEATS,
                          num_classes=CLASSES, within_density=density,
                          seed=seed)


def _scattered_graph(seed=2, n=900):
    return planetoid_like(num_nodes=n, num_edges=40 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=2)


# ----------------------------------------------------------- structure layer


def test_pad_and_stack_block_sparse_roundtrip(rng):
    budget = grasp_max_nnz(256)
    mats, sps = [], []
    for s in range(3):
        a = ((rng.random((256, 256)) < 0.04)
             * rng.random((256, 256))).astype(np.float32)
        mats.append(a)
        sps.append(pad_block_sparse(to_block_sparse(a), budget))
    for a, sp in zip(mats, sps):
        np.testing.assert_array_equal(from_block_sparse(sp), a)
    stacked = stack_block_sparse(sps)
    assert stacked.blocks.shape == (3,) + tuple(sps[0].blocks.shape)
    for b, a in enumerate(mats):
        single = dataclasses.replace(
            stacked, blocks=np.asarray(stacked.blocks[b]),
            block_cols=np.asarray(stacked.block_cols[b]),
            counts=np.asarray(stacked.counts[b]),
            bitmap=np.asarray(stacked.bitmap[b]))
        np.testing.assert_array_equal(from_block_sparse(single), a)


def test_bitmap_spmm_batched_entry(rng):
    """The public batched kernel entry (one vmap over the single-graph
    wrapper, the same lowering a batched ExecutionPlan produces) equals
    the per-graph dense matmuls."""
    from repro.kernels import ops as kops
    budget = grasp_max_nnz(256)
    mats = [((rng.random((256, 256)) < 0.04)
             * rng.random((256, 256))).astype(np.float32) for _ in range(3)]
    hs = rng.standard_normal((3, 256, 48)).astype(np.float32)
    stacked = stack_block_sparse(
        [pad_block_sparse(to_block_sparse(a), budget) for a in mats])
    got = kops.bitmap_spmm_batched(stacked, jnp.asarray(hs))
    want = np.stack([a @ h for a, h in zip(mats, hs)])
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)


def test_pad_block_sparse_rejects_over_budget(rng):
    a = rng.random((256, 256)).astype(np.float32)   # fully dense: max_nnz=2
    sp = to_block_sparse(a)
    with pytest.raises(ValueError, match="budget"):
        pad_block_sparse(sp, sp.max_nnz - 1)


def test_device_compactor_matches_host_compaction(rng):
    """`compact_block_sparse` (jnp, device-side) and `to_block_sparse` +
    `pad_block_sparse` (numpy, host-side) produce structures that densify
    to the same matrix and agree on counts/bitmap — the two build paths of
    DESIGN.md §10 must be interchangeable."""
    a = ((rng.random((384, 384)) < 0.03)
         * rng.random((384, 384))).astype(np.float32)
    budget = grasp_max_nnz(384)
    st = block_stats(a)
    if st["max_row_nnz"] > budget:      # keep the fixture eligible
        a[:, 256:] = 0.0
        st = block_stats(a)
    host = pad_block_sparse(to_block_sparse(a), budget)
    dev, counts_true = compact_block_sparse(jnp.asarray(a), max_nnz=budget)
    np.testing.assert_array_equal(np.asarray(dev.counts), host.counts)
    np.testing.assert_array_equal(np.asarray(dev.bitmap), host.bitmap)
    np.testing.assert_array_equal(np.asarray(counts_true),
                                  host.bitmap.sum(axis=1))
    np.testing.assert_array_equal(
        from_block_sparse(dataclasses.replace(
            dev, blocks=np.asarray(dev.blocks),
            block_cols=np.asarray(dev.block_cols),
            counts=np.asarray(dev.counts))), a)


# --------------------------------------------------------------- cost rule


def test_select_backend_density_rule():
    """Low block density at a large bucket → grasp; a block-row over the
    budget → ineligible → dense regardless of mode; tiny buckets → dense
    (per-step overhead dominates)."""
    backend, dense_s, grasp_s = select_agg_backend(
        1024, 16, nnz_blocks=8, max_row_nnz=1)
    assert backend == "grasp" and grasp_s < dense_s
    cb = 1024 // 128
    backend, _, _ = select_agg_backend(
        1024, 16, nnz_blocks=cb * cb, max_row_nnz=cb)
    assert backend == "dense"
    backend, _, _ = select_agg_backend(
        1024, 16, nnz_blocks=cb * cb, max_row_nnz=cb, mode="grasp")
    assert backend == "dense"               # forced mode cannot override
    backend, _, _ = select_agg_backend(128, 16, nnz_blocks=1, max_row_nnz=1)
    assert backend == "dense"


def test_grasp_budget_monotone_and_bounded():
    prev = 0
    for cap in (128, 256, 384, 512, 1024, 2048, 4096):
        b = grasp_max_nnz(cap)
        assert b >= prev and 1 <= b <= max(cap // 128, 1)
        prev = b


def test_agg_cost_model_monotone_in_nnz():
    costs = [agg_cost_model(1024, 64, nnz_blocks=k, max_nnz=2)[1]
             for k in (1, 4, 16, 64)]
    assert costs == sorted(costs)


# ------------------------------------------------------------ serving paths


def test_auto_mode_batched_grasp_matches_dense(rng):
    """The acceptance path: a GCN in `auto` mode serves a low-density
    clustered graph through the batched `bitmap_spmm` plan (batch >= 2),
    logits equal the dense backend within fp32 tolerance, and mixed
    dense/grasp traffic replays with zero recompiles after warmup."""
    g_sparse, g_scatter = _sparse_graph(), _scattered_graph()
    engines = {m: _engine(m) for m in ("dense", "auto")}
    for eng in engines.values():
        gid_s = eng.attach(g_sparse, model="gcn")
        gid_d = eng.attach(g_scatter, model="gcn")
        eng.query(gid_s)
        eng.query(gid_s)                    # same key → one batch of 2
        eng.query(gid_d)
        eng.submit(g_sparse, model="gcn")   # one-shot intake path too
        eng.run()
        eng.assert_warm()
    s = engines["auto"].summary()
    assert s["grasp_batches"] >= 2          # batched query pair + submit
    assert s["backend_fallbacks"] == 0
    assert engines["dense"].summary()["grasp_batches"] == 0
    backs = {r.uid: r.backend for r in engines["auto"].finished}
    assert "grasp" in backs.values() and "dense" in backs.values()
    ref = {r.uid: r.logits for r in engines["dense"].finished}
    for r in engines["auto"].finished:
        np.testing.assert_allclose(r.logits, ref[r.uid], atol=1e-4,
                                   rtol=1e-4)


def test_grasp_structure_cached_per_version_and_released():
    """The block structure is derived ONCE per (graph, version) from the
    cached Â, `update()` invalidates it, and `detach()` releases it — the
    same lifecycle as the CacheG operand and int8-Â caches."""
    eng = _engine("grasp")
    g = _sparse_graph()
    gid = eng.attach(g, model="gcn")
    eng.query(gid)
    eng.run()
    assert (gid, 0) in eng._grasp_cache
    traces = eng._block_compactor.trace_count
    eng.query(gid)
    eng.query(gid)
    eng.run()
    eng.assert_warm()
    assert eng._block_compactor.trace_count == traces   # replayed, not rebuilt
    assert len(eng._grasp_cache) == 1                   # one entry, reused
    g2 = _sparse_graph(seed=7)
    eng.update(gid, g2.edge_index, g2.num_nodes, g2.features)
    assert (gid, 0) not in eng._grasp_cache
    eng.query(gid)
    eng.run()
    assert (gid, 1) in eng._grasp_cache
    eng.detach(gid)
    assert not eng._grasp_cache                         # regression: released


def test_forced_mode_ineligible_graph_counts_backend_fallback():
    eng = _engine("grasp")
    gid = eng.attach(_scattered_graph(), model="gcn")   # blocks all dense
    eng.query(gid)
    eng.query(gid)          # cached decision: still one count per request
    eng.run()
    eng.assert_warm()
    s = eng.summary()
    assert s["grasp_batches"] == 0
    assert s["backend_fallbacks"] == 2
    assert all(r.backend == "dense" for r in eng.finished)


def test_eager_engine_builds_structure_on_host(rng):
    """`use_cacheg=False` keeps ALL structure work on the host: the block
    form rides `HostOperands.grasp` (bytes counted) instead of the device
    compactor, and logits still match the dense backend."""
    eng = _engine("grasp", use_cacheg=False)
    eng_d = _engine("dense", use_cacheg=False)
    g = _sparse_graph()
    b0 = eng.metrics["operand_bytes_h2d"]
    for e in (eng, eng_d):
        e.submit(g, model="gcn")
        e.submit(g, model="gcn")
        e.run()
        e.assert_warm()
    assert eng.summary()["grasp_batches"] == 1
    assert eng.metrics["operand_bytes_h2d"] - b0 > 0
    ref = {r.uid: r.logits for r in eng_d.finished}
    for r in eng.finished:
        assert r.backend == "grasp"
        np.testing.assert_allclose(r.logits, ref[r.uid], atol=1e-4,
                                   rtol=1e-4)
    # the host product itself carries the compaction (scheduler host stage)
    pg = pad_graph(g, capacity=1024)
    cfg = eng.models["gcn"].cfg
    ho = prepare_host_operands(pg, cfg, use_cacheg=False,
                               grasp_max_nnz=grasp_max_nnz(1024))
    assert ho.grasp is not None and ho.nbytes > ho.grasp.nbytes


def test_backend_fallback_counts_ref_mode_dense_run(monkeypatch):
    """A grasp dispatch while the kernel routing is in `ref` mode runs the
    aggregation as plain XLA (no skip grid) — `backend_fallbacks` must
    surface it (satellite: a silent densify is observable, never
    invisible)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    from repro.kernels.ops import bitmap_spmm_mode
    assert bitmap_spmm_mode() == "ref"
    eng = _engine("grasp", buckets=(256,))
    gid = eng.attach(_sparse_graph(n=200, density=0.03), model="gcn")
    eng.query(gid)
    eng.query(gid)
    eng.run()
    eng.assert_warm()
    s = eng.summary()
    assert s["grasp_batches"] == 1
    # per-REQUEST unit (mirrors tier_fallbacks): both requests in the one
    # dispatch ran their aggregation dense under the ref routing
    assert s["backend_fallbacks"] == 2


def test_quant_tiers_always_resolve_dense():
    """QuantGr tiers aggregate through the cached int8 Â; the grasp backend
    never applies to them, consistently per plan, so mixed-tier traffic
    over one grasp-mode model stays warm."""
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(256,)), batch_slots=2,
                          return_logits=True)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gcn", GNNConfig(kind="gcn", in_feats=IN_FEATS,
                                        hidden=8, num_classes=CLASSES),
                       tiers=("fp32", "int8"), agg_backend="grasp")
    eng.warmup()
    gid = eng.attach(_sparse_graph(n=200, density=0.03), model="gcn")
    eng.query(gid, tier="fp32")
    eng.query(gid, tier="int8")
    eng.query(gid, tier="int8")
    eng.run()
    eng.assert_warm()
    by_tier = {r.tier: r.backend for r in eng.finished}
    assert by_tier["fp32"] == "grasp"
    assert by_tier["int8"] == "dense"


def test_non_gcn_kinds_resolve_dense():
    sc = GraphServeConfig(ladder=BucketLadder(buckets=(256,)), batch_slots=2)
    eng = GraphServe(sc, seed=0)
    eng.register_model("gat", GNNConfig(kind="gat", in_feats=IN_FEATS,
                                        hidden=8, num_classes=CLASSES,
                                        heads=2), agg_backend="auto")
    eng.warmup()
    eng.submit(_sparse_graph(n=200, density=0.03), model="gat")
    eng.run()
    eng.assert_warm()
    assert all(r.backend == "dense" for r in eng.finished)
    assert eng.summary()["grasp_batches"] == 0


def test_register_model_rejects_unknown_backend_mode():
    from repro.core.layers import Techniques
    eng = GraphServe(GraphServeConfig(ladder=BucketLadder(buckets=(128,))))
    with pytest.raises(ValueError, match="agg_backend"):
        eng.register_model("m", GNNConfig(kind="gcn", in_feats=4,
                                          num_classes=2),
                           agg_backend="sparse")
    with pytest.raises(ValueError, match="backend"):
        build_plan(GNNConfig(kind="gcn", in_feats=4, num_classes=2), 128,
                   Techniques(stagr=True), backend="csr")


def test_scheduler_pipeline_serves_grasp_warm():
    """The async pipeline groups ready requests by the 4-field batch key:
    mixed dense/grasp traffic through the deterministic scheduler equals
    the engine's own sequential answers and replays warm."""
    from repro.runtime.scheduler import PipelineConfig
    eng = _engine("auto")
    eng_ref = _engine("auto")
    g_s, g_d = _sparse_graph(), _scattered_graph()
    with eng.scheduler(PipelineConfig(deterministic=True)) as sched:
        for g in (g_s, g_s, g_d, g_s):
            sched.submit(g, model="gcn")
        out = sched.drain()
    assert eng.summary()["grasp_batches"] >= 1
    eng.assert_warm()
    uids = []
    for g in (g_s, g_s, g_d, g_s):
        uids.append(eng_ref.submit(g, model="gcn"))
    eng_ref.run()
    ref = {r.uid: r for r in eng_ref.finished}
    for r, uid in zip(out, uids):
        assert r.backend == ref[uid].backend
        np.testing.assert_allclose(r.logits, ref[uid].logits, atol=1e-5)
