"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU, asserts output shapes
and no NaNs. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.nn import lm, multimodal

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=64, seed=1):
    tok = jax.random.randint(jax.random.PRNGKey(seed), (b, s + 1), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok[:, :-1], "labels": tok[:, 1:],
             "mask": jnp.ones((b, s), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = multimodal.vision_patch_embeddings(cfg, b)
    if cfg.frontend == "audio_stub":
        batch["frames"] = multimodal.audio_frame_embeddings(
            cfg, b, cfg.encoder.frames)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(ARCHS[arch])
    p = lm.lm_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.lm_loss(p, cfg, b))(p, batch)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_reduces_loss_direction(arch):
    """One SGD step along the gradient must not produce NaN params and the
    gradient must be nonzero for the embedding table."""
    cfg = reduced(ARCHS[arch])
    p = lm.lm_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: lm.lm_loss(pp, cfg, b), has_aux=True)(p)
        newp = jax.tree_util.tree_map(lambda a, b_: a - 1e-3 * b_, p, g)
        return loss, newp, g

    loss, newp, g = step(p, batch)
    leaves = jax.tree_util.tree_leaves(newp)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves), arch
    gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
    assert gnorm > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """serve_step(cache) after prefill == full forward at the same position
    — the GrAd-cursor serving path is exact for every family."""
    cfg = reduced(ARCHS[arch])
    p = lm.lm_init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    tok = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["prefix_embeds"] = multimodal.vision_patch_embeddings(cfg, b)
    if cfg.frontend == "audio_stub":
        kw["enc_embeds"] = multimodal.audio_frame_embeddings(
            cfg, b, cfg.encoder.frames)
    h, _, plen = lm.lm_hidden(p, cfg, tok,
                              prefix_embeds=kw.get("prefix_embeds"),
                              enc_embeds=kw.get("enc_embeds"))
    full_logits = lm.hidden_to_logits(p, cfg, h[:, -1])
    # cache capacity covers tokens + any multimodal prefix positions
    plen_extra = kw["prefix_embeds"].shape[1] if "prefix_embeds" in kw else 0
    _, state = lm.lm_prefill(p, cfg, tok[:, : s - 1],
                             max_len=s + plen_extra + 8, **kw)
    dec_logits, _ = lm.lm_decode_step(p, cfg, tok[:, s - 1], state)
    np.testing.assert_allclose(np.asarray(full_logits), np.asarray(dec_logits),
                               rtol=5e-3, atol=5e-3)


def test_param_counts_match_published_scale():
    """Analytic param counts should land near the published sizes."""
    expect = {
        "gemma2-27b": (27e9, 0.10),
        "chatglm3-6b": (6.2e9, 0.15),
        "qwen3-4b": (4e9, 0.25),
        "smollm-135m": (135e6, 0.15),
        "mamba2-2.7b": (2.7e9, 0.15),
        "olmoe-1b-7b": (6.9e9, 0.15),
        "llama4-scout-17b-a16e": (107e9, 0.15),   # total (active 17B)
        "jamba-v0.1-52b": (52e9, 0.15),
        "phi-3-vision-4.2b": (3.8e9, 0.15),       # text backbone of 4.2B
        "whisper-base": (72e6, 0.35),             # backbone-only (no conv/pos)
    }
    for arch, (want, tol) in expect.items():
        got = ARCHS[arch].param_count()
        assert abs(got - want) / want < tol, (arch, got, want)


def test_moe_active_params():
    cfg = ARCHS["olmoe-1b-7b"]
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < total
    assert abs(active - 1.3e9) / 1.3e9 < 0.25     # ~1B active


def test_long_context_eligibility():
    assert ARCHS["mamba2-2.7b"].sub_quadratic
    assert ARCHS["jamba-v0.1-52b"].sub_quadratic
    assert not ARCHS["gemma2-27b"].sub_quadratic  # half its layers are global
    assert not ARCHS["qwen3-4b"].sub_quadratic
