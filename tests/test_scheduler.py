"""Async pipeline scheduler (DESIGN.md §9): best-fill batching (the
head-of-line regression), per-model fairness, deterministic-mode
reproducibility, backpressure accounting, and the interleaved soak/stress
run over the threaded scheduler. Hypothesis-free — this file is tier-1."""
import numpy as np
import pytest

from repro.core.graph import BucketLadder
from repro.core.models import GNNConfig
from repro.data.graphs import planetoid_like
from repro.runtime.gnn_server import (GraphServe, GraphServeConfig,
                                      best_fill_key)
from repro.runtime.scheduler import (PipelineConfig, PipelineScheduler,
                                     QueueFull)

IN_FEATS, CLASSES = 16, 4
BUCKETS = (128, 256)


def _graph(n, seed=0):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=2)


def _cfg(kind):
    return GNNConfig(kind=kind, in_feats=IN_FEATS, hidden=16,
                     num_classes=CLASSES, heads=4)


def _engine(*kinds, batch_slots=3, tiers=None):
    sc = GraphServeConfig(ladder=BucketLadder(buckets=BUCKETS),
                          batch_slots=batch_slots, return_logits=True)
    eng = GraphServe(sc, seed=0)
    for kind in kinds:
        eng.register_model(kind, _cfg(kind), tiers=tiers)
    eng.warmup()
    return eng


# ------------------------------------------------------- best-fill batching


def test_best_fill_key_prefers_fullest_then_fairness_then_fifo():
    slots = 3
    # BatchKey is (model, bucket, tier, agg backend, fusion, shards) —
    # §10/§11/§12
    stats = {("a", 128, "fp32", "dense", "none", 0): (1, 0),  # head-of-line
             ("b", 128, "fp32", "dense", "none", 0): (3, 1),  # fills batch
             ("c", 128, "fp32", "grasp", "layer", 0): (5, 2)}  # fills (cap 3)
    # fullest wins; b vs c tie on capped fill -> FIFO (b arrived first)
    assert best_fill_key(stats, slots) == ("b", 128, "fp32", "dense",
                                           "none", 0)
    # fairness: b was just dispatched, so the tie now goes to c
    assert best_fill_key(stats, slots,
                         {"b": 7}) == ("c", 128, "fp32", "grasp", "layer", 0)
    # a full batch still beats a model that waited longer with a lone req
    assert best_fill_key(stats, slots,
                         {"b": 1, "c": 2}) == ("b", 128, "fp32", "dense",
                                               "none", 0)


def test_head_of_line_odd_request_no_longer_forces_partial_batch():
    """Regression (old `_run_batch` used queue[0]'s key): a lone odd request
    at the head must not force a 1-of-N dispatch while a fully fillable key
    waits behind it."""
    eng = _engine("gcn", "gat", batch_slots=3)
    eng.submit(_graph(40, 0), model="gat")      # lone head-of-line request
    for i in range(3):
        eng.submit(_graph(50 + i, i + 1), model="gcn")
    eng.run()
    eng.assert_warm()
    assert eng.metrics["batches"] == 2
    assert eng.metrics["slots_filled"] == 4
    # the full gcn batch dispatched FIRST; the lone gat request second
    assert [r.model for r in eng.finished] == ["gcn", "gcn", "gcn", "gat"]


def test_fairness_tie_break_round_robins_models():
    """At equal fill, the least-recently-dispatched model goes first — one
    chatty tenant cannot starve another at equal batch efficiency."""
    eng = _engine("gcn", "gat", batch_slots=2)
    for i in range(4):
        eng.submit(_graph(40 + i, i), model="gcn")
    for i in range(2):
        eng.submit(_graph(60 + i, 10 + i), model="gat")
    eng.run()
    # gcn (FIFO on the first tie), then gat (fairness), then gcn's rest
    assert [r.model for r in eng.finished] == ["gcn", "gcn", "gat", "gat",
                                               "gcn", "gcn"]


# ------------------------------------------------------ deterministic mode


def _mixed_traffic(sched, n=8):
    tickets = []
    for i in range(n):
        tickets.append(sched.submit(_graph(30 + 23 * i, seed=i),
                                    model="gcn" if i % 2 else "gat"))
    return tickets


def test_deterministic_mode_is_reproducible():
    runs = []
    for _ in range(2):
        eng = _engine("gcn", "gat", batch_slots=3)
        with eng.scheduler(PipelineConfig(deterministic=True)) as sched:
            _mixed_traffic(sched)
            out = sched.drain()
        eng.assert_warm()
        runs.append((tuple(r.uid for r in eng.finished),
                     tuple(r.model for r in eng.finished),
                     eng.metrics["batches"], eng.metrics["slots_filled"],
                     [np.asarray(r.preds) for r in out]))
    assert runs[0][:4] == runs[1][:4]           # identical batch composition
    for a, b in zip(runs[0][4], runs[1][4]):
        np.testing.assert_array_equal(a, b)


def test_deterministic_scheduler_matches_sync_run():
    """Pipelined (deterministic) serving is value-identical to the sync
    submit+run path for the same submission order."""
    eng_sync = _engine("gcn", "gat", batch_slots=3)
    for i in range(8):
        eng_sync.submit(_graph(30 + 23 * i, seed=i),
                        model="gcn" if i % 2 else "gat")
    eng_sync.run()

    eng_pipe = _engine("gcn", "gat", batch_slots=3)
    with eng_pipe.scheduler(PipelineConfig(deterministic=True)) as sched:
        _mixed_traffic(sched)
        out = sched.drain()

    by_uid = {r.uid: r for r in eng_sync.finished}
    for r in out:
        np.testing.assert_allclose(r.logits, by_uid[r.uid].logits, atol=1e-5)
        np.testing.assert_array_equal(r.preds, by_uid[r.uid].preds)


# ------------------------------------------------------------ backpressure


def test_reject_backpressure_sheds_load_and_counts():
    eng = _engine("gcn")
    sched = eng.scheduler(PipelineConfig(deterministic=True, max_pending=2,
                                         backpressure="reject"))
    sched.submit(_graph(40, 0), model="gcn")
    sched.submit(_graph(41, 1), model="gcn")
    with pytest.raises(QueueFull):
        sched.submit(_graph(42, 2), model="gcn")
    assert sched.metrics["rejected"] == 1
    assert sched.metrics["accepted"] == 2
    out = sched.drain()
    sched.close()
    assert len(out) == 2 and all(r.done for r in out)


def test_block_backpressure_advances_pipeline_inline():
    """Deterministic 'block' mode drains inline instead of waiting on a
    thread: every over-bound submit advances the pipeline and is counted."""
    eng = _engine("gcn", batch_slots=2)
    sched = eng.scheduler(PipelineConfig(deterministic=True, max_pending=2,
                                         max_ready=2, backpressure="block"))
    for i in range(7):
        sched.submit(_graph(40 + i, i), model="gcn")
    assert sched.metrics["blocked"] == 5        # submits 3..7 hit the bound
    out = sched.drain()
    sched.close()
    eng.assert_warm()
    assert len(out) == 7
    assert sorted(r.uid for r in out) == list(range(7))


def test_async_tiny_queues_complete_under_block_backpressure():
    eng = _engine("gcn", "gat", batch_slots=2)
    with eng.scheduler(PipelineConfig(host_workers=2, window_ms=1.0,
                                      max_pending=2, max_ready=2)) as sched:
        for i in range(10):
            sched.submit(_graph(30 + 17 * i, seed=i),
                         model="gcn" if i % 2 else "gat")
        out = sched.drain(timeout=120)
    eng.assert_warm()
    assert len(out) == 10 and all(r.done for r in out)
    assert sched.metrics["completed"] == sched.metrics["accepted"] == 10


def test_drain_consumes_error_and_keeps_results_recoverable():
    """A host-stage error (here: querying a graph that does not exist) is
    raised by drain() exactly once — a second drain() returns the requests
    that DID complete instead of re-raising forever."""
    eng = _engine("gcn", batch_slots=2)
    sched = eng.scheduler(PipelineConfig(host_workers=1, window_ms=0.0))
    sched.submit(_graph(40, 0), model="gcn")
    sched.query(999)                        # no such graph_id
    with pytest.raises(KeyError):
        sched.drain(timeout=60)
    out = sched.drain(timeout=60)           # error consumed, results live
    sched.close()
    assert len(out) == 1 and out[0].done
    assert sched.metrics["completed"] == sched.metrics["accepted"] == 2


def test_close_is_idempotent_and_engine_survives():
    eng = _engine("gcn")
    sched = eng.scheduler(PipelineConfig(host_workers=1))
    sched.submit(_graph(40, 0), model="gcn")
    sched.drain(timeout=60)
    sched.close()
    sched.close()
    with pytest.raises(RuntimeError):
        sched.submit(_graph(41, 1), model="gcn")
    # the bare sync path still works on the same engine
    eng.submit(_graph(42, 2), model="gcn")
    eng.run()
    eng.assert_warm()
    assert len(eng.finished) == 2


# ------------------------------------------------------------------- soak


def test_soak_interleaved_lifecycle_under_async_scheduler():
    """Interleaved attach/update/query/detach/submit across two models under
    the threaded scheduler: zero recompiles after warmup, every accepted
    request completes exactly once, and the counters conserve
    (slots_filled <= slots_total, byte/hit counters never decrease)."""
    rng = np.random.default_rng(7)
    eng = _engine("gcn", "gat", batch_slots=3,
                  tiers=("fp32", "int8", "int8+grax"))
    eng.calibrate("gcn", _graph(80, seed=100))
    eng.calibrate("gat", _graph(80, seed=101))
    gids = {"gcn": [eng.attach(_graph(60, 1), model="gcn", calibrate=False)],
            "gat": [eng.attach(_graph(70, 2), model="gat", calibrate=False)]}
    tiers = (None, "fp32", "int8", "int8+grax")

    byte_trail, tickets = [], []
    with eng.scheduler(PipelineConfig(host_workers=2, window_ms=1.0,
                                      max_pending=8, max_ready=8)) as sched:
        for step in range(60):
            model = "gcn" if rng.random() < 0.5 else "gat"
            op = rng.choice(["submit", "query", "query", "update", "cycle"])
            if op == "submit":
                tickets.append(sched.submit(
                    _graph(int(rng.integers(20, 180)), seed=1000 + step),
                    model=model, tier=tiers[rng.integers(len(tiers))]))
            elif op == "query":
                # query the long-lived graph (gid[0] is never detached):
                # a query racing a detach of ITS OWN graph is a legitimate
                # host-stage error, not what this soak asserts clean
                tickets.append(sched.query(
                    gids[model][0], tier=tiers[rng.integers(len(tiers))]))
            elif op == "update":
                g = _graph(int(rng.integers(20, 180)), seed=2000 + step)
                eng.update(gids[model][0], g.edge_index, g.num_nodes,
                           g.features)
            else:                                # cycle: detach + reattach
                if len(gids[model]) > 1:
                    eng.detach(gids[model].pop())
                gids[model].append(eng.attach(
                    _graph(int(rng.integers(20, 180)), seed=3000 + step),
                    model=model, calibrate=False))
            byte_trail.append((eng.metrics["operand_bytes_h2d"],
                               eng.metrics["operand_cache_hits"],
                               eng.metrics["operand_cache_misses"]))
        out = sched.drain(timeout=300)

    eng.assert_warm()                            # zero recompiles, threaded
    # every accepted request completed exactly once
    assert sched.metrics["completed"] == sched.metrics["accepted"]
    assert len(out) == len(tickets) == len(eng.finished)
    assert len({r.uid for r in out}) == len(out)
    assert all(r.done and r.preds is not None for r in out)
    # metrics conservation
    m = eng.metrics
    assert m["slots_filled"] <= m["slots_total"]
    assert m["slots_total"] == m["batches"] * eng.sc.batch_slots
    assert len(m["latency_s"]) == len(out)
    for a, b in zip(byte_trail, byte_trail[1:]):  # counters never decrease
        assert b[0] >= a[0] and b[1] >= a[1] and b[2] >= a[2]
    # mixed-tier traffic was actually served (calibrated: no quant fallback
    # for gcn int8; gat quant tiers exist too — both models calibrated)
    assert {r.tier for r in out} >= {"fp32", "int8"}
