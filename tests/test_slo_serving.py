"""SLO-aware serving (DESIGN.md §14), proven under a deterministic virtual
clock: slack-aware EDF dispatch, deadline expiry/late flagging, the
bias-corrected EWMA + latency bank, measured-latency backend routing (the
BENCH grasp regression, pinned), the tolerance tier router, and the
governor's downgrade/shed cycle. Every timing assertion reads the injected
`FakeClock` — zero `time.sleep` anywhere in this file. Tier-1."""
import numpy as np
import pytest

from clockwork import FakeClock

from repro.core.graph import BucketLadder
from repro.core.models import GNNConfig
from repro.core.sparsity import select_agg_backend
from repro.data.graphs import planetoid_like
from repro.runtime.ewma import Ewma, LatencyBank, StragglerGate
from repro.runtime.gnn_server import (GraphServe, GraphServeConfig,
                                      best_fill_key, edf_best_fill_key,
                                      edf_pending_stats, pending_stats)
from repro.runtime.scheduler import (PipelineConfig, PipelineScheduler,
                                     QueueFull)
from repro.runtime.slo import SLOConfig, SLOGovernor

IN_FEATS, CLASSES = 16, 4
BUCKETS = (128, 256)
INF = float("inf")


def _graph(n, seed=0):
    return planetoid_like(num_nodes=n, num_edges=3 * n, num_feats=IN_FEATS,
                          num_classes=CLASSES, seed=seed, train_per_class=2)


def _cfg(kind):
    return GNNConfig(kind=kind, in_feats=IN_FEATS, hidden=16,
                     num_classes=CLASSES, heads=4)


# Warm engines are expensive; build each flavor once and give every test a
# FRESH FakeClock (timestamps/metrics of earlier tests never leak into
# virtual-time assertions, which always diff against per-test snapshots).
_ENGINES = {}


def _engine(name):
    if name in _ENGINES:
        return _ENGINES[name]
    sc = GraphServeConfig(ladder=BucketLadder(buckets=BUCKETS),
                          batch_slots=2, return_logits=True)
    if name == "plain":            # gcn+gat, fp32 only — EDF/expiry tests
        eng = GraphServe(sc, seed=0, clock=FakeClock())
        eng.register_model("gcn", _cfg("gcn"))
        eng.register_model("gat", _cfg("gat"))
    elif name == "tiers":          # full ladder + auto agg — routing tests
        eng = GraphServe(sc, seed=0, clock=FakeClock())
        eng.register_model("gcn", _cfg("gcn"),
                           tiers=("fp32", "int8", "int8+grax"),
                           agg_backend="auto")
    elif name == "governed":       # fp32/int8 + an SLO governor
        slo = SLOConfig(target_p99_ms=10.0, window=2, min_samples=1,
                        breach_checks=2, clear_checks=2, max_queue_depth=2,
                        ladder=("fp32", "int8"))
        eng = GraphServe(sc, seed=0, clock=FakeClock(), slo=slo)
        eng.register_model("gcn", _cfg("gcn"), tiers=("fp32", "int8"))
    elif name == "solo":           # gcn fp32 only — EWMA convergence
        eng = GraphServe(sc, seed=0, clock=FakeClock())
        eng.register_model("gcn", _cfg("gcn"))
    eng.warmup()
    if name in ("tiers", "governed"):
        eng.calibrate("gcn", _graph(60, seed=9))
    _ENGINES[name] = eng
    return eng


def _fresh_clock(eng, **kw):
    clk = FakeClock(**kw)
    eng.clock = clk
    return clk


def _reset_governor(gov):
    gov.level = 0
    gov.downgrades = 0
    gov.upgrades = 0
    gov._breach_streak = 0
    gov._clear_streak = 0
    gov._lat.clear()


def _by_uid(eng, uid):
    return next(r for r in eng.finished if r.uid == uid)


# ------------------------------------------------------------ EDF dispatch


def test_edf_best_fill_key_fill_then_slack_then_fairness_then_fifo():
    slots = 2
    ka = ("a", 128, "fp32", "dense", "none", 0)
    kb = ("b", 128, "fp32", "dense", "none", 0)
    # 1. fill dominates slack: a full batch beats a tighter lone request
    stats = {ka: (2, 1, 10.0), kb: (1, 0, 0.001)}
    assert edf_best_fill_key(stats, slots) == ka
    # 2. slack breaks fill ties — even against the FIFO-older key
    stats = {ka: (2, 0, 10.0), kb: (2, 1, 0.001)}
    assert edf_best_fill_key(stats, slots) == kb
    # 3. fairness breaks slack ties (least-recently dispatched model first)
    stats = {ka: (2, 0, INF), kb: (2, 1, INF)}
    assert edf_best_fill_key(stats, slots, {"a": 5, "b": 1}) == kb
    # 4. FIFO last
    assert edf_best_fill_key(stats, slots) == ka


def test_edf_matches_best_fill_when_no_deadlines():
    """Deadline-free traffic batches exactly as before: all slacks are +inf
    and the legacy (fill, fairness, FIFO) rules decide."""
    slots = 3
    stats2 = {("a", 128, "fp32", "dense", "none", 0): (1, 0),
              ("b", 128, "fp32", "dense", "none", 0): (3, 1),
              ("c", 256, "fp32", "grasp", "layer", 0): (5, 2)}
    stats3 = {k: (c, h, INF) for k, (c, h) in stats2.items()}
    for last in ({}, {"b": 7}, {"b": 1, "c": 2}, {"a": 3}):
        assert (edf_best_fill_key(stats3, slots, dict(last))
                == best_fill_key(stats2, slots, dict(last)))


def test_edf_pending_stats_tracks_min_slack():
    eng = _engine("plain")
    clk = _fresh_clock(eng)
    eng.submit(_graph(40, 0), model="gcn", deadline_ms=50.0)
    eng.submit(_graph(41, 1), model="gcn", deadline_ms=5.0)
    eng.submit(_graph(42, 2), model="gat")
    stats = edf_pending_stats(eng.queue, clk.now())
    gcn_key = ("gcn", 128, "fp32", "dense", "none", 0)
    gat_key = ("gat", 128, "fp32", "dense", "none", 0)
    count, head, slack = stats[gcn_key]
    assert (count, head) == (2, 0)
    assert slack == pytest.approx(0.005)       # the TIGHTEST of the two
    assert stats[gat_key][2] == INF            # no deadline -> +inf
    eng.run()


def test_edf_beats_fifo_on_slack_inversion():
    """The crafted inversion: deadline-free gat arrives FIRST, a full batch
    of tight-deadline gcn arrives second. The legacy rule dispatches gat
    (FIFO); EDF dispatches gcn — same fill, tighter slack."""
    eng = _engine("plain")
    clk = _fresh_clock(eng)
    for i in range(2):
        eng.submit(_graph(40 + i, i), model="gat")
    for i in range(2):
        eng.submit(_graph(50 + i, i), model="gcn", deadline_ms=5.0)
    old = best_fill_key(pending_stats(eng.queue), 2)
    new = edf_best_fill_key(edf_pending_stats(eng.queue, clk.now()), 2)
    assert old[0] == "gat" and new[0] == "gcn"   # the differential, pinned
    n0 = len(eng.finished)
    eng.run()
    assert [r.model for r in eng.finished[n0:]] == ["gcn", "gcn",
                                                    "gat", "gat"]
    assert all(not r.deadline_missed for r in eng.finished[n0:])


def test_scheduler_dispatches_edf_order():
    eng = _engine("plain")
    _fresh_clock(eng)
    sched = PipelineScheduler(eng, PipelineConfig(deterministic=True))
    for i in range(2):
        sched.submit(_graph(40 + i, i), model="gat")
    for i in range(2):
        sched.submit(_graph(50 + i, i), model="gcn", deadline_ms=5.0)
    n0 = len(eng.finished)
    out = sched.drain()
    sched.close()
    assert len(out) == 4
    assert [r.model for r in eng.finished[n0:]] == ["gcn", "gcn",
                                                    "gat", "gat"]


# -------------------------------------------------------- deadline expiry


def test_expired_request_completes_flagged_without_dispatch():
    eng = _engine("plain")
    clk = _fresh_clock(eng)
    misses0, batches0 = (eng.metrics["deadline_misses"],
                         eng.metrics["batches"])
    uid_exp = eng.submit(_graph(40, 0), model="gcn", deadline_ms=10.0)
    clk.advance(0.02)                           # queue wait blows the budget
    uid_ok = eng.submit(_graph(41, 1), model="gat")
    eng.run()
    r_exp, r_ok = _by_uid(eng, uid_exp), _by_uid(eng, uid_ok)
    assert r_exp.done and r_exp.deadline_missed and r_exp.preds is None
    assert r_exp.finished_s - r_exp.submitted_s == pytest.approx(0.02)
    assert r_ok.preds is not None and not r_ok.deadline_missed
    # the expired request never occupied a batch slot
    assert eng.metrics["batches"] - batches0 == 1
    assert eng.metrics["deadline_misses"] - misses0 == 1


def test_executed_but_late_flags_and_still_delivers():
    eng = _engine("plain")
    _fresh_clock(eng, default_batch_s=0.05)     # every dispatch "costs" 50ms
    misses0 = eng.metrics["deadline_misses"]
    uid = eng.submit(_graph(40, 0), model="gcn", deadline_ms=10.0)
    eng.run()
    r = _by_uid(eng, uid)
    assert r.deadline_missed and r.preds is not None   # late, NOT dropped
    assert eng.metrics["deadline_misses"] - misses0 == 1


def test_no_deadline_request_can_never_expire():
    eng = _engine("plain")
    clk = _fresh_clock(eng)
    uid = eng.submit(_graph(40, 0), model="gcn")
    clk.advance(3600.0)                         # an hour in the queue
    eng.run()
    r = _by_uid(eng, uid)
    assert not r.deadline_missed and r.preds is not None


def test_scheduler_sweeps_expired_from_ready_buffer():
    eng = _engine("plain")
    clk = _fresh_clock(eng)
    sched = PipelineScheduler(eng, PipelineConfig(deterministic=True))
    t_exp = sched.submit(_graph(40, 0), model="gcn", deadline_ms=10.0)
    t_ok = sched.submit(_graph(41, 1), model="gat")
    clk.advance(0.05)
    out = sched.drain()
    assert out[t_exp].deadline_missed and out[t_exp].preds is None
    assert not out[t_ok].deadline_missed and out[t_ok].preds is not None
    assert sched.metrics["completed"] == 2      # expired still COMPLETES
    sched.close()


# ---------------------------------------- bias-corrected EWMA + the bank


def test_ewma_bias_corrected_first_sample():
    e = Ewma(alpha=0.1)
    assert e.value is None and e.count == 0
    assert e.observe(10.0) == pytest.approx(10.0)   # 1 sample -> that sample
    for _ in range(9):
        e.observe(1.0)
    # bias-corrected estimate after [10, 1x9]: s/den = 1.0003/0.6513
    assert e.value == pytest.approx(1.5358, rel=1e-3)
    # the OLD trainer rule seeded the first sample with weight 1.0:
    naive = None
    for x in [10.0] + [1.0] * 9:
        naive = x if naive is None else 0.9 * naive + 0.1 * x
    assert naive == pytest.approx(4.487, rel=1e-3)
    # the fix matters: the naive estimate is ~3x further from the truth
    assert abs(e.value - 1.0) < abs(naive - 1.0) / 3
    assert (e.min, e.max, e.count) == (1.0, 10.0, 10)


def test_straggler_gate_excludes_stragglers_and_catches_outlier():
    gate = StragglerGate(factor=2.5, alpha=0.1)
    assert gate.baseline is None
    assert not gate.check(10.0)     # first (compile-heavy) step: no verdict
    for _ in range(9):
        assert not gate.check(1.0)
    base = gate.baseline
    assert base == pytest.approx(1.5358, rel=1e-3)
    # 3.9s: flagged under the bias-corrected baseline (2.5 * 1.536 = 3.84);
    # the old weight-1.0 seeding put the bar at 2.5 * 4.487 = 11.2 — missed
    assert gate.check(3.9)
    assert gate.baseline == base    # stragglers never train the baseline
    assert not gate.check(1.0)


def test_trainer_uses_shared_straggler_gate():
    """Satellite (c): the trainer's straggler EWMA is the shared
    `runtime/ewma.py` implementation, not a private copy."""
    import repro.runtime.trainer as trainer
    assert trainer.StragglerGate is StragglerGate


def test_latency_bank_seed_vs_measured():
    bank = LatencyBank(alpha=0.2)
    key = ("m", 128, "fp32", "dense", "none", 0)
    assert bank.predict(key) is None
    bank.seed(key, 1e-3)
    assert bank.predict(key) == pytest.approx(1e-3)
    assert bank.measured(key) is None
    # first real sample REPLACES the seed outright — never blended
    bank.observe(key, 5e-3)
    assert bank.predict(key) == pytest.approx(5e-3)
    assert bank.measured(key) == pytest.approx(5e-3)
    assert bank.samples(key) == 1


def test_latency_bank_prediction_stays_within_sample_range():
    bank = LatencyBank(alpha=0.2)
    key = ("m", 128, "fp32", "dense", "none", 0)
    bank.seed(key, 123.0)                       # wildly wrong seed
    xs = [0.004, 0.011, 0.007, 0.002, 0.009, 0.005]
    for x in xs:
        bank.observe(key, x)
        assert min(xs) <= bank.predict(key) <= max(xs)


def test_latency_bank_measured_pair():
    bank = LatencyBank()
    kd = ("m", 256, "fp32", "dense", "none", 0)
    kg = ("m", 256, "fp32", "grasp", "none", 0)
    bank.seed(kd, 1e-4)
    bank.seed(kg, 2e-4)
    match = lambda k: k[0] == "m" and k[1] == 256
    backend_of = lambda k: k[3]
    assert bank.measured_pair(match=match, backend_of=backend_of) == {}
    bank.observe(kd, 3e-3)                      # seeds never count
    pair = bank.measured_pair(match=match, backend_of=backend_of)
    assert set(pair) == {"dense"}
    bank.observe(kg, 1e-3)
    pair = bank.measured_pair(match=match, backend_of=backend_of)
    assert pair["dense"] == pytest.approx(3e-3)
    assert pair["grasp"] == pytest.approx(1e-3)


def test_ewma_converges_from_wrong_roofline_seed():
    """Engine-level: the bank's roofline seed is orders of magnitude off;
    measured dispatches (scripted at 5ms) take over from the FIRST sample
    and `summary()["ewma_vs_model"]` exposes the model error."""
    eng = _engine("solo")
    clk = _fresh_clock(eng)
    clk.script({0: "gcn"}, 0.005)
    key = ("gcn", 128, "fp32", "dense", "none", 0)
    seed_pred = eng.bank.predict(key)
    assert seed_pred is not None and seed_pred < 1e-4   # roofline: way off
    for i in range(6):
        eng.submit(_graph(40 + i, i), model="gcn")
        eng.run()
        assert eng.bank.predict(key) == pytest.approx(0.005)
    assert eng.bank.samples(key) >= 6
    s = eng.summary()
    assert s["ewma_vs_model"] is not None and s["ewma_vs_model"] > 100


# ------------------------------------- measured-latency backend routing


def test_measured_inversion_flips_select_agg_backend():
    """The BENCH grasp regression, pinned: the roofline says grasp wins at
    (cap 2048, 64 feats, 4 blocks), but MEASURED latency says dense is 5x
    faster — `measured=` must flip the auto decision. This test fails
    against the old roofline-only `select_agg_backend`."""
    base, dense_s, grasp_s = select_agg_backend(
        2048, 64, nnz_blocks=4, max_row_nnz=1, mode="auto")
    assert base == "grasp" and grasp_s < dense_s        # the model's view
    flipped, d2, g2 = select_agg_backend(
        2048, 64, nnz_blocks=4, max_row_nnz=1, mode="auto",
        measured=(1e-4, 5e-4))
    assert flipped == "dense"
    assert (d2, g2) == (dense_s, grasp_s)   # reported costs stay modelled
    # a PARTIAL pair never overrides — the unmeasured path is not condemned
    part, _, _ = select_agg_backend(2048, 64, nnz_blocks=4, max_row_nnz=1,
                                    mode="auto", measured=(None, 5e-4))
    assert part == "grasp"
    # eligibility is structural and measurement can never override it
    dense_forced, _, _ = select_agg_backend(
        2048, 64, nnz_blocks=4, max_row_nnz=10, mode="auto",
        measured=(1.0, 1e-6))
    assert dense_forced == "dense"


def test_measured_inversion_flips_engine_backend_routing():
    """End to end: a sparse graph routes dense by the roofline; after the
    bank holds measured samples showing grasp 1000x cheaper at this
    (model, bucket), the SAME submission routes grasp."""
    eng = _engine("tiers")
    _fresh_clock(eng)
    uid = eng.submit(_graph(200, 0), model="gcn", tier="fp32")
    eng.run()
    assert _by_uid(eng, uid).backend == "dense"         # roofline choice
    eng.bank.observe(("gcn", 256, "fp32", "dense", "none", 0), 1e-3)
    eng.bank.observe(("gcn", 256, "fp32", "grasp", "none", 0), 1e-6)
    uid = eng.submit(_graph(200, 0), model="gcn", tier="fp32")
    eng.run()
    r = _by_uid(eng, uid)
    assert r.backend == "grasp" and r.preds is not None
    eng.assert_warm()                                   # the flip replays warm


# ------------------------------------------------------ tolerance routing


def test_tolerance_routes_cheapest_fitting_tier():
    # the dense-backend "governed" engine keeps the cost comparison
    # one-variant-per-tier (tolerance routing itself never consults the
    # governor, which only steers fully-unpinned requests)
    eng = _engine("governed")
    clk = _fresh_clock(eng)
    _reset_governor(eng.governor)
    # scripted costs keep the bank's measured side consistent with the
    # expectation as the test's own dispatches feed it: int8 runs cheaper
    clk.script({2: "fp32"}, 5e-3)
    clk.script({2: "int8"}, 1e-4)
    eng.models["gcn"].accuracy_delta["int8"] = -2.0  # costs 2 accuracy pts
    uid = eng.submit(_graph(40, 0), model="gcn", tolerance=1.0)
    eng.run()
    assert _by_uid(eng, uid).tier == "fp32"     # nothing cheap fits 1pt
    uid = eng.submit(_graph(41, 1), model="gcn", tolerance=3.0)
    eng.run()
    assert _by_uid(eng, uid).tier == "int8"     # int8 fits and is cheaper
    eng.assert_warm()


def test_tolerance_router_prefers_measured_cost_over_seed():
    """Measured samples trump seeds: the seed says int8 is cheaper, so the
    cold router picks it — but the dispatch MEASURES int8 slow (scripted),
    and the very next request routes back to fp32. The wrong seed never
    blends into the verdict."""
    eng = _engine("governed")
    clk = _fresh_clock(eng)
    _reset_governor(eng.governor)
    clk.script({2: "fp32"}, 1e-6)
    clk.script({2: "int8"}, 1e-3)
    eng.models["gcn"].accuracy_delta["int8"] = -2.0
    kf = ("gcn", 128, "fp32", "dense", "none", 0)
    ki = ("gcn", 128, "int8", "dense", "none", 0)
    old_bank = eng.bank
    try:
        eng.bank = LatencyBank()                # isolate from other tests
        eng.bank.seed(kf, 2e-7)
        eng.bank.seed(ki, 1e-7)                 # seed story: int8 cheaper
        tiers = []
        for i in range(3):
            uid = eng.submit(_graph(42 + i, i), model="gcn", tolerance=3.0)
            eng.run()
            tiers.append(_by_uid(eng, uid).tier)
        # cold: seeds route int8; its own measured 1ms then loses to fp32
        assert tiers == ["int8", "fp32", "fp32"]
        assert eng.bank.measured(ki) == pytest.approx(1e-3)
    finally:
        eng.bank = old_bank


def test_explicit_tier_is_a_contract_tolerance_never_overrides():
    eng = _engine("governed")
    _fresh_clock(eng)
    _reset_governor(eng.governor)
    uid = eng.submit(_graph(43, 3), model="gcn", tier="int8")
    eng.run()
    assert _by_uid(eng, uid).tier == "int8"     # calibrated -> served as asked


# ----------------------------------------------------------- the governor


def test_governor_hysteresis_and_recovery_unit():
    cfg = SLOConfig(target_p99_ms=10.0, window=2, min_samples=2,
                    breach_checks=3, clear_checks=2)
    gov = SLOGovernor(cfg)
    assert gov.p99_ms() is None
    gov.observe(0.05)                           # below min_samples: no verdict
    assert gov.p99_ms() is None and gov.level == 0
    gov.observe(0.05)                           # breach 1
    gov.observe(0.05)                           # breach 2
    assert gov.level == 0                       # hysteresis: not yet
    gov.observe(0.05)                           # breach 3 -> downgrade
    assert gov.level == 1 and gov.downgrades == 1
    gov.observe(0.001)                          # window [50ms, 1ms]: breach
    assert gov.level == 1
    gov.observe(0.001)                          # clear 1
    assert gov.level == 1                       # one fast check ≠ recovery
    gov.observe(0.001)                          # clear 2 -> upgrade
    assert gov.level == 0 and gov.upgrades == 1


def test_governor_saturates_at_bottom_rung():
    gov = SLOGovernor(SLOConfig(window=2, min_samples=1, breach_checks=1,
                                target_p99_ms=1.0))
    for _ in range(10):
        gov.observe(1.0)
    assert gov.level == gov.max_level == 2
    assert gov.downgrades == 2                  # never counts past the floor


def test_governor_tier_override_walks_registered_ladder():
    gov = SLOGovernor(SLOConfig())
    assert gov.tier_override("fp32", ["fp32", "int8"]) is None  # level 0
    gov.level = 1
    assert gov.tier_override("fp32", ["fp32", "int8"]) == "int8"
    assert gov.tier_override("fp32",
                             ["fp32", "int8", "int8+grax"]) == "int8"
    gov.level = 2
    assert gov.tier_override("fp32", ["fp32", "int8"]) == "int8"  # saturates
    assert gov.tier_override("fp32",
                             ["fp32", "int8", "int8+grax"]) == "int8+grax"
    assert gov.tier_override("fp32", ["fp32"]) == "fp32"


def test_governor_should_shed_requires_floor_and_depth():
    gov = SLOGovernor(SLOConfig(max_queue_depth=4))
    assert not gov.should_shed(100)             # quality rungs still left
    gov.level = gov.max_level
    assert not gov.should_shed(3)               # queue still shallow
    assert gov.should_shed(4)


def test_governor_downgrades_then_recovers_serving_tier():
    """The full engine-level cycle under scripted latencies: fp32 batches
    breach the 10ms target -> the governor steps default traffic to int8;
    int8 batches clear it -> traffic steps back up. Counted in summary()."""
    eng = _engine("governed")
    clk = _fresh_clock(eng)
    _reset_governor(eng.governor)
    clk.script({2: "fp32"}, 0.05)
    clk.script({2: "int8"}, 0.001)
    tiers = []
    for i in range(6):
        uid = eng.submit(_graph(40 + i, i), model="gcn")
        eng.run()
        tiers.append(_by_uid(eng, uid).tier)
    assert tiers == ["fp32", "fp32", "int8", "int8", "int8", "fp32"]
    s = eng.summary()
    assert s["slo_downgrades"] == 1 and s["slo_level"] == 0
    eng.assert_warm()                           # downgrades replay warm


def test_governor_never_overrides_pinned_requests():
    eng = _engine("governed")
    clk = _fresh_clock(eng)
    _reset_governor(eng.governor)
    eng.governor.level = eng.governor.max_level
    clk.script({2: "fp32"}, 0.05)
    uid = eng.submit(_graph(44, 4), model="gcn", tier="fp32")
    eng.run()
    assert _by_uid(eng, uid).tier == "fp32"     # explicit pin honored


def test_governor_sheds_at_floor_through_scheduler_reject_path():
    eng = _engine("governed")
    _fresh_clock(eng)
    _reset_governor(eng.governor)
    eng.governor.level = eng.governor.max_level  # quality exhausted
    shed0 = eng.metrics["shed_requests"]
    sched = PipelineScheduler(eng, PipelineConfig(deterministic=True))
    sched.submit(_graph(40, 0), model="gcn")     # depth 0: accepted
    sched.submit(_graph(41, 1), model="gcn")     # depth 1: accepted
    with pytest.raises(QueueFull):
        sched.submit(_graph(42, 2), model="gcn")  # depth 2 >= 2: shed
    assert sched.metrics["rejected"] == 1
    assert eng.metrics["shed_requests"] - shed0 == 1
    _reset_governor(eng.governor)                # let the backlog drain
    sched.drain()
    sched.close()


# ---------------------------------------------------------------- summary


def test_summary_exposes_slo_counters():
    eng = _engine("plain")
    s = eng.summary()
    for k in ("deadline_misses", "shed_requests", "slo_downgrades",
              "slo_upgrades", "slo_level", "ewma_vs_model"):
        assert k in s
    assert s["slo_downgrades"] == 0 and s["slo_level"] == 0  # no governor
    assert s["slo_upgrades"] == 0


# ------------------------------------------------------------------- soak


def test_zero_recompile_soak_mixed_deadlines_and_tiers():
    """Mixed deadline/tolerance/tier traffic over two buckets through the
    deterministic scheduler, entirely on virtual time: every accepted
    request completes exactly once, expiries are exactly the crafted
    zero-budget set, and nothing recompiles."""
    eng = _engine("tiers")
    clk = _fresh_clock(eng, default_batch_s=1e-3)
    misses0 = eng.metrics["deadline_misses"]
    sched = PipelineScheduler(eng, PipelineConfig(deterministic=True))
    N = 24
    expect_miss = set()
    for i in range(N):
        kw = {}
        if i % 3 == 0:
            kw["tier"] = "int8"
        elif i % 3 == 1:
            kw["tolerance"] = 5.0
        if i % 4 == 0:
            kw["deadline_ms"] = 0.0             # zero budget: must expire
            expect_miss.add(i)
        elif i % 4 == 2:
            kw["deadline_ms"] = 1e6             # must never expire
        n = 40 if i % 2 == 0 else 200           # bucket mix: 128 and 256
        t = sched.submit(_graph(n, seed=i), model="gcn", **kw)
        assert t == i
        clk.advance(1e-4)
    out = sched.drain()
    sched.close()
    assert len(out) == N
    assert len({r.uid for r in out}) == N       # exactly-once completion
    for i, r in enumerate(out):
        assert r.done
        assert r.deadline_missed == (i in expect_miss)
        assert (r.preds is None) == (i in expect_miss)
        if i % 3 == 0:
            assert r.tier == "int8"             # pins survive the SLO path
    assert eng.metrics["deadline_misses"] - misses0 == len(expect_miss)
    eng.assert_warm()                           # zero recompiles end to end
