"""Launch-layer units that don't need the 512-device env: input specs,
roofline HLO parsing, cost-config construction, shape rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch import roofline as R
from repro.launch import specs as S
from repro.nn.config import SHAPES


def test_runnable_rules():
    ok, _ = S.runnable(ARCHS["mamba2-2.7b"], SHAPES["long_500k"])
    assert ok
    ok, why = S.runnable(ARCHS["qwen3-4b"], SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    ok, _ = S.runnable(ARCHS["jamba-v0.1-52b"], SHAPES["long_500k"])
    assert ok


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_input_specs_shapes(arch):
    cfg = ARCHS[arch]
    for sname, shape in SHAPES.items():
        if not S.runnable(cfg, shape)[0]:
            continue
        specs = S.input_specs(cfg, shape)
        leaves = jax.tree_util.tree_leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        if shape.kind == "train":
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
        if shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch,)
            # KV caches (nsb, B, S, KV, hd) hold the assigned context length
            kv = [l for l in jax.tree_util.tree_leaves(specs["caches"])
                  if len(l.shape) == 5
                  and l.shape[3:] == (cfg.num_kv_heads, cfg.head_dim_)]
            if not cfg.attention_free:
                assert kv and kv[0].shape[2] == shape.seq_len


def test_abstract_params_match_param_count():
    """eval_shape'd parameter tree総 size must equal the analytic count."""
    for arch in ("smollm-135m", "olmoe-1b-7b", "mamba2-2.7b"):
        cfg = ARCHS[arch]
        params = S.abstract_params(cfg)
        total = sum(int(np.prod(l.shape))
                    for l in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        # analytic count ignores norms/biases/dt params — allow 2%
        assert abs(total - analytic) / analytic < 0.02, (arch, total, analytic)


def test_cost_config_scales_layers():
    cfg = ARCHS["jamba-v0.1-52b"]
    c1 = S.cost_config(cfg, 1)
    c2 = S.cost_config(cfg, 2)
    assert c1.num_layers == len(cfg.superblock)
    assert c2.num_layers == 2 * len(cfg.superblock)
    assert c1.unroll_scans and c2.unroll_scans
    w = ARCHS["whisper-base"]
    assert S.cost_config(w, 2).encoder.num_layers == 2


def test_collective_bytes_parsing():
    hlo = """
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64,128]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[256]{0}, f32[256]{0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a = f32[32,32]{1,0} all-to-all(%z), dimensions={1}
  %cp = u8[100]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(%p, %q)
"""
    got = R.collective_bytes(hlo)
    assert got["all-reduce"] == 1024 * 512 * 4
    assert got["all-gather"] == 64 * 128 * 2
    assert got["reduce-scatter"] == 2 * 256 * 4
    assert got["all-to-all"] == 32 * 32 * 4
    assert got["collective-permute"] == 100
    assert "dot" not in got


def test_collective_bytes_async_counted_once():
    hlo = """
  %s = f32[128]{0} all-reduce-start(%x)
  %d = f32[128]{0} all-reduce-done(%s)
"""
    got = R.collective_bytes(hlo)
    assert got.get("all-reduce", 0) == 128 * 4


def test_roofline_terms_math():
    t = R.RooflineTerms(arch="a", shape="train_4k", mesh="single",
                        flops_per_device=197e12, bytes_per_device=819e9,
                        coll_bytes_per_device=int(50e9), coll_breakdown={},
                        model_flops=197e12 * 256, n_devices=256)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 1.0) < 1e-9
    assert abs(t.t_collective - 1.0) < 1e-9
    assert t.useful_flops_fraction == 1.0
    assert t.roofline_fraction == 1.0


def test_model_flops_moe_uses_active():
    cfg = ARCHS["olmoe-1b-7b"]
    f = R.model_flops_estimate(cfg, SHAPES["train_4k"])
    dense_equiv = 6.0 * cfg.param_count() * SHAPES["train_4k"].global_batch \
        * SHAPES["train_4k"].seq_len
    assert f < dense_equiv / 3        # top-8 of 64 experts


def test_microbatch_policy():
    assert S.train_microbatches(ARCHS["gemma2-27b"], SHAPES["train_4k"], 16) == 8
    assert S.train_microbatches(ARCHS["gemma2-27b"], SHAPES["train_4k"], 32) == 8
    assert S.train_microbatches(ARCHS["gemma2-27b"], SHAPES["prefill_32k"], 16) == 2
