"""Unit tests for the idle distributed substrate (DESIGN.md §12 prereqs).

The sharded serving path reuses two pieces of the LM distribution stack
that previously only ran under the 512-device dry-run: the compressed
collectives (`dist.compress` — now also the halo-exchange wire format) and
the rule-based sharding specs (`dist.sharding` — now also the source of the
shard-mesh PartitionSpecs). These tests pin their contracts on a plain CPU
host, with the collective axis vmap-simulated — the same simulation
`build_sharded_plan` falls back to below the device count, so what is
tested here is literally the serving math.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dist.compress import (INT8_MAX, compressed_psum,
                                 compressed_psum_mean, exact_psum_mean)
from repro.dist.sharding import AXIS_RULES, spec_for_axes

# ------------------------------------------------------ compressed psum


def _vaxis(fn, *args):
    """Run `fn` under a vmap-simulated collective axis named "shard"."""
    return jax.vmap(fn, axis_name="shard")(*args)


def test_compressed_psum_mean_error_bound():
    """|compressed mean - exact mean| <= scale/2 elementwise, where
    scale = global_absmax / 127 — the documented QuantGr wire bound."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(4, 64, 8)).astype(np.float32) * 3.0)
    mean, _ = _vaxis(lambda x: compressed_psum_mean(x, "shard"), g)
    exact = _vaxis(lambda x: exact_psum_mean(x, "shard"), g)
    scale = float(np.abs(np.asarray(g)).max()) / INT8_MAX
    err = np.abs(np.asarray(mean) - np.asarray(exact)).max()
    assert err <= scale / 2 + 1e-7, (err, scale / 2)


def test_compressed_psum_residual_roundtrip():
    """residual = g - represented(g): adding it back to the represented
    form reconstructs the input exactly (error-feedback contract), and the
    residual itself is bounded by scale/2."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(2, 32, 4)).astype(np.float32))
    _, residual = _vaxis(lambda x: compressed_psum(x, "shard"), g)
    scale = float(np.abs(np.asarray(g)).max()) / INT8_MAX
    assert np.abs(np.asarray(residual)).max() <= scale / 2 + 1e-7
    represented = np.asarray(g) - np.asarray(residual)
    np.testing.assert_allclose(represented + np.asarray(residual),
                               np.asarray(g), rtol=0, atol=0)


def test_compressed_psum_disjoint_blocks_bound():
    """The halo-exchange corollary (DESIGN.md §12): when participants hold
    DISJOINT zero-padded blocks, zeros quantize exactly, each output
    element receives exactly ONE non-zero contribution, and the elementwise
    error of the SUM stays <= scale/2 regardless of the shard count."""
    rng = np.random.default_rng(2)
    shards, rows, width = 4, 16, 8
    blocks = np.zeros((shards, shards * rows, width), np.float32)
    for s in range(shards):
        blocks[s, s * rows:(s + 1) * rows] = rng.normal(
            size=(rows, width)).astype(np.float32) * (s + 1)
    g = jnp.asarray(blocks)
    total, _ = _vaxis(lambda x: compressed_psum(x, "shard"), g)
    exact = blocks.sum(axis=0)
    scale = float(np.abs(blocks).max()) / INT8_MAX
    # every lane computes the same psum; check lane 0 against the dense sum
    err = np.abs(np.asarray(total)[0] - exact).max()
    assert err <= scale / 2 + 1e-7, (err, scale / 2)


def test_compressed_psum_delta_assembles_dirty_rows():
    """Halo-DELTA exchange (DESIGN.md §15): each shard contributes only
    the dirty rows it OWNS (ownership masked inside the collective), so
    the psum assembles the dirty-row buffer exactly — `compress=False` is
    BIT-identical to the owners' rows (masked zeros add exactly), which
    is what the operand-delta path's rebuild-exact contract needs."""
    from repro.dist.compress import compressed_psum_delta
    rng = np.random.default_rng(4)
    shards, k, width = 4, 6, 8
    owners = jnp.asarray(rng.integers(0, shards, size=(k,)), jnp.int32)
    # every shard holds a DIFFERENT local buffer; only owned rows survive
    local = rng.normal(size=(shards, k, width)).astype(np.float32)
    rows = jnp.asarray(local)
    out = _vaxis(lambda x: compressed_psum_delta(x, owners, "shard",
                                                 compress=False), rows)
    expect = local[np.asarray(owners), np.arange(k)]
    np.testing.assert_array_equal(np.asarray(out)[0], expect)
    # every lane agrees (it is one psum)
    for s in range(shards):
        np.testing.assert_array_equal(np.asarray(out)[s], expect)


def test_compressed_psum_delta_int8_error_bound():
    """The compressed dirty-row wire carries the same <= scale/2
    elementwise bound as the §12 halo exchange: disjoint-by-construction
    contributions, one global pmax scale."""
    from repro.dist.compress import compressed_psum_delta
    rng = np.random.default_rng(5)
    shards, k, width = 3, 5, 16
    owners = jnp.asarray(rng.integers(0, shards, size=(k,)), jnp.int32)
    local = rng.normal(size=(shards, k, width)).astype(np.float32) * 2.0
    out = _vaxis(lambda x: compressed_psum_delta(
        x, owners, "shard", compress=True), jnp.asarray(local))
    expect = local[np.asarray(owners), np.arange(k)]
    # scale comes from the MASKED buffers each participant quantizes
    masked = local * (np.asarray(owners)[None, :, None]
                      == np.arange(shards)[:, None, None])
    scale = float(np.abs(masked).max()) / INT8_MAX
    err = np.abs(np.asarray(out)[0] - expect).max()
    assert err <= scale / 2 + 1e-7, (err, scale / 2)


def test_compressed_psum_sum_consistent_with_mean():
    """compressed_psum_mean must be exactly compressed_psum / n — one wire
    format, two reductions."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(3, 8, 8)).astype(np.float32))
    total, r1 = _vaxis(lambda x: compressed_psum(x, "shard"), g)
    mean, r2 = _vaxis(lambda x: compressed_psum_mean(x, "shard"), g)
    np.testing.assert_allclose(np.asarray(total) / 3.0, np.asarray(mean),
                               rtol=0, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


# ------------------------------------------------------- sharding rules


class _StubMesh:
    """Just enough mesh for spec_for_axes: it only reads `.shape`."""

    def __init__(self, **shape):
        self.shape = shape


def test_graph_shard_rule_maps_to_shard_axis():
    assert AXIS_RULES["graph_shard"] == "shard"
    spec = spec_for_axes(("graph_shard", None, None), (4, 128, 16),
                         _StubMesh(shard=4))
    assert tuple(spec) == ("shard", None, None)


def test_graph_replica_rule_maps_to_replica_axis():
    """Replica groups (DESIGN.md §15): the outer replica axis of an
    R-wide sharded dispatch maps onto "replica" on the R x S mesh."""
    assert AXIS_RULES["graph_replica"] == "replica"
    spec = spec_for_axes(("graph_replica", "graph_shard", None, None),
                         (2, 4, 128, 16), _StubMesh(replica=2, shard=4))
    assert tuple(spec) == ("replica", "shard", None, None)


def test_spec_divisibility_fallback():
    """A dimension NOT divisible by its mesh axis replicates instead of
    sharding — the fallback that lets one model definition run on any
    device count (and the reason a 3-shard mesh never corrupts a 4-row
    operand)."""
    spec = spec_for_axes(("graph_shard",), (4,), _StubMesh(shard=3))
    assert tuple(spec) == (None,)
    # divisible again -> sharded again
    spec = spec_for_axes(("graph_shard",), (6,), _StubMesh(shard=3))
    assert tuple(spec) == ("shard",)


def test_spec_missing_axis_and_reuse_fallback():
    """An axis absent from the mesh replicates; a mesh axis already used by
    an earlier dim is not used twice."""
    assert tuple(spec_for_axes(("graph_shard",), (4,), _StubMesh())) == (
        None,)
    spec = spec_for_axes(("graph_shard", "graph_shard"), (4, 4),
                         _StubMesh(shard=4))
    assert tuple(spec) == ("shard", None)
