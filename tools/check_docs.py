#!/usr/bin/env python3
"""Docs-consistency check (CI gate).

Two classes of documentation rot this repo has already paid for once
(DESIGN.md §6's stale "only non-default path" claim; the pre-GraphServe
README) are made mechanical failures:

  1. Section citations — every ``DESIGN.md §N`` reference in the source
     tree (code comments, docstrings, markdown) must resolve to an actual
     ``## §N`` heading in DESIGN.md. Renumbering sections without a sweep
     breaks CI, which is the point: DESIGN.md promises its numbers are
     stable *because* they are cited.
  2. README techniques glossary — every backticked ``path.py:symbol``
     entry point must name an existing file containing that symbol, every
     bare backticked code symbol must still exist under src/, and all 13
     paper techniques must have a glossary row.

Run from the repo root: ``python tools/check_docs.py`` (exit 1 on any
dangling reference; no dependencies beyond the stdlib).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
TECHNIQUES = ("GraphSplit", "StaGr", "GrAd", "NodePad", "EffOp", "GraSp",
              "PreG", "SymG", "CacheG", "QuantGr", "GrAx1", "GrAx2", "GrAx3")

SECTION_RE = re.compile(r"^## §([0-9A-Za-z-]+)", re.M)
CITATION_RE = re.compile(r"DESIGN\.md\s*§([0-9A-Za-z-]+)")
ENTRYPOINT_RE = re.compile(r"`([\w/.-]+\.py):(\w+)`")
BARE_SYMBOL_RE = re.compile(r"`([A-Za-z_][\w.]*)`")


def _scan_files():
    yield ROOT / "README.md"
    yield ROOT / "DESIGN.md"
    for d in SCAN_DIRS:
        for p in sorted((ROOT / d).rglob("*")):
            if p.suffix in (".py", ".md") and p.is_file():
                yield p


def check_design_citations(errors):
    sections = set(SECTION_RE.findall((ROOT / "DESIGN.md").read_text()))
    if not sections:
        errors.append("DESIGN.md: no '## §N' sections found at all")
        return
    for path in _scan_files():
        text = path.read_text()
        for m in CITATION_RE.finditer(text):
            if m.group(1) == "N":       # meta-mention of the citation FORM
                continue
            if m.group(1) not in sections:
                line = text[: m.start()].count("\n") + 1
                errors.append(
                    f"{path.relative_to(ROOT)}:{line}: cites DESIGN.md "
                    f"§{m.group(1)} but DESIGN.md has only "
                    f"§{{{', '.join(sorted(sections))}}}")


def _glossary_rows(readme: str, errors):
    m = re.search(r"^## Techniques glossary\n(.*?)(?=^## |\Z)", readme,
                  re.M | re.S)
    if not m:
        errors.append("README.md: '## Techniques glossary' section missing")
        return []
    return [ln for ln in m.group(1).splitlines()
            if ln.startswith("|") and not set(ln) <= {"|", "-", " "}][1:]


def check_readme_glossary(errors):
    readme = (ROOT / "README.md").read_text()
    rows = _glossary_rows(readme, errors)
    if not rows:
        return
    covered = " ".join(r.split("|")[1] for r in rows)
    for tech in TECHNIQUES:
        if not re.search(rf"\b{re.escape(tech)}\b", covered):
            errors.append(f"README.md glossary: no row for technique {tech}")

    src_text = "\n".join(p.read_text() for p in (ROOT / "src").rglob("*.py"))
    for row in rows:
        # path.py:symbol entry points → file exists and defines the symbol
        for fpath, sym in ENTRYPOINT_RE.findall(row):
            target = ROOT / fpath
            if not target.is_file():
                errors.append(f"README.md glossary: entry point file "
                              f"{fpath} does not exist")
            elif not re.search(rf"\b{re.escape(sym)}\b", target.read_text()):
                errors.append(f"README.md glossary: {fpath} no longer "
                              f"contains symbol {sym!r}")
        # bare code symbols → last identifier still exists under src/
        stripped = ENTRYPOINT_RE.sub("", row)
        for token in BARE_SYMBOL_RE.findall(stripped):
            leaf = token.split(".")[-1]
            if not re.search(rf"\b{re.escape(leaf)}\b", src_text):
                errors.append(f"README.md glossary: code symbol {token!r} "
                              f"not found anywhere under src/")


def main() -> int:
    errors = []
    check_design_citations(errors)
    check_readme_glossary(errors)
    if errors:
        print(f"docs-consistency: {len(errors)} failure(s)")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("docs-consistency: DESIGN.md citations and README glossary OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
