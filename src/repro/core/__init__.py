"""GraNNite core: the paper's contribution as composable JAX modules."""
from . import effop, graph, layers, masks, models, partition, quant, sparsity
from .graph import Graph, PaddedGraph, node_bucket, pad_graph, update_edges
from .layers import Techniques
from .models import GNNConfig, GranniteOperands, build_operands

__all__ = [
    "effop", "graph", "layers", "masks", "models", "partition", "quant",
    "sparsity", "Graph", "PaddedGraph", "node_bucket", "pad_graph",
    "update_edges", "Techniques", "GNNConfig", "GranniteOperands",
    "build_operands",
]
