"""GraphSplit: offline cost-model-driven host/device partitioning.

The paper profiles each op on CPU and NPU during calibration, adds the
CPU<->NPU transfer cost, and picks the cut that minimizes end-to-end latency
subject to RAW dependencies. We reproduce that structure for the host(CPU,
numpy) <-> device(TPU, jit) split:

  * stage graph  = a linear pipeline of named stages (GNN preprocessing ->
    aggregation -> combination -> decode), each with measured/modelled host
    and device latencies;
  * transfer cost = bytes / host_link_bw + fixed launch latency, charged at
    every host->device or device->host boundary crossing;
  * optimal cut  = DP over cut positions (the pipeline is linear, so the
    optimum is a single prefix on host — matching the paper's finding that
    graph preprocessing belongs on the CPU and the dense GNN compute on the
    accelerator).

`measure=True` swaps modelled latencies for real timeit measurements of the
provided callables — the paper's "offline profiling phase during model
calibration".

This module also hosts the N-way *device* partitioner (DESIGN.md §12): a
greedy edge-cut over the graph that splits an oversized graph into
bucket-admissible row shards plus halo (boundary-node) index sets, and the
modelled cost of serving it sharded (per-shard compute + compressed-halo
collective bytes over the link). Host-side numpy only — `core.models`
builds the device operands from the `GraphShards` this module emits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

# The wire/compute constants live in `core.costs` — one source of truth
# shared with the §10 backend rule, the LatencyBank roofline seeds, and
# the benchmark HLO pricer. Re-exported here (their historical home) for
# existing importers; see costs.py for what each number models.
from .costs import (COLLECTIVE_LATENCY_S, CPU_RATE,  # noqa: F401
                    DEVICE_LINK_BYTES_PER_S, GATHER_BW,
                    HOST_LINK_BYTES_PER_S, LAUNCH_LATENCY_S, MXU_RATE)


@dataclasses.dataclass
class Stage:
    name: str
    host_latency_s: float          # modelled or measured CPU latency
    device_latency_s: float        # modelled or measured accelerator latency
    output_bytes: int              # bytes crossing a boundary after this stage
    control_heavy: bool = False    # diagnostic only
    host_fn: Optional[Callable] = None
    device_fn: Optional[Callable] = None


def transfer_cost(nbytes: int) -> float:
    return LAUNCH_LATENCY_S + nbytes / HOST_LINK_BYTES_PER_S


def profile_stage(fn: Callable, *args, repeats: int = 5) -> float:
    """Offline profiling: median wall-clock of fn(*args)."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        # block on device results so we time compute, not dispatch
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@dataclasses.dataclass
class PartitionPlan:
    cut: int                       # stages[:cut] run on host, stages[cut:] on device
    total_latency_s: float
    per_cut_latency_s: List[float]

    def placement(self, stages: Sequence[Stage]) -> List[str]:
        return ["host" if i < self.cut else "device" for i in range(len(stages))]


def graphsplit(stages: Sequence[Stage]) -> PartitionPlan:
    """Pick the prefix cut minimizing latency = host work + 1 transfer + device work.

    A single host->device crossing is optimal for a linear pipeline whenever
    the device is faster on the suffix — the paper's RAW-dependency argument:
    bouncing back to the host pays `transfer_cost` twice and never wins unless
    the host op is dramatically faster, which the cost model captures by
    evaluating every cut position.
    """
    n = len(stages)
    costs = []
    for cut in range(n + 1):
        host = sum(s.host_latency_s for s in stages[:cut])
        dev = sum(s.device_latency_s for s in stages[cut:])
        xfer = 0.0
        if 0 < cut <= n:
            xfer = transfer_cost(stages[cut - 1].output_bytes)
        elif cut == 0 and n > 0:
            # inputs still have to reach the device
            xfer = transfer_cost(stages[0].output_bytes)
        costs.append(host + xfer + dev)
    best = int(np.argmin(costs))
    return PartitionPlan(cut=best, total_latency_s=costs[best], per_cut_latency_s=costs)


def default_gnn_stages(num_nodes: int, num_edges: int, in_feats: int,
                       out_feats: int, *, capacity: int) -> List[Stage]:
    """Modelled stage costs for a GNN layer, mirroring Fig. 4's breakdown.

    Host latencies model control-heavy degree/sqrt/scatter preprocessing as
    cheap on the CPU; device latencies model the same work as gather/scatter
    HLOs (slow, bytes-bound) vs dense matmuls (fast, MXU-bound).
    """
    cap = capacity
    flops_combine = 2.0 * cap * in_feats * out_feats
    flops_aggregate = 2.0 * cap * cap * out_feats
    MXU = MXU_RATE              # derated dense throughput (core.costs)
    GATHER = GATHER_BW          # gather/scatter effective bytes/s (DSP analogue)
    CPU = CPU_RATE              # host scalar throughput (ops/s)
    return [
        Stage("build_adjacency", num_edges / CPU * 4, (num_edges * 8) / GATHER,
              output_bytes=cap * cap * 4, control_heavy=True),
        Stage("degree_norm (PreG)", cap / CPU * 8, (cap * 12) / GATHER,
              output_bytes=cap * cap * 4, control_heavy=True),
        Stage("combine XW", flops_combine / (2e9), flops_combine / MXU,
              output_bytes=cap * out_feats * 4),
        Stage("aggregate ÂH (StaGr)", flops_aggregate / (2e9), flops_aggregate / MXU,
              output_bytes=cap * out_feats * 4),
    ]


# ---------------------------------------------------------------------------
# N-way device partitioner (DESIGN.md §12) — GraphSplit beyond the host cut.
# An oversized graph (num_nodes > the ladder's top bucket) is split into
# `shards` row blocks; each shard owns a contiguous range of SLOTS in a
# permuted full-capacity layout, computes its own rows, and fetches the
# hidden states of halo (boundary) nodes from the other shards through one
# compressed psum per layer exchange.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphShards:
    """Result of the greedy edge-cut: who owns which node, in slot layout.

    Slot layout: shard `s` owns slots [s*shard_cap, (s+1)*shard_cap);
    `perm[slot]` is the ORIGINAL padded-graph position living in that slot
    (real node id when < num_nodes, else a padding position). Permuting the
    full-capacity operands by `perm` on both axes yields the sharded layout;
    row block `s` of the permuted matrices is exactly shard s's operand.
    """

    shards: int
    shard_cap: int                 # slotted rows per shard (a NodePad bucket)
    num_nodes: int
    assignment: np.ndarray         # (num_nodes,) int32 owning shard per node
    perm: np.ndarray               # (shards*shard_cap,) slot -> original pos
    halo: Tuple[np.ndarray, ...]   # per-shard sorted remote in-neighbor ids
    loads: np.ndarray              # (shards,) real nodes per shard
    cut_edges: int                 # edges crossing a shard boundary

    @property
    def full_rows(self) -> int:
        return self.shards * self.shard_cap

    @property
    def halo_nodes(self) -> int:
        return int(sum(len(h) for h in self.halo))


PARTITION_METHODS = ("multilevel", "greedy")


def _greedy_assignment(edge_index: np.ndarray, num_nodes: int, shards: int,
                       cap: int) -> np.ndarray:
    """The original greedy streaming edge-cut (LDG-style): nodes stream in
    degree-descending order; each lands on the shard holding the most of
    its already-placed neighbors (ties: lightest load, then lowest shard
    id), under the hard per-shard load cap."""
    # undirected neighbor structure for placement affinity (CSR via sort)
    src, dst = edge_index
    both = np.concatenate([np.stack([src, dst]), np.stack([dst, src])], axis=1)
    both = both[:, both[0] < num_nodes]
    both = both[:, both[1] < num_nodes]
    order = np.argsort(both[0], kind="stable")
    nbr_flat = both[1][order]
    starts = np.searchsorted(both[0][order], np.arange(num_nodes + 1))
    degree = np.diff(starts)

    assignment = np.full((num_nodes,), -1, dtype=np.int32)
    loads = np.zeros((shards,), dtype=np.int64)
    # degree-descending, id-ascending within a degree tier (deterministic)
    stream = np.lexsort((np.arange(num_nodes), -degree))
    for u in stream:
        nbrs = nbr_flat[starts[u]: starts[u + 1]]
        placed = assignment[nbrs]
        affinity = np.bincount(placed[placed >= 0], minlength=shards)
        open_ = loads < cap
        if not open_.any():         # unreachable given the cap check above
            raise ValueError("no shard with free capacity")
        score = np.where(open_, affinity, -1)
        best = score.max()
        cand = np.flatnonzero(score == best)
        s = cand[np.argmin(loads[cand])]
        assignment[u] = s
        loads[s] += 1
    return assignment


def _finalize(edge_index: np.ndarray, num_nodes: int, shards: int,
              shard_cap: int, assignment: np.ndarray) -> GraphShards:
    """Assignment -> `GraphShards`: slot permutation (per-shard interleaved
    padding), per-shard halo (exact remote in-neighbor) sets, loads, cut."""
    src, dst = edge_index
    loads = np.bincount(assignment, minlength=shards).astype(np.int64)
    full = shards * shard_cap
    perm = np.empty((full,), dtype=np.int64)
    pad_pos = num_nodes
    for s in range(shards):
        own = np.flatnonzero(assignment == s)
        base = s * shard_cap
        perm[base: base + len(own)] = own
        n_pad = shard_cap - len(own)
        perm[base + len(own): base + shard_cap] = np.arange(
            pad_pos, pad_pos + n_pad)
        pad_pos += n_pad

    live = (src < num_nodes) & (dst < num_nodes)
    ls, ld = src[live], dst[live]
    cross = assignment[ls] != assignment[ld]
    halo = tuple(np.unique(ls[cross & (assignment[ld] == s)])
                 for s in range(shards))
    return GraphShards(shards=shards, shard_cap=shard_cap,
                       num_nodes=num_nodes,
                       assignment=assignment.astype(np.int32), perm=perm,
                       halo=halo, loads=loads, cut_edges=int(cross.sum()))


def partition_graph(edge_index: np.ndarray, num_nodes: int, shards: int,
                    *, shard_cap: int, max_load: Optional[int] = None,
                    method: str = "multilevel",
                    hierarchy: Optional["CoarseHierarchy"] = None
                    ) -> GraphShards:
    """N-way edge-cut over the graph (DESIGN.md §15).

    `method="multilevel"` (the default) runs the multilevel partitioner:
    heavy-edge-matching coarsening, a greedy weighted cut on the coarsest
    graph, then KL/FM boundary refinement on every uncoarsening step with
    the per-shard load cap as a hard constraint — measurably lower
    `cut_edges` (hence halo wire bytes) than the streaming cut on
    clustered graphs. `method="greedy"` keeps the original one-pass
    streaming LDG cut (the §12 baseline the `partition_quality` benchmark
    compares against). Both are deterministic for a given `edge_index` —
    the serving cache keys partitions by structure version. A prebuilt
    `hierarchy` (from `coarsen_graph`, with `max_shards >= shards`) skips
    the coarsening phase — `partition_for_ladder` coarsens once and
    re-cuts per candidate shard count through this.
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if method not in PARTITION_METHODS:
        raise ValueError(f"unknown partition method {method!r}; "
                         f"pick from {PARTITION_METHODS}")
    cap = max_load if max_load is not None else -(-num_nodes // shards)
    if cap > shard_cap:
        raise ValueError(
            f"per-shard load cap {cap} exceeds the shard bucket {shard_cap}")
    if shards * cap < num_nodes:
        raise ValueError(
            f"{shards} shards x load cap {cap} cannot hold {num_nodes} nodes")
    if shards == 1:
        assignment = np.zeros((num_nodes,), np.int32)
    elif method == "greedy":
        assignment = _greedy_assignment(edge_index, num_nodes, shards, cap)
    else:
        hier = (hierarchy if hierarchy is not None
                else coarsen_graph(edge_index, num_nodes, max_shards=shards))
        assignment = _multilevel_assignment(hier, shards, cap)
    return _finalize(edge_index, num_nodes, shards, shard_cap, assignment)


# ---------------------------------------------------------------------------
# Multilevel partitioner (DESIGN.md §15): HEM coarsening -> greedy cut on
# the coarsest graph -> KL/FM boundary refinement per uncoarsening step.
# Host-side numpy, deterministic (every tie broken by id).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Level:
    """One level of the coarsening hierarchy: a weighted undirected graph.

    Edges are unique (u < v) pairs; `ew` counts the DIRECTED live edges
    collapsed into the pair (so a weighted cut at any level equals the
    directed `cut_edges` of the projected fine assignment). `nw[c]` is the
    number of original nodes contained in coarse node `c`. `parent` maps
    the next-FINER level's nodes onto this level (None at the finest)."""
    n: int
    eu: np.ndarray
    ev: np.ndarray
    ew: np.ndarray
    nw: np.ndarray
    parent: Optional[np.ndarray]

    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(starts, nbr, wgt) adjacency over the weighted pairs."""
        both_u = np.concatenate([self.eu, self.ev])
        both_v = np.concatenate([self.ev, self.eu])
        both_w = np.concatenate([self.ew, self.ew])
        order = np.argsort(both_u, kind="stable")
        starts = np.searchsorted(both_u[order], np.arange(self.n + 1))
        return starts, both_v[order], both_w[order]


@dataclasses.dataclass
class CoarseHierarchy:
    """Shard-count-independent coarsening of one graph (DESIGN.md §15).

    `levels[0]` is the finest (original, unit-weight) graph, `levels[-1]`
    the coarsest. Matching never merges past `w_max` original nodes per
    coarse node, so any shard count up to `max_shards` can cut this
    hierarchy under its balanced load cap — `partition_for_ladder` builds
    it ONCE and re-cuts per candidate count."""
    num_nodes: int
    max_shards: int
    levels: List[_Level]


def _pair_weights(edge_index: np.ndarray, num_nodes: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unique undirected (u < v) pairs weighted by directed multiplicity."""
    src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
    live = (src < num_nodes) & (dst < num_nodes) & (src != dst)
    u = np.minimum(src[live], dst[live]).astype(np.int64)
    v = np.maximum(src[live], dst[live]).astype(np.int64)
    if u.size == 0:
        z = np.zeros((0,), np.int64)
        return z, z, np.zeros((0,), np.float64)
    key = u * num_nodes + v
    uniq, counts = np.unique(key, return_counts=True)
    return uniq // num_nodes, uniq % num_nodes, counts.astype(np.float64)


def _hem_match(level: _Level, w_max: int) -> np.ndarray:
    """Heavy-edge matching: visit nodes by descending incident weight (tie:
    ascending id); each unmatched node pairs with its heaviest unmatched
    neighbor (tie: lowest id) whose combined weight stays within `w_max`."""
    starts, nbr, wgt = level.csr()
    wdeg = np.zeros((level.n,), np.float64)
    np.add.at(wdeg, level.eu, level.ew)
    np.add.at(wdeg, level.ev, level.ew)
    match = np.full((level.n,), -1, np.int64)
    order = np.lexsort((np.arange(level.n), -wdeg))
    nw = level.nw
    for u in order:
        if match[u] >= 0:
            continue
        vs = nbr[starts[u]: starts[u + 1]]
        ws = wgt[starts[u]: starts[u + 1]]
        ok = (match[vs] < 0) & (vs != u) & (nw[u] + nw[vs] <= w_max)
        if not ok.any():
            continue
        vs, ws = vs[ok], ws[ok]
        best = ws.max()
        v = vs[ws == best].min()
        match[u], match[v] = v, u
    return match


def _contract(level: _Level, match: np.ndarray) -> _Level:
    """Collapse matched pairs into coarse nodes (ids in ascending order of
    each pair's smaller member), merging parallel edges and dropping the
    ones that became internal."""
    cid = np.full((level.n,), -1, np.int64)
    c = 0
    for u in range(level.n):
        if cid[u] >= 0:
            continue
        cid[u] = c
        if match[u] > u:
            cid[match[u]] = c
        c += 1
    nw = np.bincount(cid, weights=level.nw, minlength=c).astype(np.int64)
    cu, cv = cid[level.eu], cid[level.ev]
    keep = cu != cv
    a = np.minimum(cu[keep], cv[keep])
    b = np.maximum(cu[keep], cv[keep])
    if a.size:
        key = a * c + b
        uniq, inv = np.unique(key, return_inverse=True)
        ew = np.bincount(inv, weights=level.ew[keep])
        eu, ev = uniq // c, uniq % c
    else:
        eu = ev = np.zeros((0,), np.int64)
        ew = np.zeros((0,), np.float64)
    return _Level(n=c, eu=eu, ev=ev, ew=ew, nw=nw, parent=cid)


def coarsen_graph(edge_index: np.ndarray, num_nodes: int, *,
                  max_shards: int) -> CoarseHierarchy:
    """HEM coarsening down to a coarsest graph a greedy cut can see whole.

    Per-coarse-node weight is capped at ~half the TIGHTEST balanced load
    any count up to `max_shards` could impose (`ceil(n / (2*max_shards))`),
    so the weighted initial cut stays near-feasible for every candidate;
    stops at ~`max(32, 8*max_shards)` nodes or when a round shrinks the
    graph by less than 10% (matching has stalled — star graphs do this).
    """
    s_ref = max(int(max_shards), 2)
    w_max = max(1, -(-num_nodes // (2 * s_ref)))
    target = max(32, 8 * s_ref)
    eu, ev, ew = _pair_weights(edge_index, num_nodes)
    levels = [_Level(n=num_nodes, eu=eu, ev=ev, ew=ew,
                     nw=np.ones((num_nodes,), np.int64), parent=None)]
    while levels[-1].n > target:
        cur = levels[-1]
        match = _hem_match(cur, w_max)
        if match.max(initial=-1) < 0:
            break                           # nothing matched: stalled
        nxt = _contract(cur, match)
        if nxt.n > 0.9 * cur.n:
            break                           # < 10% shrink: stalled
        levels.append(nxt)
    return CoarseHierarchy(num_nodes=num_nodes, max_shards=s_ref,
                           levels=levels)


def _initial_cut(level: _Level, shards: int, cap: int) -> np.ndarray:
    """Greedy weighted cut of the coarsest graph: nodes stream heaviest
    first (ties: heaviest incident weight, then id) onto the
    highest-affinity shard with room (ties: lightest load, lowest id).
    When no shard has room — possible under a tight cap with weighted
    nodes — the lightest-loaded shard takes the node anyway; refinement's
    balance repair restores the hard cap on the way back down."""
    starts, nbr, wgt = level.csr()
    wdeg = np.zeros((level.n,), np.float64)
    np.add.at(wdeg, level.eu, level.ew)
    np.add.at(wdeg, level.ev, level.ew)
    assignment = np.full((level.n,), -1, np.int64)
    loads = np.zeros((shards,), np.int64)
    order = np.lexsort((np.arange(level.n), -wdeg, -level.nw))
    for u in order:
        vs = nbr[starts[u]: starts[u + 1]]
        ws = wgt[starts[u]: starts[u + 1]]
        placed = assignment[vs]
        aff = np.zeros((shards,), np.float64)
        np.add.at(aff, placed[placed >= 0], ws[placed >= 0])
        fits = loads + level.nw[u] <= cap
        if fits.any():
            score = np.where(fits, aff, -np.inf)
            best = score.max()
            cand = np.flatnonzero(score == best)
            s = cand[np.argmin(loads[cand])]
        else:
            s = int(np.argmin(loads))
        assignment[u] = s
        loads[s] += level.nw[u]
    return assignment


def _refine(level: _Level, assignment: np.ndarray, shards: int, cap: int,
            *, passes: int = 8) -> np.ndarray:
    """KL/FM boundary refinement at one level, cap as a hard constraint.

    Repairs any cap violation inherited from a coarser level first (moving
    the overloaded shard's best-gain node that fits elsewhere), then runs
    gain passes: nodes ordered by descending move gain, each re-checked
    against the CURRENT affinities and moved only when the gain is
    strictly positive and the target has room — every accepted move
    strictly lowers the weighted cut, so the loop cannot cycle; `passes`
    only bounds time. Affinities update incrementally per move."""
    n = level.n
    starts, nbr, wgt = level.csr()
    nw = level.nw
    loads = np.bincount(assignment, weights=nw, minlength=shards
                        ).astype(np.int64)
    aff = np.zeros((n, shards), np.float64)
    np.add.at(aff, (level.eu, assignment[level.ev]), level.ew)
    np.add.at(aff, (level.ev, assignment[level.eu]), level.ew)

    def move(u: int, a: int, b: int) -> None:
        assignment[u] = b
        loads[a] -= nw[u]
        loads[b] += nw[u]
        vs = nbr[starts[u]: starts[u + 1]]
        ws = wgt[starts[u]: starts[u + 1]]
        np.subtract.at(aff, (vs, np.full(vs.shape, a)), ws)
        np.add.at(aff, (vs, np.full(vs.shape, b)), ws)

    # balance repair: a coarse-level cut may overrun the cap (weighted
    # nodes); move the cheapest node out until every shard fits, or no
    # resident fits anywhere (deferred to the next finer level — always
    # resolvable at the finest, where weights are 1)
    while (loads > cap).any():
        a = int(np.argmax(loads))
        residents = np.flatnonzero(assignment == a)
        best = None                          # (-gain, id, u, target)
        for u in residents:
            fits = loads + nw[u] <= cap
            fits[a] = False
            if not fits.any():
                continue
            row = np.where(fits, aff[u], -np.inf)
            t = int(row.argmax())
            key = (aff[u, a] - row[t], u)
            if best is None or key < best[:2]:
                best = (*key, t)
        if best is None:
            break
        move(int(best[1]), a, int(best[2]))

    for _ in range(passes):
        own = aff[np.arange(n), assignment]
        masked = aff.copy()
        masked[np.arange(n), assignment] = -np.inf
        gain = masked.max(axis=1) - own
        order = np.lexsort((np.arange(n), -gain))
        moved = 0
        for u in order:
            if gain[u] <= 0:
                break                        # sorted: the rest were no
            a = int(assignment[u])           # better at pass start
            row = aff[u].copy()
            row[a] = -np.inf
            fits = loads + nw[u] <= cap
            fits[a] = False
            row = np.where(fits, row, -np.inf)
            t = int(row.argmax())
            if row[t] - aff[u, a] <= 0:
                continue
            move(u, a, t)
            moved += 1
        if moved == 0:
            break
    return assignment


def _multilevel_assignment(hier: CoarseHierarchy, shards: int, cap: int
                           ) -> np.ndarray:
    """Cut the coarsest level, then uncoarsen with refinement per level."""
    levels = hier.levels
    assignment = _initial_cut(levels[-1], shards, cap)
    assignment = _refine(levels[-1], assignment, shards, cap)
    for lvl in range(len(levels) - 2, -1, -1):
        assignment = assignment[levels[lvl + 1].parent]
        assignment = _refine(levels[lvl], assignment, shards, cap)
    if np.bincount(assignment, minlength=shards).max(initial=0) > cap:
        raise AssertionError("refinement left a shard over its load cap")
    return assignment.astype(np.int32)


def patch_halo(part: GraphShards, edge_index: np.ndarray) -> GraphShards:
    """GrAd delta on a partitioned graph (DESIGN.md §13): recompute the
    per-shard halo sets and the cut-edge count for an evolved edge list
    while KEEPING the node assignment and slot permutation. Edge-only
    deltas never move a node between shards — re-partitioning would (the
    greedy cut depends on the edges), which is exactly why the delta path
    must not: a fresh partition permutes the operand layout and the
    serving engine would owe a full slice rebuild. Same vectorized halo
    construction as `partition_graph`, O(E) host work."""
    src, dst = edge_index
    live = (src < part.num_nodes) & (dst < part.num_nodes)
    ls, ld = src[live], dst[live]
    cross = part.assignment[ls] != part.assignment[ld]
    halo = tuple(np.unique(ls[cross & (part.assignment[ld] == s)])
                 for s in range(part.shards))
    return dataclasses.replace(part, halo=halo, cut_edges=int(cross.sum()))


def partition_for_ladder(edge_index: np.ndarray, num_nodes: int, ladder,
                         shard_counts: Sequence[int],
                         method: str = "multilevel") -> GraphShards:
    """Bucket-aware shard-count selection: the smallest configured shard
    count whose balanced per-shard load admits into the ladder is chosen,
    and that load's bucket becomes the shard capacity. Raises ValueError
    when no configured count fits (mirroring `BucketLadder.bucket_for`).

    The coarsening hierarchy is shard-count-independent, so the multilevel
    path builds it ONCE (at the largest candidate count) and re-cuts per
    candidate — admission search stays linear in partitioner work instead
    of re-coarsening the whole graph for every rung."""
    counts = sorted(set(int(c) for c in shard_counts if int(c) >= 2))
    hier: Optional[CoarseHierarchy] = None
    if method == "multilevel" and counts:
        hier = coarsen_graph(edge_index, num_nodes, max_shards=max(counts))
    last_err: Optional[Exception] = None
    for s in counts:
        load = -(-num_nodes // s)
        try:
            bucket = ladder.bucket_for(load)
        except ValueError as e:      # even the balanced load is oversized
            last_err = e
            continue
        return partition_graph(edge_index, num_nodes, s, shard_cap=bucket,
                               method=method, hierarchy=hier)
    raise ValueError(
        f"graph with {num_nodes} nodes fits no configured shard count "
        f"{tuple(shard_counts)} on ladder buckets {ladder.buckets}"
    ) from last_err


def modelled_sharded_latency(part: GraphShards, *, in_feats: int, hidden: int,
                             classes: int, exchange_widths: Sequence[int],
                             compress: bool = True) -> float:
    """Modelled per-forward latency of the sharded plan (DESIGN.md §12):
    per-shard compute (the dominant O(C x full) aggregation scales ~1/S)
    plus one compressed-halo collective per exchanged layer width, charged
    at the DEVICE interconnect (the halo psum is device-to-device; it
    never crosses the host link). A 1-shard partition pays no wire at all
    — there is nobody to exchange with."""
    MXU = MXU_RATE                  # same derated roofline as default_gnn_stages
    c, full = part.shard_cap, part.full_rows
    flops = 2.0 * c * (in_feats * hidden + hidden * classes)      # combine
    flops += 2.0 * c * full * (hidden + classes)                  # aggregate
    compute = flops / MXU
    if part.shards == 1:
        return compute
    from repro.dist.compress import ring_psum_nbytes
    bytes_per_elt = 1 if compress else 4
    wire = 0.0
    for w in exchange_widths:
        nbytes = ring_psum_nbytes(part.shards, full * w,
                                  bytes_per_elt=bytes_per_elt)
        wire += COLLECTIVE_LATENCY_S + nbytes / DEVICE_LINK_BYTES_PER_S
    return compute + wire
