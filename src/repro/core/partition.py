"""GraphSplit: offline cost-model-driven host/device partitioning.

The paper profiles each op on CPU and NPU during calibration, adds the
CPU<->NPU transfer cost, and picks the cut that minimizes end-to-end latency
subject to RAW dependencies. We reproduce that structure for the host(CPU,
numpy) <-> device(TPU, jit) split:

  * stage graph  = a linear pipeline of named stages (GNN preprocessing ->
    aggregation -> combination -> decode), each with measured/modelled host
    and device latencies;
  * transfer cost = bytes / host_link_bw + fixed launch latency, charged at
    every host->device or device->host boundary crossing;
  * optimal cut  = DP over cut positions (the pipeline is linear, so the
    optimum is a single prefix on host — matching the paper's finding that
    graph preprocessing belongs on the CPU and the dense GNN compute on the
    accelerator).

`measure=True` swaps modelled latencies for real timeit measurements of the
provided callables — the paper's "offline profiling phase during model
calibration".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

# Host link (PCIe-class) — deliberately much slower than HBM so the model
# penalizes chatty partitions, as on a real TPU host. See DESIGN.md §2 (3).
HOST_LINK_BYTES_PER_S = 16e9
LAUNCH_LATENCY_S = 20e-6


@dataclasses.dataclass
class Stage:
    name: str
    host_latency_s: float          # modelled or measured CPU latency
    device_latency_s: float        # modelled or measured accelerator latency
    output_bytes: int              # bytes crossing a boundary after this stage
    control_heavy: bool = False    # diagnostic only
    host_fn: Optional[Callable] = None
    device_fn: Optional[Callable] = None


def transfer_cost(nbytes: int) -> float:
    return LAUNCH_LATENCY_S + nbytes / HOST_LINK_BYTES_PER_S


def profile_stage(fn: Callable, *args, repeats: int = 5) -> float:
    """Offline profiling: median wall-clock of fn(*args)."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        # block on device results so we time compute, not dispatch
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@dataclasses.dataclass
class PartitionPlan:
    cut: int                       # stages[:cut] run on host, stages[cut:] on device
    total_latency_s: float
    per_cut_latency_s: List[float]

    def placement(self, stages: Sequence[Stage]) -> List[str]:
        return ["host" if i < self.cut else "device" for i in range(len(stages))]


def graphsplit(stages: Sequence[Stage]) -> PartitionPlan:
    """Pick the prefix cut minimizing latency = host work + 1 transfer + device work.

    A single host->device crossing is optimal for a linear pipeline whenever
    the device is faster on the suffix — the paper's RAW-dependency argument:
    bouncing back to the host pays `transfer_cost` twice and never wins unless
    the host op is dramatically faster, which the cost model captures by
    evaluating every cut position.
    """
    n = len(stages)
    costs = []
    for cut in range(n + 1):
        host = sum(s.host_latency_s for s in stages[:cut])
        dev = sum(s.device_latency_s for s in stages[cut:])
        xfer = 0.0
        if 0 < cut <= n:
            xfer = transfer_cost(stages[cut - 1].output_bytes)
        elif cut == 0 and n > 0:
            # inputs still have to reach the device
            xfer = transfer_cost(stages[0].output_bytes)
        costs.append(host + xfer + dev)
    best = int(np.argmin(costs))
    return PartitionPlan(cut=best, total_latency_s=costs[best], per_cut_latency_s=costs)


def default_gnn_stages(num_nodes: int, num_edges: int, in_feats: int,
                       out_feats: int, *, capacity: int) -> List[Stage]:
    """Modelled stage costs for a GNN layer, mirroring Fig. 4's breakdown.

    Host latencies model control-heavy degree/sqrt/scatter preprocessing as
    cheap on the CPU; device latencies model the same work as gather/scatter
    HLOs (slow, bytes-bound) vs dense matmuls (fast, MXU-bound).
    """
    cap = capacity
    flops_combine = 2.0 * cap * in_feats * out_feats
    flops_aggregate = 2.0 * cap * cap * out_feats
    MXU = 197e12 * 0.4          # derated dense throughput
    GATHER = 819e9 * 0.05       # gather/scatter effective bytes/s (DSP analogue)
    CPU = 5e10                  # host scalar throughput (ops/s)
    return [
        Stage("build_adjacency", num_edges / CPU * 4, (num_edges * 8) / GATHER,
              output_bytes=cap * cap * 4, control_heavy=True),
        Stage("degree_norm (PreG)", cap / CPU * 8, (cap * 12) / GATHER,
              output_bytes=cap * cap * 4, control_heavy=True),
        Stage("combine XW", flops_combine / (2e9), flops_combine / MXU,
              output_bytes=cap * out_feats * 4),
        Stage("aggregate ÂH (StaGr)", flops_aggregate / (2e9), flops_aggregate / MXU,
              output_bytes=cap * out_feats * 4),
    ]
