"""GraphSplit: offline cost-model-driven host/device partitioning.

The paper profiles each op on CPU and NPU during calibration, adds the
CPU<->NPU transfer cost, and picks the cut that minimizes end-to-end latency
subject to RAW dependencies. We reproduce that structure for the host(CPU,
numpy) <-> device(TPU, jit) split:

  * stage graph  = a linear pipeline of named stages (GNN preprocessing ->
    aggregation -> combination -> decode), each with measured/modelled host
    and device latencies;
  * transfer cost = bytes / host_link_bw + fixed launch latency, charged at
    every host->device or device->host boundary crossing;
  * optimal cut  = DP over cut positions (the pipeline is linear, so the
    optimum is a single prefix on host — matching the paper's finding that
    graph preprocessing belongs on the CPU and the dense GNN compute on the
    accelerator).

`measure=True` swaps modelled latencies for real timeit measurements of the
provided callables — the paper's "offline profiling phase during model
calibration".

This module also hosts the N-way *device* partitioner (DESIGN.md §12): a
greedy edge-cut over the graph that splits an oversized graph into
bucket-admissible row shards plus halo (boundary-node) index sets, and the
modelled cost of serving it sharded (per-shard compute + compressed-halo
collective bytes over the link). Host-side numpy only — `core.models`
builds the device operands from the `GraphShards` this module emits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

# Host link (PCIe-class) — deliberately much slower than HBM so the model
# penalizes chatty partitions, as on a real TPU host. See DESIGN.md §2 (3).
HOST_LINK_BYTES_PER_S = 16e9
LAUNCH_LATENCY_S = 20e-6

# Device interconnect (ICI-class) — what the sharded serving path's halo
# collectives cross (DESIGN.md §12). Device-to-device psums never touch the
# host link: they move over the mesh fabric at an order of magnitude more
# bandwidth and with a per-collective latency closer to a kernel launch
# than a PCIe round-trip. Distinct constants so the GraphSplit host/device
# cut and the N-way shard model cannot silently share the wrong wire.
DEVICE_LINK_BYTES_PER_S = 100e9
COLLECTIVE_LATENCY_S = 2e-6


@dataclasses.dataclass
class Stage:
    name: str
    host_latency_s: float          # modelled or measured CPU latency
    device_latency_s: float        # modelled or measured accelerator latency
    output_bytes: int              # bytes crossing a boundary after this stage
    control_heavy: bool = False    # diagnostic only
    host_fn: Optional[Callable] = None
    device_fn: Optional[Callable] = None


def transfer_cost(nbytes: int) -> float:
    return LAUNCH_LATENCY_S + nbytes / HOST_LINK_BYTES_PER_S


def profile_stage(fn: Callable, *args, repeats: int = 5) -> float:
    """Offline profiling: median wall-clock of fn(*args)."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        # block on device results so we time compute, not dispatch
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@dataclasses.dataclass
class PartitionPlan:
    cut: int                       # stages[:cut] run on host, stages[cut:] on device
    total_latency_s: float
    per_cut_latency_s: List[float]

    def placement(self, stages: Sequence[Stage]) -> List[str]:
        return ["host" if i < self.cut else "device" for i in range(len(stages))]


def graphsplit(stages: Sequence[Stage]) -> PartitionPlan:
    """Pick the prefix cut minimizing latency = host work + 1 transfer + device work.

    A single host->device crossing is optimal for a linear pipeline whenever
    the device is faster on the suffix — the paper's RAW-dependency argument:
    bouncing back to the host pays `transfer_cost` twice and never wins unless
    the host op is dramatically faster, which the cost model captures by
    evaluating every cut position.
    """
    n = len(stages)
    costs = []
    for cut in range(n + 1):
        host = sum(s.host_latency_s for s in stages[:cut])
        dev = sum(s.device_latency_s for s in stages[cut:])
        xfer = 0.0
        if 0 < cut <= n:
            xfer = transfer_cost(stages[cut - 1].output_bytes)
        elif cut == 0 and n > 0:
            # inputs still have to reach the device
            xfer = transfer_cost(stages[0].output_bytes)
        costs.append(host + xfer + dev)
    best = int(np.argmin(costs))
    return PartitionPlan(cut=best, total_latency_s=costs[best], per_cut_latency_s=costs)


def default_gnn_stages(num_nodes: int, num_edges: int, in_feats: int,
                       out_feats: int, *, capacity: int) -> List[Stage]:
    """Modelled stage costs for a GNN layer, mirroring Fig. 4's breakdown.

    Host latencies model control-heavy degree/sqrt/scatter preprocessing as
    cheap on the CPU; device latencies model the same work as gather/scatter
    HLOs (slow, bytes-bound) vs dense matmuls (fast, MXU-bound).
    """
    cap = capacity
    flops_combine = 2.0 * cap * in_feats * out_feats
    flops_aggregate = 2.0 * cap * cap * out_feats
    MXU = 197e12 * 0.4          # derated dense throughput
    GATHER = 819e9 * 0.05       # gather/scatter effective bytes/s (DSP analogue)
    CPU = 5e10                  # host scalar throughput (ops/s)
    return [
        Stage("build_adjacency", num_edges / CPU * 4, (num_edges * 8) / GATHER,
              output_bytes=cap * cap * 4, control_heavy=True),
        Stage("degree_norm (PreG)", cap / CPU * 8, (cap * 12) / GATHER,
              output_bytes=cap * cap * 4, control_heavy=True),
        Stage("combine XW", flops_combine / (2e9), flops_combine / MXU,
              output_bytes=cap * out_feats * 4),
        Stage("aggregate ÂH (StaGr)", flops_aggregate / (2e9), flops_aggregate / MXU,
              output_bytes=cap * out_feats * 4),
    ]


# ---------------------------------------------------------------------------
# N-way device partitioner (DESIGN.md §12) — GraphSplit beyond the host cut.
# An oversized graph (num_nodes > the ladder's top bucket) is split into
# `shards` row blocks; each shard owns a contiguous range of SLOTS in a
# permuted full-capacity layout, computes its own rows, and fetches the
# hidden states of halo (boundary) nodes from the other shards through one
# compressed psum per layer exchange.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphShards:
    """Result of the greedy edge-cut: who owns which node, in slot layout.

    Slot layout: shard `s` owns slots [s*shard_cap, (s+1)*shard_cap);
    `perm[slot]` is the ORIGINAL padded-graph position living in that slot
    (real node id when < num_nodes, else a padding position). Permuting the
    full-capacity operands by `perm` on both axes yields the sharded layout;
    row block `s` of the permuted matrices is exactly shard s's operand.
    """

    shards: int
    shard_cap: int                 # slotted rows per shard (a NodePad bucket)
    num_nodes: int
    assignment: np.ndarray         # (num_nodes,) int32 owning shard per node
    perm: np.ndarray               # (shards*shard_cap,) slot -> original pos
    halo: Tuple[np.ndarray, ...]   # per-shard sorted remote in-neighbor ids
    loads: np.ndarray              # (shards,) real nodes per shard
    cut_edges: int                 # edges crossing a shard boundary

    @property
    def full_rows(self) -> int:
        return self.shards * self.shard_cap

    @property
    def halo_nodes(self) -> int:
        return int(sum(len(h) for h in self.halo))


def partition_graph(edge_index: np.ndarray, num_nodes: int, shards: int,
                    *, shard_cap: int, max_load: Optional[int] = None
                    ) -> GraphShards:
    """Greedy edge-cut (streaming LDG-style) over the graph.

    Nodes stream in degree-descending order; each is placed on the shard
    holding the most of its already-placed neighbors (ties: lightest load,
    then lowest shard id), under a hard per-shard load cap so every shard
    stays admissible to its NodePad bucket. Deterministic for a given
    edge_index — the serving cache keys partitions by structure version.
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    cap = max_load if max_load is not None else -(-num_nodes // shards)
    if cap > shard_cap:
        raise ValueError(
            f"per-shard load cap {cap} exceeds the shard bucket {shard_cap}")
    if shards * cap < num_nodes:
        raise ValueError(
            f"{shards} shards x load cap {cap} cannot hold {num_nodes} nodes")

    # undirected neighbor structure for placement affinity (CSR via sort)
    src, dst = edge_index
    both = np.concatenate([np.stack([src, dst]), np.stack([dst, src])], axis=1)
    both = both[:, both[0] < num_nodes]
    both = both[:, both[1] < num_nodes]
    order = np.argsort(both[0], kind="stable")
    nbr_flat = both[1][order]
    starts = np.searchsorted(both[0][order], np.arange(num_nodes + 1))
    degree = np.diff(starts)

    assignment = np.full((num_nodes,), -1, dtype=np.int32)
    loads = np.zeros((shards,), dtype=np.int64)
    # degree-descending, id-ascending within a degree tier (deterministic)
    stream = np.lexsort((np.arange(num_nodes), -degree))
    for u in stream:
        nbrs = nbr_flat[starts[u]: starts[u + 1]]
        placed = assignment[nbrs]
        affinity = np.bincount(placed[placed >= 0], minlength=shards)
        open_ = loads < cap
        if not open_.any():         # unreachable given the cap check above
            raise ValueError("no shard with free capacity")
        score = np.where(open_, affinity, -1)
        best = score.max()
        cand = np.flatnonzero(score == best)
        s = cand[np.argmin(loads[cand])]
        assignment[u] = s
        loads[s] += 1

    full = shards * shard_cap
    perm = np.empty((full,), dtype=np.int64)
    pad_pos = num_nodes
    for s in range(shards):
        own = np.flatnonzero(assignment == s)
        base = s * shard_cap
        perm[base: base + len(own)] = own
        n_pad = shard_cap - len(own)
        perm[base + len(own): base + shard_cap] = np.arange(
            pad_pos, pad_pos + n_pad)
        pad_pos += n_pad

    live = (src < num_nodes) & (dst < num_nodes)
    ls, ld = src[live], dst[live]
    cross = assignment[ls] != assignment[ld]
    halo = tuple(np.unique(ls[cross & (assignment[ld] == s)])
                 for s in range(shards))
    return GraphShards(shards=shards, shard_cap=shard_cap,
                       num_nodes=num_nodes, assignment=assignment, perm=perm,
                       halo=halo, loads=loads, cut_edges=int(cross.sum()))


def patch_halo(part: GraphShards, edge_index: np.ndarray) -> GraphShards:
    """GrAd delta on a partitioned graph (DESIGN.md §13): recompute the
    per-shard halo sets and the cut-edge count for an evolved edge list
    while KEEPING the node assignment and slot permutation. Edge-only
    deltas never move a node between shards — re-partitioning would (the
    greedy cut depends on the edges), which is exactly why the delta path
    must not: a fresh partition permutes the operand layout and the
    serving engine would owe a full slice rebuild. Same vectorized halo
    construction as `partition_graph`, O(E) host work."""
    src, dst = edge_index
    live = (src < part.num_nodes) & (dst < part.num_nodes)
    ls, ld = src[live], dst[live]
    cross = part.assignment[ls] != part.assignment[ld]
    halo = tuple(np.unique(ls[cross & (part.assignment[ld] == s)])
                 for s in range(part.shards))
    return dataclasses.replace(part, halo=halo, cut_edges=int(cross.sum()))


def partition_for_ladder(edge_index: np.ndarray, num_nodes: int, ladder,
                         shard_counts: Sequence[int]) -> GraphShards:
    """Bucket-aware shard-count selection: the smallest configured shard
    count whose balanced per-shard load admits into the ladder is chosen,
    and that load's bucket becomes the shard capacity. Raises ValueError
    when no configured count fits (mirroring `BucketLadder.bucket_for`)."""
    last_err: Optional[Exception] = None
    for s in sorted(set(int(c) for c in shard_counts)):
        if s < 2:
            continue                 # 1 shard == the unsharded path
        load = -(-num_nodes // s)
        try:
            bucket = ladder.bucket_for(load)
        except ValueError as e:      # even the balanced load is oversized
            last_err = e
            continue
        return partition_graph(edge_index, num_nodes, s, shard_cap=bucket)
    raise ValueError(
        f"graph with {num_nodes} nodes fits no configured shard count "
        f"{tuple(shard_counts)} on ladder buckets {ladder.buckets}"
    ) from last_err


def modelled_sharded_latency(part: GraphShards, *, in_feats: int, hidden: int,
                             classes: int, exchange_widths: Sequence[int],
                             compress: bool = True) -> float:
    """Modelled per-forward latency of the sharded plan (DESIGN.md §12):
    per-shard compute (the dominant O(C x full) aggregation scales ~1/S)
    plus one compressed-halo collective per exchanged layer width, charged
    at the DEVICE interconnect (the halo psum is device-to-device; it
    never crosses the host link). A 1-shard partition pays no wire at all
    — there is nobody to exchange with."""
    MXU = 197e12 * 0.4              # same derated roofline as default_gnn_stages
    c, full = part.shard_cap, part.full_rows
    flops = 2.0 * c * (in_feats * hidden + hidden * classes)      # combine
    flops += 2.0 * c * full * (hidden + classes)                  # aggregate
    compute = flops / MXU
    if part.shards == 1:
        return compute
    from repro.dist.compress import ring_psum_nbytes
    bytes_per_elt = 1 if compress else 4
    wire = 0.0
    for w in exchange_widths:
        nbytes = ring_psum_nbytes(part.shards, full * w,
                                  bytes_per_elt=bytes_per_elt)
        wire += COLLECTIVE_LATENCY_S + nbytes / DEVICE_LINK_BYTES_PER_S
    return compute + wire
