"""Graph containers and structure preprocessing (StaGr / PreG / SymG / NodePad).

The paper's Step-1 enablement: graphs are preprocessed on the *host*
(GraphSplit assigns control-heavy structure work to the CPU) into dense,
statically-shaped operands that the device consumes as plain matmuls.

NodePad: every graph is padded to a fixed *bucket* capacity (a multiple of
the MXU tile, 128) so the compiled program is reused across graph sizes —
the JAX analogue of the paper's "one precompiled blob" (jit cache hit).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

MXU_TILE = 128  # TPU systolic tile; NodePad buckets align to this.


@dataclasses.dataclass
class Graph:
    """A static graph snapshot. Host-side (numpy) until padded/uploaded."""

    edge_index: np.ndarray  # (2, E) int32, row 0 = src, row 1 = dst
    num_nodes: int
    features: np.ndarray  # (N, F) float32
    labels: Optional[np.ndarray] = None  # (N,) int32
    train_mask: Optional[np.ndarray] = None  # (N,) bool
    test_mask: Optional[np.ndarray] = None  # (N,) bool

    @property
    def num_edges(self) -> int:
        return int(self.edge_index.shape[1])


def node_bucket(num_nodes: int, *, tile: int = MXU_TILE, slack: float = 0.0) -> int:
    """NodePad bucket: smallest tile multiple >= num_nodes*(1+slack).

    `slack` reserves headroom for dynamic node insertion (GrAd) without a
    recompile — the paper pads Cora 2708 -> 3000; we pad to tile multiples so
    the same capacity also satisfies the Pallas kernel grids.
    """
    want = int(np.ceil(num_nodes * (1.0 + slack)))
    return int(-(-want // tile) * tile)


def add_self_loops(edge_index: np.ndarray, num_nodes: int) -> np.ndarray:
    loops = np.arange(num_nodes, dtype=edge_index.dtype)
    return np.concatenate([edge_index, np.stack([loops, loops])], axis=1)


def dense_adjacency(edge_index: np.ndarray, capacity: int, *, self_loops: bool = True,
                    num_nodes: Optional[int] = None) -> np.ndarray:
    """(capacity, capacity) float32 0/1 adjacency; A[dst, src] = 1.

    Padded rows/cols stay zero — the paper's convention '0 = no edge' makes
    NodePad padding semantically inert.
    """
    a = np.zeros((capacity, capacity), dtype=np.float32)
    src, dst = edge_index
    a[dst, src] = 1.0
    if self_loops:
        n = capacity if num_nodes is None else num_nodes
        idx = np.arange(n)
        a[idx, idx] = 1.0
    return a


def gcn_norm_adjacency(edge_index: np.ndarray, num_nodes: int, capacity: int) -> np.ndarray:
    """PreG: Â = D^-1/2 (A + I) D^-1/2 precomputed on the host.

    The sqrt/recip ops (the NPU's slow-DSP work, TPU's non-MXU scalar work)
    happen exactly once, offline; the device only ever sees one dense matmul
    operand. Padded nodes have degree 0 -> their norm rows/cols are 0.
    """
    a = dense_adjacency(edge_index, capacity, self_loops=True, num_nodes=num_nodes)
    deg = a.sum(axis=1)
    with np.errstate(divide="ignore"):
        d_inv_sqrt = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-12)), 0.0)
    return (d_inv_sqrt[:, None] * a * d_inv_sqrt[None, :]).astype(np.float32)


def mean_adjacency(edge_index: np.ndarray, num_nodes: int, capacity: int,
                   *, self_loops: bool = True) -> np.ndarray:
    """Row-normalized adjacency (mean aggregation): D^-1 (A [+ I])."""
    a = dense_adjacency(edge_index, capacity, self_loops=self_loops, num_nodes=num_nodes)
    deg = a.sum(axis=1, keepdims=True)
    return (a / np.maximum(deg, 1.0)).astype(np.float32)


# ---------------------------------------------------------------------------
# SymG — triangular packing of the symmetric normalized adjacency.
# On TPU this is a storage/transfer optimization (checkpoint + host->device
# bytes ~halved); compute reassembles the dense matrix (see DESIGN.md §2).
# ---------------------------------------------------------------------------

def symg_pack(sym: np.ndarray) -> Tuple[np.ndarray, int]:
    """Pack a symmetric (N, N) matrix into its upper triangle (incl. diag)."""
    n = sym.shape[0]
    if not np.allclose(sym, sym.T, atol=1e-6):
        raise ValueError("symg_pack requires a symmetric matrix")
    iu = np.triu_indices(n)
    return sym[iu].astype(sym.dtype), n


def symg_unpack(packed: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros((n, n), dtype=packed.dtype)
    iu = np.triu_indices(n)
    out[iu] = packed
    out = out + np.triu(out, k=1).T
    return out


def pad_features(x: np.ndarray, capacity: int) -> np.ndarray:
    """NodePad: zero-pad node features to the bucket capacity."""
    n, f = x.shape
    if n > capacity:
        raise ValueError(f"graph ({n} nodes) exceeds NodePad capacity {capacity}")
    if n == capacity:
        return x.astype(np.float32)
    out = np.zeros((capacity, f), dtype=np.float32)
    out[:n] = x
    return out


def pad_labels(y: np.ndarray, capacity: int, *, fill: int = -1) -> np.ndarray:
    out = np.full((capacity,), fill, dtype=np.int32)
    out[: y.shape[0]] = y
    return out


@dataclasses.dataclass
class PaddedGraph:
    """Device-ready NodePad'ded graph: every array statically (cap, ·)-shaped.

    `norm_adj` is the GrAd *input* form — passed as an argument, never baked
    into the trace — so edge updates re-run only host preprocessing, never
    XLA compilation (the paper's recompile-free dynamic-graph path).
    """

    capacity: int
    num_nodes: int
    features: np.ndarray      # (cap, F)
    norm_adj: np.ndarray      # (cap, cap)  Â (PreG-normalized)
    adj: np.ndarray           # (cap, cap)  raw 0/1 (no self loops) for GAT masks
    node_mask: np.ndarray     # (cap,) 1.0 for real nodes
    labels: Optional[np.ndarray] = None
    train_mask: Optional[np.ndarray] = None
    test_mask: Optional[np.ndarray] = None


def pad_graph(g: Graph, *, capacity: Optional[int] = None, slack: float = 0.0,
              norm: str = "gcn") -> PaddedGraph:
    cap = capacity if capacity is not None else node_bucket(g.num_nodes, slack=slack)
    if norm == "gcn":
        na = gcn_norm_adjacency(g.edge_index, g.num_nodes, cap)
    elif norm == "mean":
        na = mean_adjacency(g.edge_index, g.num_nodes, cap)
    else:
        raise ValueError(f"unknown norm {norm!r}")
    mask = np.zeros((cap,), dtype=np.float32)
    mask[: g.num_nodes] = 1.0

    def _pad_bool(m):
        if m is None:
            return None
        out = np.zeros((cap,), dtype=bool)
        out[: g.num_nodes] = m
        return out

    return PaddedGraph(
        capacity=cap,
        num_nodes=g.num_nodes,
        features=pad_features(g.features, cap),
        norm_adj=na,
        adj=dense_adjacency(g.edge_index, cap, self_loops=False),
        node_mask=mask,
        labels=None if g.labels is None else pad_labels(g.labels, cap),
        train_mask=_pad_bool(g.train_mask),
        test_mask=_pad_bool(g.test_mask),
    )


def update_edges(pg: PaddedGraph, edge_index: np.ndarray, num_nodes: int,
                 *, norm: str = "gcn") -> PaddedGraph:
    """GrAd: rebuild only the runtime mask inputs for an evolved graph.

    No recompilation: shapes are unchanged (same capacity), only array
    *values* change. Raises if the graph outgrew its bucket (the caller then
    re-buckets — the one legitimate recompile).
    """
    if num_nodes > pg.capacity:
        raise ValueError(
            f"graph grew to {num_nodes} nodes > capacity {pg.capacity}; re-bucket")
    if norm == "gcn":
        na = gcn_norm_adjacency(edge_index, num_nodes, pg.capacity)
    else:
        na = mean_adjacency(edge_index, num_nodes, pg.capacity)
    mask = np.zeros((pg.capacity,), dtype=np.float32)
    mask[:num_nodes] = 1.0
    return dataclasses.replace(
        pg, num_nodes=num_nodes, norm_adj=na,
        adj=dense_adjacency(edge_index, pg.capacity, self_loops=False),
        node_mask=mask)
