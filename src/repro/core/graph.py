"""Graph containers and structure preprocessing (StaGr / PreG / SymG / NodePad).

The paper's Step-1 enablement: graphs are preprocessed on the *host*
(GraphSplit assigns control-heavy structure work to the CPU) into dense,
statically-shaped operands that the device consumes as plain matmuls.

NodePad: every graph is padded to a fixed *bucket* capacity (a multiple of
the MXU tile, 128) so the compiled program is reused across graph sizes —
the JAX analogue of the paper's "one precompiled blob" (jit cache hit).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

MXU_TILE = 128  # TPU systolic tile; NodePad buckets align to this.


@dataclasses.dataclass
class Graph:
    """A static graph snapshot. Host-side (numpy) until padded/uploaded."""

    edge_index: np.ndarray  # (2, E) int32, row 0 = src, row 1 = dst
    num_nodes: int
    features: np.ndarray  # (N, F) float32
    labels: Optional[np.ndarray] = None  # (N,) int32
    train_mask: Optional[np.ndarray] = None  # (N,) bool
    test_mask: Optional[np.ndarray] = None  # (N,) bool

    @property
    def num_edges(self) -> int:
        return int(self.edge_index.shape[1])


def required_capacity(num_nodes: int, slack: float = 0.0) -> int:
    """Single owner of the NodePad admission rule: nodes * (1 + slack).

    `slack` reserves headroom for dynamic node insertion (GrAd) without a
    recompile — the paper pads Cora 2708 -> 3000. Both the free-form
    `node_bucket` and the ladder's `bucket_for` round THIS number up, so the
    slack policy cannot drift between the two call sites.
    """
    return int(np.ceil(num_nodes * (1.0 + slack)))


def node_bucket(num_nodes: int, *, tile: int = MXU_TILE, slack: float = 0.0) -> int:
    """NodePad bucket: smallest tile multiple >= required_capacity.

    We pad to tile multiples so the same capacity also satisfies the Pallas
    kernel grids.
    """
    want = required_capacity(num_nodes, slack)
    return int(-(-want // tile) * tile)


def add_self_loops(edge_index: np.ndarray, num_nodes: int) -> np.ndarray:
    loops = np.arange(num_nodes, dtype=edge_index.dtype)
    return np.concatenate([edge_index, np.stack([loops, loops])], axis=1)


def dense_adjacency(edge_index: np.ndarray, capacity: int, *, self_loops: bool = True,
                    num_nodes: Optional[int] = None) -> np.ndarray:
    """(capacity, capacity) float32 0/1 adjacency; A[dst, src] = 1.

    Padded rows/cols stay zero — the paper's convention '0 = no edge' makes
    NodePad padding semantically inert.
    """
    a = np.zeros((capacity, capacity), dtype=np.float32)
    src, dst = edge_index
    a[dst, src] = 1.0
    if self_loops:
        n = capacity if num_nodes is None else num_nodes
        idx = np.arange(n)
        a[idx, idx] = 1.0
    return a


def gcn_norm_adjacency(edge_index: np.ndarray, num_nodes: int, capacity: int) -> np.ndarray:
    """PreG: Â = D^-1/2 (A + I) D^-1/2 precomputed on the host.

    The sqrt/recip ops (the NPU's slow-DSP work, TPU's non-MXU scalar work)
    happen exactly once, offline; the device only ever sees one dense matmul
    operand. Padded nodes have degree 0 -> their norm rows/cols are 0.
    """
    a = dense_adjacency(edge_index, capacity, self_loops=True, num_nodes=num_nodes)
    deg = a.sum(axis=1)
    with np.errstate(divide="ignore"):
        d_inv_sqrt = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-12)), 0.0)
    return (d_inv_sqrt[:, None] * a * d_inv_sqrt[None, :]).astype(np.float32)


def mean_adjacency(edge_index: np.ndarray, num_nodes: int, capacity: int,
                   *, self_loops: bool = True) -> np.ndarray:
    """Row-normalized adjacency (mean aggregation): D^-1 (A [+ I])."""
    a = dense_adjacency(edge_index, capacity, self_loops=self_loops, num_nodes=num_nodes)
    deg = a.sum(axis=1, keepdims=True)
    return (a / np.maximum(deg, 1.0)).astype(np.float32)


# ---------------------------------------------------------------------------
# SymG — triangular packing of the symmetric normalized adjacency.
# On TPU this is a storage/transfer optimization (checkpoint + host->device
# bytes ~halved); compute reassembles the dense matrix (see DESIGN.md §2).
# ---------------------------------------------------------------------------

def symg_pack(sym: np.ndarray) -> Tuple[np.ndarray, int]:
    """Pack a symmetric (N, N) matrix into its upper triangle (incl. diag)."""
    n = sym.shape[0]
    if not np.allclose(sym, sym.T, atol=1e-6):
        raise ValueError("symg_pack requires a symmetric matrix")
    iu = np.triu_indices(n)
    return sym[iu].astype(sym.dtype), n


def symg_unpack(packed: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros((n, n), dtype=packed.dtype)
    iu = np.triu_indices(n)
    out[iu] = packed
    out = out + np.triu(out, k=1).T
    return out


# ---------------------------------------------------------------------------
# CacheG compact transfer format (DESIGN.md §7): a 0/1 adjacency crosses the
# host→device link as PACKED BITS, not float32 — 32× fewer bytes, 64× when
# the graph is undirected and SymG keeps only the upper triangle. The dense
# operands are re-derived on device (core.models.materialize_operands).
# ---------------------------------------------------------------------------


def triangular_nbits(n: int) -> int:
    """Bits in the upper triangle (incl. diagonal) of an (n, n) matrix."""
    return n * (n + 1) // 2


def is_symmetric_adjacency(adj: np.ndarray) -> bool:
    """True when the 0/1 adjacency is undirected (SymG-packable)."""
    return bool(np.array_equal(adj, adj.T))


def pack_adjacency_bits(adj: np.ndarray) -> np.ndarray:
    """Bit-pack a full 0/1 (cap, cap) adjacency row-major -> (cap²/8,) uint8."""
    return np.packbits((adj > 0).reshape(-1))


def symg_pack_adjacency_bits(adj: np.ndarray, *, check: bool = True
                             ) -> np.ndarray:
    """SymG + bit-pack: upper triangle (incl. diag) of an undirected 0/1
    adjacency -> (cap(cap+1)/2 / 8,) uint8. Raises on a directed matrix —
    callers fall back to `pack_adjacency_bits` (or the eager dense path).
    `check=False` skips the O(cap²) validation when the caller already ran
    `is_symmetric_adjacency` on this matrix.
    """
    if check and not is_symmetric_adjacency(adj):
        raise ValueError("symg_pack_adjacency_bits requires an undirected "
                         "(symmetric) adjacency")
    iu = np.triu_indices(adj.shape[0])
    return np.packbits((adj[iu] > 0))


def pad_features(x: np.ndarray, capacity: int) -> np.ndarray:
    """NodePad: zero-pad node features to the bucket capacity."""
    n, f = x.shape
    if n > capacity:
        raise ValueError(f"graph ({n} nodes) exceeds NodePad capacity {capacity}")
    if n == capacity:
        return x.astype(np.float32)
    out = np.zeros((capacity, f), dtype=np.float32)
    out[:n] = x
    return out


def pad_labels(y: np.ndarray, capacity: int, *, fill: int = -1) -> np.ndarray:
    out = np.full((capacity,), fill, dtype=np.int32)
    out[: y.shape[0]] = y
    return out


@dataclasses.dataclass
class PaddedGraph:
    """Device-ready NodePad'ded graph: every array statically (cap, ·)-shaped.

    `norm_adj` is the GrAd *input* form — passed as an argument, never baked
    into the trace — so edge updates re-run only host preprocessing, never
    XLA compilation (the paper's recompile-free dynamic-graph path).
    """

    capacity: int
    num_nodes: int
    features: np.ndarray      # (cap, F)
    norm_adj: np.ndarray      # (cap, cap)  Â (PreG-normalized)
    adj: np.ndarray           # (cap, cap)  raw 0/1 (no self loops) for GAT masks
    node_mask: np.ndarray     # (cap,) 1.0 for real nodes
    labels: Optional[np.ndarray] = None
    train_mask: Optional[np.ndarray] = None
    test_mask: Optional[np.ndarray] = None


def pad_graph(g: Graph, *, capacity: Optional[int] = None, slack: float = 0.0,
              norm: str = "gcn") -> PaddedGraph:
    cap = capacity if capacity is not None else node_bucket(g.num_nodes, slack=slack)
    if norm == "gcn":
        na = gcn_norm_adjacency(g.edge_index, g.num_nodes, cap)
    elif norm == "mean":
        na = mean_adjacency(g.edge_index, g.num_nodes, cap)
    else:
        raise ValueError(f"unknown norm {norm!r}")
    mask = np.zeros((cap,), dtype=np.float32)
    mask[: g.num_nodes] = 1.0

    def _pad_bool(m):
        if m is None:
            return None
        out = np.zeros((cap,), dtype=bool)
        out[: g.num_nodes] = m
        return out

    return PaddedGraph(
        capacity=cap,
        num_nodes=g.num_nodes,
        features=pad_features(g.features, cap),
        norm_adj=na,
        adj=dense_adjacency(g.edge_index, cap, self_loops=False),
        node_mask=mask,
        labels=None if g.labels is None else pad_labels(g.labels, cap),
        train_mask=_pad_bool(g.train_mask),
        test_mask=_pad_bool(g.test_mask),
    )


# ---------------------------------------------------------------------------
# BucketLadder — the multi-graph NodePad policy (DESIGN.md §3).
# One compiled blob per (model, bucket); a graph joins the smallest bucket
# that holds it, and a growing graph re-buckets (the one legitimate
# recompile) only when it outgrows its current capacity.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """A sorted set of NodePad capacities shared by many graphs.

    `slack` reserves growth headroom at admission: a graph is placed in the
    smallest bucket >= num_nodes * (1 + slack), so GrAd updates have room
    before the re-bucket policy has to move it up the ladder.
    """

    buckets: Tuple[int, ...] = (256, 512, 1024, 2048)
    slack: float = 0.0

    def __post_init__(self):
        bs = tuple(sorted(int(b) for b in self.buckets))
        if not bs:
            raise ValueError("BucketLadder needs at least one bucket")
        for b in bs:
            if b <= 0 or b % MXU_TILE:
                raise ValueError(
                    f"bucket {b} is not a positive multiple of the MXU tile "
                    f"{MXU_TILE} (NodePad buckets must tile-align)")
        object.__setattr__(self, "buckets", bs)

    def bucket_for(self, num_nodes: int) -> int:
        """Smallest bucket holding num_nodes (+ admission slack)."""
        want = required_capacity(num_nodes, self.slack)
        for b in self.buckets:
            if want <= b:
                return b
        # slack is headroom, not a hard requirement: a graph that fits the
        # top bucket without slack is still admissible there.
        if num_nodes <= self.buckets[-1]:
            return self.buckets[-1]
        raise ValueError(
            f"graph with {num_nodes} nodes exceeds the largest bucket "
            f"{self.buckets[-1]}")

    def pad(self, g: Graph, *, norm: str = "gcn") -> PaddedGraph:
        return pad_graph(g, capacity=self.bucket_for(g.num_nodes), norm=norm)

    def grow(self, pg: PaddedGraph, edge_index: np.ndarray, num_nodes: int,
             features: np.ndarray, *, norm: str = "gcn"
             ) -> Tuple[PaddedGraph, bool]:
        """GrAd update with re-bucket policy.

        Returns (updated graph, rebucketed). While the graph fits its
        current capacity this is a pure value update (zero recompiles); once
        it outgrows the bucket, the graph is re-padded into the next rung —
        the caller pays exactly one new (model, bucket) compile, which the
        serving engine counts as a rebucket event.
        """
        if num_nodes <= pg.capacity:
            upd = update_edges(pg, edge_index, num_nodes, norm=norm)
            upd = dataclasses.replace(
                upd, features=pad_features(features, pg.capacity))
            return upd, False
        # Re-bucket: carry the supervision arrays across the move. Nodes
        # beyond the old capacity are new and unlabeled (fill -1 / False) —
        # silently dropping labels/train_mask/test_mask here would strand an
        # attached graph's evaluation state the first time it climbs.
        old = pg.capacity

        def _grown(arr, fill, dtype):
            if arr is None:
                return None
            out = np.full((num_nodes,), fill, dtype=dtype)
            out[:old] = arr[:old]
            return out

        fresh = Graph(edge_index=edge_index, num_nodes=num_nodes,
                      features=features,
                      labels=_grown(pg.labels, -1, np.int32),
                      train_mask=_grown(pg.train_mask, False, bool),
                      test_mask=_grown(pg.test_mask, False, bool))
        cap = self.bucket_for(num_nodes)
        return pad_graph(fresh, capacity=cap, norm=norm), True


@dataclasses.dataclass
class BatchedGraphs:
    """Same-bucket PaddedGraphs stacked with a leading batch dimension."""

    capacity: int
    num_nodes: np.ndarray     # (B,) int32
    features: np.ndarray      # (B, cap, F)
    norm_adj: np.ndarray      # (B, cap, cap)
    adj: np.ndarray           # (B, cap, cap)
    node_mask: np.ndarray     # (B, cap)

    @property
    def batch(self) -> int:
        return int(self.features.shape[0])


def stack_padded(pgs: Sequence[PaddedGraph]) -> BatchedGraphs:
    """Stack PaddedGraphs of one bucket for vmapped batched execution."""
    if not pgs:
        raise ValueError("cannot stack an empty graph batch")
    caps = {pg.capacity for pg in pgs}
    if len(caps) != 1:
        raise ValueError(f"mixed NodePad buckets in one batch: {sorted(caps)}")
    return BatchedGraphs(
        capacity=pgs[0].capacity,
        num_nodes=np.asarray([pg.num_nodes for pg in pgs], np.int32),
        features=np.stack([pg.features for pg in pgs]),
        norm_adj=np.stack([pg.norm_adj for pg in pgs]),
        adj=np.stack([pg.adj for pg in pgs]),
        node_mask=np.stack([pg.node_mask for pg in pgs]),
    )


@dataclasses.dataclass
class EdgeDelta:
    """Host product of one EFFECTIVE GrAd edge delta (DESIGN.md §13).

    `apply_edge_delta` patches the raw adjacency and Â in O(|touched|·cap)
    instead of the O(cap²) full rebuild, with the renormalized rows/cols
    computed by the exact expression (and association order) of
    `gcn_norm_adjacency` — so `norm_adj` here is bit-identical to a full
    rebuild of the patched structure, and the flip/touched/dis arrays are
    everything the device-side patcher (`core.models.patch_operands`)
    needs to bring a cached operand entry to the same bits.
    """
    adj: np.ndarray                # (cap, cap) patched raw 0/1 adjacency
    norm_adj: np.ndarray           # (cap, cap) patched Â, rebuild-exact
    dis: np.ndarray                # (cap,) patched D^-1/2 (float32)
    flip_i: np.ndarray             # (P,) int32 canonical flip endpoints
    flip_j: np.ndarray             # (P,) int32   (i < j; device scatters
    flip_v: np.ndarray             # (P,) float32  both orientations)
    touched: np.ndarray            # (T,) int32 sorted nodes with changed
    #                                rows/cols (the flip endpoints)

    def boundary_rows(self, assignment: np.ndarray,
                      num_nodes: int) -> np.ndarray:
        """Touched nodes whose rows cross a shard boundary (DESIGN.md §15).

        Against a shard `assignment` (GraphShards.assignment, original
        node ids), returns the sorted subset of `touched` that has at
        least one neighbor on ANOTHER shard in the PATCHED adjacency —
        the only rows whose remote copies a sharded halo re-exchange must
        refresh. A delta confined to one shard's interior returns an
        empty set: nothing crosses the wire.
        """
        t = self.touched[self.touched < num_nodes]
        if t.size == 0:
            return t.astype(np.int32)
        sub = self.adj[t][:, :num_nodes] != 0
        diff = assignment[None, :num_nodes] != assignment[t][:, None]
        return t[(sub & diff).any(axis=1)].astype(np.int32)


def apply_edge_delta(adj: np.ndarray, norm_adj: np.ndarray, num_nodes: int,
                     add_edges, remove_edges) -> Optional[EdgeDelta]:
    """GrAd incremental structure update on the host (DESIGN.md §13).

    `add_edges` / `remove_edges` are (k, 2) node-pair arrays (any order,
    both orientations equivalent — the graph is undirected). Ineffective
    flips (adding a present edge, removing an absent one) and self-loop
    pairs (the GCN/GAT diagonal is forced, so they cannot change any
    operand) are skipped; returns None when NOTHING effective remains, so
    the caller can skip the version bump entirely. Out-of-range nodes and
    a pair listed on both sides raise — those are caller bugs, not deltas.
    """
    def _pairs(edges) -> np.ndarray:
        e = np.asarray(edges if edges is not None else [],
                       dtype=np.int64).reshape(-1, 2)
        if e.size and (e.min() < 0 or e.max() >= num_nodes):
            raise ValueError(
                f"edge delta references node outside [0, {num_nodes}) — "
                "node-set changes take the full update() path")
        e = e[e[:, 0] != e[:, 1]]
        if not len(e):
            return e.reshape(0, 2)
        lo = np.minimum(e[:, 0], e[:, 1])
        hi = np.maximum(e[:, 0], e[:, 1])
        return np.unique(np.stack([lo, hi], axis=1), axis=0)

    adds, removes = _pairs(add_edges), _pairs(remove_edges)
    if len(adds) and len(removes):
        both = (set(map(tuple, adds.tolist()))
                & set(map(tuple, removes.tolist())))
        if both:
            raise ValueError(f"edge pair(s) {sorted(both)} listed as both "
                             "add and remove")
    if len(adds):
        adds = adds[adj[adds[:, 0], adds[:, 1]] == 0]
    if len(removes):
        removes = removes[adj[removes[:, 0], removes[:, 1]] != 0]
    if not len(adds) and not len(removes):
        return None
    flips = np.concatenate([adds, removes], axis=0)
    vals = np.concatenate([np.ones(len(adds), np.float32),
                           np.zeros(len(removes), np.float32)])
    new_adj = adj.copy()
    new_adj[flips[:, 0], flips[:, 1]] = vals
    new_adj[flips[:, 1], flips[:, 0]] = vals
    touched = np.unique(flips)

    # renorm the touched rows/cols with gcn_norm_adjacency's EXACT
    # expression — same forced diagonal, same 1e-12 clamp, same
    # left-associated products — so patched entries match a rebuild's bits
    awl = new_adj.copy()
    idx = np.arange(num_nodes)
    awl[idx, idx] = 1.0
    deg = awl.sum(axis=1)
    with np.errstate(divide="ignore"):
        dis = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-12)), 0.0)
    na = norm_adj.copy()
    na[touched, :] = dis[touched][:, None] * awl[touched, :] * dis[None, :]
    na[:, touched] = dis[:, None] * awl[:, touched] * dis[touched][None, :]
    return EdgeDelta(adj=new_adj, norm_adj=na, dis=dis.astype(np.float32),
                     flip_i=flips[:, 0].astype(np.int32),
                     flip_j=flips[:, 1].astype(np.int32),
                     flip_v=vals,
                     touched=touched.astype(np.int32))


def edge_index_from_adjacency(adj: np.ndarray, num_nodes: int) -> np.ndarray:
    """Recover the (2, E) edge list from a dense adjacency (A[dst, src]=1)
    — the full-rebuild fallback's input when only the patched adjacency is
    on hand."""
    dst, src = np.nonzero(adj[:num_nodes, :num_nodes])
    return np.stack([src, dst]).astype(np.int32)


def update_edges(pg: PaddedGraph, edge_index: np.ndarray, num_nodes: int,
                 *, norm: str = "gcn") -> PaddedGraph:
    """GrAd: rebuild only the runtime mask inputs for an evolved graph.

    No recompilation: shapes are unchanged (same capacity), only array
    *values* change. Raises if the graph outgrew its bucket (the caller then
    re-buckets — the one legitimate recompile).
    """
    if num_nodes > pg.capacity:
        raise ValueError(
            f"graph grew to {num_nodes} nodes > capacity {pg.capacity}; re-bucket")
    if norm == "gcn":
        na = gcn_norm_adjacency(edge_index, num_nodes, pg.capacity)
    else:
        na = mean_adjacency(edge_index, num_nodes, pg.capacity)
    mask = np.zeros((pg.capacity,), dtype=np.float32)
    mask[:num_nodes] = 1.0
    return dataclasses.replace(
        pg, num_nodes=num_nodes, norm_adj=na,
        adj=dense_adjacency(edge_index, pg.capacity, self_loops=False),
        node_mask=mask)
