"""GraSp: sparsity exploitation — ZVC packing and block bitmaps.

Two granularities, mirroring the paper's Fig. 13:

  * element ZVC (Zero Value Compression): store only non-zeros + a bitmap.
    On TPU this is a *storage/transfer* format (checkpoint, host->device);
    dense compute unpacks it. Matches DESIGN.md's SymG discussion.
  * 128x128 block bitmap: the compute-side form. Real graph adjacencies are
    >99% zero; after NodePad alignment most 128x128 blocks of Â are entirely
    zero. The host compacts the non-zero block coordinates per block-row and
    the `bitmap_spmm` Pallas kernel loops only over those — the TPU-native
    realization of "the bitmap directs the NPU to skip zero entries".
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .graph import MXU_TILE


# ----------------------------- element-level ZVC ---------------------------

def zvc_pack(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray, Tuple[int, ...]]:
    """Pack: (nonzero values, packed bitmap bytes, original shape)."""
    flat = x.reshape(-1)
    mask = flat != 0
    values = flat[mask]
    bitmap = np.packbits(mask.astype(np.uint8))
    return values.astype(x.dtype), bitmap, x.shape


def zvc_unpack(values: np.ndarray, bitmap: np.ndarray, shape: Tuple[int, ...],
               dtype=np.float32) -> np.ndarray:
    total = int(np.prod(shape))
    mask = np.unpackbits(bitmap)[:total].astype(bool)
    out = np.zeros(total, dtype=dtype)
    out[mask] = values
    return out.reshape(shape)


def zvc_compressed_bytes(x: np.ndarray) -> int:
    """Bytes after ZVC: non-zeros * itemsize + bitmap (1 bit/elem)."""
    nnz = int(np.count_nonzero(x))
    return nnz * x.dtype.itemsize + (x.size + 7) // 8


# ----------------------------- block-level bitmap --------------------------

@dataclasses.dataclass
class BlockSparse:
    """Block-compacted matrix for the bitmap_spmm kernel.

    blocks:     (n_blocks, bs, bs) gathered non-zero blocks (row-major order
                within each block-row).
    block_cols: (n_row_blocks, max_nnz) int32 column-block index of each
                non-zero block, padded with 0 (kernel masks via counts).
    counts:     (n_row_blocks,) int32 non-zero blocks in each block-row.
    bitmap:     (n_row_blocks, n_col_blocks) uint8 — diagnostic / GraSp stats.
    """

    blocks: np.ndarray
    block_cols: np.ndarray
    counts: np.ndarray
    bitmap: np.ndarray
    block_size: int
    shape: Tuple[int, int]

    @property
    def density(self) -> float:
        return float(self.bitmap.mean())


def to_block_sparse(a: np.ndarray, *, block_size: int = MXU_TILE) -> BlockSparse:
    n, m = a.shape
    bs = block_size
    if n % bs or m % bs:
        raise ValueError(f"shape {a.shape} not a multiple of block {bs} (NodePad first)")
    rb, cb = n // bs, m // bs
    view = a.reshape(rb, bs, cb, bs).transpose(0, 2, 1, 3)  # (rb, cb, bs, bs)
    bitmap = (np.abs(view).sum(axis=(2, 3)) > 0).astype(np.uint8)
    counts = bitmap.sum(axis=1).astype(np.int32)
    max_nnz = max(int(counts.max()), 1)
    # Pad each block-row's list to max_nnz; gather the blocks densely so the
    # kernel indexes them with a flat dynamic slice.
    block_cols = np.zeros((rb, max_nnz), dtype=np.int32)
    blocks = np.zeros((rb * max_nnz, bs, bs), dtype=a.dtype)
    for i in range(rb):
        cols = np.nonzero(bitmap[i])[0]
        block_cols[i, : len(cols)] = cols
        for k, c in enumerate(cols):
            blocks[i * max_nnz + k] = view[i, c]
    return BlockSparse(blocks=blocks, block_cols=block_cols, counts=counts,
                       bitmap=bitmap, block_size=bs, shape=(n, m))


def from_block_sparse(sp: BlockSparse) -> np.ndarray:
    n, m = sp.shape
    bs = sp.block_size
    rb = n // bs
    max_nnz = sp.block_cols.shape[1]
    out = np.zeros((n, m), dtype=sp.blocks.dtype)
    for i in range(rb):
        for k in range(int(sp.counts[i])):
            c = int(sp.block_cols[i, k])
            out[i * bs:(i + 1) * bs, c * bs:(c + 1) * bs] = sp.blocks[i * max_nnz + k]
    return out


def bfs_reorder(adj: np.ndarray, num_nodes: int) -> np.ndarray:
    """BFS (Cuthill–McKee-like) node permutation to densify blocks.

    Beyond-paper GraSp enhancement (DESIGN.md §6): element-level ZVC is the
    paper's NPU mechanism, but the TPU's skip granularity is the 128×128 MXU
    block. Uniformly-scattered edges leave almost every block non-zero even
    at 99% element sparsity; ordering nodes by BFS over the graph clusters
    neighborhoods near the diagonal, concentrating edges into far fewer
    blocks (the block-skip fraction becomes meaningful). Returns a
    permutation `perm` such that A' = A[perm][:, perm].
    """
    n = num_nodes
    deg = (adj[:n, :n] > 0).sum(axis=1)
    visited = np.zeros(n, dtype=bool)
    order = []
    # start from lowest-degree nodes (classic CM heuristic)
    for seed in np.argsort(deg):
        if visited[seed]:
            continue
        queue = [int(seed)]
        visited[seed] = True
        while queue:
            v = queue.pop(0)
            order.append(v)
            nbrs = np.nonzero(adj[v, :n])[0]
            nbrs = nbrs[~visited[nbrs]]
            nbrs = nbrs[np.argsort(deg[nbrs])]
            visited[nbrs] = True
            queue.extend(int(x) for x in nbrs)
    perm = np.asarray(order + list(range(n, adj.shape[0])), dtype=np.int64)
    return perm


def apply_reorder(a: np.ndarray, perm: np.ndarray) -> np.ndarray:
    return a[perm][:, perm]


def sparsity_report(a: np.ndarray, *, block_size: int = MXU_TILE) -> dict:
    sp = to_block_sparse(a, block_size=block_size)
    return {
        "element_density": float(np.count_nonzero(a) / a.size),
        "block_density": sp.density,
        "dense_bytes": int(a.nbytes),
        "zvc_bytes": zvc_compressed_bytes(a),
        "block_compacted_bytes": int(sp.blocks.nbytes + sp.block_cols.nbytes
                                     + sp.counts.nbytes),
        "flop_skip_fraction": 1.0 - sp.density,
    }
