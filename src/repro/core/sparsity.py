"""GraSp: sparsity exploitation — ZVC packing, block bitmaps, agg backends.

Two granularities, mirroring the paper's Fig. 13:

  * element ZVC (Zero Value Compression): store only non-zeros + a bitmap.
    On TPU this is a *storage/transfer* format (checkpoint, host->device);
    dense compute unpacks it. Matches DESIGN.md's SymG discussion.
  * 128x128 block bitmap: the compute-side form. Real graph adjacencies are
    >99% zero; after NodePad alignment most 128x128 blocks of Â are entirely
    zero. The host compacts the non-zero block coordinates per block-row and
    the `bitmap_spmm` Pallas kernel loops only over those — the TPU-native
    realization of "the bitmap directs the NPU to skip zero entries".

Serving contract (DESIGN.md §10): `BlockSparse` is a registered pytree, so
a compacted structure rides `GranniteOperands` across jit/vmap boundaries
as a runtime argument. To make that SHAPE-STABLE per NodePad bucket, every
serving-path structure is padded to the bucket's `grasp_max_nnz` budget —
`pad_block_sparse` on the host, `compact_block_sparse` (pure jnp, jitted
per bucket) when the fp32 Â is already device-resident — and same-bucket
structures stack into one batched operand (`stack_block_sparse`).
`select_agg_backend` is the density/cost rule (same modelled-latency style
as `partition.py` / `benchmarks/tpu_model.py`) that decides, per graph and
bucket, whether the batched `bitmap_spmm` dispatch beats the dense matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import MXU_TILE


# ----------------------------- element-level ZVC ---------------------------

def zvc_pack(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray, Tuple[int, ...]]:
    """Pack: (nonzero values, packed bitmap bytes, original shape)."""
    flat = x.reshape(-1)
    mask = flat != 0
    values = flat[mask]
    bitmap = np.packbits(mask.astype(np.uint8))
    return values.astype(x.dtype), bitmap, x.shape


def zvc_unpack(values: np.ndarray, bitmap: np.ndarray, shape: Tuple[int, ...],
               dtype=np.float32) -> np.ndarray:
    total = int(np.prod(shape))
    mask = np.unpackbits(bitmap)[:total].astype(bool)
    out = np.zeros(total, dtype=dtype)
    out[mask] = values
    return out.reshape(shape)


def zvc_compressed_bytes(x: np.ndarray) -> int:
    """Bytes after ZVC: non-zeros * itemsize + bitmap (1 bit/elem)."""
    nnz = int(np.count_nonzero(x))
    return nnz * x.dtype.itemsize + (x.size + 7) // 8


# ----------------------------- block-level bitmap --------------------------

@dataclasses.dataclass
class BlockSparse:
    """Block-compacted matrix for the bitmap_spmm kernel.

    blocks:     (n_blocks, bs, bs) gathered non-zero blocks (row-major order
                within each block-row).
    block_cols: (n_row_blocks, max_nnz) int32 column-block index of each
                non-zero block; padded entries hold an arbitrary VALID block
                index (the kernel masks them via counts but still prefetches
                them, so they must stay in range).
    counts:     (n_row_blocks,) int32 non-zero blocks in each block-row.
    bitmap:     (n_row_blocks, n_col_blocks) uint8 — diagnostic / GraSp stats.

    Registered as a jax pytree: (blocks, block_cols, counts, bitmap) are
    runtime leaves, (block_size, shape) static structure — so a compacted Â
    crosses jit/vmap boundaries as a plan ARGUMENT (GrAd discipline), and a
    batched form is simply the same pytree with a leading B on every leaf
    (`stack_block_sparse`).
    """

    blocks: np.ndarray
    block_cols: np.ndarray
    counts: np.ndarray
    bitmap: np.ndarray
    block_size: int
    shape: Tuple[int, int]

    @property
    def density(self) -> float:
        return float(np.asarray(self.bitmap).mean())

    @property
    def max_nnz(self) -> int:
        """The per-block-row list budget this structure is padded to."""
        return int(self.block_cols.shape[-1])

    @property
    def nbytes(self) -> int:
        """Bytes the compacted form occupies / moves (blocks + indices)."""
        return int(self.blocks.nbytes + self.block_cols.nbytes
                   + self.counts.nbytes)


jax.tree_util.register_pytree_node(
    BlockSparse,
    lambda s: ((s.blocks, s.block_cols, s.counts, s.bitmap),
               (s.block_size, s.shape)),
    lambda aux, ch: BlockSparse(*ch, *aux))


def to_block_sparse(a: np.ndarray, *, block_size: int = MXU_TILE,
                    bitmap: np.ndarray = None) -> BlockSparse:
    """Host-side block compaction. `bitmap` short-circuits the O(n·m)
    non-zero reduction when the caller already ran `block_stats` on this
    matrix (the serving backend rule does — one scan, not two)."""
    n, m = a.shape
    bs = block_size
    if n % bs or m % bs:
        raise ValueError(f"shape {a.shape} not a multiple of block {bs} (NodePad first)")
    rb, cb = n // bs, m // bs
    view = a.reshape(rb, bs, cb, bs).transpose(0, 2, 1, 3)  # (rb, cb, bs, bs)
    if bitmap is None:
        bitmap = (np.abs(view).sum(axis=(2, 3)) > 0).astype(np.uint8)
    counts = bitmap.sum(axis=1).astype(np.int32)
    max_nnz = max(int(counts.max()), 1)
    # Pad each block-row's list to max_nnz; gather the blocks densely so the
    # kernel indexes them with a flat dynamic slice.
    block_cols = np.zeros((rb, max_nnz), dtype=np.int32)
    blocks = np.zeros((rb * max_nnz, bs, bs), dtype=a.dtype)
    for i in range(rb):
        cols = np.nonzero(bitmap[i])[0]
        block_cols[i, : len(cols)] = cols
        for k, c in enumerate(cols):
            blocks[i * max_nnz + k] = view[i, c]
    return BlockSparse(blocks=blocks, block_cols=block_cols, counts=counts,
                       bitmap=bitmap, block_size=bs, shape=(n, m))


def from_block_sparse(sp: BlockSparse) -> np.ndarray:
    n, m = sp.shape
    bs = sp.block_size
    rb = n // bs
    max_nnz = sp.block_cols.shape[1]
    out = np.zeros((n, m), dtype=sp.blocks.dtype)
    for i in range(rb):
        for k in range(int(sp.counts[i])):
            c = int(sp.block_cols[i, k])
            out[i * bs:(i + 1) * bs, c * bs:(c + 1) * bs] = sp.blocks[i * max_nnz + k]
    return out


# ------------------------- batched serving form (DESIGN.md §10) ------------

# Per-bucket block-list budget: every serving-path BlockSparse at capacity
# `cap` pads its per-block-row lists to grasp_max_nnz(cap), so one compiled
# (bucket, backend) plan serves every admitted structure. A quarter of the
# column blocks (floor 2, ceiling cb) keeps the budget well under the dense
# fetch while admitting community/banded structure; graphs whose densest
# block-row exceeds it are ineligible and serve dense (select_agg_backend).

def grasp_max_nnz(capacity: int, *, block_size: int = MXU_TILE) -> int:
    """Block-list budget for one NodePad bucket (monotone in capacity)."""
    cb = max(capacity // block_size, 1)
    return min(cb, max(2, -(-cb // 4)))          # clamp(ceil(cb/4), 2, cb)


def pad_block_sparse(sp: BlockSparse, max_nnz: int) -> BlockSparse:
    """Pad a host-compacted structure's block lists to a bucket budget.

    Serving plans are shape-stable per bucket, so every graph's data-driven
    `to_block_sparse` width must grow to the shared `grasp_max_nnz` budget
    before it can enter a batch. Raises when the structure is too dense for
    the budget — callers run `select_agg_backend` first, which rejects
    those to the dense backend instead.
    """
    rb, mx = sp.block_cols.shape
    if mx > max_nnz:
        raise ValueError(
            f"block structure needs max_nnz={mx} > budget {max_nnz}; "
            "select_agg_backend should have routed this graph dense")
    if mx == max_nnz:
        return sp
    bs = sp.block_size
    cols = np.zeros((rb, max_nnz), np.int32)
    cols[:, :mx] = sp.block_cols
    blocks = np.zeros((rb, max_nnz, bs, bs), np.asarray(sp.blocks).dtype)
    blocks[:, :mx] = np.asarray(sp.blocks).reshape(rb, mx, bs, bs)
    return dataclasses.replace(sp, blocks=blocks.reshape(rb * max_nnz, bs, bs),
                               block_cols=cols)


def stack_block_sparse(sps: Sequence[BlockSparse]) -> BlockSparse:
    """Stack same-bucket structures into one batched (B, ...) operand.

    Requires identical (block_size, shape, max_nnz) — which every structure
    padded to one bucket's budget has. The result is the same pytree with a
    leading batch dim on every leaf; vmapped plans strip it back off, so
    `bitmap_spmm` always sees the single-graph form.
    """
    if not sps:
        raise ValueError("cannot stack an empty block-sparse batch")
    head = sps[0]
    for sp in sps[1:]:
        if (sp.block_size, sp.shape, sp.max_nnz) != (
                head.block_size, head.shape, head.max_nnz):
            raise ValueError(
                "mixed block-sparse structures in one batch: "
                f"{(sp.block_size, sp.shape, sp.max_nnz)} vs "
                f"{(head.block_size, head.shape, head.max_nnz)} "
                "(pad to one bucket budget first)")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sps)


def block_counts(a: jnp.ndarray, *, block_size: int = MXU_TILE
                 ) -> jnp.ndarray:
    """Per-block-row non-zero block counts of one dense operand — the
    cheap device-side reduction feeding the backend rule (pure jnp). A
    graph the rule routes dense never needs the full `compact_block_sparse`
    gather; deriving counts alone keeps the dense-routed decision at one
    bitmap reduction per structure version."""
    n, m = a.shape
    bs = block_size
    rb, cb = n // bs, m // bs
    nz = jnp.abs(a.reshape(rb, bs, cb, bs)).sum(axis=(1, 3)) > 0
    return nz.sum(axis=1).astype(jnp.int32)


def compact_block_sparse(a: jnp.ndarray, *, max_nnz: int,
                         block_size: int = MXU_TILE
                         ) -> Tuple[BlockSparse, jnp.ndarray]:
    """Device-side `to_block_sparse`: derive the budgeted block structure
    from an (already device-resident) dense Â with pure jnp ops.

    This is the CacheG-derived sparse operand (DESIGN.md §10): when the
    fp32 Â was materialized on device (§7), re-deriving its block structure
    there moves ZERO extra host→device bytes — the engine jits this per
    bucket (`core.models.build_block_compactor`) and caches the result per
    (graph_id, structure_version). Padded list entries gather genuine
    all-zero blocks at valid in-range column indices (argsort puts zero
    blocks last), so the kernel's count mask is belt-and-braces.

    Returns (structure, true_counts): `true_counts` is the UNCLAMPED
    per-block-row non-zero count — a row exceeding `max_nnz` means the
    structure is truncated and MUST NOT serve (the caller's eligibility
    check; `counts` inside the structure is clamped to the budget).
    """
    n, m = a.shape
    bs = block_size
    rb, cb = n // bs, m // bs
    view = a.reshape(rb, bs, cb, bs).transpose(0, 2, 1, 3)   # (rb, cb, bs, bs)
    nz = jnp.abs(view).sum(axis=(2, 3)) > 0                  # (rb, cb)
    counts_true = nz.sum(axis=1).astype(jnp.int32)
    # non-zero column indices first (ascending), zero blocks pushed to cb
    keys = jnp.where(nz, jnp.arange(cb, dtype=jnp.int32), cb)
    order = jnp.argsort(keys, axis=1)[:, :max_nnz].astype(jnp.int32)
    blocks = jnp.take_along_axis(view, order[:, :, None, None], axis=1)
    return BlockSparse(blocks=blocks.reshape(rb * max_nnz, bs, bs),
                       block_cols=order,
                       counts=jnp.minimum(counts_true, max_nnz),
                       bitmap=nz.astype(jnp.uint8),
                       block_size=bs, shape=(n, m)), counts_true


def block_stats(a: np.ndarray, *, block_size: int = MXU_TILE) -> Dict:
    """Host-side block-bitmap statistics of one dense operand (numpy; the
    cheap O(cap²) pass the serving host stage runs to feed the backend
    rule when the graph is not yet device-resident)."""
    a = np.asarray(a)
    n, m = a.shape
    rb, cb = n // block_size, m // block_size
    nz = np.abs(a.reshape(rb, block_size, cb, block_size)).sum(axis=(1, 3)) > 0
    counts = nz.sum(axis=1)
    return {"nnz_blocks": int(counts.sum()),
            "max_row_nnz": int(counts.max()) if counts.size else 0,
            "n_row_blocks": rb, "n_col_blocks": cb,
            "block_density": float(nz.mean()) if nz.size else 0.0,
            # the bitmap itself, so a follow-up to_block_sparse on the
            # same matrix can skip its own reduction pass
            "bitmap": nz.astype(np.uint8)}


# --------------------- backend dispatch rule (DESIGN.md §10) ----------------

# Same modelled-latency style as partition.default_gnn_stages and
# benchmarks/tpu_model.py — structurally the same numbers now: every
# consumer reads `core.costs` (re-exported here for existing importers).
from .costs import HBM_BW, MXU_RATE  # noqa: F401  (re-export)
# Per-grid-step cost of the sparse kernel (scalar-prefetch read, index-map
# evaluation, small-dot underutilization) — what keeps tiny buckets dense.
GRASP_STEP_OVERHEAD_S = 5e-8


def agg_cost_model(capacity: int, feats: int, *, nnz_blocks: int,
                   max_nnz: int, block_size: int = MXU_TILE
                   ) -> Tuple[float, float]:
    """Modelled aggregation latency (dense_s, grasp_s) for one Â @ H.

    Dense: one (cap, cap) @ (cap, F) matmul — roofline max of MXU FLOPs and
    HBM bytes. GraSp: the kernel MACs only the `nnz_blocks` real blocks but
    FETCHES the full padded budget (`rb * max_nnz` block + H-tile DMAs —
    masked grid steps skip compute, not the prefetch) and pays a per-step
    overhead. The crossover this produces is the technique's win condition:
    large buckets with block-sparse structure go grasp, tiny buckets and
    scattered graphs stay dense.
    """
    bs = block_size
    rb = max(capacity // bs, 1)
    dense_flops = 2.0 * capacity * capacity * feats
    dense_bytes = 4.0 * (capacity * capacity + 2 * capacity * feats)
    dense_s = max(dense_flops / MXU_RATE, dense_bytes / HBM_BW)
    steps = rb * max_nnz * max(feats // 128, 1)
    grasp_flops = 2.0 * nnz_blocks * bs * bs * feats
    grasp_bytes = 4.0 * (rb * max_nnz * (bs * bs + bs * feats)
                         + capacity * feats)
    grasp_s = (max(grasp_flops / MXU_RATE, grasp_bytes / HBM_BW)
               + steps * GRASP_STEP_OVERHEAD_S)
    return dense_s, grasp_s


def select_agg_backend(capacity: int, feats: int, *, nnz_blocks: int,
                       max_row_nnz: int, mode: str = "auto",
                       block_size: int = MXU_TILE,
                       measured: Optional[Tuple[Optional[float],
                                                Optional[float]]] = None
                       ) -> Tuple[str, float, float]:
    """The per-(graph, bucket) AggBackend decision: "dense" | "grasp".

    Eligibility first — a block-row denser than the bucket's budget cannot
    be represented (truncation would drop real blocks), so it serves dense
    regardless of `mode`; its reported grasp cost is priced at the list
    width it WOULD need (`max_row_nnz`), so the returned costs stay
    meaningful either way. Within eligibility, `mode="grasp"` forces the
    sparse path and `mode="auto"` takes the cost winner.

    `measured=(dense_s, grasp_s)` is the hardware-in-the-loop override
    (DESIGN.md §14): when BOTH backends carry a real measured latency
    (from the serving `LatencyBank`), auto mode ranks on those instead of
    the analytic model — measurement corrects the roofline where they
    disagree (BENCH_gnn.json's grasp rows on CPU). A partial pair (either
    side None) falls back to the model: an unmeasured path is never
    condemned by the measured one. Eligibility is never overridden —
    measurement can't make an unrepresentable row representable. Returns
    (backend, dense_s, grasp_s) modelled costs so callers can surface the
    decision.
    """
    if mode not in ("auto", "grasp"):
        raise ValueError(f"mode must be 'auto' or 'grasp', got {mode!r}")
    budget = grasp_max_nnz(capacity, block_size=block_size)
    width = max(budget, max_row_nnz)
    dense_s, grasp_s = agg_cost_model(capacity, feats, nnz_blocks=nnz_blocks,
                                      max_nnz=width, block_size=block_size)
    if max_row_nnz > budget:
        return "dense", dense_s, grasp_s
    if mode == "grasp":
        return "grasp", dense_s, grasp_s
    rank_dense, rank_grasp = dense_s, grasp_s
    if measured is not None and measured[0] is not None \
            and measured[1] is not None:
        rank_dense, rank_grasp = float(measured[0]), float(measured[1])
    return ("grasp" if rank_grasp < rank_dense else "dense"), dense_s, grasp_s


def bfs_reorder(adj: np.ndarray, num_nodes: int) -> np.ndarray:
    """BFS (Cuthill–McKee-like) node permutation to densify blocks.

    Beyond-paper GraSp enhancement (DESIGN.md §6): element-level ZVC is the
    paper's NPU mechanism, but the TPU's skip granularity is the 128×128 MXU
    block. Uniformly-scattered edges leave almost every block non-zero even
    at 99% element sparsity; ordering nodes by BFS over the graph clusters
    neighborhoods near the diagonal, concentrating edges into far fewer
    blocks (the block-skip fraction becomes meaningful). Returns a
    permutation `perm` such that A' = A[perm][:, perm].
    """
    n = num_nodes
    deg = (adj[:n, :n] > 0).sum(axis=1)
    visited = np.zeros(n, dtype=bool)
    order = []
    # start from lowest-degree nodes (classic CM heuristic)
    for seed in np.argsort(deg):
        if visited[seed]:
            continue
        queue = [int(seed)]
        visited[seed] = True
        while queue:
            v = queue.pop(0)
            order.append(v)
            nbrs = np.nonzero(adj[v, :n])[0]
            nbrs = nbrs[~visited[nbrs]]
            nbrs = nbrs[np.argsort(deg[nbrs])]
            visited[nbrs] = True
            queue.extend(int(x) for x in nbrs)
    perm = np.asarray(order + list(range(n, adj.shape[0])), dtype=np.int64)
    return perm


def apply_reorder(a: np.ndarray, perm: np.ndarray) -> np.ndarray:
    return a[perm][:, perm]


def sparsity_report(a: np.ndarray, *, block_size: int = MXU_TILE) -> dict:
    sp = to_block_sparse(a, block_size=block_size)
    return {
        "element_density": float(np.count_nonzero(a) / a.size),
        "block_density": sp.density,
        "dense_bytes": int(a.nbytes),
        "zvc_bytes": zvc_compressed_bytes(a),
        "block_compacted_bytes": int(sp.blocks.nbytes + sp.block_cols.nbytes
                                     + sp.counts.nbytes),
        "flop_skip_fraction": 1.0 - sp.density,
    }
