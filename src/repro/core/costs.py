"""One source of truth for the modelled wire/compute constants.

Before this module, three copies of the derated-accelerator numbers had
drifted into the tree: `core/partition.py` (the GraphSplit stage planner
and `modelled_sharded_latency` each inlined `197e12 * 0.4`),
`core/sparsity.py` (the §10 backend rule's `MXU_RATE`/`HBM_BW`), and
`benchmarks/tpu_model.py` (the HLO pricer's `PEAK_BF16`/`HBM_BW`/
`GATHER_BW`). The `LatencyBank` roofline seeds (`GraphServe.
_modelled_batch_s`), the sharded latency model, and the benchmark pricer
all claim to use "the same constants" — this module makes that claim
structural instead of a comment. Every consumer imports from here;
the historical module-level names stay re-exported at their old homes
so external callers keep working.

The numbers model a TPU-v4-class part (same spirit as the paper's NPU
asymmetry): a fast dense MXU datapath, full-bandwidth HBM, serialized
gather/scatter, a PCIe-class host link, and an ICI-class device fabric.
"""
from __future__ import annotations

# --- compute (derated dense roofline) --------------------------------------
PEAK_BF16 = 197e12             # peak dense bf16/fp32-accum FLOPs/s
MXU_DERATE = 0.4               # sustained fraction of peak on real layers
MXU_RATE = PEAK_BF16 * MXU_DERATE  # derated dense throughput (FLOPs/s)
HBM_BW = 819e9                 # HBM bytes/s, full streaming bandwidth
GATHER_BW = HBM_BW * 0.05      # serialized gather/scatter effective bytes/s
CPU_RATE = 5e10                # host scalar throughput (ops/s)

# --- host link (PCIe-class) ------------------------------------------------
# Deliberately much slower than HBM so the GraphSplit cost model penalizes
# chatty host/device partitions, as on a real TPU host (DESIGN.md §2).
HOST_LINK_BYTES_PER_S = 16e9
LAUNCH_LATENCY_S = 20e-6

# --- device interconnect (ICI-class) ---------------------------------------
# What the sharded serving path's halo collectives cross (DESIGN.md §12):
# an order of magnitude more bandwidth than the host link and a
# per-collective latency closer to a kernel launch than a PCIe round-trip.
# Distinct constants so the host/device cut and the N-way shard model can
# never silently share the wrong wire.
DEVICE_LINK_BYTES_PER_S = 100e9
COLLECTIVE_LATENCY_S = 2e-6
