"""EffOp: rewrite control-heavy ops as data-parallel masked arithmetic.

Catalogue of the paper's DSP->DPU substitutions, expressed as
gather/select-HLO -> dense-MXU-HLO rewrites (the TPU analogue — gather,
scatter, and select lower to slow non-MXU work on TPU exactly as they land on
the NPU's DSP):

  gather(h, idx)            -> one_hot(idx) @ h
  segment_sum(msg, dst)     -> A_mask @ msg
  where(mask, x, -inf)      -> x + additive_bias          (GrAx1)
  a_src[i] + a_dst[j] edge  -> outer broadcast-add         (GrAx2 ordering)
  segment_max(msg, dst)     -> max over (mask*msg + bias)  (GrAx3)

These are semantically exact when masks are exact; the GrAx variants trade
bit-exactness for fewer ops (documented per function).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def one_hot_gather(h: jnp.ndarray, idx: jnp.ndarray, *, dtype=jnp.float32) -> jnp.ndarray:
    """EffOp gather: rows h[idx] computed as one_hot(idx) @ h.

    Turns a (slow, sequential) row gather into an MXU matmul. Worth it when
    idx is reused across many feature columns (GNN aggregation) — the one-hot
    operand is exactly StaGr's "precomputed mask".
    """
    oh = jax.nn.one_hot(idx, h.shape[0], dtype=dtype)
    return oh @ h


def masked_select_add(scores: jnp.ndarray, additive_bias: jnp.ndarray) -> jnp.ndarray:
    """GrAx1: replace where(mask, scores, -inf) with scores + bias."""
    return scores + additive_bias


def masked_select_exact(scores: jnp.ndarray, mask01: jnp.ndarray) -> jnp.ndarray:
    """Exact (baseline) masking: multiplicative mask then Select. This is the
    control-heavy form the paper's Fig. 16 removes."""
    return jnp.where(mask01 > 0, scores * mask01, NEG_INF)


def broadcast_add_scores(src_term: jnp.ndarray, dst_term: jnp.ndarray,
                         *, grax2: bool = True) -> jnp.ndarray:
    """GAT edge logits e[i,j] = dst_term[i] + src_term[j].

    exact path (grax2=False): materialize dst broadcast, transpose the src
    broadcast, then add — the transpose+broadcast pair Fig. 17 eliminates.
    GrAx2 path: single fused rank-promotion add (add then broadcast). The
    results are numerically identical; the win is purely op-count/layout —
    on TPU the exact path forces an extra copy, visible in the HLO.
    """
    if grax2:
        return dst_term[:, None] + src_term[None, :]
    d = jnp.broadcast_to(dst_term[:, None], (dst_term.shape[0], src_term.shape[0]))
    s = jnp.transpose(jnp.broadcast_to(src_term[:, None],
                                       (src_term.shape[0], dst_term.shape[0])))
    return d + s


def masked_max_aggregate(h: jnp.ndarray, mask01: jnp.ndarray,
                         *, grax3: bool = True,
                         row_block: int = 128) -> jnp.ndarray:
    """SAGE-max aggregation over a 0/1 sampled adjacency.

    GrAx3 (paper Fig. 18): mask * h broadcast-multiply then max-pool on the
    DPU. Correct whenever the aggregated features are >= 0 (paper's stated
    condition; after ReLU this always holds). Rows with no neighbors get 0.

    The (N, N, F) product is streamed in `row_block`-row tiles (the NPU
    streams it through the DPU exactly the same way; materializing it whole
    is 45 TB for Cora layer 1) — this is also the Pallas kernel's tiling.

    exact path: additive -inf bias (select-based), correct for any sign.

    `mask01` may be rectangular (rows, cols) — the sharded serving path
    (DESIGN.md §12) aggregates a shard's OWN rows against the FULL column
    space, so only the row axis is tiled; `h` must have cols rows.
    """
    n = mask01.shape[0]
    rb = min(row_block, n)

    def block(mrows):
        if grax3:
            prod = mrows[:, :, None] * h[None, :, :]
            return jnp.max(prod, axis=1)
        bias = jnp.where(mrows > 0, 0.0, NEG_INF)
        masked = h[None, :, :] + bias[:, :, None]
        out = jnp.max(masked, axis=1)
        has_nbr = mrows.sum(axis=1, keepdims=True) > 0
        return jnp.where(has_nbr, out, 0.0)

    if n % rb:
        return block(mask01)
    blocks = mask01.reshape(n // rb, rb, mask01.shape[1])
    # checkpoint: the (rb, N, F) product is recomputed in backward instead
    # of 22 blocks' residuals living at once (44 GB for Cora layer 1)
    return jax.lax.map(jax.checkpoint(block), blocks).reshape(n, h.shape[1])


def segment_softmax_dense(logits: jnp.ndarray, additive_bias: jnp.ndarray) -> jnp.ndarray:
    """Dense row-softmax with additive masking — EffOp's replacement for
    per-destination segment softmax over edge lists."""
    z = logits + additive_bias
    z = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    e = jnp.exp(z)
    return e / jnp.maximum(e.sum(axis=-1, keepdims=True), 1e-12)
