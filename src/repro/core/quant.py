"""QuantGr: symmetric, static INT8 quantization.

Paper semantics reproduced exactly:
  * symmetric (zero_point = 0, one scale for +/-),
  * static (scales fixed offline during a calibration pass, never at runtime),
  * both weights and activations quantized,
  * INT8 matmul accumulates in INT32 (the NPU's 2x TOPs datapath; the TPU
    MXU's int8 path likewise doubles bf16 throughput).

Calibration = run FP32 forward over calibration inputs, record absmax per
tensor (activations: per-tensor; weights: per-output-channel).

Module contracts (what callers may rely on):

  * Pytree registration — `QParams`, `QuantizedLinear`, and `QuantizedAgg`
    are registered jax pytrees whose LEAVES are the quantized arrays and
    scales. They cross jit/vmap boundaries as runtime arguments, so a
    serving plan traced once against a calibration pytree replays warm for
    every later calibration of the same model shape (the zero-recompile
    contract, DESIGN.md §3/§8). Nothing in here is ever a static jit arg.
  * Static scales — every `*_scale` is fixed at calibration time. Runtime
    code quantizes activations with a stored scale; it never re-derives
    activation ranges (the paper's "static" claim). The ONE exception is
    `quantize_agg_dynamic`: Â is graph *structure*, not an activation, so
    its per-row scales are a deterministic function of an operand the
    serving cache already holds and may be re-derived in-trace without
    violating static-ness (DESIGN.md §8).
  * Numerics — `quantized_matmul_ref` / `apply_quantized_agg` are the
    INT8×INT8→INT32→FP32 oracles; the Pallas kernel path (`use_kernel`)
    must match them bit-for-bit on tile-aligned shapes (tests/test_kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

INT8_MAX = 127.0


@dataclasses.dataclass
class QParams:
    """Static quantization parameters for one tensor."""
    scale: jnp.ndarray  # () for per-tensor, (C,) for per-channel

    def tree_flatten(self):
        return (self.scale,), None


jax.tree_util.register_pytree_node(
    QParams, lambda q: ((q.scale,), None), lambda _, c: QParams(scale=c[0]))


def calibrate_absmax(x: jnp.ndarray, *, axis=None) -> QParams:
    """Static calibration: scale = absmax / 127 (symmetric)."""
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(jnp.abs(x), axis=axis)
    return QParams(scale=jnp.maximum(amax, 1e-8) / INT8_MAX)


def quantize(x: jnp.ndarray, q: QParams) -> jnp.ndarray:
    return jnp.clip(jnp.round(x / q.scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)


def dequantize(xq: jnp.ndarray, q: QParams) -> jnp.ndarray:
    return xq.astype(jnp.float32) * q.scale


def quantized_matmul_ref(xq: jnp.ndarray, wq: jnp.ndarray, sx: jnp.ndarray,
                         sw: jnp.ndarray) -> jnp.ndarray:
    """INT8 x INT8 -> INT32 accumulate -> FP32 rescale (pure-jnp oracle).

    The int8 operands feed the dot DIRECTLY (preferred_element_type does
    the s32 accumulation): an explicit astype(int32) first would bake 4x
    operand copies into the HLO — an artifact no int8 datapath pays, and
    one the roofline model (benchmarks.tpu_model) would mis-price.
    """
    acc = jnp.matmul(xq, wq, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (sx * sw)


@dataclasses.dataclass
class QuantizedLinear:
    """Offline-quantized weight + static activation scale (QuantGr layer)."""
    wq: jnp.ndarray        # (in, out) int8
    w_scale: jnp.ndarray   # (out,) per-channel
    x_scale: jnp.ndarray   # () per-tensor, from calibration


jax.tree_util.register_pytree_node(
    QuantizedLinear,
    lambda q: ((q.wq, q.w_scale, q.x_scale), None),
    lambda _, c: QuantizedLinear(*c))


def quantize_linear(w: jnp.ndarray, calib_x: jnp.ndarray) -> QuantizedLinear:
    """Offline: per-channel weight quant + per-tensor activation calibration."""
    qw = calibrate_absmax(w, axis=0)           # (out,) channel scales
    qx = calibrate_absmax(calib_x)             # () tensor scale
    return QuantizedLinear(wq=quantize(w, qw), w_scale=qw.scale, x_scale=qx.scale)


def apply_quantized_linear(x: jnp.ndarray, ql: QuantizedLinear,
                           *, use_kernel: bool = False) -> jnp.ndarray:
    """Runtime: static-scale activation quant -> int8 matmul -> dequant."""
    xq = jnp.clip(jnp.round(x / ql.x_scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.int8_matmul(xq, ql.wq, ql.x_scale, ql.w_scale)
    return quantized_matmul_ref(xq, ql.wq, ql.x_scale, ql.w_scale)


@dataclasses.dataclass
class QuantizedAgg:
    """QuantGr for the AGGREGATION matmul: Â quantized offline (per-row
    scales — Â rows are the normalized neighborhoods), H quantized with a
    static calibration scale. The paper's 2× INT8 claim applies to the
    whole datapath; aggregation dominates GCN FLOPs (2·N²·H vs 2·N·F·H),
    so combine-only quantization leaves the speedup on the table."""
    aq: jnp.ndarray        # (N, N) int8
    a_scale: jnp.ndarray   # (N, 1) per-row
    h_scale: jnp.ndarray   # () static activation scale


jax.tree_util.register_pytree_node(
    QuantizedAgg,
    lambda q: ((q.aq, q.a_scale, q.h_scale), None),
    lambda _, c: QuantizedAgg(*c))


def quantize_agg(norm_adj: jnp.ndarray, calib_h: jnp.ndarray) -> QuantizedAgg:
    amax = jnp.maximum(jnp.max(jnp.abs(norm_adj), axis=1, keepdims=True), 1e-8)
    a_scale = amax / INT8_MAX
    aq = jnp.clip(jnp.round(norm_adj / a_scale), -INT8_MAX, INT8_MAX
                  ).astype(jnp.int8)
    return QuantizedAgg(aq=aq, a_scale=a_scale,
                        h_scale=calibrate_absmax(calib_h).scale)


def quantize_rowwise(a: jnp.ndarray):
    """Per-row symmetric INT8 quantization -> (aq, a_scale).

    The Â half of QuantGr aggregation (rows are normalized neighborhoods),
    shared by the offline (`quantize_agg`), in-trace
    (`quantize_agg_dynamic`), and serving tier-derived
    (`core.models.derive_tier_operands`) paths — one rounding rule, so all
    three produce bit-identical int8 Â for the same input. Pure jnp.
    """
    amax = jnp.maximum(jnp.max(jnp.abs(a), axis=-1, keepdims=True), 1e-8)
    a_scale = amax / INT8_MAX
    aq = jnp.clip(jnp.round(a / a_scale), -INT8_MAX, INT8_MAX
                  ).astype(jnp.int8)
    return aq, a_scale


def quantize_agg_dynamic(norm_adj: jnp.ndarray,
                         h_scale: jnp.ndarray) -> QuantizedAgg:
    """Derive Â's QuantizedAgg form *inside the trace*.

    `quantize_agg` bakes one graph's int8 Â offline, which is useless to a
    multi-graph serving plan. Â is structure, not activation: its per-row
    scales are a deterministic function of the fp32 operand, so deriving
    them in-trace does not violate QuantGr's static-scale contract — only
    the activation scale `h_scale` is calibration state. The serving
    engine goes one step further and CACHES the derived form per structure
    version (DESIGN.md §8: `derive_tier_operands`), because re-quantizing
    an unchanged Â every query re-reads the 4× fp32 bytes the int8 form
    exists to avoid; this in-trace path remains for one-shot/eager calls.
    Numerics match `quantize_agg` exactly for the same Â.
    """
    aq, a_scale = quantize_rowwise(norm_adj)
    return QuantizedAgg(aq=aq, a_scale=a_scale, h_scale=h_scale)


def apply_quantized_agg(qa: QuantizedAgg, h: jnp.ndarray,
                        *, use_kernel: bool = False) -> jnp.ndarray:
    hq = jnp.clip(jnp.round(h / qa.h_scale), -INT8_MAX, INT8_MAX
                  ).astype(jnp.int8)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.int8_matmul(qa.aq, hq, 1.0, jnp.ones(h.shape[1]))
        return out * (qa.a_scale * qa.h_scale)
    acc = jnp.matmul(qa.aq, hq, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (qa.a_scale * qa.h_scale)


def quantize_tree(params: Dict, calib_acts: Dict) -> Dict:
    """Quantize every (name -> (in,out) weight) given matching calib acts."""
    return {k: quantize_linear(w, calib_acts[k]) for k, w in params.items()}


def quant_error(x: jnp.ndarray) -> float:
    """Round-trip relative error — used by tests to bound QuantGr loss."""
    q = calibrate_absmax(x)
    rt = dequantize(quantize(x, q), q)
    return float(jnp.linalg.norm(rt - x) / jnp.maximum(jnp.linalg.norm(x), 1e-12))
