"""Paper models: 2-layer GCN / GAT / GraphSAGE for node classification.

Matches the paper's Section V setup: hidden width 64 (GAT: 8 heads x 8),
trained with Adam-style optimization, evaluated top-1 on a held-out mask.
Both execution paths (baseline edge-list vs GraNNite dense) share the SAME
parameters, so the benchmark harness compares *implementations*, never
different models.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, masks
from .graph import PaddedGraph
from .layers import Techniques
from .quant import QuantizedLinear, quantize_linear
from .sparsity import BlockSparse, to_block_sparse


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    kind: str                  # "gcn" | "gat" | "sage"
    in_feats: int
    hidden: int = 64
    num_classes: int = 7
    heads: int = 8             # GAT only (hidden per-head = hidden // heads)
    aggregator: str = "mean"   # SAGE only: "mean" | "max"
    max_neighbors: int = 10    # SAGE sampling cap (paper: 10)


def init_params(key, cfg: GNNConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    if cfg.kind == "gcn":
        return {"l1": layers.gcn_init(k1, cfg.in_feats, cfg.hidden),
                "l2": layers.gcn_init(k2, cfg.hidden, cfg.num_classes)}
    if cfg.kind == "gat":
        per_head = cfg.hidden // cfg.heads
        return {"l1": layers.gat_init(k1, cfg.in_feats, per_head, cfg.heads),
                "l2": layers.gat_init(k2, cfg.heads * per_head, cfg.num_classes, 1)}
    if cfg.kind == "sage":
        return {"l1": layers.sage_init(k1, cfg.in_feats, cfg.hidden,
                                       aggregator=cfg.aggregator),
                "l2": layers.sage_init(k2, cfg.hidden, cfg.num_classes,
                                       aggregator=cfg.aggregator)}
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward_baseline(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                     edge_index: jnp.ndarray, num_nodes: int) -> jnp.ndarray:
    if cfg.kind == "gcn":
        h = jax.nn.relu(layers.gcn_baseline(params["l1"], x, edge_index, num_nodes))
        return layers.gcn_baseline(params["l2"], h, edge_index, num_nodes)
    if cfg.kind == "gat":
        per_head = cfg.hidden // cfg.heads
        h = jax.nn.elu(layers.gat_baseline(params["l1"], x, edge_index, num_nodes,
                                           heads=cfg.heads, out_feats=per_head))
        return layers.gat_baseline(params["l2"], h, edge_index, num_nodes,
                                   heads=1, out_feats=cfg.num_classes)
    if cfg.kind == "sage":
        h = jax.nn.relu(layers.sage_baseline(params["l1"], x, edge_index, num_nodes,
                                             aggregator=cfg.aggregator))
        return layers.sage_baseline(params["l2"], h, edge_index, num_nodes,
                                    aggregator=cfg.aggregator)
    raise ValueError(cfg.kind)


@dataclasses.dataclass
class GranniteOperands:
    """Host-precomputed (GraphSplit/PreG/StaGr) dense operands.

    For GrAd these are *arguments*; for StaGr-static callers may close over
    them. Building this object is the 'CPU side' of GraphSplit.
    """
    norm_adj: jnp.ndarray                 # (cap, cap) PreG-normalized
    mask_mult: jnp.ndarray                # GAT exact multiplicative mask
    bias_add: jnp.ndarray                 # GrAx1 additive mask
    sample_mask: jnp.ndarray              # SAGE sampled 0/1 adjacency
    mean_mask: jnp.ndarray                # row-normalized sample mask
    block_sparse: Optional[BlockSparse] = None  # GraSp compacted Â
    quant: Optional[Dict[str, QuantizedLinear]] = None  # QuantGr layers


def build_operands(pg: PaddedGraph, cfg: GNNConfig, *, grasp: bool = False,
                   rng: Optional[np.random.Generator] = None) -> GranniteOperands:
    awl = masks.adj_with_self_loops(pg.adj, pg.num_nodes)
    sample = masks.sage_sample_adjacency(pg.adj, pg.num_nodes,
                                         max_neighbors=cfg.max_neighbors, rng=rng)
    return GranniteOperands(
        norm_adj=jnp.asarray(pg.norm_adj),
        mask_mult=jnp.asarray(masks.attention_bias_multiplicative(awl)),
        bias_add=jnp.asarray(masks.attention_bias_additive(awl)),
        sample_mask=jnp.asarray(sample),
        mean_mask=jnp.asarray(masks.mean_from_mask(sample)),
        block_sparse=to_block_sparse(pg.norm_adj) if grasp else None,
    )


def calibrate_quant(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                    ops_: GranniteOperands) -> Dict:
    """QuantGr static calibration — whole GCN datapath (combine matmuls AND
    the aggregation Â@H, which dominates FLOPs at 2·N²·H)."""
    from .quant import quantize_agg
    if cfg.kind != "gcn":
        raise NotImplementedError("QuantGr calibration wired for GCN (paper Fig. 20)")
    pre1 = x @ params["l1"]["w"]
    h1 = jax.nn.relu(layers.gcn_grannite(params["l1"], x, ops_.norm_adj,
                                         Techniques(stagr=True)))
    pre2 = h1 @ params["l2"]["w"]
    return {"l1": quantize_linear(params["l1"]["w"], x),
            "l2": quantize_linear(params["l2"]["w"], h1),
            "agg1": quantize_agg(ops_.norm_adj, pre1),
            "agg2": quantize_agg(ops_.norm_adj, pre2)}


def forward_grannite(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                     ops_: GranniteOperands, t: Techniques) -> jnp.ndarray:
    if cfg.kind == "gcn":
        q = ops_.quant or {}
        h = jax.nn.relu(layers.gcn_grannite(
            params["l1"], x, ops_.norm_adj, t, quant=q.get("l1"),
            quant_agg=q.get("agg1"), block_sparse=ops_.block_sparse))
        return layers.gcn_grannite(params["l2"], h, ops_.norm_adj, t,
                                   quant=q.get("l2"),
                                   quant_agg=q.get("agg2"),
                                   block_sparse=ops_.block_sparse)
    if cfg.kind == "gat":
        per_head = cfg.hidden // cfg.heads
        h = jax.nn.elu(layers.gat_grannite(
            params["l1"], x, ops_.mask_mult, ops_.bias_add, t,
            heads=cfg.heads, out_feats=per_head))
        return layers.gat_grannite(params["l2"], h, ops_.mask_mult, ops_.bias_add,
                                   t, heads=1, out_feats=cfg.num_classes)
    if cfg.kind == "sage":
        h = jax.nn.relu(layers.sage_grannite(
            params["l1"], x, ops_.sample_mask, ops_.mean_mask, t,
            aggregator=cfg.aggregator))
        return layers.sage_grannite(params["l2"], h, ops_.sample_mask,
                                    ops_.mean_mask, t, aggregator=cfg.aggregator)
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Training / evaluation (to reproduce the paper's accuracy table)
# ---------------------------------------------------------------------------

def masked_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                         mask: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[:, None], axis=-1)[:, 0]
    m = mask.astype(logits.dtype)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    pred = jnp.argmax(logits, axis=-1)
    ok = (pred == labels) & mask
    return ok.sum() / jnp.maximum(mask.sum(), 1)


def train_node_classifier(key, cfg: GNNConfig, pg: PaddedGraph,
                          forward: Callable[[Dict, jnp.ndarray], jnp.ndarray],
                          params: Optional[Dict] = None, *, lr: float = 0.01,
                          weight_decay: float = 5e-4, epochs: int = 100) -> Dict:
    """Full-batch Adam training (paper: lr 0.01, wd 5e-4, 100 epochs)."""
    from repro.optim.adamw import adamw_init, adamw_update

    x = jnp.asarray(pg.features)
    y = jnp.asarray(pg.labels)
    tm = jnp.asarray(pg.train_mask)
    params = params if params is not None else init_params(key, cfg)
    opt = adamw_init(params)

    def loss_fn(p):
        return masked_cross_entropy(forward(p, x), y, tm)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, o = adamw_update(p, g, o, lr=lr, weight_decay=weight_decay)
        return p, o, loss

    for _ in range(epochs):
        params, opt, _ = step(params, opt)
    return params


def evaluate(cfg: GNNConfig, params: Dict, pg: PaddedGraph,
             forward: Callable[[Dict, jnp.ndarray], jnp.ndarray]) -> float:
    logits = forward(params, jnp.asarray(pg.features))
    return float(accuracy(logits, jnp.asarray(pg.labels), jnp.asarray(pg.test_mask)))
