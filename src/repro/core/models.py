"""Paper models: 2-layer GCN / GAT / GraphSAGE for node classification.

Matches the paper's Section V setup: hidden width 64 (GAT: 8 heads x 8),
trained with Adam-style optimization, evaluated top-1 on a held-out mask.
Both execution paths (baseline edge-list vs GraNNite dense) share the SAME
parameters, so the benchmark harness compares *implementations*, never
different models.

Module contracts (what the serving layer relies on):

  * Pytree registration — `GranniteOperands` and `CompactOperands` are
    registered pytrees: runtime leaves cross jit/vmap boundaries as
    arguments, and `CompactOperands`' aux data (capacity, fields,
    triangular) is the ONLY static structure, so one jitted materializer
    specializes exactly once per (bucket, operand-fieldset).
  * Zero-recompile accounting — `ExecutionPlan.trace_count` and
    `OperandMaterializer.trace_count` increment on actual jit traces (a
    python side effect inside the traced fn), never on cache-key inserts.
    `GraphServe.compiled_blobs` sums them; `assert_warm()` is therefore a
    claim about the COMPILER's behavior, not our bookkeeping.
  * Plan identity — `PlanKey = (cfg, capacity, batch, techniques, backend)`.
    Params and QuantGr calibrations are runtime arguments, never closed
    over, so models sharing a key legitimately share one compiled blob; a
    quality tier (DESIGN.md §8) is fully identified by its `Techniques`,
    and the aggregation backend (DESIGN.md §10: `dense` matmul vs `grasp`
    block-sparse `bitmap_spmm`) is the key's orthogonal last dimension — a
    grasp plan's operands always carry a block structure, a dense plan's
    never do, so the trace structure per key is fixed.
  * Calibration shape invariance — `calibrate_tier` output contains only
    model-shaped arrays (per-layer int8 weights + scalar scales); its
    pytree structure is a function of `GNNConfig` alone, never of the
    calibration graph, so a plan warmed against a placeholder calibration
    replays warm against every real one.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import effop, layers, masks
from .graph import PaddedGraph
from .layers import Techniques
from .quant import QuantizedLinear, quantize_linear
from .sparsity import (BlockSparse, block_counts, compact_block_sparse,
                       pad_block_sparse, stack_block_sparse, to_block_sparse)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    kind: str                  # "gcn" | "gat" | "sage"
    in_feats: int
    hidden: int = 64
    num_classes: int = 7
    heads: int = 8             # GAT only (hidden per-head = hidden // heads)
    aggregator: str = "mean"   # SAGE only: "mean" | "max"
    max_neighbors: int = 10    # SAGE sampling cap (paper: 10)


def init_params(key, cfg: GNNConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    if cfg.kind == "gcn":
        return {"l1": layers.gcn_init(k1, cfg.in_feats, cfg.hidden),
                "l2": layers.gcn_init(k2, cfg.hidden, cfg.num_classes)}
    if cfg.kind == "gat":
        per_head = cfg.hidden // cfg.heads
        return {"l1": layers.gat_init(k1, cfg.in_feats, per_head, cfg.heads),
                "l2": layers.gat_init(k2, cfg.heads * per_head, cfg.num_classes, 1)}
    if cfg.kind == "sage":
        return {"l1": layers.sage_init(k1, cfg.in_feats, cfg.hidden,
                                       aggregator=cfg.aggregator),
                "l2": layers.sage_init(k2, cfg.hidden, cfg.num_classes,
                                       aggregator=cfg.aggregator)}
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward_baseline(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                     edge_index: jnp.ndarray, num_nodes: int) -> jnp.ndarray:
    if cfg.kind == "gcn":
        h = jax.nn.relu(layers.gcn_baseline(params["l1"], x, edge_index, num_nodes))
        return layers.gcn_baseline(params["l2"], h, edge_index, num_nodes)
    if cfg.kind == "gat":
        per_head = cfg.hidden // cfg.heads
        h = jax.nn.elu(layers.gat_baseline(params["l1"], x, edge_index, num_nodes,
                                           heads=cfg.heads, out_feats=per_head))
        return layers.gat_baseline(params["l2"], h, edge_index, num_nodes,
                                   heads=1, out_feats=cfg.num_classes)
    if cfg.kind == "sage":
        h = jax.nn.relu(layers.sage_baseline(params["l1"], x, edge_index, num_nodes,
                                             aggregator=cfg.aggregator))
        return layers.sage_baseline(params["l2"], h, edge_index, num_nodes,
                                    aggregator=cfg.aggregator)
    raise ValueError(cfg.kind)


@dataclasses.dataclass
class GranniteOperands:
    """Host-precomputed (GraphSplit/PreG/StaGr) dense operands.

    For GrAd these are *arguments*; for StaGr-static callers may close over
    them. Building this object is the 'CPU side' of GraphSplit. Registered as
    a jax pytree so a whole operand set crosses jit/vmap boundaries as one
    runtime input (the plan/executor split, DESIGN.md §2).
    """
    norm_adj: jnp.ndarray                 # (cap, cap) PreG-normalized
    mask_mult: jnp.ndarray                # GAT exact multiplicative mask
    bias_add: jnp.ndarray                 # GrAx1 additive mask
    sample_mask: jnp.ndarray              # SAGE sampled 0/1 adjacency
    mean_mask: jnp.ndarray                # row-normalized sample mask
    block_sparse: Optional[BlockSparse] = None  # GraSp compacted Â
    quant: Optional[Dict[str, QuantizedLinear]] = None  # QuantGr layers


jax.tree_util.register_pytree_node(
    GranniteOperands,
    lambda o: ((o.norm_adj, o.mask_mult, o.bias_add, o.sample_mask,
                o.mean_mask, o.block_sparse, o.quant), None),
    lambda _, c: GranniteOperands(*c))


# Which operand fields each model kind actually reads; the rest may be
# placeholder zeros when operands are built per-request (lean=True).
OPERAND_FIELDS = {
    "gcn": ("norm_adj",),
    "gat": ("mask_mult", "bias_add"),
    "sage": ("sample_mask", "mean_mask"),
}


def build_operands(pg: PaddedGraph, cfg: GNNConfig, *, grasp: bool = False,
                   rng: Optional[np.random.Generator] = None,
                   lean: bool = False) -> GranniteOperands:
    """Host side of GraphSplit: all dense operands for one padded graph.

    lean=True builds only the fields `cfg.kind` consumes (OPERAND_FIELDS) and
    fills the rest with (1, 1) placeholders — the serving engine builds
    operands per request, where the unused (cap, cap) masks would dominate
    host time and memory. Placeholders are safe through jit/vmap because the
    forward for that kind never touches them.
    """
    fields = OPERAND_FIELDS[cfg.kind] if lean else (
        "norm_adj", "mask_mult", "bias_add", "sample_mask", "mean_mask")
    hole = jnp.zeros((1, 1), jnp.float32)
    vals = {k: hole for k in ("norm_adj", "mask_mult", "bias_add",
                              "sample_mask", "mean_mask")}
    if "norm_adj" in fields:
        vals["norm_adj"] = jnp.asarray(pg.norm_adj)
    if "mask_mult" in fields or "bias_add" in fields:
        awl = masks.adj_with_self_loops(pg.adj, pg.num_nodes)
        vals["mask_mult"] = jnp.asarray(masks.attention_bias_multiplicative(awl))
        vals["bias_add"] = jnp.asarray(masks.attention_bias_additive(awl))
    if "sample_mask" in fields or "mean_mask" in fields:
        sample = masks.sage_sample_adjacency(
            pg.adj, pg.num_nodes, max_neighbors=cfg.max_neighbors, rng=rng)
        vals["sample_mask"] = jnp.asarray(sample)
        vals["mean_mask"] = jnp.asarray(masks.mean_from_mask(sample))
    return GranniteOperands(
        block_sparse=to_block_sparse(pg.norm_adj) if grasp else None, **vals)


def stack_operands(ops: Sequence[GranniteOperands]) -> GranniteOperands:
    """Stack per-graph operands into one batched (B, ...) operand set.

    Batched plans execute vmapped, so every field gains a leading batch dim
    — including GraSp block structures: same-bucket structures padded to
    one `grasp_max_nnz` budget stack via `stack_block_sparse` (all-or-none
    per batch; a grasp plan's operands always carry one, a dense plan's
    never do — DESIGN.md §10). Only the per-graph OFFLINE QuantGr form
    (`ops.quant`, from `calibrate_quant`) has no batched shape — it bakes
    ONE graph's Â into its QuantizedAgg, so the engine runs it
    single-graph. Serving-tier QuantGr does not hit this limit: its
    calibration is model-level (`calibrate_tier`) and rides the plan's
    broadcast `quant` argument, never the operands (DESIGN.md §8).
    """
    if any(o.quant is not None for o in ops):
        raise ValueError(
            "per-graph offline QuantGr operands (ops.quant, built by "
            "calibrate_quant) cannot be batched — their QuantizedAgg bakes "
            "one graph's Â; serve quantized tiers through the model-level "
            "calibrate_tier path instead (DESIGN.md §8)")
    with_blocks = [o.block_sparse is not None for o in ops]
    if any(with_blocks) and not all(with_blocks):
        raise ValueError(
            "cannot batch a mix of GraSp and dense operand sets — resolve "
            "one aggregation backend per batch (DESIGN.md §10)")
    return GranniteOperands(
        norm_adj=jnp.stack([o.norm_adj for o in ops]),
        mask_mult=jnp.stack([o.mask_mult for o in ops]),
        bias_add=jnp.stack([o.bias_add for o in ops]),
        sample_mask=jnp.stack([o.sample_mask for o in ops]),
        mean_mask=jnp.stack([o.mean_mask for o in ops]),
        block_sparse=(stack_block_sparse([o.block_sparse for o in ops])
                      if all(with_blocks) else None),
    )


# ---------------------------------------------------------------------------
# CacheG operand pipeline (DESIGN.md §7)
#
# The eager path above builds the O(cap²) float32 operands on the HOST and
# ships them over the host→device link on every request. CacheG replaces
# that with (1) a compact transfer form — one bit-packed 0/1 adjacency plus
# a degree vector (`CompactOperands`), SymG-triangular when the graph is
# undirected — and (2) a jitted device-side materializer that re-derives the
# dense operands with VPU ops, so the big arrays are *created* in device
# memory and never cross the link. GraphServe then caches the materialized
# result per (graph_id, structure_version).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompactOperands:
    """Compact host→device transfer form of one graph's operand structure.

    `packed` is the bit-packed 0/1 adjacency: SymG upper triangle for
    undirected GCN/GAT graphs (`triangular=True`), the full row-major matrix
    otherwise — for SAGE it packs the host-*sampled* adjacency (sampling
    stays on the host for seeded determinism; it is O(cap²) bit work, not
    float32 mask construction). `degree` carries the row sums the
    materializer divides by (deg(A+I) for GCN, sample row sums for SAGE), so
    host and device paths normalize with bit-identical denominators.

    Registered as a pytree: (packed, degree, num_nodes) are runtime leaves;
    (capacity, fields, triangular) are static structure, so one jitted
    materializer specializes exactly once per (bucket, operand-fieldset).
    """
    packed: jnp.ndarray      # (nbits/8,) uint8
    degree: jnp.ndarray      # (cap,) float32
    num_nodes: jnp.ndarray   # () int32
    capacity: int
    fields: Tuple[str, ...]  # which GranniteOperands fields to materialize
    triangular: bool         # SymG triangular packing vs full row-major

    @property
    def nbytes(self) -> int:
        """Bytes this form moves host→device (the operand_bytes_h2d unit)."""
        return int(self.packed.nbytes + self.degree.nbytes
                   + self.num_nodes.nbytes)


jax.tree_util.register_pytree_node(
    CompactOperands,
    lambda c: ((c.packed, c.degree, c.num_nodes),
               (c.capacity, c.fields, c.triangular)),
    lambda aux, ch: CompactOperands(*ch, *aux))


def compact_operands(pg: PaddedGraph, cfg: GNNConfig, *,
                     rng: Optional[np.random.Generator] = None,
                     check_symmetry: bool = True) -> CompactOperands:
    """Host side of CacheG: pack one graph's structure into transfer form.

    GCN/GAT pack the raw adjacency (SymG triangular — requires an undirected
    graph; callers check `is_symmetric_adjacency` and fall back to the eager
    dense path for directed ones, see GraphServe). SAGE samples on the host
    (same seeded rng as `build_operands`) and packs the sampled mask, which
    is direction-biased, hence always full row-major.

    `check_symmetry=False` skips the O(cap²) symmetry re-validation for
    callers that already ran `is_symmetric_adjacency` on this adjacency
    (the serving hot path checks once to pick compact-vs-fallback).
    """
    from .graph import pack_adjacency_bits, symg_pack_adjacency_bits
    fields = OPERAND_FIELDS[cfg.kind]
    cap = pg.capacity
    if cfg.kind == "sage":
        sample = masks.sage_sample_adjacency(
            pg.adj, pg.num_nodes, max_neighbors=cfg.max_neighbors, rng=rng)
        packed = pack_adjacency_bits(sample)
        degree = sample.sum(axis=1).astype(np.float32)
        triangular = False
    else:
        packed = symg_pack_adjacency_bits(pg.adj, check=check_symmetry)
        if "norm_adj" in fields:
            # degree of A+I via the idempotent self-loop set (NOT adj.sum+1,
            # which would double-count an explicit (i, i) edge in edge_index)
            degree = masks.adj_with_self_loops(pg.adj, pg.num_nodes).sum(
                axis=1).astype(np.float32)
        else:
            # GAT reads only the masks; the degree leaf must still exist
            # (stable pytree structure) but need not be computed
            degree = np.zeros((cap,), np.float32)
        triangular = True
    return CompactOperands(
        packed=jnp.asarray(packed),
        degree=jnp.asarray(degree),
        num_nodes=jnp.asarray(pg.num_nodes, jnp.int32),
        capacity=cap, fields=fields, triangular=triangular)


def _unpack_adjacency(co: CompactOperands) -> jnp.ndarray:
    """Device-side unpack: packed bits -> dense (cap, cap) float32 0/1.

    The triangular path gathers each (i, j >= i) entry from its linear
    upper-triangle offset computed with iota arithmetic (no O(cap²) index
    constants baked into the trace) and symmetrizes with a max against the
    transpose — exact for 0/1 matrices.
    """
    from .graph import triangular_nbits
    cap = co.capacity
    if co.triangular:
        nbits = triangular_nbits(cap)
        bits = jnp.unpackbits(co.packed, count=nbits)
        i = jnp.arange(cap, dtype=jnp.int32)[:, None]
        j = jnp.arange(cap, dtype=jnp.int32)[None, :]
        # row i's triangle starts at i*cap - i(i-1)/2; entry (i, j) sits j-i in
        lin = i * (2 * cap - i + 1) // 2 + (j - i)
        upper = jnp.where(j >= i, bits[jnp.clip(lin, 0, nbits - 1)], 0)
        return jnp.maximum(upper, upper.T).astype(jnp.float32)
    bits = jnp.unpackbits(co.packed, count=cap * cap)
    return bits.reshape(cap, cap).astype(jnp.float32)


def materialize_operands(co: CompactOperands) -> GranniteOperands:
    """Device side of CacheG: expand the compact form into the dense operand
    set `co.fields` names, leaving the rest as (1, 1) placeholders exactly
    like `build_operands(lean=True)`. Pure jnp — jit it once per bucket
    (GraphServe warms it in `warmup()`), after which every structure miss is
    one tiny upload plus O(cap²) VPU work entirely in device memory.
    """
    cap = co.capacity
    adj = _unpack_adjacency(co)
    hole = jnp.zeros((1, 1), jnp.float32)
    vals = {k: hole for k in ("norm_adj", "mask_mult", "bias_add",
                              "sample_mask", "mean_mask")}
    if "sample_mask" in co.fields or "mean_mask" in co.fields:
        # packed IS the sampled adjacency (self loops already included)
        vals["sample_mask"] = adj
        vals["mean_mask"] = adj / jnp.maximum(co.degree[:, None], 1.0)
    else:
        i = jnp.arange(cap, dtype=jnp.int32)
        real = (i < co.num_nodes)
        awl = jnp.where((i[:, None] == i[None, :]) & real[:, None], 1.0, adj)
        if "norm_adj" in co.fields:
            dis = jnp.where(co.degree > 0,
                            1.0 / jnp.sqrt(jnp.maximum(co.degree, 1e-12)), 0.0)
            vals["norm_adj"] = dis[:, None] * awl * dis[None, :]
        if "mask_mult" in co.fields or "bias_add" in co.fields:
            vals["mask_mult"] = (awl > 0).astype(jnp.float32)
            vals["bias_add"] = jnp.where(awl > 0, 0.0, masks.NEG_INF
                                         ).astype(jnp.float32)
    return GranniteOperands(**vals)


@dataclasses.dataclass
class OperandMaterializer:
    """The jitted CacheG expander, with the same trace accounting as
    ExecutionPlan: jit specializes on the CompactOperands *structure*
    (capacity, fields, triangular), so `trace_count` is the number of
    (bucket, fieldset) combinations compiled — GraphServe warms them all in
    `warmup()` and folds the count into the zero-recompile contract.
    """
    fn: Callable = dataclasses.field(default=None, repr=False)
    trace_count: int = 0

    def __call__(self, co: CompactOperands) -> GranniteOperands:
        return self.fn(co)


def build_materializer() -> OperandMaterializer:
    mat = OperandMaterializer()

    def _materialize(co):
        mat.trace_count += 1              # python side effect: traces only
        return materialize_operands(co)

    mat.fn = jax.jit(_materialize)
    return mat


@dataclasses.dataclass
class HostOperands:
    """Product of the pipeline's HOST stage for one request (DESIGN.md §9).

    The GraphSplit host work — padding aside (the caller pads), this is
    CompactOperands bit-packing or the eager dense build — is separable
    from the DEVICE work (materialization + the plan dispatch) so a
    scheduler can run the two on different threads: `prepare_host_operands`
    is pure numpy/bit work a host worker executes, `realize_operands` turns
    the result into the device-resident `GranniteOperands` the plan
    consumes. Exactly one of `compact` / `eager` is set; `nbytes` is the
    host→device operand traffic this form moves (the `operand_bytes_h2d`
    unit), and `fallback` marks a directed GCN/GAT graph that could not
    take the SymG compact path (counted as `cacheg_fallbacks`).

    `grasp` carries the GraSp block structure through the host stage when
    the request resolved to the grasp backend AND the structure had to be
    built host-side (`to_block_sparse` + `pad_block_sparse` — the eager
    path, where the dense Â crosses the link anyway). On the compact path
    it stays None: the engine derives the structure DEVICE-side from the
    materialized Â (`BlockCompactor`, zero extra bytes — DESIGN.md §10).
    """
    compact: Optional[CompactOperands] = None
    eager: Optional[GranniteOperands] = None
    grasp: Optional[BlockSparse] = None
    nbytes: int = 0
    fallback: bool = False


def prepare_host_operands(pg: PaddedGraph, cfg: GNNConfig, *,
                          use_cacheg: bool = True,
                          rng: Optional[np.random.Generator] = None,
                          grasp_max_nnz: Optional[int] = None,
                          grasp_bitmap: Optional[np.ndarray] = None,
                          symmetric: Optional[bool] = None
                          ) -> HostOperands:
    """HOST stage of the operand pipeline: pack (CacheG) or build (eager).

    Prefers the CacheG compact transfer form; directed GCN/GAT graphs
    (SymG needs symmetry) and engines running with `use_cacheg=False` fall
    back to the eager dense host build. No device work happens here — a
    scheduler host worker can call this from any thread.

    `grasp_max_nnz` marks a request the engine resolved to the GraSp
    backend (DESIGN.md §10): the eager path then also compacts the block
    structure here on the host (`to_block_sparse`, padded to the bucket
    budget, its bytes counted in `nbytes` since they cross the link —
    `grasp_bitmap`, when the backend rule already scanned this Â, skips
    the compaction's own reduction pass); the compact path ignores it —
    the structure is derived device-side from the materialized Â, which
    is the whole point of caching it. `symmetric` short-circuits the
    O(cap²) symmetry check when the caller already ran it on this
    adjacency (one scan per request, not two).
    """
    from .graph import is_symmetric_adjacency
    if use_cacheg and (cfg.kind == "sage"
                       or (symmetric if symmetric is not None
                           else is_symmetric_adjacency(pg.adj))):
        co = compact_operands(pg, cfg, rng=rng, check_symmetry=False)
        return HostOperands(compact=co, nbytes=co.nbytes)
    ops = build_operands(pg, cfg, lean=True, rng=rng)
    grasp = None
    nbytes = operand_nbytes(ops)
    if grasp_max_nnz is not None and cfg.kind == "gcn":
        grasp = pad_block_sparse(
            to_block_sparse(pg.norm_adj, bitmap=grasp_bitmap), grasp_max_nnz)
        nbytes += grasp.nbytes
    return HostOperands(eager=ops, grasp=grasp, nbytes=nbytes,
                        fallback=use_cacheg)


def realize_operands(ho: HostOperands,
                     materializer: OperandMaterializer) -> GranniteOperands:
    """DEVICE stage counterpart: expand the host product into the dense
    operand set (a jitted materializer call for the compact form, identity
    for the eager fallback), attaching the host-built GraSp structure when
    the host stage carried one. Dispatch is async under jax, so a host
    worker calling this merely *enqueues* device work — the dense arrays
    are created in device memory either way."""
    if ho.compact is not None:
        return materializer(ho.compact)
    if ho.grasp is not None:
        return dataclasses.replace(ho.eager, block_sparse=ho.grasp)
    return ho.eager


def operand_nbytes(ops: GranniteOperands) -> int:
    """Host→device bytes of one eagerly built operand set (the five dense
    fields; a GraSp structure's bytes are accounted where it is built —
    `prepare_host_operands` on the eager path, zero on the device-derived
    path — and offline QuantGr never takes the batched serve path).
    Reads `.nbytes` (both jnp and np expose it) — no device→host copy."""
    return int(sum(f.nbytes for f in (
        ops.norm_adj, ops.mask_mult, ops.bias_add, ops.sample_mask,
        ops.mean_mask)))


def calibrate_quant(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                    ops_: GranniteOperands) -> Dict:
    """QuantGr static calibration — whole GCN datapath (combine matmuls AND
    the aggregation Â@H, which dominates FLOPs at 2·N²·H)."""
    from .quant import quantize_agg
    if cfg.kind != "gcn":
        raise NotImplementedError("QuantGr calibration wired for GCN (paper Fig. 20)")
    pre1 = x @ params["l1"]["w"]
    h1 = jax.nn.relu(layers.gcn_grannite(params["l1"], x, ops_.norm_adj,
                                         Techniques(stagr=True)))
    pre2 = h1 @ params["l2"]["w"]
    return {"l1": quantize_linear(params["l1"]["w"], x),
            "l2": quantize_linear(params["l2"]["w"], h1),
            "agg1": quantize_agg(ops_.norm_adj, pre1),
            "agg2": quantize_agg(ops_.norm_adj, pre2)}


@dataclasses.dataclass
class TierOperands:
    """Per-(graph, tier) DERIVED operands (DESIGN.md §8).

    Today this is GCN's int8 aggregation form: Â quantized per-row ONCE per
    structure version, then cached device-resident next to the fp32 operand
    set it was derived from. The point is byte traffic, not math: the int8
    plan reads 1-byte Â rows instead of re-reading (and re-quantizing) the
    4-byte fp32 Â every query — on the NPU this is exactly the state CacheG
    keeps SRAM-resident. GAT/SAGE tiers need no per-graph derivation (their
    QuantGr state is model-level weights), so they pass None.
    """
    agg_aq: jnp.ndarray        # (cap, cap) int8 row-quantized Â
    agg_a_scale: jnp.ndarray   # (cap, 1) float32 per-row scales


jax.tree_util.register_pytree_node(
    TierOperands,
    lambda o: ((o.agg_aq, o.agg_a_scale), None),
    lambda _, c: TierOperands(*c))


def derive_tier_operands(norm_adj: jnp.ndarray) -> TierOperands:
    """Device side of the tier-operand derivation: row-quantize one fp32 Â
    (`quantize_rowwise` — the same rounding rule as every other QuantGr agg
    path). Pure jnp; the serving engine jits it per bucket
    (`build_agg_quantizer`) and caches the result per structure version."""
    from .quant import quantize_rowwise
    aq, a_scale = quantize_rowwise(norm_adj)
    return TierOperands(agg_aq=aq, agg_a_scale=a_scale)


def stack_tier_operands(tos: Sequence[TierOperands]) -> TierOperands:
    """Stack per-graph tier operands for one vmapped batched dispatch."""
    return TierOperands(agg_aq=jnp.stack([t.agg_aq for t in tos]),
                        agg_a_scale=jnp.stack([t.agg_a_scale for t in tos]))


@dataclasses.dataclass
class AggQuantizer:
    """The jitted tier-operand deriver, with the same trace accounting as
    ExecutionPlan / OperandMaterializer: jit specializes on Â's shape, so
    `trace_count` is the number of buckets compiled — GraphServe warms them
    in `warmup()` and folds the count into the zero-recompile contract."""
    fn: Callable = dataclasses.field(default=None, repr=False)
    trace_count: int = 0

    def __call__(self, norm_adj: jnp.ndarray) -> TierOperands:
        return self.fn(norm_adj)


def build_agg_quantizer() -> AggQuantizer:
    q = AggQuantizer()

    def _derive(norm_adj):
        q.trace_count += 1                # python side effect: traces only
        return derive_tier_operands(norm_adj)

    q.fn = jax.jit(_derive)
    return q


# ---------------------------------------------------------------------------
# GrAd edge-delta patching (DESIGN.md §13): device-side incremental update
# of the cached operand forms — scatter the flipped awl entries, renorm the
# touched rows/cols of Â with the host-recomputed D^-1/2, re-quantize only
# the rows whose fp32 values changed. Every arithmetic expression below
# copies `materialize_operands` / `quantize_rowwise` operand-for-operand, so
# a patched entry is BIT-IDENTICAL to a fresh rebuild of the new structure
# version — the differential property suite holds it to that.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeltaSpec:
    """Device-side description of one symmetric edge delta.

    Static padding keeps the trace count bounded: `flip_*` and `touched`
    are padded to engine-configured widths by REPEATING their first entry —
    the scatters then write identical values at duplicate indices, which is
    deterministic, and the row renorms recompute a row to the same bits
    twice. `dis` is the FULL patched D^-1/2 vector, computed host-side with
    the exact `gcn_norm_adjacency` expression (a few cap·4 bytes on the
    wire — the dense Â itself never crosses).
    """
    flip_i: jnp.ndarray            # (K_e,) int32 flip endpoints (symmetric:
    flip_j: jnp.ndarray            # (K_e,) int32  both (i,j) and (j,i) write)
    flip_v: jnp.ndarray            # (K_e,) float32 new awl value (1=add 0=rm)
    touched: jnp.ndarray           # (K_t,) int32 nodes with changed rows/cols
    dirty: jnp.ndarray             # (K_t,) int32 boundary-dirty subset of
    #                                `touched` (§15): rows whose remote
    #                                copies a sharded halo-delta exchange
    #                                must refresh — padded like `touched`;
    #                                unused by the local patch math (an
    #                                unsharded delta pads it inertly)
    dis: jnp.ndarray               # (cap,) float32 patched D^-1/2
    fields: Tuple[str, ...] = ()   # static: which operand fields to patch


jax.tree_util.register_pytree_node(
    DeltaSpec,
    lambda d: ((d.flip_i, d.flip_j, d.flip_v, d.touched, d.dirty, d.dis),
               d.fields),
    lambda fields, c: DeltaSpec(*c, fields=fields))


def patch_operands(ops: GranniteOperands, d: DeltaSpec) -> GranniteOperands:
    """Patch one graph's cached dense operands in place of a rebuild.

    GCN: awl is recovered exactly from the cached Â (an entry is non-zero
    iff awl=1 — real rows have dis>0, padded rows are all zero), the flips
    scattered in, and only the touched rows/cols renormalized with the same
    left-associated `dis[:, None] * awl * dis[None, :]` products the
    materializer uses — untouched entries keep their bits, touched ones
    get the bits a full rebuild would produce. GAT: the mask IS awl, so the
    flips scatter straight in and the bias re-derives from it. Pure jnp.
    """
    if "norm_adj" in d.fields:
        na = ops.norm_adj
        awl = (na != 0).astype(jnp.float32)
        awl = awl.at[d.flip_i, d.flip_j].set(d.flip_v)
        awl = awl.at[d.flip_j, d.flip_i].set(d.flip_v)
        rows = d.dis[d.touched][:, None] * awl[d.touched, :] * d.dis[None, :]
        na = na.at[d.touched, :].set(rows)
        cols = d.dis[:, None] * awl[:, d.touched] * d.dis[d.touched][None, :]
        na = na.at[:, d.touched].set(cols)
        ops = dataclasses.replace(ops, norm_adj=na)
    if "mask_mult" in d.fields:
        m = ops.mask_mult
        m = m.at[d.flip_i, d.flip_j].set(d.flip_v)
        m = m.at[d.flip_j, d.flip_i].set(d.flip_v)
        bias = jnp.where(m > 0, 0.0, masks.NEG_INF).astype(jnp.float32)
        ops = dataclasses.replace(ops, mask_mult=m, bias_add=bias)
    return ops


def patch_tier_operands(tops: TierOperands, norm_adj: jnp.ndarray,
                        touched: jnp.ndarray) -> TierOperands:
    """Re-quantize ONLY the touched rows of the cached int8 Â from the
    patched fp32 Â. `quantize_rowwise` is row-local (per-row absmax), so
    quantizing a gathered row block is bit-identical to the same rows of a
    full `derive_tier_operands` — the whole-matrix requant stays the
    fallback when the changed-row set exceeds the pad width."""
    from .quant import quantize_rowwise
    aq, a_scale = quantize_rowwise(norm_adj[touched, :])
    return TierOperands(
        agg_aq=tops.agg_aq.at[touched].set(aq),
        agg_a_scale=tops.agg_a_scale.at[touched].set(a_scale))


@dataclasses.dataclass
class DeltaPatcher:
    """The jitted GrAd delta patchers, with the same trace accounting as
    ExecutionPlan / OperandMaterializer / AggQuantizer: `fn` specializes
    per (capacity, fieldset, pad widths), `tier_fn` per (capacity, requant
    width) — GraphServe warms both per bucket in `warmup()` and folds the
    count into the zero-recompile contract."""
    fn: Callable = dataclasses.field(default=None, repr=False)
    tier_fn: Callable = dataclasses.field(default=None, repr=False)
    trace_count: int = 0

    def __call__(self, ops: GranniteOperands, d: DeltaSpec
                 ) -> GranniteOperands:
        return self.fn(ops, d)

    def patch_tier(self, tops: TierOperands, norm_adj: jnp.ndarray,
                   touched: jnp.ndarray) -> TierOperands:
        return self.tier_fn(tops, norm_adj, touched)


def build_delta_patcher() -> DeltaPatcher:
    p = DeltaPatcher()

    def _patch(ops, d):
        p.trace_count += 1                # python side effect: traces only
        return patch_operands(ops, d)

    def _tier(tops, norm_adj, touched):
        p.trace_count += 1                # python side effect: traces only
        return patch_tier_operands(tops, norm_adj, touched)

    p.fn = jax.jit(_patch)
    p.tier_fn = jax.jit(_tier)
    return p


@dataclasses.dataclass
class BlockCompactor:
    """The jitted GraSp structure deriver (DESIGN.md §10), with the same
    trace accounting as ExecutionPlan / OperandMaterializer / AggQuantizer:
    jit specializes on Â's shape and the static `max_nnz` budget, so
    `trace_count` is the number of buckets compiled — GraphServe warms them
    in `warmup()` and folds the count into the zero-recompile contract.

    Like the int8 Â (`AggQuantizer`), the block structure is DERIVED state:
    computed device-side from the cached fp32 `norm_adj` once per
    (graph_id, structure_version), so repeat grasp queries move zero
    sparse-structure bytes over the host→device link. `counts` is the
    cheap half of that derivation (one bitmap reduction, no block
    gather) — enough for the backend rule, so a graph the rule routes
    dense never pays the full compaction.
    """
    fn: Callable = dataclasses.field(default=None, repr=False)
    counts_fn: Callable = dataclasses.field(default=None, repr=False)
    trace_count: int = 0

    def __call__(self, norm_adj: jnp.ndarray, *,
                 max_nnz: int) -> Tuple[BlockSparse, jnp.ndarray]:
        return self.fn(norm_adj, max_nnz)

    def counts(self, norm_adj: jnp.ndarray) -> jnp.ndarray:
        return self.counts_fn(norm_adj)


def build_block_compactor() -> BlockCompactor:
    c = BlockCompactor()

    def _compact(norm_adj, max_nnz):
        c.trace_count += 1                # python side effect: traces only
        return compact_block_sparse(norm_adj, max_nnz=max_nnz)

    def _counts(norm_adj):
        c.trace_count += 1                # python side effect: traces only
        return block_counts(norm_adj)

    c.fn = jax.jit(_compact, static_argnames=("max_nnz",))
    c.counts_fn = jax.jit(_counts)
    return c


def calibrate_tier(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                   ops_: GranniteOperands) -> Dict:
    """Model-level QuantGr calibration for one serving tier (all kinds).

    Unlike `calibrate_quant` (whose QuantizedAgg bakes ONE graph's Â into
    int8 — the right thing for a paper table, useless to a multi-graph
    plan), the returned pytree carries only model-shaped state: per-layer
    QuantizedLinear weights plus, for GCN, the static aggregation
    activation scales. One calibration therefore serves every graph of the
    model — the per-graph int8 Â is a separate DERIVED operand the engine
    quantizes once per structure version (`derive_tier_operands`, cached
    device-resident) and feeds to the plan as `tier_ops`; in-trace
    derivation (`quantize_agg_dynamic`) remains only as the fallback for
    one-shot/eager calls. Runs one fp32 forward over the calibration
    features to record absmax ranges (static scales, never re-derived at
    query time).
    """
    if cfg.kind == "gcn":
        # same math as calibrate_quant minus its QuantizedAgg construction
        # (which row-quantizes the full (cap, cap) Â twice only for the
        # scalar h_scales to survive — serving derives the int8 Â per
        # structure version instead, derive_tier_operands)
        from .quant import calibrate_absmax
        pre1 = x @ params["l1"]["w"]
        h1 = jax.nn.relu(layers.gcn_grannite(params["l1"], x, ops_.norm_adj,
                                             Techniques(stagr=True)))
        pre2 = h1 @ params["l2"]["w"]
        return {"l1": quantize_linear(params["l1"]["w"], x),
                "l2": quantize_linear(params["l2"]["w"], h1),
                "agg1_h": calibrate_absmax(pre1).scale,
                "agg2_h": calibrate_absmax(pre2).scale}
    if cfg.kind == "gat":
        per_head = cfg.hidden // cfg.heads
        h1 = jax.nn.elu(layers.gat_grannite(
            params["l1"], x, ops_.mask_mult, ops_.bias_add,
            Techniques(effop=True), heads=cfg.heads, out_feats=per_head))
        return {"l1": quantize_linear(params["l1"]["w"], x),
                "l2": quantize_linear(params["l2"]["w"], h1)}
    if cfg.kind == "sage":
        t0 = Techniques(effop=True)

        def _layer(p, xin):
            if cfg.aggregator == "max":
                pooled = jax.nn.relu(xin @ p["w_pool"] + p["b_pool"])
                agg = effop.masked_max_aggregate(pooled, ops_.sample_mask,
                                                 grax3=False)
            else:
                agg = ops_.mean_mask @ xin
            ql = {"self": quantize_linear(p["w_self"], xin),
                  "neigh": quantize_linear(p["w_neigh"], agg)}
            if "w_pool" in p:
                ql["pool"] = quantize_linear(p["w_pool"], xin)
            return ql

        q1 = _layer(params["l1"], x)
        h1 = jax.nn.relu(layers.sage_grannite(
            params["l1"], x, ops_.sample_mask, ops_.mean_mask, t0,
            aggregator=cfg.aggregator))
        return {"l1": q1, "l2": _layer(params["l2"], h1)}
    raise ValueError(cfg.kind)


# Fusion modes (DESIGN.md §11): how a plan executes each LAYER.
#   none  — aggregate and combine as separate XLA dots (+ host-side act).
#   layer — one fused kernel pass per layer (aggregate + combine + bias +
#           act in a single grid; EffOp epilogue dispatch). A plan
#           dimension, not a tier: fused and unfused plans compute the same
#           tier math, only the execution schedule differs.
FUSION_MODES = ("none", "layer")


def forward_grannite(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                     ops_: GranniteOperands, t: Techniques,
                     quant: Optional[Dict] = None,
                     tier_ops: Optional[TierOperands] = None,
                     fusion: str = "none") -> jnp.ndarray:
    """One dense GraNNite forward. `quant` is the model-level tier
    calibration from `calibrate_tier` (serving tiers); `ops_.quant` is the
    per-graph offline form from `calibrate_quant` (paper tables). When both
    are present the per-graph form wins — it is the more faithful one.
    `tier_ops` carries the per-graph DERIVED tier operands (GCN's cached
    int8 Â); without it a QuantGr GCN forward derives the int8 Â in-trace.
    `fusion="layer"` executes each layer as one fused kernel pass
    (`layers.*_grannite_fused`) with the inter-layer activation folded into
    the kernel epilogue — same math, one grid per layer (DESIGN.md §11).
    """
    if fusion not in FUSION_MODES:
        raise ValueError(f"unknown fusion mode {fusion!r}; pick from "
                         f"{FUSION_MODES}")
    fused = fusion == "layer"
    tq = (quant or {}) if t.quantgr else {}
    if cfg.kind == "gcn":
        q = ops_.quant or {}
        taq = tier_ops.agg_aq if tier_ops is not None else None
        tas = tier_ops.agg_a_scale if tier_ops is not None else None
        l1_kw = dict(quant=q.get("l1") or tq.get("l1"),
                     quant_agg=q.get("agg1"), agg_h_scale=tq.get("agg1_h"),
                     tier_aq=taq, tier_a_scale=tas,
                     block_sparse=ops_.block_sparse)
        l2_kw = dict(quant=q.get("l2") or tq.get("l2"),
                     quant_agg=q.get("agg2"), agg_h_scale=tq.get("agg2_h"),
                     tier_aq=taq, tier_a_scale=tas,
                     block_sparse=ops_.block_sparse)
        if fused:
            h = layers.gcn_grannite_fused(params["l1"], x, ops_.norm_adj, t,
                                          activation="relu", **l1_kw)
            return layers.gcn_grannite_fused(params["l2"], h, ops_.norm_adj,
                                             t, activation="none", **l2_kw)
        h = jax.nn.relu(layers.gcn_grannite(params["l1"], x, ops_.norm_adj,
                                            t, **l1_kw))
        return layers.gcn_grannite(params["l2"], h, ops_.norm_adj, t, **l2_kw)
    if cfg.kind == "gat":
        per_head = cfg.hidden // cfg.heads
        if fused:
            h = layers.gat_grannite_fused(params["l1"], x, ops_.bias_add, t,
                                          heads=cfg.heads, out_feats=per_head,
                                          activation="elu", quant=tq.get("l1"))
            return layers.gat_grannite_fused(params["l2"], h, ops_.bias_add,
                                             t, heads=1,
                                             out_feats=cfg.num_classes,
                                             activation="none",
                                             quant=tq.get("l2"))
        h = jax.nn.elu(layers.gat_grannite(
            params["l1"], x, ops_.mask_mult, ops_.bias_add, t,
            heads=cfg.heads, out_feats=per_head, quant=tq.get("l1")))
        return layers.gat_grannite(params["l2"], h, ops_.mask_mult, ops_.bias_add,
                                   t, heads=1, out_feats=cfg.num_classes,
                                   quant=tq.get("l2"))
    if cfg.kind == "sage":
        if fused:
            h = layers.sage_grannite_fused(
                params["l1"], x, ops_.sample_mask, ops_.mean_mask, t,
                aggregator=cfg.aggregator, activation="relu",
                quant=tq.get("l1"))
            return layers.sage_grannite_fused(
                params["l2"], h, ops_.sample_mask, ops_.mean_mask, t,
                aggregator=cfg.aggregator, activation="none",
                quant=tq.get("l2"))
        h = jax.nn.relu(layers.sage_grannite(
            params["l1"], x, ops_.sample_mask, ops_.mean_mask, t,
            aggregator=cfg.aggregator, quant=tq.get("l1")))
        return layers.sage_grannite(params["l2"], h, ops_.sample_mask,
                                    ops_.mean_mask, t, aggregator=cfg.aggregator,
                                    quant=tq.get("l2"))
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Plan / executor split (DESIGN.md §2)
# ---------------------------------------------------------------------------

# Aggregation backends (DESIGN.md §10): how a plan executes Â @ H.
#   dense — one dense matmul over the full (cap, cap) operand.
#   grasp — the block-sparse bitmap_spmm kernel over a compacted structure
#           (the operands MUST carry `block_sparse`, padded to the bucket's
#           grasp_max_nnz budget; dense plans must carry None).
AGG_BACKENDS = ("dense", "grasp")

# (cfg, capacity, batch, techniques, backend, fusion, shards)
PlanKey = Tuple[GNNConfig, int, int, Techniques, str, str, int]


@dataclasses.dataclass
class ExecutionPlan:
    """One compiled execution recipe: (model kind, NodePad bucket,
    Techniques, aggregation backend).

    The plan owns the jitted callable; operands are *runtime arguments*
    (GrAd discipline), so every graph that lands in the same bucket reuses
    the same compiled blob — callers never rebuild traces ad hoc. With
    batch_size > 0 the forward is vmapped over a leading batch dim of both
    features and operands (params broadcast), which is how GraphServe turns
    many small irregular graphs into one dense statically-shaped dispatch.

    `trace_count` counts actual jit traces (not cache-key entries), so the
    zero-recompile contract is asserted against the compiler, not our own
    bookkeeping. Params are runtime arguments (never closed over), so `key`
    is the full identity of the compiled blob: models sharing (cfg,
    capacity, batch, techniques, backend) can legitimately share one plan.
    A quality tier (DESIGN.md §8) is a Techniques variant, so tiers get
    their own plans through the same key — and tiers that alias the same
    Techniques (GCN's int8 vs int8+grax) share one blob. `backend` is the
    orthogonal aggregation dimension (DESIGN.md §10): "grasp" plans run the
    block-sparse `bitmap_spmm` aggregation and expect operands carrying a
    budget-padded block structure; "dense" plans expect None there.
    `fusion` is the orthogonal execution-schedule dimension (DESIGN.md §11):
    "layer" plans run each layer as ONE fused kernel pass — same tier math,
    different compiled blob, hence part of the key.
    `shards` > 0 marks a SHARDED plan (DESIGN.md §12): `capacity` is then
    the per-shard row bucket, the leading dim of x/operands is the shard
    axis (not a batch), and the trace includes the halo-exchange
    collectives — a different blob per shard count, hence part of the key
    (0 = the ordinary unsharded plan).
    """
    cfg: GNNConfig
    techniques: Techniques
    capacity: int
    batch_size: int = 0                       # 0 = single-graph plan
    backend: str = "dense"
    fusion: str = "none"
    shards: int = 0                           # 0 = unsharded plan
    fn: Callable = dataclasses.field(default=None, repr=False)
    trace_count: int = 0
    # Captured AT TRACE TIME for grasp plans: True when the kernel routing
    # lowered the aggregation through the dense `ref` path (no skip grid).
    # The compiled blob keeps whatever lowering it was traced with, so
    # fallback accounting must read this — not the env at dispatch time.
    grasp_ref_fallback: bool = False

    @property
    def key(self) -> PlanKey:
        return (self.cfg, self.capacity, self.batch_size, self.techniques,
                self.backend, self.fusion, self.shards)

    def __call__(self, params: Dict, x: jnp.ndarray, ops_: GranniteOperands,
                 quant: Optional[Dict] = None,
                 tier_ops: Optional[TierOperands] = None,
                 node_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        if self.shards:
            return self.fn(params, x, ops_, node_mask, quant)
        return self.fn(params, x, ops_, quant, tier_ops)


def build_plan(cfg: GNNConfig, capacity: int, t: Techniques, *,
               batch_size: int = 0, backend: str = "dense",
               fusion: str = "none") -> ExecutionPlan:
    """Compile-on-first-call plan for (cfg.kind, capacity, t, backend,
    fusion).

    batch_size > 0 builds the batched executor: x is (B, cap, F) and every
    operand field carries a leading B dim (see stack_operands); the
    model-level `quant` calibration broadcasts (in_axes=None), exactly like
    params, while the per-graph `tier_ops` are batched like the operands
    (stack_tier_operands). Call discipline for warmth: a plan whose
    Techniques enable QuantGr must ALWAYS be called with a calibration
    pytree (placeholder or real — same structure either way, see
    `calibrate_tier`) and, for GCN, with TierOperands; a non-QuantGr plan
    with None for both. Flipping between None and a pytree changes the
    trace structure and would recompile — the same discipline covers the
    backend dimension: a "grasp" plan's operands must always carry a
    block structure padded to ONE budget, a "dense" plan's never any.

    `backend="grasp"` (DESIGN.md §10) executes the aggregation through the
    block-sparse `bitmap_spmm` path: the tier's Techniques identity is
    unchanged (tiers are serving policy, the backend is a dispatch
    decision), the executed techniques just gain the grasp flag.
    `fusion="layer"` (DESIGN.md §11) executes each layer as one fused
    kernel pass — like the backend, a dispatch decision orthogonal to the
    tier, carried in the key because it changes the compiled blob.
    """
    if backend not in AGG_BACKENDS:
        raise ValueError(f"unknown aggregation backend {backend!r}; pick "
                         f"from {AGG_BACKENDS}")
    if fusion not in FUSION_MODES:
        raise ValueError(f"unknown fusion mode {fusion!r}; pick from "
                         f"{FUSION_MODES}")
    exec_t = dataclasses.replace(t, grasp=True) if backend == "grasp" else t
    plan = ExecutionPlan(cfg=cfg, techniques=t, capacity=capacity,
                         batch_size=batch_size, backend=backend,
                         fusion=fusion)

    def _forward(params, x, ops_, quant, tier_ops):
        plan.trace_count += 1                 # python side effect: traces only
        if backend == "grasp":
            from repro.kernels.ops import bitmap_spmm_mode
            plan.grasp_ref_fallback = bitmap_spmm_mode() == "ref"
        return forward_grannite(params, cfg, x, ops_, exec_t, quant=quant,
                                tier_ops=tier_ops, fusion=fusion)

    if batch_size > 0:
        plan.fn = jax.jit(jax.vmap(_forward, in_axes=(None, 0, 0, None, 0)))
    else:
        plan.fn = jax.jit(_forward)
    return plan


# ---------------------------------------------------------------------------
# Sharded execution (DESIGN.md §12) — GraphSplit across N devices.
#
# A graph too large for the ladder's top bucket is row-partitioned
# (core.partition.partition_graph): shard s owns slot rows
# [s*shard_cap, (s+1)*shard_cap) of a permuted full-capacity layout. Each
# layer runs as: project OWN rows -> halo-exchange the projected rows into
# the full row space (one int8-compressed psum of disjoint zero-padded
# blocks, dist.compress) -> aggregate OWN rows against the FULL space
# through a rectangular (shard_cap, full_rows) operand row block. Row
# blocks keep complete Â rows, so per-row quantization scales — and hence
# the int8 tier numerics — match the single-device path exactly; the only
# sharding-induced error is the wire compression (<= scale/2 per element,
# zero when halo_compress is off).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardSlice:
    """One shard's device-resident operand slice.

    The serving CacheG unit for sharded graphs: cached per
    (graph_id, structure_version, shard) and stacked along a leading shard
    axis at dispatch (`stack_shard_slices`).
    """
    x: jnp.ndarray              # (shard_cap, F) this shard's feature rows
    ops: GranniteOperands       # kind fields (shard_cap, full_rows); holes (1,1)
    node_mask: jnp.ndarray      # (shard_cap,) 1.0 real / 0.0 padding


def build_sharded_operands(g, part, cfg: GNNConfig, *,
                           rng: Optional[np.random.Generator] = None
                           ) -> Tuple[ShardSlice, ...]:
    """Host side of N-way GraphSplit: per-shard operand row blocks.

    Builds the ordinary full-capacity operands once (identical math to the
    unsharded path — including SAGE's seeded neighbor sampling, so the
    sharded forward is differentially testable against it), permutes rows
    AND columns into the slot layout, and slices shard row blocks. Padding
    is interleaved per shard; padded rows/cols are zero, hence inert.
    """
    from .graph import pad_graph
    pg = pad_graph(g, capacity=part.full_rows)
    ops = build_operands(pg, cfg, lean=True, rng=rng)
    perm = part.perm
    fields = OPERAND_FIELDS[cfg.kind]
    mats = {f: np.asarray(getattr(ops, f))[perm][:, perm] for f in fields}
    feats = pg.features[perm]
    mask = (perm < pg.num_nodes).astype(np.float32)
    hole = jnp.zeros((1, 1), jnp.float32)
    c = part.shard_cap
    out = []
    for s in range(part.shards):
        rows = slice(s * c, (s + 1) * c)
        vals = {k: hole for k in ("norm_adj", "mask_mult", "bias_add",
                                  "sample_mask", "mean_mask")}
        for f in fields:
            vals[f] = jnp.asarray(mats[f][rows])
        out.append(ShardSlice(x=jnp.asarray(feats[rows]),
                              ops=GranniteOperands(**vals),
                              node_mask=jnp.asarray(mask[rows])))
    return tuple(out)


def stack_shard_slices(slices: Sequence[ShardSlice]
                       ) -> Tuple[jnp.ndarray, GranniteOperands, jnp.ndarray]:
    """Stack per-shard slices -> (x, ops, node_mask) with a leading shard
    axis, the sharded plan's calling convention."""
    return (jnp.stack([s.x for s in slices]),
            stack_operands([s.ops for s in slices]),
            jnp.stack([s.node_mask for s in slices]))


def unshard_logits(stacked: np.ndarray, part) -> np.ndarray:
    """(shards, shard_cap, classes) slot-ordered logits -> (num_nodes,
    classes) in the original node order (inverse of `part.perm`)."""
    flat = np.asarray(stacked).reshape(part.full_rows, -1)
    out = np.empty_like(flat)
    out[part.perm] = flat
    return out[: part.num_nodes]


def halo_exchange(h_own: jnp.ndarray, node_mask: jnp.ndarray, *,
                  shard_cap: int, full_rows: int, axis_name: str = "shard",
                  compress: bool = True) -> jnp.ndarray:
    """Assemble the full (full_rows, width) matrix from per-shard row blocks.

    Each shard writes its (masked) rows into its slot range of a zeroed
    full-height buffer and the buffers are summed across the shard axis —
    with `compress` the sum is the int8-on-the-wire psum of
    `dist.compress.compressed_psum` (QuantGr applied to the halo traffic).
    Because the blocks are disjoint and zeros quantize exactly, every
    element of the result carries at most scale/2 absolute error, where
    scale = (global absmax)/127 — the bound the dist unit tests assert.
    Padded rows are zeroed BEFORE the exchange so softmax garbage in pad
    rows (GAT) can never inflate the shared compression scale.
    """
    from repro.dist.compress import compressed_psum
    h_own = h_own * node_mask[:, None]
    idx = jax.lax.axis_index(axis_name)
    buf = jnp.zeros((full_rows, h_own.shape[1]), h_own.dtype)
    buf = jax.lax.dynamic_update_slice(buf, h_own, (idx * shard_cap, 0))
    if compress:
        full, _ = compressed_psum(buf, axis_name)
        return full
    return jax.lax.psum(buf, axis_name)


def forward_grannite_sharded(params: Dict, cfg: GNNConfig, x: jnp.ndarray,
                             ops_: GranniteOperands, node_mask: jnp.ndarray,
                             t: Techniques, quant: Optional[Dict] = None, *,
                             shard_cap: int, full_rows: int,
                             axis_name: str = "shard",
                             compress: bool = True) -> jnp.ndarray:
    """One shard's slice of a sharded GraNNite forward (DESIGN.md §12).

    Runs under an SPMD shard axis (`shard_map` or a vmap-simulated axis):
    `x` is this shard's (shard_cap, F) feature rows, `ops_` carries
    rectangular (shard_cap, full_rows) operand row blocks, and the return
    value is this shard's (shard_cap, num_classes) logit rows in slot
    order. Exchange schedule per kind: GCN exchanges the projected hidden
    rows (widths hidden then classes); GAT the per-head projections; SAGE
    the aggregation INPUTS (raw features then layer-1 activations). QuantGr
    GCN derives the int8 Â from the row block in-trace — complete rows
    quantize to exactly the single-device scales, so no sharded tier-operand
    cache is needed.
    """
    from .quant import (QuantizedAgg, apply_quantized_agg,
                        apply_quantized_linear, quantize_rowwise)
    tq = (quant or {}) if t.quantgr else {}

    def _exchange(h_own):
        return halo_exchange(h_own, node_mask, shard_cap=shard_cap,
                             full_rows=full_rows, axis_name=axis_name,
                             compress=compress)

    if cfg.kind == "gcn":
        def _layer(p, v_own, ql, h_scale):
            h_own = (apply_quantized_linear(v_own, ql)
                     if ql is not None else v_own @ p["w"])
            h_full = _exchange(h_own)
            if h_scale is not None:
                aq, a_scale = quantize_rowwise(ops_.norm_adj)
                agg = apply_quantized_agg(
                    QuantizedAgg(aq=aq, a_scale=a_scale, h_scale=h_scale),
                    h_full)
            else:
                agg = ops_.norm_adj @ h_full
            return agg + p["b"]

        h = jax.nn.relu(_layer(params["l1"], x, tq.get("l1"),
                               tq.get("agg1_h")))
        return _layer(params["l2"], h, tq.get("l2"), tq.get("agg2_h"))

    if cfg.kind == "gat":
        def _layer(p, v_own, heads, f_out, ql):
            h_own = (apply_quantized_linear(v_own, ql)
                     if ql is not None else v_own @ p["w"])
            h_full = _exchange(h_own).reshape(full_rows, heads, f_out)
            h_mine = h_own.reshape(shard_cap, heads, f_out)
            a_src = jnp.einsum("nhf,hf->nh", h_full, p["a_src"])  # (full, H)
            a_dst = jnp.einsum("nhf,hf->nh", h_mine, p["a_dst"])  # (C, H)
            outs = []
            for hd in range(heads):
                e = effop.broadcast_add_scores(a_src[:, hd], a_dst[:, hd],
                                               grax2=t.grax2)   # (C, full)
                e = jax.nn.leaky_relu(e, negative_slope=0.2)
                if t.grax1:
                    attn = effop.segment_softmax_dense(e, ops_.bias_add)
                else:
                    e = effop.masked_select_exact(e, ops_.mask_mult)
                    attn = jax.nn.softmax(e, axis=-1)
                outs.append(attn @ h_full[:, hd, :])
            out = jnp.stack(outs, axis=1).reshape(shard_cap, heads * f_out)
            return out + p["b"]

        per_head = cfg.hidden // cfg.heads
        h = jax.nn.elu(_layer(params["l1"], x, cfg.heads, per_head,
                              tq.get("l1")))
        return _layer(params["l2"], h, 1, cfg.num_classes, tq.get("l2"))

    if cfg.kind == "sage":
        def _lin(v, w, ql):
            return apply_quantized_linear(v, ql) if ql is not None else v @ w

        def _layer(p, v_own, q):
            q = q or {}
            v_full = _exchange(v_own)
            if cfg.aggregator == "mean":
                agg = ops_.mean_mask @ v_full
            else:
                pooled = jax.nn.relu(_lin(v_full, p["w_pool"], q.get("pool"))
                                     + p["b_pool"])
                agg = effop.masked_max_aggregate(pooled, ops_.sample_mask,
                                                 grax3=t.grax3)
            return (_lin(v_own, p["w_self"], q.get("self"))
                    + _lin(agg, p["w_neigh"], q.get("neigh")) + p["b"])

        h = jax.nn.relu(_layer(params["l1"], x, tq.get("l1")))
        return _layer(params["l2"], h, tq.get("l2"))
    raise ValueError(cfg.kind)


def build_sharded_plan(cfg: GNNConfig, shard_cap: int, shards: int,
                       t: Techniques, *, compress: bool = True,
                       replicas: int = 1) -> ExecutionPlan:
    """Sharded ExecutionPlan: per-shard aggregate+combine under a shard
    axis, halo exchange as a compressed psum (DESIGN.md §12).

    Placement: with >= `shards` devices the plan runs under `shard_map` on
    a 1-D shard mesh (`launch.mesh.make_shard_mesh`), in/out specs derived
    through the `dist.sharding` rules ("graph_shard" -> "shard", everything
    else replicated). With fewer devices — the common 1-CPU test box — the
    shard axis is vmap-simulated (`axis_name` collectives are identical),
    so the plan's math and trace structure never depend on device count.
    Sharded plans are dense, fusion="none", single-graph (the shard axis
    occupies the leading dim a batched plan would use); call with
    `plan(params, x, ops, quant, node_mask=mask)`.

    `replicas=R > 1` adds a replica axis (DESIGN.md §15): every array
    operand gains a LEADING R dim and the plan runs R independent sharded
    batches concurrently — on the ("replica", "shard") R x S mesh when the
    host has R*S devices, else under an outer anonymous vmap. The replica
    axis carries NO collectives (halo psums name only "shard", so each
    replica row exchanges within itself); replica rows are bit-identical
    to R separate single-replica dispatches, which the property tests
    assert. `replicas=1` is the historical calling convention exactly —
    no leading dim, same jaxpr.
    """
    plan = ExecutionPlan(cfg=cfg, techniques=t, capacity=shard_cap,
                         batch_size=0, backend="dense", fusion="none",
                         shards=shards)
    full_rows = shards * shard_cap

    def _forward(params, x, ops_, mask, quant):
        plan.trace_count += 1                 # python side effect: traces only
        return forward_grannite_sharded(
            params, cfg, x, ops_, mask, t, quant=quant, shard_cap=shard_cap,
            full_rows=full_rows, axis_name="shard", compress=compress)

    lead = 1 if replicas == 1 else 2          # dims ahead of (cap, ...)
    if shards > 1 and len(jax.devices()) >= shards * replicas:
        from jax.sharding import PartitionSpec as P

        from repro.dist.sharding import spec_for_axes
        from repro.launch.mesh import make_shard_mesh
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:                   # newer jax moved it
            from jax import shard_map
        if replicas == 1:
            mesh = make_shard_mesh(shards)
            row = spec_for_axes(("graph_shard",), (shards,), mesh)
        else:
            mesh = make_shard_mesh(shards, replicas)
            row = spec_for_axes(("graph_replica", "graph_shard"),
                                (replicas, shards), mesh)
        x_spec = P(*row, None, None)
        mask_spec = P(*row, None)

        def _spmd(params, x, ops_, mask, quant):
            # shard_map leaves keep leading block dims of 1 per mesh axis
            sq = lambda l: l.reshape(l.shape[lead:])
            out = _forward(params, sq(x), jax.tree_util.tree_map(sq, ops_),
                           sq(mask), quant)
            return out.reshape((1,) * lead + out.shape)

        plan.fn = jax.jit(shard_map(
            _spmd, mesh=mesh,
            in_specs=(P(), x_spec, P(*row), mask_spec, P()),
            out_specs=x_spec, check_rep=False))
    else:
        fn = jax.vmap(_forward, in_axes=(None, 0, 0, 0, None),
                      axis_name="shard")
        if replicas > 1:
            fn = jax.vmap(fn, in_axes=(None, 0, 0, 0, None))
        plan.fn = jax.jit(fn)
    return plan


def sharded_exchange_widths(cfg: GNNConfig) -> Tuple[int, ...]:
    """Per-layer halo widths `forward_grannite_sharded` exchanges (§12).

    GCN moves the projected hidden rows then the class rows; GAT the
    concatenated per-head layer-1 projections then the single-head class
    rows; SAGE the aggregation INPUTS (raw features, then the layer-1
    activations). One source of truth for the serving engine's collective
    byte accounting and the benchmark's modelled latency — if the exchange
    schedule changes, both move with it.
    """
    if cfg.kind == "gcn":
        return (cfg.hidden, cfg.num_classes)
    if cfg.kind == "gat":
        return (cfg.heads * (cfg.hidden // cfg.heads), cfg.num_classes)
    return (cfg.in_feats, cfg.hidden)


# ---------------------------------------------------------------------------
# Training / evaluation (to reproduce the paper's accuracy table)
# ---------------------------------------------------------------------------

def masked_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                         mask: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[:, None], axis=-1)[:, 0]
    m = mask.astype(logits.dtype)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    pred = jnp.argmax(logits, axis=-1)
    ok = (pred == labels) & mask
    return ok.sum() / jnp.maximum(mask.sum(), 1)


def train_node_classifier(key, cfg: GNNConfig, pg: PaddedGraph,
                          forward: Callable[[Dict, jnp.ndarray], jnp.ndarray],
                          params: Optional[Dict] = None, *, lr: float = 0.01,
                          weight_decay: float = 5e-4, epochs: int = 100) -> Dict:
    """Full-batch Adam training (paper: lr 0.01, wd 5e-4, 100 epochs)."""
    from repro.optim.adamw import adamw_init, adamw_update

    x = jnp.asarray(pg.features)
    y = jnp.asarray(pg.labels)
    tm = jnp.asarray(pg.train_mask)
    params = params if params is not None else init_params(key, cfg)
    opt = adamw_init(params)

    def loss_fn(p):
        return masked_cross_entropy(forward(p, x), y, tm)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, o = adamw_update(p, g, o, lr=lr, weight_decay=weight_decay)
        return p, o, loss

    for _ in range(epochs):
        params, opt, _ = step(params, opt)
    return params


def evaluate(cfg: GNNConfig, params: Dict, pg: PaddedGraph,
             forward: Callable[[Dict, jnp.ndarray], jnp.ndarray]) -> float:
    logits = forward(params, jnp.asarray(pg.features))
    return float(accuracy(logits, jnp.asarray(pg.labels), jnp.asarray(pg.test_mask)))
