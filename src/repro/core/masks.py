"""StaGr / GrAd mask builders.

StaGr bakes masks as compile-time constants (closure captures) for truly
static graphs; GrAd passes the *same* masks as runtime arguments so dynamic
graphs never recompile. Both paths share these builders.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

NEG_INF = -1e9  # GrAx1 additive masking constant (paper: "large negative number")


def attention_bias_multiplicative(adj_with_loops: np.ndarray) -> np.ndarray:
    """Exact GAT masking operand: 1 where edge, 0 elsewhere.

    The exact path computes `where(mask, scores, -inf)` — a Select, the
    control-heavy op the paper measures on the DSP.
    """
    return (adj_with_loops > 0).astype(np.float32)


def attention_bias_additive(adj_with_loops: np.ndarray) -> np.ndarray:
    """GrAx1: additive bias. scores + bias ≈ masked scores, no Select/mul.

    bias = 0 on edges, NEG_INF off edges. After softmax the off-edge weights
    are ~exp(-1e9)=0; quality loss is negligible (paper Fig. 16).
    """
    return np.where(adj_with_loops > 0, 0.0, NEG_INF).astype(np.float32)


def adj_with_self_loops(adj: np.ndarray, num_nodes: int) -> np.ndarray:
    out = adj.copy()
    idx = np.arange(num_nodes)
    out[idx, idx] = 1.0
    return out


def sage_sample_adjacency(adj: np.ndarray, num_nodes: int, *, max_neighbors: int,
                          rng: Optional[np.random.Generator] = None,
                          include_self: bool = True) -> np.ndarray:
    """StaGr for GraphSAGE: precomputed *sampled* adjacency, reused at inference.

    Uniformly samples up to `max_neighbors` in-neighbors per node (paper
    uses 10 on Cora). Returns a 0/1 (cap, cap) mask.

    Vectorized: every edge draws one uniform key and each row keeps its
    `max_neighbors` smallest-keyed neighbors (a per-row random permutation
    prefix == uniform sampling without replacement), so the whole sample is
    one argpartition over the matrix instead of an O(N) Python loop — this
    runs on the serving hot path at every structure miss. Deterministic for
    a seeded rng (default seed 0, matching the historical behavior).
    """
    rng = rng or np.random.default_rng(0)
    cap = adj.shape[0]
    out = np.zeros_like(adj)
    if num_nodes > 0 and max_neighbors > 0:
        live = adj[:num_nodes] > 0
        keys = np.where(live, rng.random((num_nodes, cap)), np.inf)
        kth = min(max_neighbors, cap - 1)
        kept = np.argpartition(keys, kth, axis=1)[:, :max_neighbors]
        rows = np.repeat(np.arange(num_nodes), kept.shape[1])
        cols = kept.reshape(-1)
        picked = live[rows, cols]          # rows with < k neighbors pad w/ inf
        out[rows[picked], cols[picked]] = 1.0
    if include_self:
        idx = np.arange(num_nodes)
        out[idx, idx] = 1.0
    return out


def mean_from_mask(mask: np.ndarray) -> np.ndarray:
    """Row-normalize a 0/1 sampled mask -> mean-aggregation operand."""
    deg = mask.sum(axis=1, keepdims=True)
    return (mask / np.maximum(deg, 1.0)).astype(np.float32)


def max_bias_from_mask(mask: np.ndarray) -> np.ndarray:
    """Additive bias for exact masked-max: 0 on edges, -inf off-edge."""
    return np.where(mask > 0, 0.0, NEG_INF).astype(np.float32)
