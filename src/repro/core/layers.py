"""GCN / GAT / GraphSAGE layers — baseline (gather) and GraNNite paths.

Every layer has two executable forms:

  * baseline  — edge-list gather/scatter/segment ops, with graph
    preprocessing (degree, normalization) ON DEVICE. This mirrors the
    out-of-the-box NPU mapping the paper measures (Fig. 4/5: preprocessing +
    control ops land on the DSP); on TPU these lower to gather/scatter HLOs.
  * grannite — dense masked matmuls on statically padded operands (StaGr /
    PreG / EffOp / GrAx), optionally through the Pallas kernels.

The set of enabled techniques is explicit (`Techniques`) so the benchmark
harness can reproduce the paper's progressive Fig. 20 stacking.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import effop
from .quant import QuantizedLinear, apply_quantized_linear

NEG_INF = effop.NEG_INF


@dataclasses.dataclass(frozen=True)
class Techniques:
    """Which GraNNite techniques are active (paper Fig. 7 suite)."""
    stagr: bool = False        # dense precomputed-mask aggregation
    grad_dynamic: bool = False  # masks as runtime inputs (vs baked constants)
    graphsplit: bool = False   # host-side preprocessing (PreG on CPU)
    grasp: bool = False        # block-sparse bitmap aggregation kernel
    quantgr: bool = False      # INT8 combine matmuls
    effop: bool = False        # dense masked attention / max instead of gather
    grax1: bool = False        # additive attention mask
    grax2: bool = False        # fused broadcast-add ordering
    grax3: bool = False        # SAGE-max as mask-mul + maxpool
    use_pallas: bool = False   # route matmuls through Pallas kernels

    @staticmethod
    def baseline() -> "Techniques":
        return Techniques()

    @staticmethod
    def full_gcn() -> "Techniques":
        return Techniques(stagr=True, grad_dynamic=True, graphsplit=True,
                          grasp=True, quantgr=True)

    @staticmethod
    def full_gat() -> "Techniques":
        return Techniques(stagr=True, graphsplit=True, effop=True,
                          grax1=True, grax2=True)

    @staticmethod
    def full_sage() -> "Techniques":
        return Techniques(stagr=True, graphsplit=True, effop=True, grax3=True)


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


# =========================================================================
# GCN
# =========================================================================

def gcn_init(key, in_feats: int, out_feats: int) -> Dict:
    kw, = jax.random.split(key, 1)
    return {"w": glorot(kw, (in_feats, out_feats)), "b": jnp.zeros((out_feats,))}


def gcn_baseline(params: Dict, x: jnp.ndarray, edge_index: jnp.ndarray,
                 num_nodes: int) -> jnp.ndarray:
    """Edge-list GCN with ON-DEVICE preprocessing (the paper's slow path).

    degree -> rsqrt -> per-edge gather of norms -> scatter-add: four
    control-heavy stages that land on the DSP on the NPU and on serialized
    gather/scatter HLOs on TPU.
    """
    src, dst = edge_index[0], edge_index[1]
    h = x @ params["w"]
    ones = jnp.ones(src.shape[0], dtype=h.dtype)
    deg = jax.ops.segment_sum(ones, dst, num_segments=num_nodes)
    dis = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-12)), 0.0)
    coef = dis[dst] * dis[src]                     # gather (DSP analogue)
    msgs = h[src] * coef[:, None]                  # gather + mul
    agg = jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)  # scatter
    return agg + params["b"]


def gcn_grannite(params: Dict, x: jnp.ndarray, norm_adj: jnp.ndarray,
                 t: Techniques, *, quant: Optional[QuantizedLinear] = None,
                 quant_agg=None, agg_h_scale=None, tier_aq=None,
                 tier_a_scale=None, block_sparse=None) -> jnp.ndarray:
    """StaGr/PreG path: out = Â @ (X W) + b — two dense matmuls.

    Â arrives precomputed (PreG on host when t.graphsplit) and either baked
    (StaGr, static) or as a runtime arg (GrAd) — identical math here; the
    trace/caching difference is exercised by the caller. QuantGr covers the
    WHOLE datapath (combine + aggregation) as on the paper's NPU. The
    aggregation has three QuantGr forms, all bit-identical for the same Â:
    `quant_agg` (offline QuantizedAgg, one baked graph — the paper-table
    path); `agg_h_scale` + `tier_aq`/`tier_a_scale` (serving tiers: int8 Â
    derived ONCE per structure version and passed as a runtime arg, so the
    plan reads 1-byte Â rows instead of 4-byte — DESIGN.md §8); or
    `agg_h_scale` alone (in-trace derivation, `quantize_agg_dynamic`, for
    one-shot/eager calls where caching would never amortize).
    """
    if t.quantgr and quant is not None:
        h = apply_quantized_linear(x, quant, use_kernel=t.use_pallas)
    elif t.use_pallas:
        from repro.kernels import ops as kops
        h = kops.matmul(x, params["w"])
    else:
        h = x @ params["w"]

    if t.quantgr and quant_agg is not None:
        from .quant import apply_quantized_agg
        agg = apply_quantized_agg(quant_agg, h, use_kernel=t.use_pallas)
    elif t.quantgr and agg_h_scale is not None:
        from .quant import (QuantizedAgg, apply_quantized_agg,
                            quantize_agg_dynamic)
        if tier_aq is not None:
            qa = QuantizedAgg(aq=tier_aq, a_scale=tier_a_scale,
                              h_scale=agg_h_scale)
        else:
            qa = quantize_agg_dynamic(norm_adj, agg_h_scale)
        agg = apply_quantized_agg(qa, h, use_kernel=t.use_pallas)
    elif t.grasp and block_sparse is not None:
        from repro.kernels import ops as kops
        agg = kops.bitmap_spmm(block_sparse, h)
    elif t.use_pallas:
        from repro.kernels import ops as kops
        agg = kops.matmul(norm_adj, h)
    else:
        agg = norm_adj @ h
    return agg + params["b"]


# =========================================================================
# GAT (single layer, H heads)
# =========================================================================

def gat_init(key, in_feats: int, out_feats: int, heads: int) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": glorot(k1, (in_feats, heads * out_feats)),
        "a_src": glorot(k2, (heads, out_feats)),
        "a_dst": glorot(k3, (heads, out_feats)),
        "b": jnp.zeros((heads * out_feats,)),
    }


def _gat_head_feats(params, x, heads, out_feats):
    h = x @ params["w"]
    return h.reshape(x.shape[0], heads, out_feats)


def gat_baseline(params: Dict, x: jnp.ndarray, edge_index: jnp.ndarray,
                 num_nodes: int, *, heads: int, out_feats: int,
                 concat: bool = True) -> jnp.ndarray:
    """Edge-list GAT: per-edge gathers, segment softmax, scatter-add.

    This is the Fig. 5 profile: Select/Greater/Softmax/Elu on the DSP.
    """
    src, dst = edge_index[0], edge_index[1]
    h = _gat_head_feats(params, x, heads, out_feats)          # (N, H, F)
    alpha_src = jnp.einsum("nhf,hf->nh", h, params["a_src"])  # (N, H)
    alpha_dst = jnp.einsum("nhf,hf->nh", h, params["a_dst"])
    e = alpha_dst[dst] + alpha_src[src]                       # gathers
    e = jax.nn.leaky_relu(e, negative_slope=0.2)
    # segment softmax over incoming edges of each dst (control-heavy)
    e_max = jax.ops.segment_max(e, dst, num_segments=num_nodes)
    e = jnp.exp(e - e_max[dst])
    e_sum = jax.ops.segment_sum(e, dst, num_segments=num_nodes)
    attn = e / jnp.maximum(e_sum[dst], 1e-12)
    msgs = h[src] * attn[:, :, None]
    out = jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)  # (N, H, F)
    out = out.reshape(num_nodes, heads * out_feats) if concat else out.mean(axis=1)
    return out + (params["b"] if concat else 0.0)


def gat_grannite(params: Dict, x: jnp.ndarray, mask_mult: jnp.ndarray,
                 bias_add: jnp.ndarray, t: Techniques, *, heads: int,
                 out_feats: int, concat: bool = True,
                 quant: Optional[QuantizedLinear] = None) -> jnp.ndarray:
    """EffOp dense GAT: scores as broadcast-add, dense masked softmax,
    aggregation as matmul. GrAx1 picks additive masking, GrAx2 the fused
    broadcast ordering; the Pallas `gat_attention` kernel fuses the whole
    score->softmax->aggregate pipeline per head.

    QuantGr on GAT quantizes the combine matmul X @ W (the FLOPs-dominant
    term at Cora's F=1433); the per-head score einsums and the softmax stay
    fp32 — attention weights are exactly the small-magnitude tensors the
    paper keeps in float.
    """
    n = x.shape[0]
    if t.quantgr and quant is not None:
        h = apply_quantized_linear(x, quant, use_kernel=t.use_pallas)
        h = h.reshape(n, heads, out_feats)
    else:
        h = _gat_head_feats(params, x, heads, out_feats)      # (N, H, F)
    alpha_src = jnp.einsum("nhf,hf->nh", h, params["a_src"])  # (N, H)
    alpha_dst = jnp.einsum("nhf,hf->nh", h, params["a_dst"])

    if t.use_pallas:
        from repro.kernels import ops as kops
        out = kops.gat_attention(h, alpha_dst, alpha_src, bias_add)
    else:
        outs = []
        for hd in range(heads):  # heads unrolled; N x N per head
            e = effop.broadcast_add_scores(alpha_src[:, hd], alpha_dst[:, hd],
                                           grax2=t.grax2)
            e = jax.nn.leaky_relu(e, negative_slope=0.2)
            if t.grax1:
                attn = effop.segment_softmax_dense(e, bias_add)
            else:
                e = effop.masked_select_exact(e, mask_mult)
                attn = jax.nn.softmax(e, axis=-1)
            outs.append(attn @ h[:, hd, :])
        out = jnp.stack(outs, axis=1)                          # (N, H, F)
    out = out.reshape(n, heads * out_feats) if concat else out.mean(axis=1)
    return out + (params["b"] if concat else 0.0)


# =========================================================================
# GraphSAGE (mean / max aggregators)
# =========================================================================

def sage_init(key, in_feats: int, out_feats: int, *, aggregator: str) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_self": glorot(k1, (in_feats, out_feats)),
        "w_neigh": glorot(k2, (in_feats, out_feats)),
        "b": jnp.zeros((out_feats,)),
    }
    if aggregator == "max":
        p["w_pool"] = glorot(k3, (in_feats, in_feats))
        p["b_pool"] = jnp.zeros((in_feats,))
    return p


def sage_baseline(params: Dict, x: jnp.ndarray, edge_index: jnp.ndarray,
                  num_nodes: int, *, aggregator: str) -> jnp.ndarray:
    """Edge-list SAGE. max: sequential per-neighborhood segment_max (DSP)."""
    src, dst = edge_index[0], edge_index[1]
    if aggregator == "mean":
        msgs = x[src]
        agg = jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)
        cnt = jax.ops.segment_sum(jnp.ones_like(src, dtype=x.dtype), dst,
                                  num_segments=num_nodes)
        agg = agg / jnp.maximum(cnt, 1.0)[:, None]
    elif aggregator == "max":
        pooled = jax.nn.relu(x @ params["w_pool"] + params["b_pool"])
        agg = jax.ops.segment_max(pooled[src], dst, num_segments=num_nodes)
        agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
    else:
        raise ValueError(aggregator)
    return x @ params["w_self"] + agg @ params["w_neigh"] + params["b"]


def sage_grannite(params: Dict, x: jnp.ndarray, sample_mask: jnp.ndarray,
                  mean_mask: jnp.ndarray, t: Techniques, *,
                  aggregator: str,
                  quant: Optional[Dict] = None) -> jnp.ndarray:
    """StaGr sampled-adjacency SAGE. mean: mask matmul; max: GrAx3.

    QuantGr quantizes the three combine matmuls (`self` / `neigh` / `pool`
    keys of `quant`, each a QuantizedLinear); the mean-mask aggregation stays
    fp32 — its rows are already 1/deg-scaled and contribute negligible FLOPs
    next to the F-wide combines.
    """
    q = quant if (t.quantgr and quant is not None) else {}

    def _lin(v, w, ql):
        if ql is not None:
            return apply_quantized_linear(v, ql, use_kernel=t.use_pallas)
        return v @ w

    if aggregator == "mean":
        if t.use_pallas:
            from repro.kernels import ops as kops
            agg = kops.matmul(mean_mask, x)
        else:
            agg = mean_mask @ x
    elif aggregator == "max":
        pooled = jax.nn.relu(_lin(x, params["w_pool"], q.get("pool"))
                             + params["b_pool"])
        if t.use_pallas and t.grax3:
            from repro.kernels import ops as kops
            agg = kops.sage_max(sample_mask, pooled)
        else:
            agg = effop.masked_max_aggregate(pooled, sample_mask, grax3=t.grax3)
    else:
        raise ValueError(aggregator)
    return (_lin(x, params["w_self"], q.get("self"))
            + _lin(agg, params["w_neigh"], q.get("neigh")) + params["b"])


# =========================================================================
# Fused per-layer dispatch (fusion="layer" plans — DESIGN.md §11)
# =========================================================================
#
# One call per layer into `kernels.ops.fused_*_layer`: aggregate + combine +
# bias + activation execute as a single kernel pass (EffOp resolves the tier
# scale selection / backend flag / mask application into the kernel epilogue
# at trace time). Branch ladders mirror the unfused functions above so a
# fused plan traces the same operand structure per PlanKey. Two combinations
# fuse PARTIALLY by design: QuantGr GAT (int8 combine outside, attention +
# epilogue fused) and QuantGr SAGE (nothing legally foldable — the unfused
# tier math runs with the activation folded here); see DESIGN.md §11.


def _apply_act(z: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "relu":
        return jax.nn.relu(z)
    if activation == "elu":
        return jax.nn.elu(z)
    if activation == "none":
        return z
    raise ValueError(f"unknown activation {activation!r}")


def gcn_grannite_fused(params: Dict, x: jnp.ndarray, norm_adj: jnp.ndarray,
                       t: Techniques, *, activation: str = "none",
                       quant: Optional[QuantizedLinear] = None,
                       quant_agg=None, agg_h_scale=None, tier_aq=None,
                       tier_a_scale=None, block_sparse=None) -> jnp.ndarray:
    """Fused twin of `gcn_grannite` (+ bias + activation in the kernel).

    Same QuantGr aggregation forms, same precedence; the GraSp form takes
    the block-skip fused kernel, everything else the dense one.
    """
    from repro.kernels import ops as kops
    if t.quantgr and quant is not None:
        if quant_agg is not None:
            qa = quant_agg
        elif agg_h_scale is not None:
            if tier_aq is not None:
                from .quant import QuantizedAgg
                qa = QuantizedAgg(aq=tier_aq, a_scale=tier_a_scale,
                                  h_scale=agg_h_scale)
            else:
                from .quant import quantize_agg_dynamic
                qa = quantize_agg_dynamic(norm_adj, agg_h_scale)
        else:
            # No aggregation scales: nothing past the combine can fuse —
            # degenerate to the unfused tier math with the act folded here.
            return _apply_act(gcn_grannite(params, x, norm_adj, t,
                                           quant=quant), activation)
        qt = (quant.wq, quant.w_scale, quant.x_scale, qa.h_scale, qa.aq,
              qa.a_scale)
        return kops.fused_gcn_layer(x, params["w"], params["b"], quant=qt,
                                    activation=activation)
    if t.grasp and block_sparse is not None:
        return kops.fused_gcn_layer(x, params["w"], params["b"],
                                    block_sparse=block_sparse,
                                    activation=activation)
    return kops.fused_gcn_layer(x, params["w"], params["b"],
                                norm_adj=norm_adj, activation=activation)


def gat_grannite_fused(params: Dict, x: jnp.ndarray, bias_add: jnp.ndarray,
                       t: Techniques, *, heads: int, out_feats: int,
                       activation: str = "none",
                       quant: Optional[QuantizedLinear] = None) -> jnp.ndarray:
    """Fused twin of `gat_grannite` (concat form): the whole layer for fp32
    tiers; QuantGr keeps the int8 combine outside and fuses attention +
    bias + activation (the precombined kernel)."""
    from repro.kernels import ops as kops
    n = x.shape[0]
    b = params["b"].reshape(heads, out_feats)
    if t.quantgr and quant is not None:
        h = apply_quantized_linear(x, quant, use_kernel=t.use_pallas)
        h = h.reshape(n, heads, out_feats)
        alpha_src = jnp.einsum("nhf,hf->nh", h, params["a_src"])
        alpha_dst = jnp.einsum("nhf,hf->nh", h, params["a_dst"])
        out = kops.fused_gat_layer(None, None, params["a_src"],
                                   params["a_dst"], bias_add, b,
                                   activation=activation,
                                   precombined=(h, alpha_dst, alpha_src))
    else:
        w3 = params["w"].reshape(x.shape[1], heads, out_feats)
        out = kops.fused_gat_layer(x, w3, params["a_src"], params["a_dst"],
                                   bias_add, b, activation=activation)
    return out.reshape(n, heads * out_feats)


def sage_grannite_fused(params: Dict, x: jnp.ndarray,
                        sample_mask: jnp.ndarray, mean_mask: jnp.ndarray,
                        t: Techniques, *, aggregator: str,
                        activation: str = "none",
                        quant: Optional[Dict] = None) -> jnp.ndarray:
    """Fused twin of `sage_grannite`: mean (M @ X) or GrAx3 masked-max plus
    both combines and the epilogue in one pass. QuantGr SAGE cannot fuse
    (the neighbor combine consumes the aggregation output and all three
    combines are int8): the unfused tier math runs with the act folded."""
    from repro.kernels import ops as kops
    if t.quantgr and quant is not None:
        return _apply_act(sage_grannite(params, x, sample_mask, mean_mask, t,
                                        aggregator=aggregator, quant=quant),
                          activation)
    if aggregator == "mean":
        return kops.fused_sage_layer(x, params["w_self"], params["w_neigh"],
                                     params["b"], mean_mask=mean_mask,
                                     activation=activation)
    pooled = jax.nn.relu(x @ params["w_pool"] + params["b_pool"])
    return kops.fused_sage_layer(x, params["w_self"], params["w_neigh"],
                                 params["b"], sample_mask=sample_mask,
                                 pooled=pooled, activation=activation)
