"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init; smoke
tests see the default 1 device).

Topology (TPU v5e-class):
  single-pod:  (16, 16)    = ("data", "model")   — 256 chips
  multi-pod:   (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
               axis is an outer data-parallel axis whose collectives cross
               the (slower, DCN-class) inter-pod links. Keeping "model"
               innermost aligns tensor-parallel collectives with the
               fastest ICI dimension.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            f"sets this before importing jax)")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:need])
    except TypeError:  # older jax without the devices kwarg
        return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    devs = jax.devices()
    d = data if data is not None else max(1, len(devs) // model)
    need = d * model
    return Mesh(np.asarray(devs[:need]).reshape(d, model), ("data", "model"))


def make_shard_mesh(shards: int, replicas: int = 1):
    """Mesh for sharded GNN serving (DESIGN.md §12, §15).

    `replicas=1` (the default) builds the 1-D ("shard",) mesh — one device
    per graph shard. `replicas=R > 1` builds the R x S replica-group mesh
    ("replica", "shard"): R concurrent batches of the SAME shard layout,
    each replica row owning its own S-device column set, so halo psums
    (over "shard") stay within a replica. Raises when the host exposes too
    few devices (the sharded plan then falls back to a vmap-simulated
    axis, which computes the identical collective math on one device —
    CI's multi-device leg runs the real SPMD placement under
    XLA_FLAGS=--xla_force_host_platform_device_count=8).
    """
    devs = jax.devices()
    need = shards * replicas
    if len(devs) < need:
        raise RuntimeError(
            f"shard mesh needs {need} devices, found {len(devs)}")
    if replicas == 1:
        return Mesh(np.asarray(devs[:shards]), ("shard",))
    return Mesh(np.asarray(devs[:need]).reshape(replicas, shards),
                ("replica", "shard"))
