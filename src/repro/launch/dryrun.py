import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (including
# `from repro...`): jax locks the device count at first init. Only the
# dry-run sees 512 placeholder devices; tests/benches keep 1 CPU device.

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS               # noqa: E402
from repro.dist import sharding as shd        # noqa: E402
from repro.launch import roofline as R        # noqa: E402
from repro.launch import specs as S           # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.nn.config import SHAPES            # noqa: E402

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell:
  jax.jit(step, in_shardings, out_shardings).lower(*ShapeDtypeStructs)
      .compile()
must succeed; we then print memory_analysis() (fits-on-chip proof) and
cost_analysis() (FLOPs/bytes for §Roofline) and emit one JSON row.

  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
"""


def _data_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in shd.mesh_batch_axes(mesh)]))


def lower_cell(cfg, shape_name: str, mesh, *,
               microbatches: Optional[int] = None):
    """Build + lower one cell. Returns (lowered, aux_info)."""
    shape = SHAPES[shape_name]
    params = S.abstract_params(cfg, serve=(shape.kind != "train"))
    pshard = shd.param_shardings(params, mesh)

    if shape.kind == "train":
        opt = S.abstract_opt(cfg)
        osh = shd.optimizer_shardings(params, mesh)
        oshard = {"m": osh, "v": osh, "count": shd.scalar_sharding(mesh)}
        batch = S.train_batch_specs(cfg, shape)
        bshard = shd.batch_shardings(batch, mesh)
        mb = (microbatches if microbatches is not None
              else S.train_microbatches(cfg, shape, _data_size(mesh)))
        step_fn = S.make_train_step(cfg, microbatches=mb)
        jitted = jax.jit(step_fn,
                         in_shardings=(pshard, oshard, bshard, None),
                         out_shardings=(pshard, oshard, None))
        lowered = jitted.lower(params, opt, batch,
                               jax.ShapeDtypeStruct((), jnp.int32))
        return lowered, {"microbatches": mb}

    if shape.kind == "prefill":
        batch = S.prefill_specs(cfg, shape)
        bshard = shd.batch_shardings(batch, mesh)
        step_fn = S.make_prefill_step(cfg, shape)
        jitted = jax.jit(step_fn, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params, batch)
        return lowered, {}

    # decode
    specs = S.decode_specs(cfg, shape)
    seq_sharded = shape.global_batch < _data_size(mesh)

    def nshard(tree):
        spec = shd.cache_specs(tree, mesh, seq_sharded=seq_sharded)
        return jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s), spec,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    tok_sh = jax.sharding.NamedSharding(
        mesh, shd.batch_spec(mesh, ndim=1)
        if shape.global_batch % _data_size(mesh) == 0
        else jax.sharding.PartitionSpec())
    step_fn = S.make_serve_step(cfg, shape)
    args = [params, specs["caches"], specs["token"], specs["pos"]]
    in_sh = [pshard, nshard(specs["caches"]), tok_sh,
             shd.scalar_sharding(mesh)]
    if "enc_kv" in specs:
        args.append(specs["enc_kv"])
        in_sh.append(nshard(specs["enc_kv"]))
    # donate the caches: the in-place GrAd cursor update aliases input ->
    # output and HBM holds ONE cache copy (without this, gemma2 decode_32k
    # needs 24 GiB/dev; with it, ~12 GiB)
    jitted = jax.jit(step_fn, in_shardings=tuple(in_sh), donate_argnums=(1,))
    lowered = jitted.lower(*args)
    return lowered, {"seq_sharded_cache": seq_sharded}


def measure_cost_metrics(cfg, shape_name: str, mesh,
                         ) -> Dict[str, Any]:
    """Two-point unrolled measurement -> exact per-device cost metrics.

    See specs.cost_config: M_k = F + k·B per metric; the deployed stack
    costs F + nsb·B. Collective bytes are combined per collective kind.
    """
    points = []
    for k in (1, 2):
        ccfg = S.cost_config(cfg, k)
        lowered, _ = lower_cell(ccfg, shape_name, mesh, microbatches=1)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll = R.collective_bytes(compiled.as_text())
        points.append({"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0)),
                       "coll": coll})
    nsb = cfg.num_superblocks
    out: Dict[str, Any] = {}
    for key in ("flops", "bytes"):
        b = points[1][key] - points[0][key]
        f = points[0][key] - b
        out[key] = max(f + nsb * b, 0.0)
    kinds = set(points[0]["coll"]) | set(points[1]["coll"])
    coll_true = {}
    for kd in kinds:
        m1 = points[0]["coll"].get(kd, 0)
        m2 = points[1]["coll"].get(kd, 0)
        b = m2 - m1
        coll_true[kd] = max((m1 - b) + nsb * b, 0)
    out["coll"] = coll_true
    return out


def apply_variant(cfg, variant: str, mesh):
    """§Perf variants (baseline = paper-faithful, everything off).

      opt        — attn block-skip + bf16 scores + adaptive expert axis
      opt_f32s   — same but fp32 scores (isolates the score-dtype bytes:
                   score_bytes_bf16 = M(opt_f32s) - M(opt), which is also
                   the flash-kernel adjustment — see EXPERIMENTS.md §Perf)
    """
    import dataclasses as dc
    if variant == "baseline":
        shd.set_expert_axis("data")
        return cfg
    shd.set_expert_axis(shd.choose_expert_axis(cfg, mesh))
    if variant == "opt":
        return dc.replace(cfg, attn_block_skip=True, logits_bf16=True)
    if variant == "opt_f32s":
        return dc.replace(cfg, attn_block_skip=True, logits_bf16=False)
    if variant == "opt_flash":
        # memory-term measurement for the Pallas flash-kernel path: the
        # reported t_memory is valid; t_compute/t_collective come from "opt"
        return dc.replace(cfg, attn_block_skip=True, attn_flash_stub=True)
    raise ValueError(variant)


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             verbose: bool = True, with_cost: bool = True,
             variant: str = "baseline") -> Dict[str, Any]:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = S.runnable(cfg, shape)
    row: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "variant": variant}
    if not ok:
        row["status"] = "skipped"
        row["reason"] = why
        return row

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cfg = apply_variant(cfg, variant, mesh)
    t0 = time.time()
    try:
        with mesh, shd.use_distribution(mesh):
            lowered, aux = lower_cell(cfg, shape_name, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            terms = R.extract_terms(
                compiled, arch=arch, shape=shape, mesh_name=mesh_name,
                n_devices=mesh.size, cfg=cfg)
            if with_cost:
                exact = measure_cost_metrics(cfg, shape_name, mesh)
                terms.flops_per_device = exact["flops"]
                terms.bytes_per_device = exact["bytes"]
                terms.coll_breakdown = exact["coll"]
                terms.coll_bytes_per_device = sum(exact["coll"].values())
    except Exception as e:  # a failing cell is a bug in our system
        row["status"] = "FAILED"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc()[-2000:]
        return row

    row.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1), devices=mesh.size, **aux)
    row.update(terms.row())
    if ma is not None:
        row["memory_analysis"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_estimate_gib": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 2**30, 3),
        }
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] "
              f"compile={row['compile_s']}s "
              f"args/dev={row['memory_analysis']['argument_bytes']/2**30:.2f}GiB "
              f"temp/dev={row['memory_analysis']['temp_bytes']/2**30:.2f}GiB "
              f"t=({R.fmt_seconds(row['t_compute_s'])}, "
              f"{R.fmt_seconds(row['t_memory_s'])}, "
              f"{R.fmt_seconds(row['t_collective_s'])}) "
              f"bound={row['bottleneck']} "
              f"roofline={row['roofline_fraction']:.1%}")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-cost", action="store_true",
                    help="deployment compile only (no two-point cost pass)")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt", "opt_f32s", "opt_flash"],
                    help="§Perf variant (baseline = paper-faithful)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        assert args.arch, "--arch (+ optional --shape) or --all"
        shapes = [args.shape] if args.shape else list(SHAPES)
        for s in shapes:
            for m in meshes:
                cells.append((args.arch, s, m))

    rows = []
    for a, s, m in cells:
        # cost pass runs on the single-pod mesh only (§Roofline is single-pod)
        row = run_cell(a, s, m, with_cost=(not args.skip_cost and m == "single"),
                       variant=args.variant)
        rows.append(row)
        if row.get("status") == "FAILED":
            print(f"[{a} × {s} × {m}] FAILED: {row['error']}")
        elif row.get("status") == "skipped":
            print(f"[{a} × {s} × {m}] skipped: {row['reason']}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rows[-1]) + "\n")

    n_ok = sum(r.get("status") == "ok" for r in rows)
    n_skip = sum(r.get("status") == "skipped" for r in rows)
    n_fail = len(rows) - n_ok - n_skip
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED "
          f"of {len(rows)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
