"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch × shape × mesh) cell we derive three per-chip time terms from the
AOT-compiled module (no TPU needed — the brief's methodology):

  compute    = HLO_FLOPs(per device)      / peak_FLOP/s
  memory     = HLO_bytes(per device)      / HBM_bw
  collective = collective_bytes(per dev.) / link_bw

Sources: `compiled.cost_analysis()` (per-device flops & bytes after SPMD
partitioning); collective bytes are NOT in cost_analysis — we parse the
post-partitioning HLO (`compiled.as_text()`) and sum the output-shape bytes
of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction (fusion never hides collectives, so text
parsing is exact at op granularity).

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI. INT8 doubles MXU throughput (QuantGr's 2×
claim maps to the same factor on the MXU datapath).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

from repro.core.costs import HBM_BW, PEAK_BF16  # noqa: F401  (one source)

PEAK_FLOPS_BF16 = PEAK_BF16   # per chip
PEAK_FLOPS_INT8 = 2 * PEAK_BF16
ICI_BW = 50e9                 # bytes/s per link (~per-chip effective)
DCN_BW = 6.25e9               # bytes/s per chip across pods (50 Gb/s class)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\/ ]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind from post-SPMD HLO text.

    `-start/-done` async pairs are counted once (the -done op has the same
    shape tuple; we match only `-start` when present by skipping `-done`).
    """
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: int
    coll_breakdown: Dict[str, int]
    peak_flops: float = PEAK_FLOPS_BF16

    # analytic bookkeeping
    model_flops: float = 0.0            # 6·N·D (train) / 2·N·D (inference)
    n_devices: int = 256
    argument_bytes: Optional[int] = None
    temp_bytes: Optional[int] = None
    output_bytes: Optional[int] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × devices): catches remat/redundancy."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time — the score we hillclimb."""
        t_useful = self.model_flops / (self.n_devices * self.peak_flops)
        return t_useful / self.t_bound if self.t_bound else 0.0

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.flops_per_device * self.n_devices,
            "useful_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "coll_breakdown": self.coll_breakdown,
            "arg_bytes_per_dev": self.argument_bytes,
            "temp_bytes_per_dev": self.temp_bytes,
        }


def model_flops_estimate(cfg, shape) -> float:
    """6·N·D for training, 2·N·D per generated/processed token otherwise
    (MoE: N_active). D = tokens processed by the lowered step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: ONE token per sequence, plus attention reads over the cache
    tokens = shape.global_batch
    attn_read = 0.0
    if not cfg.attention_free:
        n_attn = sum(1 for k in cfg.superblock if k.startswith("attn"))
        n_attn *= cfg.num_superblocks
        # 2 (QK^T) + 2 (PV) flops per cached key element per head dim
        attn_read = (4.0 * shape.global_batch * shape.seq_len
                     * cfg.num_heads * cfg.head_dim_ * n_attn)
    return 2.0 * n * tokens + attn_read


def extract_terms(compiled, *, arch: str, shape, mesh_name: str,
                  n_devices: int, cfg=None) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    ma = None
    try:
        ma = compiled.memory_analysis()
    except Exception:
        pass
    return RooflineTerms(
        arch=arch, shape=shape.name, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=sum(coll.values()), coll_breakdown=coll,
        model_flops=model_flops_estimate(cfg, shape) if cfg else 0.0,
        n_devices=n_devices,
        argument_bytes=getattr(ma, "argument_size_in_bytes", None),
        temp_bytes=getattr(ma, "temp_size_in_bytes", None),
        output_bytes=getattr(ma, "output_size_in_bytes", None),
    )


def fmt_seconds(t: float) -> str:
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t * 1e3:.2f}ms"
    return f"{t * 1e6:.1f}us"


def render_table(rows: List[Dict[str, Any]]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':10s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s} "
           f"{'roofline':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
            f"{fmt_seconds(r['t_compute_s']):>9s} "
            f"{fmt_seconds(r['t_memory_s']):>9s} "
            f"{fmt_seconds(r['t_collective_s']):>9s} "
            f"{r['bottleneck']:>10s} {r['useful_fraction']:>7.2%} "
            f"{r['roofline_fraction']:>8.2%}")
    return "\n".join(lines)
