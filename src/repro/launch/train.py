"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/run1

`--reduced` trains the family-faithful shrink (CPU-runnable); without it the
full config is instantiated (requires real accelerators). On a multi-host
pod this script is launched once per host (jax.distributed); the data
pipeline shards itself by (host_id, num_hosts).
"""
from __future__ import annotations

import argparse
import json

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="layer override for --reduced")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import ARCHS, reduced
    from repro.runtime.trainer import TrainConfig, Trainer

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, layers=args.layers)
    tc = TrainConfig(steps=args.steps, seq_len=args.seq,
                     global_batch=args.batch, microbatches=args.microbatches,
                     lr=args.lr, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, seed=args.seed)
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M devices={len(jax.devices())}")
    trainer = Trainer(cfg, tc)
    trainer.run()
    print(json.dumps(trainer.summary(), indent=2))


if __name__ == "__main__":
    main()
