"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

`input_specs(cfg, shape)` returns weak-type-correct, shardable specs for all
model inputs — no device allocation ever happens for the FULL configs; only
`.lower().compile()` consumes these (the shannon/kernels pattern).

Cell semantics (per the assignment):
  train_4k    — lower `train_step`  (loss + grads + AdamW update)
  prefill_32k — lower `prefill_step` (forward + cache build; enc-dec archs
                run the encoder at the assigned seq_len with a short decoder
                prompt — the frontend stub feeds 32k frames)
  decode_32k  — lower `serve_step`  (ONE new token against a seq_len cache)
  long_500k   — `serve_step` at 524288; only sub-quadratic archs (ssm /
                jamba hybrid) run it, pure full-attention archs are skipped
                (recorded in DESIGN.md §Arch-applicability / EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import lm, transformer as tfm
from repro.nn.config import ArchConfig, ShapeConfig, SHAPES
from repro.optim.adamw import adamw_init


WHISPER_DECODE_PROMPT = 256   # decoder prompt length when the encoder is the
                              # sequence carrier (prefill cells of enc-dec)


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def runnable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is this (arch, shape) cell runnable? (per the assignment rules)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 512k decode needs "
                       "sub-quadratic attention (skip per assignment)")
    return True, ""


# ---------------------------------------------------------------------------
# Abstract trees (params / optimizer / caches) via eval_shape
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _abstract_params_cached(cfg: ArchConfig, dtype_override: Optional[str]):
    tree = jax.eval_shape(lambda: lm.lm_init(jax.random.PRNGKey(0), cfg))
    if dtype_override is None:
        return tree
    dt = jnp.dtype(dtype_override)

    def cast(leaf):
        if isinstance(leaf, jax.ShapeDtypeStruct) and \
                jnp.issubdtype(leaf.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(leaf.shape, dt)
        return leaf
    return jax.tree_util.tree_map(cast, tree)


def abstract_params(cfg: ArchConfig, *, serve: bool = False):
    """Training: fp32 master weights. Serving: bf16 weights (the standard
    inference deployment — fp32 masters are a training artifact; llama4's
    107 B would otherwise overflow 16 GB/chip at decode)."""
    return _abstract_params_cached(cfg, "bfloat16" if serve else None)


def abstract_opt(cfg: ArchConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(adamw_init, params)


def abstract_caches(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: tfm.init_caches(cfg, batch, max_len))


def abstract_enc_kv(cfg: ArchConfig, batch: int, frames: int):
    nsb = cfg.num_superblocks
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    return (sds((nsb, batch, frames, kvh, hd), cfg.dtype),
            sds((nsb, batch, frames, kvh, hd), cfg.dtype))


# ---------------------------------------------------------------------------
# Per-cell input specs
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
        "mask": sds((b, s), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        specs["patches"] = sds((b, cfg.num_patches, cfg.d_model), cfg.dtype)
    if cfg.frontend == "audio_stub":
        specs["frames"] = sds((b, cfg.encoder.frames, cfg.d_model), cfg.dtype)
    return specs


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        # the 32k sequence rides the encoder (stub frames); short dec prompt
        return {"tokens": sds((b, WHISPER_DECODE_PROMPT), jnp.int32),
                "frames": sds((b, s, cfg.d_model), cfg.dtype)}
    specs = {"tokens": sds((b, s), jnp.int32)}
    if cfg.frontend == "vision_stub":
        specs["patches"] = sds((b, cfg.num_patches, cfg.d_model), cfg.dtype)
    return specs


def decode_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "token": sds((b,), jnp.int32),
        "pos": sds((), jnp.int32),
        "caches": abstract_caches(cfg, b, s),
    }
    if cfg.is_encdec:
        specs["enc_kv"] = abstract_enc_kv(cfg, b, cfg.encoder.frames)
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)


# ---------------------------------------------------------------------------
# Step functions to lower (one per cell kind)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, *, microbatches: int = 8,
                    steps: int = 1000):
    """Raw (unjitted) train step — jitted at the call site with explicit
    shardings (dryrun) or plainly (examples)."""
    from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                                   linear_warmup_cosine)

    def loss_fn(params, batch):
        return lm.lm_loss(params, cfg, batch)

    def step_fn(params, opt, batch, step):
        n_micro = microbatches

        def micro(carry, mb):
            gacc, lacc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gacc = jax.tree_util.tree_map(jnp.add, gacc, grads)
            return (gacc, lacc + loss), None

        if n_micro > 1:
            from repro.dist.sharding import constrain_scan_slices

            def reshape(x):
                b = x.shape[0]
                y = x.reshape((n_micro, b // n_micro) + x.shape[1:])
                return constrain_scan_slices(y)   # keep batch dim sharded
            mbs = jax.tree_util.tree_map(reshape, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = linear_warmup_cosine(step, base_lr=3e-4, warmup_steps=100,
                                  total_steps=steps)
        new_params, new_opt = adamw_update(params, grads, opt, lr=lr,
                                           weight_decay=0.1)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return step_fn


def make_prefill_step(cfg: ArchConfig, shape: ShapeConfig):
    max_len = shape.seq_len if not cfg.is_encdec else (
        WHISPER_DECODE_PROMPT + 256)
    if cfg.frontend == "vision_stub":
        max_len += cfg.num_patches      # NodePad: prefix positions included

    def prefill_step(params, batch):
        logits, state = lm.lm_prefill(
            params, cfg, batch["tokens"], max_len=max_len,
            prefix_embeds=batch.get("patches"),
            enc_embeds=batch.get("frames"))
        return logits, state.caches
    return prefill_step


def make_serve_step(cfg: ArchConfig, shape: ShapeConfig):
    """Decode step with caches as a SEPARATE argument so the launcher can
    donate them (jit donate_argnums): the cache update aliases in place and
    per-device HBM holds ONE cache copy, not input+output."""
    def serve_step(params, caches, token, pos, enc_kv=None):
        state = lm.ServeState(caches=caches, pos=pos, enc_kv=enc_kv)
        logits, state = lm.lm_decode_step(params, cfg, token, state)
        return logits, state.caches, state.pos
    return serve_step


# ---------------------------------------------------------------------------
# Dry-run microbatch policy: keep per-device live activations << HBM.
# ---------------------------------------------------------------------------


def train_microbatches(cfg: ArchConfig, shape: ShapeConfig, n_data: int) -> int:
    """Largest power-of-two microbatch count that keeps the per-device
    microbatch >= 1 sequence; 8 is the default derived in DESIGN.md §5
    (27B × 4k × 16/dev: boundary activations 13.9 GB -> 1.7 GB)."""
    per_dev = max(shape.global_batch // n_data, 1)
    return min(8, per_dev)


def cost_config(cfg: ArchConfig, k: int) -> ArchConfig:
    """Cost-exact variant with k superblocks, every loop unrolled.

    XLA's HLO cost analysis counts while-loop bodies ONCE (not × trip
    count), so scanned programs under-report FLOPs/bytes/collectives. The
    dry-run therefore lowers TWO unrolled variants (k=1, 2): per-metric
    M_k = F + k·B  =>  B = M2 − M1, F = 2·M1 − M2, and the true cost of the
    deployed stack is F + num_superblocks·B. Chunk sizes are enlarged only
    where the metric is invariant to them (flash q/kv blocks, loss chunk).
    """
    sb = len(cfg.superblock)
    changes: Dict[str, Any] = dict(
        num_layers=k * sb, unroll_scans=True, loss_chunk=4096)
    if not cfg.attn_block_skip:
        # enlarging flash blocks is metric-invariant ONLY without block-skip
        # (the skipped fraction depends on the block grid)
        changes.update(q_chunk=8192, kv_chunk=8192)
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(cfg.encoder, num_layers=k)
    return dataclasses.replace(cfg, **changes)
