"""Serving launcher: NodePad-bucketed batch inference.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 16 --max-new 8
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--buckets", type=int, nargs="+", default=[32, 64])
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import ARCHS, reduced
    from repro.runtime.server import ServeConfig, Server

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    sc = ServeConfig(buckets=tuple(args.buckets), max_len=args.max_len,
                     batch_slots=args.slots)
    server = Server(cfg, sc, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        n = int(rng.integers(4, args.buckets[-1]))
        server.submit(rng.integers(0, cfg.vocab_size, size=n),
                      max_new_tokens=args.max_new)
    server.run()
    print(json.dumps(server.summary(), indent=2))


if __name__ == "__main__":
    main()
