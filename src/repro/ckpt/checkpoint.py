"""Fault-tolerant checkpointing: atomic, keep-k, async, elastic-reshard.

Large-scale runnability requirements this implements:

  * ATOMIC    — write to `<dir>/tmp.<step>/` then os.rename to `<dir>/step_k`
                (rename is atomic on POSIX); a crash mid-write never corrupts
                the restore target. A `manifest.json` carries step, flat key
                list, and a structure fingerprint.
  * KEEP-K    — completed checkpoints beyond `keep` are deleted oldest-first.
  * ASYNC     — save runs on a background thread (double-buffered: arrays are
                fetched to host synchronously — cheap vs train step — and the
                file I/O overlaps the next steps); `wait()` joins.
  * ELASTIC   — arrays are saved UNSHARDED (host-gathered). Restore takes a
                target sharding tree and device_puts each array under the NEW
                mesh, so a job may resume on a different topology (e.g. a
                256-chip pod after losing one pod of a 2-pod job) — the
                elastic-scaling path the brief requires.
  * SSM/GNN   — pytree-generic: anything of arrays round-trips (params,
                optimizer moments, data-stream step counter, GrAx masks).

SymG hook: symmetric (N, N) fp32 arrays (the GNN norm-adjacency operands)
are stored triangular-packed (~2x smaller on disk), reassembled on restore —
the paper's storage-level SymG realized at the checkpoint layer.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _is_symmetric(a: np.ndarray) -> bool:
    return (a.ndim == 2 and a.shape[0] == a.shape[1] and a.shape[0] >= 256
            and a.dtype == np.float32 and np.allclose(a, a.T, atol=1e-6))


def save_checkpoint(directory: str, step: int, tree: Any, *, keep: int = 3,
                    symg_pack: bool = True) -> str:
    """Atomic synchronous save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten_with_paths(tree)
    arrays: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": step, "keys": [], "symg": [],
                                "time": time.time()}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i}"
        if symg_pack and _is_symmetric(arr):
            iu = np.triu_indices(arr.shape[0])
            arrays[name] = arr[iu]
            manifest["symg"].append([name, int(arr.shape[0])])
        else:
            arrays[name] = arr
        manifest["keys"].append([key, name, list(arr.shape), str(arr.dtype)])

    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)            # atomic publish
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int) -> None:
    done = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in done[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # abandoned tmp dirs from crashed writers
    for d in os.listdir(directory):
        if d.startswith("tmp."):
            try:
                age = time.time() - os.path.getmtime(os.path.join(directory, d))
                if age > 3600:
                    shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
            except OSError:
                pass


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    done = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    return int(done[-1].split("_")[1]) if done else None


def restore_checkpoint(directory: str, tree: Any, *,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[int, Any]:
    """Restore into the structure of `tree` (values replaced).

    `shardings`: optional matching tree of NamedShardings (the NEW mesh) —
    elastic resharding happens here via device_put.
    """
    s = step if step is not None else latest_step(directory)
    if s is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{s:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    symg = {name: n for name, n in manifest.get("symg", [])}

    by_key: Dict[str, np.ndarray] = {}
    for key, name, shape, dtype in manifest["keys"]:
        arr = data[name]
        if name in symg:
            n = symg[name]
            full = np.zeros((n, n), dtype=arr.dtype)
            iu = np.triu_indices(n)
            full[iu] = arr
            arr = full + np.triu(full, k=1).T
        by_key[key] = arr.reshape(shape).astype(dtype)

    flat = _flatten_with_paths(tree)
    flat_sh = (None if shardings is None
               else [l for _, l in _flatten_with_paths(shardings)])
    leaves = []
    for i, (key, leaf) in enumerate(flat):
        if key not in by_key:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = by_key[key]
        if flat_sh is not None:
            arr = jax.device_put(arr, flat_sh[i])
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree)
    return s, jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Async keep-k manager used by the trainer."""

    def __init__(self, directory: str, *, keep: int = 3, every: int = 50):
        self.directory = directory
        self.keep = keep
        self.every = every
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    def maybe_save(self, step: int, tree: Any, *, force: bool = False) -> bool:
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self.saved_steps.append(step)
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree: Any, *, shardings: Any = None
                       ) -> Tuple[Optional[int], Any]:
        self.wait()
        if latest_step(self.directory) is None:
            return None, tree
        return restore_checkpoint(self.directory, tree, shardings=shardings)
