from .checkpoint import (CheckpointManager, restore_checkpoint,  # noqa: F401
                         save_checkpoint)
