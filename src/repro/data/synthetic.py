"""Deterministic synthetic token pipeline for the LM architectures.

Design goals (large-scale runnability):
  * deterministic per (seed, step, host): restart-safe — resuming from a
    checkpoint at step k regenerates exactly the batches >= k;
  * host-sharded: each host materializes only its slice of the global batch
    (global_batch // num_hosts), the standard multi-pod input pipeline shape;
  * zero I/O: a counter-based hash (threefry via jax, or numpy Philox) makes
    tokens on the fly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0, \
            "global batch must divide across hosts"
        return self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Counter-based generation: Philox keyed on (seed, step, host)."""
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, step, self.host_id]))
        tokens = rng.integers(0, self.vocab_size,
                              size=(self.host_batch, self.seq_len + 1),
                              dtype=np.int32)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            # full-length segments; runtime may mask paddings for ragged data
            "mask": np.ones((self.host_batch, self.seq_len), dtype=np.int32),
        }


def lm_batch_iterator(stream: TokenStream, *, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield stream.batch_at(step)
        step += 1
