from .graphs import cora_like, citeseer_like, dynamic_graph_stream, planetoid_like
from .synthetic import TokenStream, lm_batch_iterator

__all__ = ["cora_like", "citeseer_like", "planetoid_like",
           "dynamic_graph_stream", "TokenStream", "lm_batch_iterator"]
