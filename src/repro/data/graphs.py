"""Synthetic Planetoid-shaped graph datasets (offline container: no downloads).

Generates graphs with the exact shape statistics of the paper's datasets
(Cora: 2708 nodes / 5429 edges / 1433 feats / 7 classes; Citeseer: 3327 /
4732 / 3703 / 6) and *learnable* class structure: a stochastic block model
whose communities correlate with both labels and sparse class-conditioned
features. 2-layer GNNs reach high accuracy on it, so QuantGr / GrAx quality
deltas are meaningful, which is what the paper's accuracy tables need.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core.graph import Graph


def planetoid_like(*, num_nodes: int, num_edges: int, num_feats: int,
                   num_classes: int, seed: int = 0, homophily: float = 0.9,
                   feat_sparsity: float = 0.98, train_per_class: int = 20,
                   test_frac: float = 0.35) -> Graph:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, size=num_nodes).astype(np.int32)

    # --- edges: homophilous SBM, drawn without replacement ------------------
    src = rng.integers(0, num_nodes, size=num_edges * 3)
    same = rng.random(num_edges * 3) < homophily
    dst = np.where(
        same,
        _random_same_class(rng, labels, src, num_classes),
        rng.integers(0, num_nodes, size=src.shape[0]),
    )
    keep = src != dst
    edges = np.unique(np.stack([src[keep], dst[keep]]), axis=1)[:, :num_edges]
    # symmetrize (undirected, as Planetoid) and dedupe the directed set —
    # duplicate edges would double-count in segment-sum baselines
    edge_index = np.unique(np.concatenate([edges, edges[::-1]], axis=1),
                           axis=1).astype(np.int32)

    # --- features: sparse bag-of-words with class-specific vocabulary -------
    feats = np.zeros((num_nodes, num_feats), dtype=np.float32)
    words_per_class = num_feats // num_classes
    nnz_per_node = max(int(num_feats * (1.0 - feat_sparsity)), 4)
    for i in range(num_nodes):
        c = labels[i]
        lo = c * words_per_class
        own = rng.integers(lo, lo + words_per_class, size=nnz_per_node * 3 // 4)
        noise = rng.integers(0, num_feats, size=nnz_per_node // 4)
        feats[i, np.concatenate([own, noise])] = 1.0
    # row-normalize (standard Planetoid preprocessing)
    feats /= np.maximum(feats.sum(axis=1, keepdims=True), 1.0)

    # --- Planetoid-style split ----------------------------------------------
    train_mask = np.zeros(num_nodes, dtype=bool)
    for c in range(num_classes):
        idx = np.nonzero(labels == c)[0]
        train_mask[rng.choice(idx, size=min(train_per_class, len(idx)),
                              replace=False)] = True
    rest = np.nonzero(~train_mask)[0]
    test_idx = rng.choice(rest, size=int(num_nodes * test_frac), replace=False)
    test_mask = np.zeros(num_nodes, dtype=bool)
    test_mask[test_idx] = True

    return Graph(edge_index=edge_index, num_nodes=num_nodes, features=feats,
                 labels=labels, train_mask=train_mask, test_mask=test_mask)


def _random_same_class(rng, labels, src, num_classes):
    """For each src node pick a random node of the same class."""
    order = np.argsort(labels, kind="stable")
    sorted_labels = labels[order]
    starts = np.searchsorted(sorted_labels, np.arange(num_classes))
    ends = np.searchsorted(sorted_labels, np.arange(num_classes), side="right")
    c = labels[src]
    span = np.maximum(ends[c] - starts[c], 1)
    pick = starts[c] + (rng.integers(0, 1 << 30, size=src.shape[0]) % span)
    return order[pick].astype(src.dtype)


def clustered_like(*, num_nodes: int, num_feats: int, num_classes: int,
                   within_density: float = 0.05, cluster: int = 128,
                   cross_frac: float = 0.0, seed: int = 0,
                   train_per_class: int = 2,
                   test_frac: float = 0.35) -> Graph:
    """Community-clustered graph whose adjacency is block-structured at the
    MXU tile: nodes [k·cluster, (k+1)·cluster) form one community and edges
    stay inside it (plus a `cross_frac` fraction drawn uniformly across the
    whole graph), so after NodePad the Â block bitmap is (near-)
    block-diagonal — the workload GraSp's block-skip targets (DESIGN.md
    §10). `within_density` is the directed edge probability inside a
    community; labels follow communities, features are class-conditioned
    bag-of-words like `planetoid_like`, so the graphs are learnable enough
    for calibration/quality audits.
    """
    rng = np.random.default_rng(seed)
    comm = (np.arange(num_nodes) // cluster).astype(np.int64)
    labels = (comm % num_classes).astype(np.int32)
    srcs, dsts = [], []
    for k in range(int(comm.max()) + 1):
        lo, hi = k * cluster, min(num_nodes, (k + 1) * cluster)
        sz = hi - lo
        ne = int(within_density * sz * sz)
        if ne == 0:
            continue
        s = rng.integers(lo, hi, size=ne)
        d = rng.integers(lo, hi, size=ne)
        keep = s != d
        srcs.append(s[keep])
        dsts.append(d[keep])
    n_cross = int(cross_frac * sum(s.size for s in srcs)) if srcs else 0
    if n_cross:
        s = rng.integers(0, num_nodes, size=n_cross)
        d = rng.integers(0, num_nodes, size=n_cross)
        keep = s != d
        srcs.append(s[keep])
        dsts.append(d[keep])
    if srcs:
        edges = np.stack([np.concatenate(srcs), np.concatenate(dsts)])
        # symmetrize + dedupe (undirected, SymG/CacheG-compatible)
        edge_index = np.unique(np.concatenate([edges, edges[::-1]], axis=1),
                               axis=1).astype(np.int32)
    else:
        edge_index = np.zeros((2, 0), np.int32)

    feats = np.zeros((num_nodes, num_feats), dtype=np.float32)
    words_per_class = max(num_feats // num_classes, 1)
    nnz = max(num_feats // 16, 4)
    for i in range(num_nodes):
        lo = labels[i] * words_per_class
        own = rng.integers(lo, min(lo + words_per_class, num_feats),
                           size=nnz * 3 // 4)
        noise = rng.integers(0, num_feats, size=nnz // 4)
        feats[i, np.concatenate([own, noise])] = 1.0
    feats /= np.maximum(feats.sum(axis=1, keepdims=True), 1.0)

    train_mask = np.zeros(num_nodes, dtype=bool)
    for c in range(num_classes):
        idx = np.nonzero(labels == c)[0]
        if idx.size:
            train_mask[rng.choice(idx, size=min(train_per_class, idx.size),
                                  replace=False)] = True
    rest = np.nonzero(~train_mask)[0]
    test_mask = np.zeros(num_nodes, dtype=bool)
    if rest.size:
        test_mask[rng.choice(rest, size=int(num_nodes * test_frac),
                             replace=False)] = True
    return Graph(edge_index=edge_index, num_nodes=num_nodes, features=feats,
                 labels=labels, train_mask=train_mask, test_mask=test_mask)


def cora_like(seed: int = 0) -> Graph:
    return planetoid_like(num_nodes=2708, num_edges=5429, num_feats=1433,
                          num_classes=7, seed=seed)


def citeseer_like(seed: int = 0) -> Graph:
    return planetoid_like(num_nodes=3327, num_edges=4732, num_feats=3703,
                          num_classes=6, seed=seed)


def dynamic_graph_stream(base: Graph, *, steps: int, edges_per_step: int = 16,
                         nodes_per_step: int = 2, seed: int = 0,
                         feat_dim: int | None = None) -> Iterator[Tuple[np.ndarray, int, np.ndarray]]:
    """GrAd/NodePad workload: an evolving graph (paper Fig. 10 knowledge graph).

    Yields (edge_index, num_nodes, features) snapshots with nodes/edges added
    over time. The serving runtime consumes this without recompiling as long
    as num_nodes stays within the NodePad bucket.
    """
    rng = np.random.default_rng(seed)
    edge_index = base.edge_index.copy()
    feats = base.features.copy()
    n = base.num_nodes
    f = feat_dim or feats.shape[1]
    for _ in range(steps):
        new_feats = rng.random((nodes_per_step, f)).astype(np.float32) * 0.1
        feats = np.concatenate([feats, new_feats], axis=0)
        lo = n
        n += nodes_per_step
        src = rng.integers(0, n, size=edges_per_step)
        dst = np.concatenate([
            rng.integers(lo, n, size=edges_per_step // 2),
            rng.integers(0, n, size=edges_per_step - edges_per_step // 2)])
        edge_index = np.concatenate(
            [edge_index, np.stack([src, dst]).astype(np.int32)], axis=1)
        yield edge_index, n, feats
