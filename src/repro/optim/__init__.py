from .adamw import (adamw_init, adamw_update, clip_by_global_norm,
                    cosine_schedule, linear_warmup_cosine)

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "linear_warmup_cosine"]
