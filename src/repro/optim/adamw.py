"""AdamW + schedules + global-norm clipping, pure JAX pytree implementation.

No optax in this environment, so the optimizer is built from scratch. State
is a pytree-of-pytrees (m, v, count) matching the parameter structure — it
shards with the parameters under pjit (same PartitionSpecs), which is what
the multi-pod launcher relies on.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Dict[str, Any]


def adamw_init(params: Params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def adamw_update(params: Params, grads: Params, state: OptState, *,
                 lr: float | jnp.ndarray = 1e-3, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0) -> Tuple[Params, OptState]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps)
        new_p = p - lr * (step + weight_decay * p)
        return new_p, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def cosine_schedule(step: jnp.ndarray, *, base_lr: float, total_steps: int,
                    min_frac: float = 0.1) -> jnp.ndarray:
    t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return base_lr * (min_frac + (1.0 - min_frac) * cos)


def linear_warmup_cosine(step: jnp.ndarray, *, base_lr: float, warmup_steps: int,
                         total_steps: int, min_frac: float = 0.1) -> jnp.ndarray:
    warm = base_lr * (step.astype(jnp.float32) + 1.0) / max(warmup_steps, 1)
    decay = cosine_schedule(step - warmup_steps, base_lr=base_lr,
                            total_steps=max(total_steps - warmup_steps, 1),
                            min_frac=min_frac)
    return jnp.where(step < warmup_steps, warm, decay)
