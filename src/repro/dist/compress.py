"""Compressed gradient collectives (QuantGr applied to the all-reduce).

The paper's QuantGr discipline — symmetric int8 with a static scale — maps
onto distributed training as compressed all-reduce: each replica quantizes
its gradient shard to int8 against a *globally agreed* scale (one pmax), the
collective moves 4x fewer bytes, and dequantization happens after the sum.
Error feedback returns the local quantization residual so the optimizer can
fold it into the next step (the standard 1-bit-Adam-style correction).
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

INT8_MAX = 127.0

AxisNames = Union[str, Tuple[str, ...]]


def ring_psum_nbytes(shards: int, elems: float, *,
                     bytes_per_elt: float) -> float:
    """Bytes ONE participant moves in a ring all-reduce over `elems`
    elements: ~2(S-1)/S of the buffer (reduce-scatter + all-gather). The
    single owner of that factor — both the serving engine's collective-byte
    metrics (`GraphServe._halo_bytes`) and the sharded latency model
    (`core.partition.modelled_sharded_latency`) price the wire through
    here, so the accounting cannot drift from the model. A 1-shard ring
    moves nothing — there is nobody to exchange with."""
    if shards <= 1:
        return 0.0
    return 2.0 * (shards - 1) / shards * elems * bytes_per_elt


def exact_psum_mean(g: jnp.ndarray, axis_names: AxisNames) -> jnp.ndarray:
    n = jax.lax.psum(jnp.ones((), g.dtype), axis_names)
    return jax.lax.psum(g, axis_names) / n


def compressed_psum_mean(g: jnp.ndarray, axis_names: AxisNames
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8-compressed mean-all-reduce with error feedback.

    Returns (mean, residual): |mean - exact_mean| <= scale/2 elementwise,
    where scale = global_absmax / 127, and residual = g - represented(g) so
    the caller can add it to the next step's gradient (error feedback).
    """
    total, residual = compressed_psum(g, axis_names)
    n = jax.lax.psum(jnp.ones((), g.dtype), axis_names)
    return total / n, residual


def compressed_psum(g: jnp.ndarray, axis_names: AxisNames
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8-compressed sum-all-reduce (QuantGr on the wire).

    Returns (sum, residual). Each participant quantizes against one global
    scale (scale = global_absmax / 127, agreed via a pmax), so every
    contribution is off by at most scale/2 per element and the summed error
    is bounded by participants * scale/2. The sharded GNN halo exchange
    (DESIGN.md §12) relies on a tighter corollary: when the participants'
    buffers are DISJOINT zero-padded blocks, zeros quantize exactly, each
    output element receives exactly one non-zero contribution, and the
    elementwise error stays <= scale/2 regardless of the shard count.
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_names)
    scale = jnp.maximum(amax, 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(g / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    represented = q.astype(g.dtype) * scale
    residual = g - represented
    # the wire format is int8; the sum accumulates in the working dtype
    return jax.lax.psum(q.astype(g.dtype), axis_names) * scale, residual


def compressed_psum_delta(rows: jnp.ndarray, owners: jnp.ndarray,
                          axis_names: AxisNames, *,
                          compress: bool = True) -> jnp.ndarray:
    """Halo-DELTA exchange: assemble only the dirty boundary rows from
    their owning shards (DESIGN.md §15).

    A GrAd edge delta dirties a handful of boundary rows; re-exchanging
    the full halo would move the whole (full_rows, width) buffer when
    only `k` rows changed. Each participant passes its local (k, width)
    copy of the dirty-row buffer plus the (k,) `owners` vector mapping
    each dirty row to the shard that owns it; rows this participant does
    NOT own are masked to zero, so the contributions are disjoint by
    construction and the psum is an assembly, not an accumulation — the
    wire moves k rows instead of full_rows (`ring_psum_nbytes` over
    k*width elements prices it). `compress=True` rides the int8 QuantGr
    wire of `compressed_psum` (<= scale/2 elementwise error, exactly the
    §12 halo bound); `compress=False` psums exact fp32 — BIT-identical
    assembly (masked zeros add exactly), which is what the operand-delta
    path requires to keep patched slices rebuild-exact.
    """
    idx = jax.lax.axis_index(axis_names)
    mine = (owners == idx).astype(rows.dtype)[:, None]
    buf = rows * mine
    if compress:
        full, _ = compressed_psum(buf, axis_names)
        return full
    return jax.lax.psum(buf, axis_names)
