"""Logical-axis -> mesh-axis distribution rules.

Every parameter in the LM substrate carries *logical* axis names
(`repro.nn.common.Param`); this module maps them onto physical mesh axes.
The mapping is rule-based and divisibility-checked: a dimension is sharded
over a mesh axis only when (a) a rule names that axis, (b) the axis exists
in the mesh, and (c) the dimension is divisible by the axis size — otherwise
the dimension falls back to replication. That fallback is what lets the same
model definition run unchanged on 1 CPU device, a (16, 16) single pod, or a
(2, 16, 16) multi-pod mesh (the NodePad philosophy — one artifact, many
deployments — applied to distribution).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.nn.common import Param

# Tensor-parallel ("model") axes: wide output-ish dimensions whose matmul
# partials reduce over the fast inner ICI dimension. Everything else is
# replicated; batch dims shard over the data axes ("pod" outer, "data" inner).
AXIS_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "ff": "model",
    "mlp": "model",
    "heads": "model",
    "ssm_in": "model",
    "ssm_heads": "model",
    "embed": None,       # contracted in every matmul: replicate
    "kv": None,          # small KV head counts rarely divide; replicate
    "frames": None,
    # GNN sharded serving (DESIGN.md §12): the leading shard axis of the
    # row-partitioned operands maps onto the "shard" mesh axis of
    # launch.mesh.make_shard_mesh; every other operand dim replicates.
    "graph_shard": "shard",
    # Replica groups (DESIGN.md §15): the outer replica axis of an R-wide
    # sharded dispatch maps onto the "replica" mesh axis of the R x S mesh.
    "graph_replica": "replica",
}

# Expert parallelism is placement-dependent (capacity vs bandwidth); the
# dry-run picks per-(arch, mesh) via choose_expert_axis and pins it here.
_EXPERT_AXIS: Optional[str] = "model"


def set_expert_axis(name: Optional[str]) -> None:
    global _EXPERT_AXIS
    _EXPERT_AXIS = name


def choose_expert_axis(cfg, mesh) -> Optional[str]:
    """Prefer the model axis; fall back to data when expert count divides it
    better (small-expert archs on wide model axes)."""
    n = int(getattr(cfg, "num_experts", 0) or 0)
    for axis in ("model", "data"):
        if axis in mesh.shape and n > 0 and n % mesh.shape[axis] == 0:
            return axis
    return "model"


def _mesh_axis_for(logical: Optional[str]) -> Optional[str]:
    if logical == "experts":
        return _EXPERT_AXIS
    return AXIS_RULES.get(logical) if logical else None


def spec_for_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                  mesh) -> P:
    """PartitionSpec for one tensor; indivisible dims replicate (fallback)."""
    entries = []
    used = set()
    for dim, logical in zip(shape, axes):
        a = _mesh_axis_for(logical)
        if (a is None or a not in mesh.shape or a in used
                or dim % mesh.shape[a] != 0):
            entries.append(None)
        else:
            entries.append(a)
            used.add(a)
    return P(*entries)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def param_specs(params, mesh):
    """Param tree -> PartitionSpec tree (same structure, one spec per Param)."""
    return jax.tree_util.tree_map(
        lambda p: spec_for_axes(p.axes, p.value.shape, mesh),
        params, is_leaf=_is_param)


def param_shardings(params, mesh):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, spec_for_axes(p.axes, p.value.shape, mesh)),
        params, is_leaf=_is_param)


def optimizer_shardings(params, mesh):
    """Adam moments mirror the parameter layout exactly."""
    return param_shardings(params, mesh)


def scalar_sharding(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Batch / activation shardings
# ---------------------------------------------------------------------------

def mesh_batch_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel axes present in this mesh, outermost first."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _data_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh_batch_axes(mesh)] or [1]))


def batch_spec(mesh, *, ndim: int) -> P:
    axes = mesh_batch_axes(mesh)
    lead = axes if axes else None
    return P(lead, *([None] * (ndim - 1)))


def batch_shardings(batch, mesh):
    """Shard dim 0 of every batch leaf over the data axes (when divisible)."""
    n = _data_size(mesh)

    def one(leaf):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] % n == 0:
            return NamedSharding(mesh, batch_spec(mesh, ndim=leaf.ndim))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, batch)


def cache_specs(tree, mesh, *, seq_sharded: bool = False):
    """Decode-cache PartitionSpecs: batch dim over the data axes.

    When the global batch cannot fill the data axes (seq_sharded), the cache
    replicates — correctness first; the dry-run reports the idle fraction.
    """
    n = _data_size(mesh)

    def one(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim >= 1 and not seq_sharded and leaf.shape[0] % n == 0:
            return batch_spec(mesh, ndim=ndim)
        return P()

    return jax.tree_util.tree_map(one, tree)


# ---------------------------------------------------------------------------
# Inside-jit constraints
# ---------------------------------------------------------------------------

_ACTIVE_MESH = None


@contextlib.contextmanager
def use_distribution(mesh):
    """Activate a mesh so in-trace sharding constraints resolve against it."""
    global _ACTIVE_MESH
    prev, _ACTIVE_MESH = _ACTIVE_MESH, mesh
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev


def constrain_scan_slices(y: Any) -> Any:
    """Keep the per-microbatch batch dim data-sharded across scan slices.

    `y` is (n_micro, batch/n_micro, ...) — without the constraint XLA is free
    to gather the whole microbatch stack onto one replica between scan
    iterations. No-op outside a `use_distribution` mesh (single-device tests).
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return y
    axes = mesh_batch_axes(mesh)
    n = _data_size(mesh)
    if not axes or getattr(y, "ndim", 0) < 2 or y.shape[1] % n != 0:
        return y
    spec = P(None, axes, *([None] * (y.ndim - 2)))
    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, spec))
