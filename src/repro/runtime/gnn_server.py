"""GraphServe: a multi-graph, multi-bucket GNN inference engine.

The LM server (`runtime/server.py`) turns the paper's Step-1 techniques into
a serving discipline for token streams; GraphServe does the same for streams
of *graphs* — the paper's actual workload:

  * NodePad / BucketLadder — every request's graph is padded into one rung
    of a shared bucket ladder (tile-aligned capacities, e.g. 256/512/1024/
    2048), so the engine holds exactly one compiled blob per
    (model kind, bucket) after warmup, independent of request shapes.
  * GrAd — adjacency operands are runtime *arguments* of an ExecutionPlan
    (`core.models.build_plan`), never baked constants: evolving graphs
    re-run host preprocessing only. A graph that outgrows its bucket moves
    up the ladder (`BucketLadder.grow`) — the one legitimate recompile,
    surfaced as a `rebucket_events` metric.
  * GraphSplit — padding, PreG normalization, and mask construction happen
    on the host at submit/update time; the device executes one dense,
    statically-shaped, vmapped forward per batch.
  * Batching — same-bucket requests are stacked with a leading batch dim
    (`core.models.stack_operands`) and executed through the plan's vmapped
    callable at a FIXED batch width; partial batches repeat a real request
    into the junk slots (dropped on output) so batch width never changes
    shape — the same trick as the LM server's empty decode slots.
  * CacheG (DESIGN.md §7) — operands cross the host→device link as a
    bit-packed compact form (SymG triangular for undirected graphs) and are
    expanded to the dense float32 set ON DEVICE by a jitted materializer;
    attached graphs cache the materialized result per
    (graph_id, structure_version), so repeated queries of an unchanged
    graph move ZERO operand bytes and `_run_batch` stacks device-resident
    buffers. `update()` bumps the version and re-materializes once.
    Directed GCN/GAT graphs fall back to the eager dense upload (counted as
    `cacheg_fallbacks`) — same plans, no extra traces.

Zero-recompile contract: after `warmup()`, `assert_warm()` holds however
many mixed-size requests arrive, as long as no graph climbs the ladder.
The materializer's jit traces (one per bucket × operand-fieldset, all
compiled in `warmup()`) are folded into the same contract.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import (BucketLadder, Graph, PaddedGraph,
                              is_symmetric_adjacency, pad_graph,
                              stack_padded)
from repro.core.layers import Techniques
from repro.core.models import (ExecutionPlan, GNNConfig, GranniteOperands,
                               PlanKey, build_materializer, build_operands,
                               build_plan, compact_operands, init_params,
                               operand_nbytes, stack_operands)

# Per-kind serving techniques: the full dense-path stacks minus GraSp /
# QuantGr, whose operands are per-graph compile-time structures with no
# batched (vmapped) form — see stack_operands.
DEFAULT_TECHNIQUES: Dict[str, Techniques] = {
    "gcn": Techniques(stagr=True, grad_dynamic=True, graphsplit=True),
    "gat": Techniques.full_gat(),
    "sage": Techniques.full_sage(),
}


@dataclasses.dataclass
class GNNRequest:
    uid: int
    model: str
    pg: PaddedGraph
    ops: GranniteOperands
    bucket: int
    submitted_s: float
    finished_s: float = 0.0
    done: bool = False
    preds: Optional[np.ndarray] = None     # (num_nodes,) argmax classes
    logits: Optional[np.ndarray] = None    # (num_nodes, C) if return_logits


@dataclasses.dataclass
class GraphServeConfig:
    ladder: BucketLadder = dataclasses.field(default_factory=BucketLadder)
    batch_slots: int = 4                   # fixed batch width per dispatch
    return_logits: bool = False
    use_cacheg: bool = True                # CacheG operand pipeline (§7);
    # False = eager host-built dense operands uploaded per request


@dataclasses.dataclass
class _ModelEntry:
    cfg: GNNConfig
    params: Dict
    techniques: Techniques


class GraphServe:
    def __init__(self, sc: Optional[GraphServeConfig] = None, *, seed: int = 0):
        self.sc = sc or GraphServeConfig()
        self.seed = seed
        self.models: Dict[str, _ModelEntry] = {}
        self.queue: List[GNNRequest] = []
        self.finished: List[GNNRequest] = []
        self.graphs: Dict[int, Tuple[str, PaddedGraph]] = {}
        self._plans: Dict[PlanKey, ExecutionPlan] = {}
        self._materializer = build_materializer()
        # CacheG device-resident operand cache: (graph_id, structure_version)
        # -> materialized GranniteOperands living in device memory. update()
        # bumps the version and evicts, so stale structure can never serve.
        self._operand_cache: Dict[Tuple[int, int], GranniteOperands] = {}
        self._graph_version: Dict[int, int] = {}
        self._warm_blobs: Optional[int] = None
        self._uid = 0
        self._gid = 0
        self.metrics = {"batches": 0, "slots_filled": 0, "slots_total": 0,
                        "rebucket_events": 0, "latency_s": [],
                        "first_submit_s": None, "last_finish_s": None,
                        "operand_bytes_h2d": 0, "operand_cache_hits": 0,
                        "operand_cache_misses": 0, "cacheg_fallbacks": 0}

    # ------------------------------------------------------------------ setup
    def register_model(self, name: str, cfg: GNNConfig, params: Optional[Dict] = None,
                       *, techniques: Optional[Techniques] = None) -> None:
        import jax
        if params is None:
            params = init_params(jax.random.PRNGKey(self.seed), cfg)
        t = techniques if techniques is not None else DEFAULT_TECHNIQUES[cfg.kind]
        self.models[name] = _ModelEntry(cfg=cfg, params=params, techniques=t)

    def plan_for(self, model: str, bucket: int) -> ExecutionPlan:
        # keyed by the plan's full identity, not the model name: params are
        # runtime args, so models registered with identical (cfg, techniques)
        # share one compiled blob per bucket
        e = self.models[model]
        key: PlanKey = (e.cfg, bucket, self.sc.batch_slots, e.techniques)
        if key not in self._plans:
            self._plans[key] = build_plan(e.cfg, bucket, e.techniques,
                                          batch_size=self.sc.batch_slots)
        return self._plans[key]

    @property
    def compiled_blobs(self) -> int:
        """Actual jit traces: all plans + the CacheG materializer (one trace
        per bucket × operand-fieldset, compiled during warmup)."""
        return (sum(p.trace_count for p in self._plans.values())
                + self._materializer.trace_count)

    def warmup(self, *, buckets: Optional[Tuple[int, ...]] = None) -> int:
        """Compile every (model, bucket) plan — and, with CacheG enabled,
        every (bucket, fieldset) materializer — once with placeholder inputs.
        """
        buckets = buckets if buckets is not None else self.sc.ladder.buckets
        b = self.sc.batch_slots
        for bucket in buckets:
            empty = pad_graph(Graph(edge_index=np.zeros((2, 0), np.int32),
                                    num_nodes=1,
                                    features=np.zeros((1, 1), np.float32)),
                              capacity=bucket)
            for name, e in self.models.items():
                pg = dataclasses.replace(
                    empty, features=np.zeros((bucket, e.cfg.in_feats),
                                             np.float32))
                if self.sc.use_cacheg:
                    single = self._materializer(compact_operands(pg, e.cfg))
                else:
                    single = build_operands(pg, e.cfg, lean=True)
                ops = stack_operands([single] * b)
                x = jnp.zeros((b, bucket, e.cfg.in_feats), jnp.float32)
                out = self.plan_for(name, bucket)(e.params, x, ops)
                out.block_until_ready()
        self._warm_blobs = self.compiled_blobs
        return self._warm_blobs

    def assert_warm(self) -> None:
        """The zero-recompile contract (mirrors the LM server's assertion)."""
        assert self._warm_blobs is not None, "call warmup() first"
        assert self.compiled_blobs == self._warm_blobs, (
            f"recompile after warmup: {self.compiled_blobs} traces vs "
            f"{self._warm_blobs} at warmup")

    # ------------------------------------------------------------------ intake
    def _device_operands(self, model: str, pg: PaddedGraph) -> GranniteOperands:
        """Build one graph's device-resident operands, preferring the CacheG
        compact transfer + on-device materialization; directed GCN/GAT graphs
        (SymG needs symmetry) fall back to the eager dense upload — same
        plans, no new traces, just more host→device bytes."""
        e = self.models[model]
        if self.sc.use_cacheg:
            if e.cfg.kind == "sage" or is_symmetric_adjacency(pg.adj):
                # symmetry was just checked — don't pay the O(cap²)
                # comparison a second time inside the packer
                co = compact_operands(pg, e.cfg, check_symmetry=False)
                self.metrics["operand_bytes_h2d"] += co.nbytes
                return self._materializer(co)
            self.metrics["cacheg_fallbacks"] += 1
        ops = build_operands(pg, e.cfg, lean=True)
        self.metrics["operand_bytes_h2d"] += operand_nbytes(ops)
        return ops

    def _enqueue(self, model: str, pg: PaddedGraph,
                 ops: Optional[GranniteOperands] = None) -> int:
        now = time.perf_counter()
        req = GNNRequest(uid=self._uid, model=model, pg=pg,
                         ops=ops if ops is not None
                         else self._device_operands(model, pg),
                         bucket=pg.capacity, submitted_s=now)
        self._uid += 1
        if self.metrics["first_submit_s"] is None:
            self.metrics["first_submit_s"] = now
        self.queue.append(req)
        return req.uid

    def submit(self, g: Graph, *, model: str) -> int:
        """One-shot inference request over a static graph."""
        return self._enqueue(model, self.sc.ladder.pad(g))

    def attach(self, g: Graph, *, model: str) -> int:
        """Register an evolving graph; returns a graph_id for update/query.

        Operands materialize lazily on the first `query()` and stay cached
        on device until `update()` changes the structure."""
        gid = self._gid
        self._gid += 1
        self.graphs[gid] = (model, self.sc.ladder.pad(g))
        self._graph_version[gid] = 0
        return gid

    def detach(self, graph_id: int) -> None:
        """Release an attached graph and its device-resident operands.

        The cache pins O(cap²) float32 per attached graph in device memory
        (~32 MB for GAT at cap=2048) — long-running multi-tenant servers
        must detach graphs they stop serving, or the cache grows without
        bound (there is deliberately no silent LRU: evicting a live tenant's
        operands would turn its next query into a surprise re-materialize).
        """
        self._operand_cache.pop(
            (graph_id, self._graph_version.pop(graph_id, -1)), None)
        self.graphs.pop(graph_id, None)

    def update(self, graph_id: int, edge_index: np.ndarray, num_nodes: int,
               features: np.ndarray) -> bool:
        """GrAd update of an attached graph; True if it climbed the ladder.

        Bumps the structure version, which invalidates the CacheG operand
        cache — the next `query()` re-materializes exactly once."""
        model, pg = self.graphs[graph_id]
        pg, rebucketed = self.sc.ladder.grow(pg, edge_index, num_nodes,
                                             features)
        self.graphs[graph_id] = (model, pg)
        ver = self._graph_version[graph_id]
        self._operand_cache.pop((graph_id, ver), None)
        self._graph_version[graph_id] = ver + 1
        if rebucketed:
            self.metrics["rebucket_events"] += 1
        return rebucketed

    def query(self, graph_id: int) -> int:
        """Enqueue inference over an attached graph's current snapshot.

        CacheG hit path: an unchanged structure serves straight from the
        device-resident cache — zero host-side operand construction, zero
        operand bytes over the link."""
        model, pg = self.graphs[graph_id]
        if not self.sc.use_cacheg:
            return self._enqueue(model, pg)
        key = (graph_id, self._graph_version[graph_id])
        ops = self._operand_cache.get(key)
        if ops is None:
            self.metrics["operand_cache_misses"] += 1
            ops = self._device_operands(model, pg)
            self._operand_cache[key] = ops
        else:
            self.metrics["operand_cache_hits"] += 1
        return self._enqueue(model, pg, ops)

    # --------------------------------------------------------------- execution
    def run(self) -> List[GNNRequest]:
        while self.queue:
            self._run_batch()
        return self.finished

    def _run_batch(self) -> None:
        head = self.queue[0]
        key = (head.model, head.bucket)
        batch = [r for r in self.queue
                 if (r.model, r.bucket) == key][: self.sc.batch_slots]
        taken = {r.uid for r in batch}
        self.queue = [r for r in self.queue if r.uid not in taken]

        b = self.sc.batch_slots
        # fixed batch width: junk slots repeat a real request, outputs dropped
        slots = batch + [batch[-1]] * (b - len(batch))
        e = self.models[head.model]
        x = jnp.asarray(stack_padded([r.pg for r in slots]).features)
        # CacheG: r.ops are device-resident (materialized or cached), so this
        # stack is a device-side concat — only the activations `x` crossed
        # the host→device link for this dispatch (DESIGN.md §7).
        ops = stack_operands([r.ops for r in slots])
        logits = self.plan_for(head.model, head.bucket)(e.params, x, ops)
        logits.block_until_ready()

        now = time.perf_counter()
        host_logits = np.asarray(logits)
        for i, r in enumerate(batch):
            lg = host_logits[i, : r.pg.num_nodes]
            r.preds = lg.argmax(axis=-1).astype(np.int32)
            if self.sc.return_logits:
                r.logits = lg
            r.done = True
            r.finished_s = now
            self.metrics["latency_s"].append(now - r.submitted_s)
            self.finished.append(r)
        self.metrics["batches"] += 1
        self.metrics["slots_filled"] += len(batch)
        self.metrics["slots_total"] += b
        self.metrics["last_finish_s"] = now

    # ---------------------------------------------------------------- metrics
    def summary(self) -> Dict[str, object]:
        lat = np.asarray(self.metrics["latency_s"], np.float64)
        t0, t1 = self.metrics["first_submit_s"], self.metrics["last_finish_s"]
        span = (t1 - t0) if (t0 is not None and t1 is not None) else 0.0
        return {
            "requests": len(self.finished),
            "compiled_blobs": self.compiled_blobs,
            "batches": self.metrics["batches"],
            "batch_occupancy": (self.metrics["slots_filled"]
                                / max(self.metrics["slots_total"], 1)),
            "rebucket_events": self.metrics["rebucket_events"],
            "operand_bytes_h2d": self.metrics["operand_bytes_h2d"],
            "operand_cache_hits": self.metrics["operand_cache_hits"],
            "operand_cache_misses": self.metrics["operand_cache_misses"],
            "cacheg_fallbacks": self.metrics["cacheg_fallbacks"],
            "throughput_rps": (len(self.finished) / span if span > 0 else 0.0),
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else 0.0,
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else 0.0,
            "mean_latency_s": float(lat.mean()) if lat.size else 0.0,
        }
