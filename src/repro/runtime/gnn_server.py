"""GraphServe: a multi-graph, multi-bucket GNN inference engine.

The LM server (`runtime/server.py`) turns the paper's Step-1 techniques into
a serving discipline for token streams; GraphServe does the same for streams
of *graphs* — the paper's actual workload:

  * NodePad / BucketLadder — every request's graph is padded into one rung
    of a shared bucket ladder (tile-aligned capacities, e.g. 256/512/1024/
    2048), so the engine holds exactly one compiled blob per
    (model kind, bucket) after warmup, independent of request shapes.
  * GrAd — adjacency operands are runtime *arguments* of an ExecutionPlan
    (`core.models.build_plan`), never baked constants: evolving graphs
    re-run host preprocessing only. A graph that outgrows its bucket moves
    up the ladder (`BucketLadder.grow`) — the one legitimate recompile,
    surfaced as a `rebucket_events` metric.
  * GraphSplit — padding, PreG normalization, and mask construction happen
    on the host at submit/update time; the device executes one dense,
    statically-shaped, vmapped forward per batch. With `shard_counts`
    configured the split also goes multi-device (DESIGN.md §12): a graph
    too large for the TOP ladder bucket no longer errors out of `attach()`
    — the engine partitions it N-way (`core.partition.partition_for_ladder`,
    greedy edge-cut under a per-shard bucket cap) and serves it through a
    sharded plan: per-shard aggregate+combine under a shard axis, the halo
    exchanged per layer as an int8-compressed psum (`dist.compress` —
    QuantGr applied to the wire). Sharded dispatches are width-1 (the
    shard axis occupies the leading dim a batch would use), the shard
    count joins the batch key so a dispatch never mixes sharded and
    unsharded plans, and `warmup()` pre-traces every configured
    (shard count, bucket, tier) — mixed traffic replays warm.
  * Batching — same-bucket requests are stacked with a leading batch dim
    (`core.models.stack_operands`) and executed through the plan's vmapped
    callable at a FIXED batch width; partial batches repeat a real request
    into the junk slots (dropped on output) so batch width never changes
    shape — the same trick as the LM server's empty decode slots. Batch
    selection is best-fill (`best_fill_key`): the fullest (model, bucket,
    tier, backend) key dispatches first, with per-model fairness on ties,
    so a lone odd request at the head of the queue cannot force a 1-of-N
    batch.
  * Pipeline (DESIGN.md §9) — the sync path (`submit`/`query` + `run()`)
    executes host and device stages serially; `scheduler()` attaches the
    async two-stage pipeline (`runtime/scheduler.py`): host worker threads
    run `prepare_submit`/`prepare_query` (padding, operand build/packing,
    CacheG lookups) while the dispatcher thread drives `_execute_batch`,
    so host preprocessing for request N+1 overlaps device execution of
    request N. Every engine contract below holds under both drivers.
  * CacheG (DESIGN.md §7) — operands cross the host→device link as a
    bit-packed compact form (SymG triangular for undirected graphs) and are
    expanded to the dense float32 set ON DEVICE by a jitted materializer;
    attached graphs cache the materialized result per
    (graph_id, structure_version), so repeated queries of an unchanged
    graph move ZERO operand bytes and `_run_batch` stacks device-resident
    buffers. `update()` bumps the version and re-materializes once.
    Directed GCN/GAT graphs fall back to the eager dense upload (counted as
    `cacheg_fallbacks`) — same plans, no extra traces.
  * GraSp agg backends (DESIGN.md §10) — every request's aggregation
    dispatches through one of two backends: `dense` (one matmul over the
    full padded Â) or `grasp` (the block-sparse `bitmap_spmm` kernel over
    a compacted structure padded to the bucket's `grasp_max_nnz` budget).
    A model registered with `agg_backend="auto"` routes each graph by the
    modelled density/cost rule (`core.sparsity.select_agg_backend`);
    `"grasp"` forces the sparse path where eligible. The block structure
    is DERIVED state like the int8 Â: computed device-side from the cached
    fp32 Â once per (graph_id, structure_version) (`BlockCompactor`),
    host-built (`to_block_sparse`) only on the eager fallback path. The
    backend joins the batch key, so a dispatch never mixes backends, and
    warmup pre-traces BOTH backends' plans — mixed dense/grasp traffic
    replays warm. Every REQUEST whose grasp intent could not run the skip
    grid (forced-but-ineligible structure, or the kernel routing's dense
    `ref` fallback) is counted in `backend_fallbacks` — the same
    per-request unit as `tier_fallbacks`, never silent.
  * Quality tiers (DESIGN.md §8) — every registered model carries a tier
    registry mapping tier names to `Techniques` variants (standard ladder:
    `fp32` exact / `int8` QuantGr / `int8+grax` QuantGr + the kind's GrAx
    approximations). A tier is just another ExecutionPlan: requests pick
    one per call (`query(gid, tier="int8")`), QuantGr tiers carry a
    model-level calibration (`calibrate_tier`) as the plan's broadcast
    runtime argument, and an uncalibrated quant tier serves through fp32
    (counted as `tier_fallbacks`) rather than erroring. Calibration runs
    once per (model, tier) — on the first `attach()` or an explicit
    `calibrate()` — and also measures `accuracy_delta_vs_fp32` on the
    held-out part of the calibration graph.

Engine contracts (what tests and operators may rely on):

  * Zero-recompile — after `warmup()`, `assert_warm()` holds however many
    mixed-size, mixed-TIER, mixed-BACKEND requests arrive, as long as no
    graph climbs the ladder. Warmup compiles every (model, bucket, tier,
    backend) plan — quant-tier plans against a placeholder calibration
    whose pytree structure equals any real one (calibration shapes are
    model-level, see core.models), grasp plans against a placeholder
    block structure at the bucket budget — plus one CacheG materializer
    trace per (bucket, operand-fieldset) and two block-compactor traces
    (counts reduction + full gather) per grasp-capable bucket.
  * Cache keys — all four operand caches (fp32 operands, sharded slices,
    int8 Â, grasp structure) are keyed by (graph_id, structure_version)
    and NOTHING else. The primary caches hold the tier- and
    backend-agnostic forms every request shares; the tier and grasp
    caches hold forms DERIVED from that same version (GCN's int8 Â,
    quantized once per version so the int8 plan reads 1-byte rows instead
    of re-quantizing 4-byte fp32 every query; the budget-padded block
    structure plus the backend decision, compacted once per version).
    `update()`/`update_delta()` bumping the version (or `detach()`) is
    the only INVALIDATION path for all four — and capacity eviction
    (below) is not invalidation: an evicted entry's key is still live and
    the next query rebuilds or re-materializes the identical value.
  * Bounded residency (DESIGN.md §13) — with `device_cache_budget_bytes`
    set, all four caches live under one byte-budgeted manager
    (`runtime/cache.py`): every entry carries its measured device cost,
    cost-aware LRU eviction keeps `cache_resident_bytes <= budget` at
    every step (derived forms evict before the primary they hang off),
    evicted primaries spill to a host-RAM compact form re-materialized on
    fault (`cache_spill_hits` — compact bytes cross the link again, zero
    host packing), and `attach()` becomes the admission gate
    (`CacheAdmissionError`, policy `admission="evict"|"reject"`).
    Eviction, spill, and re-materialization never trace: the materializer
    and patcher blobs are bucket-shaped and warm.
  * GrAd deltas (§13) — `update_delta(gid, add_edges, remove_edges)`
    patches the packed adjacency host-side and every device-resident
    cached form IN PLACE of a rebuild (touched-row Â renorm, GAT
    mask/bias rescatter, touched-row int8 re-quantization, grasp
    re-derivation, sharded row blocks with the partition kept), bit-exact
    against a full rebuild; deltas past the warmed pad widths (or SAGE)
    fall back to `update()` — `delta_updates` vs `delta_fallbacks`.
  * Plan identity — plans are keyed by (cfg, bucket, batch, Techniques,
    backend, fusion): tenants sharing a config share blobs, and tier names
    that alias the same Techniques (GCN int8 vs int8+grax) share too. Tier
    names are a serving-policy concept; the compiler only ever sees
    Techniques plus the aggregation backend and the fusion mode.
  * Fused layers (DESIGN.md §11) — `fusion="layer"` routes each GNN layer
    through one fused Pallas kernel (aggregate + combine + bias + act in a
    single grid, `kernels/fused_layers.py`) with per-request control flow
    expressed as EffOp masked arithmetic in the kernel epilogue instead of
    host-side branching. Fusion is a PLAN dimension, not a tier: it never
    changes numerics beyond kernel-vs-XLA float ordering, so it joins the
    batch key (a dispatch never mixes fused and unfused plans) and warmup
    pre-traces BOTH fusion modes per (tier, backend) — mixed fused/unfused
    traffic replays warm.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import (BucketLadder, Graph, PaddedGraph,
                              apply_edge_delta, edge_index_from_adjacency,
                              is_symmetric_adjacency, pad_graph, stack_padded)
from repro.core.layers import Techniques
from repro.core.models import (FUSION_MODES, OPERAND_FIELDS, DeltaSpec,
                               ExecutionPlan, GNNConfig, GranniteOperands,
                               PlanKey, ShardSlice, TierOperands,
                               build_agg_quantizer, build_block_compactor,
                               build_delta_patcher, build_materializer,
                               build_operands, build_plan,
                               build_sharded_operands, build_sharded_plan,
                               calibrate_tier, compact_operands,
                               derive_tier_operands, forward_grannite,
                               init_params, prepare_host_operands,
                               realize_operands, sharded_exchange_widths,
                               stack_operands, stack_shard_slices,
                               stack_tier_operands, unshard_logits)
from repro.core.partition import (GraphShards, partition_for_ladder,
                                  patch_halo, transfer_cost)
from repro.core.sparsity import (HBM_BW, MXU_RATE, block_stats,
                                 grasp_max_nnz, select_agg_backend)
from repro.dist.compress import ring_psum_nbytes
from repro.runtime.cache import (CacheAdmissionError, DeviceCacheManager,
                                 estimate_dense_entry_bytes,
                                 estimate_shard_entry_bytes, pytree_nbytes)
from repro.runtime.clock import WALL, Clock
from repro.runtime.ewma import LatencyBank
from repro.runtime.slo import SLOConfig, SLOGovernor

# Per-kind serving techniques for models registered WITHOUT a tier ladder.
# GraSp is deliberately NOT a technique flag here: block-sparse aggregation
# is an execution *backend* the engine dispatches per (graph, bucket)
# (`agg_backend=` on register_model, DESIGN.md §10), not part of a tier's
# quality identity; QuantGr is tier-servable via the model-level
# calibration path (DESIGN.md §8).
DEFAULT_TECHNIQUES: Dict[str, Techniques] = {
    "gcn": Techniques(stagr=True, grad_dynamic=True, graphsplit=True),
    "gat": Techniques.full_gat(),
    "sage": Techniques.full_sage(),
}

STANDARD_TIERS = ("fp32", "int8", "int8+grax")

# Aggregation-backend serving modes (register_model(agg_backend=...)):
# "dense" never dispatches GraSp, "auto" routes per graph by the modelled
# density/cost rule, "grasp" forces the sparse path where eligible.
AGG_BACKEND_MODES = ("dense", "auto", "grasp")

# (model, bucket, tier, agg backend, fusion mode, shard count — 0 unsharded;
# for a sharded request the bucket element is the PER-SHARD capacity)
BatchKey = Tuple[str, int, str, str, str, int]


def best_fill_key(stats: Dict[BatchKey, Tuple[int, int]], batch_slots: int,
                  last_dispatch: Optional[Dict[str, int]] = None,
                  *, replica_slots: int = 1) -> BatchKey:
    """Pick the batch key to dispatch next (DESIGN.md §9).

    `stats` maps each pending (model, bucket, tier, backend, fusion) key to
    `(count, head_order)` — how many requests wait under it and the arrival
    order of its oldest one. Selection order:

      1. best fill — most waiting requests, capped at `batch_slots` (a key
         with 9 waiting fills a 4-slot batch no better than one with 4);
      2. per-model fairness — among equal fills, the model dispatched
         LONGEST ago (its serial in `last_dispatch`) goes first, so one
         chatty tenant cannot starve another at equal batch efficiency;
      3. FIFO — oldest head request breaks remaining ties.

    This replaces the old head-of-line rule (`queue[0]`'s key, whatever it
    was), under which a lone odd request at the head forced a 1-of-N batch
    while fully-fillable keys waited behind it.

    Dispatch width differs per key: an unsharded key fills up to
    `batch_slots` requests, a sharded key (`key[5] > 0`) fills up to
    `replica_slots` replica rows of the mesh (DESIGN.md §15) — so "fill"
    is the FRACTION of the key's own width it can occupy, making a
    2-of-2-replicas sharded dispatch exactly as good as a 4-of-4-slot
    dense one. With every width equal the fraction orders identically to
    the historical absolute count.
    """
    last_dispatch = last_dispatch or {}

    def width(k: BatchKey) -> int:
        return replica_slots if k[5] else batch_slots

    return min(stats.items(),
               key=lambda kv: (-min(kv[1][0], width(kv[0])) / width(kv[0]),
                               last_dispatch.get(kv[0][0], -1),
                               kv[1][1]))[0]


def edf_best_fill_key(stats: Dict[BatchKey, Tuple[int, int, float]],
                      batch_slots: int,
                      last_dispatch: Optional[Dict[str, int]] = None,
                      *, replica_slots: int = 1) -> BatchKey:
    """Slack-aware EDF variant of `best_fill_key` (DESIGN.md §14).

    `stats` values are `(count, head_order, min_slack)` where `min_slack`
    is the tightest `deadline - now` (seconds) among the key's pending
    requests, `+inf` when none carries a deadline. Selection order:

      1. best fill — identical to `best_fill_key`: batching efficiency is
         still the primary axis (a tight deadline never justifies a 1-of-N
         dispatch while a full batch waits — that would miss MORE
         deadlines under load);
      2. tightest slack — among equal fills, the key whose most urgent
         request expires soonest dispatches first (earliest-deadline-first
         as the tie-break, which is where a deadline actually changes the
         outcome);
      3. per-model fairness, then FIFO — unchanged from `best_fill_key`,
         so deadline-free traffic batches exactly as before (every slack
         is +inf and rules 3-4 decide).

    Fill is the per-key width fraction exactly as in `best_fill_key`
    (sharded keys fill `replica_slots` replica rows, unsharded keys fill
    `batch_slots`).
    """
    last_dispatch = last_dispatch or {}

    def width(k: BatchKey) -> int:
        return replica_slots if k[5] else batch_slots

    return min(stats.items(),
               key=lambda kv: (-min(kv[1][0], width(kv[0])) / width(kv[0]),
                               kv[1][2],
                               last_dispatch.get(kv[0][0], -1),
                               kv[1][1]))[0]


def pending_stats(reqs: Sequence["GNNRequest"]
                  ) -> Dict[BatchKey, Tuple[int, int]]:
    """Fold a pending-request sequence into `best_fill_key` stats."""
    stats: Dict[BatchKey, Tuple[int, int]] = {}
    for i, r in enumerate(reqs):
        k = (r.model, r.bucket, r.tier, r.backend, r.fusion, r.shards)
        c = stats.get(k)
        stats[k] = (1, i) if c is None else (c[0] + 1, c[1])
    return stats


def edf_pending_stats(reqs: Sequence["GNNRequest"], now: float
                      ) -> Dict[BatchKey, Tuple[int, int, float]]:
    """Fold pending requests into `edf_best_fill_key` stats at time `now`."""
    stats: Dict[BatchKey, Tuple[int, int, float]] = {}
    for i, r in enumerate(reqs):
        k = (r.model, r.bucket, r.tier, r.backend, r.fusion, r.shards)
        slack = (r.deadline_s - now if r.deadline_s is not None
                 else float("inf"))
        c = stats.get(k)
        stats[k] = ((1, i, slack) if c is None
                    else (c[0] + 1, c[1], min(c[2], slack)))
    return stats


def tier_techniques(kind: str) -> Dict[str, Techniques]:
    """The standard quality-tier registry for one model kind (DESIGN.md §8).

    `fp32` is the exact dense serving path — no approximation, the accuracy
    reference every other tier's delta is measured against. `int8` switches
    the combine matmuls (and, for GCN, the Â aggregation) to QuantGr.
    `int8+grax` adds the kind's GrAx approximations: GrAx1+GrAx2 for GAT
    attention, GrAx3 for SAGE-max; GCN has no GrAx variant, so its
    `int8+grax` aliases the int8 Techniques and shares its compiled plans.
    """
    fp32 = {"gcn": Techniques(stagr=True, grad_dynamic=True, graphsplit=True),
            "gat": Techniques(stagr=True, graphsplit=True, effop=True),
            "sage": Techniques(stagr=True, graphsplit=True, effop=True)}[kind]
    int8 = dataclasses.replace(fp32, quantgr=True)
    grax = {"gcn": int8,
            "gat": dataclasses.replace(int8, grax1=True, grax2=True),
            "sage": dataclasses.replace(int8, grax3=True)}[kind]
    return {"fp32": fp32, "int8": int8, "int8+grax": grax}


def _delta_points(base_logits, tier_logits, pg: PaddedGraph) -> float:
    """`accuracy_delta_vs_fp32` in percentage points, on the held-out batch.

    Labeled calibration graphs score top-1 accuracy on `test_mask` (the
    held-out split; falls back to all labeled nodes when no mask exists);
    unlabeled ones fall back to argmax agreement with the fp32 tier, shifted
    so 0.0 still reads "identical predictions" and negative "divergence".
    """
    n = pg.num_nodes
    bp = np.asarray(base_logits)[:n].argmax(-1)
    tp = np.asarray(tier_logits)[:n].argmax(-1)
    if pg.labels is not None:
        labels = np.asarray(pg.labels)[:n]
        mask = labels >= 0
        if pg.test_mask is not None and np.asarray(pg.test_mask)[:n].any():
            mask = mask & np.asarray(pg.test_mask)[:n]
        if mask.any():
            acc_b = float((bp[mask] == labels[mask]).mean())
            acc_t = float((tp[mask] == labels[mask]).mean())
            return (acc_t - acc_b) * 100.0
    return (float((tp == bp).mean()) - 1.0) * 100.0


@dataclasses.dataclass
class GNNRequest:
    uid: int
    model: str
    pg: PaddedGraph
    ops: GranniteOperands
    bucket: int
    submitted_s: float
    tier: str = "fp32"                     # resolved tier (post-fallback)
    backend: str = "dense"                 # resolved agg backend (§10)
    fusion: str = "none"                   # resolved fusion mode (§11)
    tier_ops: Optional[TierOperands] = None  # derived (e.g. GCN int8 Â)
    deadline_s: Optional[float] = None     # absolute clock deadline (§14);
    # None = no SLO — the request can never expire or be flagged late
    tolerance: Optional[float] = None      # max |accuracy_delta| (points)
    # the tier router may trade away (§14); None = no tolerance routing
    deadline_missed: bool = False          # §14: expired unserved (preds is
    # None) or finished past its deadline (preds still delivered)
    shards: int = 0                        # >0: sharded dispatch (§12);
    # then `ops` holds the STACKED per-shard operand row blocks and the
    # three fields below carry the rest of the sharded calling convention
    part: Optional[GraphShards] = None     # the partition (unshard map)
    shard_x: Optional[jnp.ndarray] = None  # (S, C, F) stacked features
    shard_mask: Optional[jnp.ndarray] = None  # (S, C) real-row masks
    finished_s: float = 0.0
    done: bool = False
    preds: Optional[np.ndarray] = None     # (num_nodes,) argmax classes
    logits: Optional[np.ndarray] = None    # (num_nodes, C) if return_logits


@dataclasses.dataclass
class GraphServeConfig:
    ladder: BucketLadder = dataclasses.field(default_factory=BucketLadder)
    batch_slots: int = 4                   # fixed batch width per dispatch
    return_logits: bool = False
    use_cacheg: bool = True                # CacheG operand pipeline (§7);
    # False = eager host-built dense operands uploaded per request
    shard_counts: Tuple[int, ...] = ()     # §12: shard counts attach() may
    # auto-shard an over-ladder graph across; () keeps sharding disabled
    # (oversized graphs raise, exactly the pre-§12 behavior)
    halo_compress: bool = True             # int8 QuantGr on the halo wire;
    # False exchanges exact fp32 (4x the collective bytes)
    device_cache_budget_bytes: Optional[int] = None   # §13: byte budget the
    # four operand caches share; None keeps them unbounded (pre-§13)
    spill_to_host: bool = True             # §13: evicted primaries keep a
    # host-RAM compact form, re-materialized on fault; False drops them
    admission: str = "evict"               # §13 attach() policy when a new
    # graph's projected operands overflow the budget: "evict" admits and
    # lets insert-time eviction make room, "reject" raises
    delta_pad_rows: int = 64               # §13 GrAd delta threshold: max
    # touched nodes update_delta() patches device-side (flip scatters pad
    # to 2x this); bigger deltas — and 0, disabling the path — take the
    # full update() rebuild
    replica_groups: int = 1                # §15: sharded dispatch width —
    # R concurrent sharded batches per plan call on an R x S mesh (falls
    # back to a vmap-simulated replica axis below R*S devices); 1 keeps
    # the pre-§15 single-replica convention exactly
    partition_method: str = "multilevel"   # §15 partitioner for attach()'s
    # auto-sharding: "multilevel" (coarsen + KL/FM refine) or "greedy"
    # (the §12 streaming baseline the benchmark compares against)


@dataclasses.dataclass
class _ModelEntry:
    cfg: GNNConfig
    params: Dict
    tiers: Dict[str, Techniques]           # tier name -> execution variant
    default_tier: str
    agg_backend: str = "dense"             # "dense" | "auto" | "grasp" (§10)
    default_fusion: str = "none"           # "none" | "layer" (§11)
    name: str = ""                         # registry name (bank/routing key)
    # once per (model, tier): calibrate_tier pytrees for QuantGr tiers, and
    # the measured accuracy_delta_vs_fp32 for every non-fp32 tier
    calibrations: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    accuracy_delta: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def techniques(self) -> Techniques:
        """The default tier's Techniques (back-compat accessor)."""
        return self.tiers[self.default_tier]


class GraphServe:
    def __init__(self, sc: Optional[GraphServeConfig] = None, *, seed: int = 0,
                 clock: Optional[Clock] = None,
                 slo: Optional[SLOConfig] = None):
        self.sc = sc or GraphServeConfig()
        self.seed = seed
        # §14: every timestamp, deadline comparison, and latency sample in
        # the serving path reads THIS clock — tests inject a fake one and
        # drive the whole SLO loop without a single real sleep
        self.clock = clock if clock is not None else WALL
        # §14: measured-latency oracle per BatchKey, roofline-seeded; the
        # single cost source behind backend routing and the tier router
        self.bank = LatencyBank()
        # §14: optional SLO governor — None keeps serving exactly pre-§14
        self.governor = SLOGovernor(slo) if slo is not None else None
        self.models: Dict[str, _ModelEntry] = {}
        self.queue: List[GNNRequest] = []
        self.finished: List[GNNRequest] = []
        self.graphs: Dict[int, Tuple[str, PaddedGraph]] = {}
        self._plans: Dict[PlanKey, ExecutionPlan] = {}
        self._materializer = build_materializer()
        self._agg_quantizer = build_agg_quantizer()
        self._block_compactor = build_block_compactor()
        self._delta_patcher = build_delta_patcher()
        if self.sc.admission not in ("evict", "reject"):
            raise ValueError(f"unknown admission policy "
                             f"{self.sc.admission!r}; pick evict|reject")
        # CacheG device-resident operand hierarchy, keyed by (graph_id,
        # structure_version) and NOTHING else: the primary fp32 operands
        # ("operand"), the DERIVED forms of the same version — GCN's int8 Â
        # ("tier") and the resolved agg backend plus budget-padded block
        # structure ("grasp", DESIGN.md §10) — and the sharded slice tuple
        # ("shard", §12). Since §13 all four live under one byte-budgeted
        # manager (`runtime/cache.py`): cost-aware LRU eviction against
        # `device_cache_budget_bytes`, evicted primaries spilling to a
        # host-RAM compact form, update()/detach() invalidating by key.
        self._cache = DeviceCacheManager(
            budget_bytes=self.sc.device_cache_budget_bytes,
            spill_to_host=self.sc.spill_to_host)
        # sharded registry (§12): graph_id -> (partition, source Graph) for
        # graphs attach() auto-sharded past the top ladder bucket
        self._sharded: Dict[int, Tuple[GraphShards, Graph]] = {}
        self._graph_version: Dict[int, int] = {}
        self._warm_blobs: Optional[int] = None
        self._uid = 0
        self._gid = 0
        # one lock guards uid/gid counters, metrics, the operand caches, and
        # the graphs registry: the pipeline scheduler (runtime/scheduler.py)
        # runs prepare_submit/prepare_query on host worker threads while
        # update()/detach() arrive from the caller's thread. Never call a
        # _lock-taking helper while holding _lock.
        self._lock = threading.Lock()
        self._dispatch_serial = 0
        self._last_dispatch: Dict[str, int] = {}   # model -> dispatch serial
        self.metrics = {"batches": 0, "slots_filled": 0, "slots_total": 0,
                        "rebucket_events": 0, "latency_s": [],
                        "first_submit_s": None, "last_finish_s": None,
                        "device_busy_s": 0.0,
                        "operand_bytes_h2d": 0, "operand_cache_hits": 0,
                        "operand_cache_misses": 0, "cacheg_fallbacks": 0,
                        "tier_fallbacks": 0, "backend_fallbacks": 0,
                        "grasp_batches": 0, "sharded_batches": 0,
                        "halo_bytes_exchanged": 0,
                        "collective_bytes_compressed": 0,
                        "collective_bytes_exact": 0,
                        "cache_spill_hits": 0, "cache_admission_rejects": 0,
                        "delta_updates": 0, "delta_fallbacks": 0,
                        # §15 halo-delta wire accounting: what the
                        # dirty-boundary-row exchange moved vs what a full
                        # halo re-exchange would have, per sharded delta
                        "delta_halo_bytes_exchanged": 0,
                        "delta_halo_bytes_full": 0,
                        "delta_dirty_rows": 0,
                        "deadline_misses": 0, "shed_requests": 0}

    def _count(self, name: str, delta=1) -> None:
        with self._lock:
            self.metrics[name] += delta

    # ----------------------------------------------------- cache compat views
    # (snapshot views of the §13 cache manager in the plain-dict shape the
    # four caches had before it — tests and diagnostics read these)
    @property
    def _operand_cache(self) -> Dict[Tuple[int, int], GranniteOperands]:
        return self._cache.view("operand")

    @property
    def _tier_operand_cache(self) -> Dict[Tuple[int, int], TierOperands]:
        return self._cache.view("tier")

    @property
    def _grasp_cache(self) -> Dict[Tuple[int, int], Tuple[str, object]]:
        return self._cache.view("grasp")

    @property
    def _shard_cache(self) -> Dict[Tuple[int, int], Tuple[ShardSlice, ...]]:
        return self._cache.view("shard")

    # ------------------------------------------------------- cache cost model
    def _projected_primary_bytes(self, model: str, pg: PaddedGraph,
                                 part: Optional[GraphShards]) -> int:
        """Projected device cost of the PRIMARY entry this graph pins on
        first query — what attach() admission control (§13) sizes against.
        Derived forms (int8 Â, grasp structure) are not counted: they rank
        below the primary in eviction order and never exceed it."""
        cfg = self.models[model].cfg
        nf = len(OPERAND_FIELDS[cfg.kind])
        if part is not None:
            return estimate_shard_entry_bytes(part.shards, part.shard_cap,
                                              part.full_rows, nf,
                                              cfg.in_feats)
        return estimate_dense_entry_bytes(nf, pg.capacity)

    @staticmethod
    def _shard_entry_nbytes(slices: Tuple[ShardSlice, ...]) -> int:
        """Measured device bytes of a sharded slice-tuple entry (ShardSlice
        is a plain dataclass, not a pytree — sum its array members)."""
        return sum(pytree_nbytes((s.x, s.ops, s.node_mask)) for s in slices)

    def _operand_spill_fn(self, graph_id: int, ver: int, model: str):
        """Eviction-time producer of the §13 host-RAM spill form: re-packs
        the CacheG compact `HostOperands` from the graph's CURRENT host
        snapshot (SymG bit-packed, ~64x smaller than the dense fp32 entry;
        SAGE re-samples under the same seeded default rng, so the packed
        mask reproduces the evicted operands bit-for-bit). Called by the
        manager under the engine `_lock`. Declines — the entry is dropped
        and the next miss runs the full build — when the version moved on,
        the graph detached or went sharded, or the pack fell back to the
        eager dense form (directed structure: nothing compact to keep)."""
        def _spill():
            if (self._graph_version.get(graph_id) != ver
                    or graph_id in self._sharded):
                return None
            entry = self.graphs.get(graph_id)
            if entry is None:
                return None
            ho = prepare_host_operands(entry[1], self.models[model].cfg,
                                       use_cacheg=True)
            return None if ho.fallback else ho
        return _spill

    # ------------------------------------------------------------------ setup
    def register_model(self, name: str, cfg: GNNConfig, params: Optional[Dict] = None,
                       *, techniques: Optional[Techniques] = None,
                       tiers=None, default_tier: str = "fp32",
                       agg_backend: str = "dense",
                       fusion: str = "none") -> None:
        """Register a model with its quality-tier registry.

        `tiers` may be: None (single-tier registry {"fp32": techniques or
        DEFAULT_TECHNIQUES}); a sequence of STANDARD_TIERS names (resolved
        through `tier_techniques(cfg.kind)`); or a full {name: Techniques}
        dict. The registry must always contain "fp32" — it is the accuracy
        reference and the calibration-fallback target, not just a tier.

        `agg_backend` picks the model's GraSp dispatch mode (DESIGN.md
        §10): "dense" (default — never block-sparse), "auto" (per-graph
        density/cost rule), or "grasp" (forced where the structure fits the
        bucket budget; ineligible graphs serve dense, counted in
        `backend_fallbacks`). Only GCN aggregation has a block-sparse form
        today — other kinds (and QuantGr tiers, whose aggregation is the
        cached int8 Â) always resolve dense, so a non-"dense" mode on them
        is a no-op, not an error.

        `fusion` picks the model's DEFAULT fused-layer mode (DESIGN.md
        §11): "none" (per-op dispatch) or "layer" (one fused Pallas kernel
        per GNN layer). Requests may override it per call
        (`query(gid, fusion=...)`); warmup pre-traces both modes either
        way, so the default is a routing preference, not a compile
        commitment.
        """
        import jax
        if params is None:
            params = init_params(jax.random.PRNGKey(self.seed), cfg)
        if tiers is None:
            registry = {"fp32": techniques if techniques is not None
                        else DEFAULT_TECHNIQUES[cfg.kind]}
        else:
            if techniques is not None:
                raise ValueError(
                    "pass per-tier Techniques inside `tiers`, not both "
                    "`techniques` and `tiers`")
            if isinstance(tiers, dict):
                registry = dict(tiers)
            else:
                std = tier_techniques(cfg.kind)
                unknown = [tn for tn in tiers if tn not in std]
                if unknown:
                    raise ValueError(
                        f"unknown standard tier name(s) {unknown}; pick "
                        f"from {sorted(std)} or pass a "
                        f"{{name: Techniques}} dict")
                registry = {tn: std[tn] for tn in tiers}
        if "fp32" not in registry:
            raise ValueError("tier registry must include 'fp32' (the "
                             "accuracy reference / calibration fallback)")
        if registry["fp32"].quantgr:
            # the fallback tier must be servable UNCALIBRATED: a QuantGr
            # fp32 tier would fall back to itself and execute its plan with
            # quant=None, flipping the trace structure warmup compiled
            raise ValueError("the 'fp32' tier cannot enable QuantGr — it "
                             "is the uncalibrated-fallback path; register "
                             "quantized variants under another tier name")
        if default_tier not in registry:
            raise ValueError(f"default tier {default_tier!r} not in "
                             f"{sorted(registry)}")
        if agg_backend not in AGG_BACKEND_MODES:
            raise ValueError(f"unknown agg_backend mode {agg_backend!r}; "
                             f"pick from {AGG_BACKEND_MODES}")
        if fusion not in FUSION_MODES:
            raise ValueError(f"unknown fusion mode {fusion!r}; "
                             f"pick from {FUSION_MODES}")
        self.models[name] = _ModelEntry(cfg=cfg, params=params,
                                        tiers=registry,
                                        default_tier=default_tier,
                                        agg_backend=agg_backend,
                                        default_fusion=fusion,
                                        name=name)

    def _modelled_batch_s(self, model: str, bucket: int, tier: str,
                          backend: str, shards: int) -> float:
        """Roofline seed for the latency bank (§14): modelled seconds for
        ONE dispatch under this key. Two-layer GNN forward priced with the
        same MXU/HBM constants as `agg_cost_model`: per layer one dense
        (cap, cap) @ (cap, w) aggregation plus the (cap, w_in) @ (w_in,
        w_out) combine, times the batch width. Backend/tier scaling is
        deliberately coarse (grasp halves the aggregation term, int8 runs
        combines at the 2x rate over quarter bytes): the seed only has to
        ORDER cold keys — the first measured sample replaces it outright,
        and `ewma_vs_model` in `summary()` tracks how wrong it was."""
        cfg = self.models[model].cfg
        widths = [cfg.in_feats, cfg.hidden, cfg.num_classes]
        b = self.sc.replica_groups if shards else self.sc.batch_slots
        cap = bucket
        quant = self.models[model].tiers[tier].quantgr
        total = 0.0
        for w_in, w_out in zip(widths[:-1], widths[1:]):
            agg_flops = 2.0 * cap * cap * w_in
            agg_bytes = 4.0 * (cap * cap + 2 * cap * w_in)
            agg = max(agg_flops / MXU_RATE, agg_bytes / HBM_BW)
            if backend == "grasp":
                agg *= 0.5
            comb_flops = 2.0 * cap * w_in * w_out
            comb_bytes = 4.0 * cap * (w_in + w_out) + 4.0 * w_in * w_out
            rate, byte_scale = ((2.0 * MXU_RATE, 0.25) if quant
                                else (MXU_RATE, 1.0))
            comb = max(comb_flops / rate, comb_bytes * byte_scale / HBM_BW)
            total += agg + comb
        return total * b

    def _bank_key(self, model: str, bucket: int, tier: str, backend: str,
                  fusion: str, shards: int) -> BatchKey:
        return (model, bucket, tier, backend, fusion, shards)

    def _seed_bank(self, model: str, bucket: int, tier: str, backend: str,
                   fusion: str, shards: int) -> None:
        key = self._bank_key(model, bucket, tier, backend, fusion, shards)
        self.bank.seed(key, self._modelled_batch_s(model, bucket, tier,
                                                   backend, shards))

    def plan_for(self, model: str, bucket: int, tier: Optional[str] = None,
                 backend: str = "dense", fusion: str = "none",
                 shards: int = 0) -> ExecutionPlan:
        # keyed by the plan's full identity, not the (model, tier) names:
        # params and calibrations are runtime args, so models/tiers with
        # identical (cfg, techniques, backend, fusion, shards) share one
        # compiled blob per bucket
        e = self.models[model]
        tier_name = tier if tier is not None else e.default_tier
        t = e.tiers[tier_name]
        # §14: every plan resolution (warmup included) seeds the latency
        # bank's modelled figure for its batch key, so routing has a cost
        # ordering before the first measured sample lands
        self._seed_bank(model, bucket, tier_name,
                        "dense" if shards else backend,
                        "none" if shards else fusion, shards)
        if shards:
            # sharded plans (§12) are dense/unfused single-graph dispatches
            # — the shard axis occupies the leading dim, so batch is 0 and
            # `bucket` is the PER-SHARD capacity
            key: PlanKey = (e.cfg, bucket, 0, t, "dense", "none", shards)
            if key not in self._plans:
                self._plans[key] = build_sharded_plan(
                    e.cfg, bucket, shards, t,
                    compress=self.sc.halo_compress,
                    replicas=self.sc.replica_groups)
            return self._plans[key]
        key = (e.cfg, bucket, self.sc.batch_slots, t, backend, fusion, 0)
        if key not in self._plans:
            self._plans[key] = build_plan(e.cfg, bucket, t,
                                          batch_size=self.sc.batch_slots,
                                          backend=backend, fusion=fusion)
        return self._plans[key]

    @property
    def compiled_blobs(self) -> int:
        """Actual jit traces: all plans + the CacheG materializer (one trace
        per bucket × operand-fieldset) + the tier-operand deriver (one per
        bucket with a QuantGr GCN tier) + the GraSp block compactor (two
        per bucket with a grasp-capable model — the counts reduction and
        the full gather) + the GrAd delta patcher (one per bucket ×
        GCN/GAT fieldset, plus one row-requant trace per bucket with a
        QuantGr GCN tier, when `delta_pad_rows > 0`), all compiled during
        warmup."""
        return (sum(p.trace_count for p in self._plans.values())
                + self._materializer.trace_count
                + self._agg_quantizer.trace_count
                + self._block_compactor.trace_count
                + self._delta_patcher.trace_count)

    def warmup(self, *, buckets: Optional[Tuple[int, ...]] = None) -> int:
        """Compile every (model, bucket, tier, backend, fusion) plan — and,
        with CacheG enabled, every (bucket, fieldset) materializer — once
        with placeholder inputs. BOTH fusion modes warm per (tier,
        backend): fusion is a per-request plan dimension (DESIGN.md §11),
        so mixed fused/unfused traffic must replay warm exactly like mixed
        tiers and backends do.

        QuantGr tiers not yet calibrated warm against a THROWAWAY
        calibration built from the placeholder graph: `calibrate_tier`'s
        pytree structure depends only on the model config, so the trace
        compiled here replays warm when the real calibration arrives — the
        placeholder is never stored, and an uncalibrated tier still falls
        back to fp32 at query time.

        Models with a non-"dense" `agg_backend` additionally warm the
        GraSp side per (bucket, non-quant tier): the per-bucket block
        compactor plus the grasp-backend plan, called with a placeholder
        block structure at the bucket's `grasp_max_nnz` budget — so mixed
        dense/grasp traffic after warmup replays entirely warm however the
        per-graph rule routes it (DESIGN.md §10).

        With `shard_counts` configured, a final leg warms every sharded
        plan (shard count x bucket x tier, DESIGN.md §12) against
        placeholder shard slices, so a giant graph attaching AFTER warmup
        serves with zero new traces — the zero-recompile contract covers
        mixed sharded/unsharded traffic too.
        """
        buckets = buckets if buckets is not None else self.sc.ladder.buckets
        b = self.sc.batch_slots
        warm_cal: Dict[Tuple[str, str], Dict] = {}
        warmed: set = set()
        for bucket in buckets:
            empty = pad_graph(Graph(edge_index=np.zeros((2, 0), np.int32),
                                    num_nodes=1,
                                    features=np.zeros((1, 1), np.float32)),
                              capacity=bucket)
            for name, e in self.models.items():
                pg = dataclasses.replace(
                    empty, features=np.zeros((bucket, e.cfg.in_feats),
                                             np.float32))
                if self.sc.use_cacheg:
                    single = self._materializer(compact_operands(pg, e.cfg))
                else:
                    single = build_operands(pg, e.cfg, lean=True)
                ops = stack_operands([single] * b)
                x = jnp.zeros((b, bucket, e.cfg.in_feats), jnp.float32)
                ops_grasp = None
                if self._grasp_capable(e):
                    # placeholder block structure at the bucket budget —
                    # these calls also warm the per-bucket block compactor
                    # (both halves: the counts reduction the backend rule
                    # reads, and the full gather grasp graphs pay)
                    self._block_compactor.counts(single.norm_adj)
                    bsp, _ = self._block_compactor(
                        single.norm_adj, max_nnz=grasp_max_nnz(bucket))
                    ops_grasp = stack_operands(
                        [dataclasses.replace(single, block_sparse=bsp)] * b)
                for tier, t in e.tiers.items():
                    backends = ("dense",) if (ops_grasp is None or t.quantgr
                                              ) else ("dense", "grasp")
                    for backend in backends:
                        for fusion in FUSION_MODES:
                            # alias tiers (e.g. GCN int8+grax == int8)
                            # share a plan AND a calibration structure —
                            # exercising them again would just recompute
                            # placeholders for zero new traces
                            plan = self.plan_for(name, bucket, tier,
                                                 backend, fusion)
                            if (name, plan.key) in warmed:
                                continue
                            warmed.add((name, plan.key))
                            quant = e.calibrations.get(tier)
                            if quant is None and t.quantgr:
                                if (name, tier) not in warm_cal:
                                    x1 = jnp.zeros((bucket, e.cfg.in_feats),
                                                   jnp.float32)
                                    warm_cal[(name, tier)] = calibrate_tier(
                                        e.params, e.cfg, x1, single)
                                quant = warm_cal[(name, tier)]
                            tops = None
                            if self._needs_tier_ops(e, tier):
                                # also warms the per-bucket tier-operand
                                # deriver
                                tops = stack_tier_operands(
                                    [self._agg_quantizer(single.norm_adj)]
                                    * b)
                            out = plan(e.params, x,
                                       ops_grasp if backend == "grasp"
                                       else ops,
                                       quant, tops)
                            out.block_until_ready()
                self._warm_delta(e, bucket, single, warmed)
        for shards in sorted({int(s) for s in self.sc.shard_counts
                              if int(s) >= 2}):
            for bucket in buckets:
                for name, e in self.models.items():
                    # placeholder sharded calling convention: (S, C, F)
                    # features, (S, C, S*C) rectangular operand row blocks
                    # for the kind's fields, (1, 1) holes for the rest,
                    # all-pad node masks — shape identity is all a trace
                    # needs. With replica groups (§15) every shape gains
                    # the leading R dim the R-wide plan expects.
                    full = shards * bucket
                    lead = (() if self.sc.replica_groups == 1
                            else (self.sc.replica_groups,))
                    x = jnp.zeros((*lead, shards, bucket, e.cfg.in_feats),
                                  jnp.float32)
                    mask = jnp.zeros((*lead, shards, bucket), jnp.float32)
                    hole = jnp.zeros((*lead, shards, 1, 1), jnp.float32)
                    blk = jnp.zeros((*lead, shards, bucket, full),
                                    jnp.float32)
                    kind_fields = set(OPERAND_FIELDS[e.cfg.kind])
                    ops = GranniteOperands(**{
                        f: (blk if f in kind_fields else hole)
                        for f in ("norm_adj", "mask_mult", "bias_add",
                                  "sample_mask", "mean_mask")})
                    for tier, t in e.tiers.items():
                        plan = self.plan_for(name, bucket, tier,
                                             shards=shards)
                        if (name, plan.key) in warmed:
                            continue
                        warmed.add((name, plan.key))
                        quant = e.calibrations.get(tier)
                        if quant is None and t.quantgr:
                            # the dense leg above already built this
                            # placeholder calibration for every
                            # uncalibrated QuantGr tier
                            quant = warm_cal[(name, tier)]
                        out = plan(e.params, x, ops, quant,
                                   node_mask=mask)
                        out.block_until_ready()
                    if (self.sc.delta_pad_rows > 0
                            and e.cfg.kind in ("gcn", "gat")):
                        # sharded delta patch runs over the CONCATENATED
                        # (full, full) permuted operand matrices (§13) —
                        # one extra patcher trace per (full rows, fieldset)
                        fields = OPERAND_FIELDS[e.cfg.kind]
                        if ("delta", full, fields) not in warmed:
                            warmed.add(("delta", full, fields))
                            hole1 = jnp.zeros((1, 1), jnp.float32)
                            fmat = jnp.zeros((full, full), jnp.float32)
                            ph = GranniteOperands(**{
                                f: (fmat if f in kind_fields else hole1)
                                for f in ("norm_adj", "mask_mult",
                                          "bias_add", "sample_mask",
                                          "mean_mask")})
                            self._delta_patcher(
                                ph, self._placeholder_delta(full, fields))
        self._warm_blobs = self.compiled_blobs
        return self._warm_blobs

    def _delta_pads(self, cap: int) -> Tuple[int, int]:
        """(touched, flip) static pad widths of the delta patcher at one
        capacity — the §13 delta-vs-rebuild threshold in shape form."""
        kt = min(self.sc.delta_pad_rows, cap)
        return kt, 2 * kt

    def _placeholder_delta(self, cap: int, fields: Tuple[str, ...]
                           ) -> DeltaSpec:
        kt, ke = self._delta_pads(cap)
        return DeltaSpec(flip_i=jnp.zeros((ke,), jnp.int32),
                         flip_j=jnp.zeros((ke,), jnp.int32),
                         flip_v=jnp.zeros((ke,), jnp.float32),
                         touched=jnp.zeros((kt,), jnp.int32),
                         dirty=jnp.zeros((kt,), jnp.int32),
                         dis=jnp.zeros((cap,), jnp.float32), fields=fields)

    def _warm_delta(self, e: _ModelEntry, bucket: int,
                    single: GranniteOperands, warmed: set) -> None:
        """Warm the GrAd delta patcher for one (bucket, model): the operand
        patch trace per fieldset, plus the tier row-requant trace when a
        QuantGr GCN tier will keep a derived int8 Â to patch."""
        if (self.sc.delta_pad_rows <= 0 or not self.sc.use_cacheg
                or e.cfg.kind not in ("gcn", "gat")):
            return
        fields = OPERAND_FIELDS[e.cfg.kind]
        if ("delta", bucket, fields) not in warmed:
            warmed.add(("delta", bucket, fields))
            self._delta_patcher(single,
                                self._placeholder_delta(bucket, fields))
        if (any(self._needs_tier_ops(e, tn) for tn in e.tiers)
                and ("delta_tier", bucket) not in warmed):
            warmed.add(("delta_tier", bucket))
            self._delta_patcher.patch_tier(
                self._agg_quantizer(single.norm_adj), single.norm_adj,
                jnp.zeros((min(2 * self.sc.delta_pad_rows, bucket),),
                          jnp.int32))

    def assert_warm(self) -> None:
        """The zero-recompile contract (mirrors the LM server's assertion)."""
        assert self._warm_blobs is not None, "call warmup() first"
        assert self.compiled_blobs == self._warm_blobs, (
            f"recompile after warmup: {self.compiled_blobs} traces vs "
            f"{self._warm_blobs} at warmup")

    # ------------------------------------------------------------- calibration
    def calibrate(self, model: str, g: Graph, *,
                  force: bool = False) -> Dict[str, float]:
        """Per-(model, tier) QuantGr calibration + quality audit.

        Runs one fp32 forward over `g` to record each QuantGr tier's static
        activation scales (`core.models.calibrate_tier`) — once per (model,
        tier); re-calling with another graph is a true no-op unless
        `force=True` (scales AND the audited deltas both keep their first
        graph), because swapping scales mid-traffic would silently change
        every tenant's numerics and re-auditing on a different graph would
        silently change the advertised quality numbers. Every non-fp32
        tier gets its `accuracy_delta_vs_fp32` measured against the fp32
        tier on the held-out part of `g` (test_mask when labeled, argmax
        agreement otherwise), in percentage points. Pure value work: no
        new traces, `assert_warm()` still holds afterwards.
        """
        return self._calibrate(model, self.sc.ladder.pad(g), force=force)

    def _calibrate(self, model: str, pg: PaddedGraph, *,
                   force: bool = False) -> Dict[str, float]:
        e = self.models[model]
        x = jnp.asarray(pg.features)
        ops = base = None
        # alias tiers (equal Techniques, e.g. GCN int8+grax == int8) share
        # one calibration pytree and one audit forward, like they share a plan
        done_cal: Dict[Techniques, Dict] = {}
        done_delta: Dict[Techniques, float] = {}
        for tier, t in e.tiers.items():
            if tier == "fp32" or (not force and tier in e.accuracy_delta
                                  and (not t.quantgr
                                       or tier in e.calibrations)):
                continue
            if t in done_delta:
                if t.quantgr:
                    e.calibrations[tier] = done_cal[t]
                e.accuracy_delta[tier] = done_delta[t]
                continue
            if ops is None:
                ops = build_operands(pg, e.cfg, lean=True)
                base = forward_grannite(e.params, e.cfg, x, ops,
                                        e.tiers["fp32"])
            if t.quantgr:
                if force or tier not in e.calibrations:
                    e.calibrations[tier] = calibrate_tier(e.params, e.cfg,
                                                          x, ops)
                done_cal[t] = e.calibrations[tier]
            out = forward_grannite(e.params, e.cfg, x, ops, t,
                                   quant=e.calibrations.get(tier))
            done_delta[t] = _delta_points(base, out, pg)
            e.accuracy_delta[tier] = done_delta[t]
        return dict(e.accuracy_delta)

    def _resolve_tier(self, model: str, tier: Optional[str]) -> str:
        """Requested tier -> served tier: model default when unspecified,
        fp32 fallback (counted, never an error) for an uncalibrated QuantGr
        tier — a tenant asking for int8 before anyone calibrated should get
        correct-but-slower answers, not a 500."""
        e = self.models[model]
        tier = tier if tier is not None else e.default_tier
        if tier not in e.tiers:
            raise KeyError(f"model {model!r} has no tier {tier!r} "
                           f"(registered: {sorted(e.tiers)})")
        if e.tiers[tier].quantgr and tier not in e.calibrations:
            self._count("tier_fallbacks")
            return "fp32"
        return tier

    def _tier_for_tolerance(self, model: str, tolerance: float,
                            bucket: int) -> str:
        """Tolerance tier router (§14): the cheapest SERVABLE tier whose
        measured accuracy delta fits the request's tolerance (percentage
        points vs fp32). Candidates: fp32 always (delta 0 by definition),
        plus every tier with a MEASURED delta within tolerance that is
        also servable right now (QuantGr ⇒ calibrated — the router never
        selects a tier `_resolve_tier` would bounce, so the fallback
        contract is preserved by construction, not by luck). Cost is the
        latency bank's prediction at this bucket — measured EWMA when
        samples exist, roofline seed otherwise; an unpredictable tier
        ranks last. fp32 leads the candidate list, so a cost tie (e.g.
        totally cold bank) degrades to the exact path."""
        e = self.models[model]
        cands = ["fp32"]
        for tn in e.tiers:
            if tn == "fp32":
                continue
            delta = e.accuracy_delta.get(tn)
            if delta is None or abs(delta) > tolerance:
                continue
            if e.tiers[tn].quantgr and tn not in e.calibrations:
                continue
            cands.append(tn)

        def cost(tn: str) -> float:
            # MEASURED latencies trump seeds within a tier: once any of
            # the tier's execution variants has real samples, an
            # optimistic roofline seed on a sibling variant cannot mask a
            # measured slowdown. Across tiers the comparison may still mix
            # measured vs seed — that is the cold-start contract.
            m_best, s_best = None, None
            for key in self.bank.keys():
                if key[0] != model or key[1] != bucket or key[2] != tn:
                    continue
                m = self.bank.measured(key)
                if m is not None:
                    m_best = m if m_best is None else min(m_best, m)
                else:
                    p = self.bank.predict(key)
                    if p is not None:
                        s_best = p if s_best is None else min(s_best, p)
            if m_best is not None:
                return m_best
            return s_best if s_best is not None else float("inf")

        return min(cands, key=lambda tn: (cost(tn), cands.index(tn)))

    def _route_tier(self, model: str, tier: Optional[str],
                    tolerance: Optional[float], bucket: int) -> str:
        """Requested (tier, tolerance) -> served tier (§14). An explicit
        tier is a contract: it resolves exactly as before (fallback
        included) and tolerance/governor never override it. A tolerance
        with no tier runs the tolerance router. Neither -> the governor
        (when configured) may downgrade the model default; its pick still
        flows through `_resolve_tier`, so an uncalibrated downgrade target
        falls back to fp32, counted, instead of erroring."""
        if tier is None and tolerance is not None:
            tier = self._tier_for_tolerance(model, tolerance, bucket)
        elif tier is None and self.governor is not None:
            e = self.models[model]
            tier = self.governor.tier_override(e.default_tier, list(e.tiers))
        return self._resolve_tier(model, tier)

    def _resolve_fusion(self, model: str, fusion: Optional[str]) -> str:
        """Requested fusion mode -> served mode: model default when
        unspecified; an unknown name is a caller error (unlike tier
        fallback, there is no quality ladder to degrade along)."""
        fusion = (fusion if fusion is not None
                  else self.models[model].default_fusion)
        if fusion not in FUSION_MODES:
            raise ValueError(f"unknown fusion mode {fusion!r}; "
                             f"pick from {FUSION_MODES}")
        return fusion

    @staticmethod
    def _needs_tier_ops(e: _ModelEntry, tier: str) -> bool:
        """GCN QuantGr tiers consume a per-graph derived operand (the int8
        Â); every other (kind, tier) passes None — consistently per plan,
        so the trace structure never flips."""
        return e.cfg.kind == "gcn" and e.tiers[tier].quantgr

    @staticmethod
    def _grasp_capable(e: _ModelEntry) -> bool:
        """Whether this model can ever dispatch the GraSp backend: a
        non-"dense" mode AND a kind whose aggregation has a block-sparse
        form (GCN's Â @ H today)."""
        return e.agg_backend != "dense" and e.cfg.kind == "gcn"

    def _measured_agg_pair(self, model: str, capacity: int
                           ) -> Tuple[Optional[float], Optional[float]]:
        """Best MEASURED batch latency per agg backend at (model, bucket),
        from the §14 latency bank — the hardware-in-the-loop input to
        `select_agg_backend`. None on either side until that backend has
        served a real dispatch here, which keeps the override inert (the
        roofline decides) for cold paths."""
        best = self.bank.measured_pair(
            match=lambda k: k[0] == model and k[1] == capacity,
            backend_of=lambda k: k[3])
        return best.get("dense"), best.get("grasp")

    def _backend_from_stats(self, e: _ModelEntry, capacity: int,
                            stats: Dict) -> str:
        """Run the density/cost rule (DESIGN.md §10) for one graph at one
        bucket, preferring MEASURED costs (§14) where both backends have
        served here before. Pure decision — `backend_fallbacks`
        accounting happens per REQUEST at the resolution sites (mirroring
        how `tier_fallbacks` counts), never here, so cached decisions and
        fresh ones count identically."""
        mode = "grasp" if e.agg_backend == "grasp" else "auto"
        choice, _, _ = select_agg_backend(
            capacity, e.cfg.hidden, nnz_blocks=stats["nnz_blocks"],
            max_row_nnz=stats["max_row_nnz"], mode=mode,
            measured=self._measured_agg_pair(e.name, capacity))
        return choice

    def _count_forced_fallback(self, e: _ModelEntry, backend: str) -> None:
        """One REQUEST under a forced-grasp model resolved dense (its
        structure exceeds the bucket budget): count it, per request —
        asked for sparse, quietly ran dense, so it must be observable
        (`backend_fallbacks`, same unit as `tier_fallbacks`)."""
        if e.agg_backend == "grasp" and backend == "dense":
            self._count("backend_fallbacks")

    def _derive_grasp(self, e: _ModelEntry, capacity: int, norm_adj
                      ) -> Tuple[str, object]:
        """Counts-first device-side derivation shared by the cached query
        path and one-shot compact submits: one cheap jitted bitmap
        reduction feeds the backend rule, and ONLY a grasp-routed graph
        pays the full block gather — so eligibility is always judged
        against the exact (materialized) Â the gather would read, and a
        dense-routed decision costs a reduction, not a structure."""
        ct = np.asarray(self._block_compactor.counts(norm_adj))
        stats = {"nnz_blocks": int(ct.sum()),
                 "max_row_nnz": int(ct.max()) if ct.size else 0}
        backend = self._backend_from_stats(e, capacity, stats)
        bsp = None
        if backend == "grasp":
            bsp, _ = self._block_compactor(norm_adj,
                                           max_nnz=grasp_max_nnz(capacity))
        return backend, bsp

    def _resolve_and_build(self, model: str, tier: str, pg: PaddedGraph
                           ) -> Tuple[str, GranniteOperands]:
        """One-shot intake: resolve this request's agg backend AND build
        its device-resident operands, deriving the backend rule's inputs
        wherever they are cheapest. QuantGr tiers aggregate through the
        cached int8 Â, not the fp32 matmul, so they always resolve dense
        without any scan. On the CacheG compact path the decision comes
        from the jitted counts reduction over the MATERIALIZED Â — no
        host O(cap²) pass, and eligibility is checked against the exact
        matrix the block gather reads (same discipline as prepare_query).
        The eager path decides from host-side `block_stats`, whose bitmap
        the host block build then reuses instead of re-scanning."""
        e = self.models[model]
        if not self._grasp_capable(e) or e.tiers[tier].quantgr:
            return "dense", self._device_operands(model, pg)
        from repro.core.graph import is_symmetric_adjacency
        if self.sc.use_cacheg and is_symmetric_adjacency(pg.adj):
            # compact + materialize (symmetry already checked, scan once)
            ops = self._device_operands(model, pg, symmetric=True)
            backend, bsp = self._derive_grasp(e, pg.capacity, ops.norm_adj)
            self._count_forced_fallback(e, backend)
            if backend == "grasp":
                ops = dataclasses.replace(ops, block_sparse=bsp)
            return backend, ops
        stats = block_stats(pg.norm_adj)
        backend = self._backend_from_stats(e, pg.capacity, stats)
        self._count_forced_fallback(e, backend)
        return backend, self._device_operands(
            model, pg, backend=backend, grasp_bitmap=stats["bitmap"],
            symmetric=False if self.sc.use_cacheg else None)

    # ------------------------------------------------------------------ intake
    def _device_operands(self, model: str, pg: PaddedGraph, *,
                         backend: str = "dense", grasp_bitmap=None,
                         symmetric: Optional[bool] = None
                         ) -> GranniteOperands:
        """Build one graph's device-resident operands: the HOST stage
        (`prepare_host_operands` — CacheG compact packing, or the eager
        dense build for directed GCN/GAT graphs, counted as
        `cacheg_fallbacks`) followed immediately by the DEVICE stage
        (`realize_operands`). The pipeline scheduler runs the same two
        calls, just on a host worker thread.

        A grasp-backend request on the eager host path additionally
        builds and ships the block structure here (`HostOperands.grasp`,
        bytes counted, DESIGN.md §10). Compact-path grasp derivation does
        NOT happen here: the callers that own it (`_resolve_and_build`,
        `prepare_query`) run the device-side counts check first, so no
        structure is ever gathered without its eligibility verified
        against the same materialized Â."""
        budget = grasp_max_nnz(pg.capacity) if backend == "grasp" else None
        ho = prepare_host_operands(pg, self.models[model].cfg,
                                   use_cacheg=self.sc.use_cacheg,
                                   grasp_max_nnz=budget,
                                   grasp_bitmap=grasp_bitmap,
                                   symmetric=symmetric)
        self._count("operand_bytes_h2d", ho.nbytes)
        if ho.fallback:
            self._count("cacheg_fallbacks")
        return realize_operands(ho, self._materializer)

    def _prepare(self, model: str, pg: PaddedGraph,
                 ops: Optional[GranniteOperands] = None, *,
                 tier: Optional[str] = None,
                 tier_ops: Optional[TierOperands] = None,
                 tier_resolved: bool = False,
                 backend: Optional[str] = None,
                 fusion: Optional[str] = None,
                 submitted_s: Optional[float] = None,
                 deadline_ms: Optional[float] = None,
                 tolerance: Optional[float] = None) -> GNNRequest:
        """Host-stage tail shared by every intake path: resolve the tier
        (router-aware, §14), agg backend, and fusion mode, realize
        operands if the caller didn't, assign the uid. Returns the
        ready-to-dispatch request WITHOUT touching the
        engine queue — the sync path pushes it (`_push`), the pipeline
        scheduler hands it to its own ready stage. `submitted_s` lets the
        scheduler pin latency accounting to intake time (queue wait
        included) rather than to host-stage completion; `deadline_ms` is
        RELATIVE to that same submit instant, so queue wait spends the
        budget."""
        now = self.clock.now()
        submitted_s = submitted_s if submitted_s is not None else now
        if not tier_resolved:
            tier = self._route_tier(model, tier, tolerance, pg.capacity)
        fusion = self._resolve_fusion(model, fusion)
        if backend is None:
            backend, ops = self._resolve_and_build(model, tier, pg)
        elif ops is None:
            # a resolved backend implies the caller owned the grasp
            # derivation discipline (counts-checked structure attached for
            # grasp, none for dense) — building operands here would skip it
            raise ValueError("callers resolving the backend themselves "
                             "must pass the operands they derived it for")
        if tier_ops is None and self._needs_tier_ops(self.models[model], tier):
            # one-shot request: derive without caching (nothing to key on)
            tier_ops = self._agg_quantizer(ops.norm_adj)
        with self._lock:
            uid = self._uid
            self._uid += 1
            if self.metrics["first_submit_s"] is None:
                self.metrics["first_submit_s"] = submitted_s
        deadline_s = (submitted_s + deadline_ms * 1e-3
                      if deadline_ms is not None else None)
        return GNNRequest(uid=uid, model=model, pg=pg, ops=ops,
                          bucket=pg.capacity, submitted_s=submitted_s,
                          tier=tier, backend=backend, fusion=fusion,
                          tier_ops=tier_ops, deadline_s=deadline_s,
                          tolerance=tolerance)

    def _push(self, req: GNNRequest) -> int:
        self.queue.append(req)
        return req.uid

    def prepare_submit(self, g: Graph, *, model: str,
                       tier: Optional[str] = None,
                       fusion: Optional[str] = None,
                       submitted_s: Optional[float] = None,
                       deadline_ms: Optional[float] = None,
                       tolerance: Optional[float] = None) -> GNNRequest:
        """HOST stage of a one-shot request: NodePad padding + operand
        build/packing. Scheduler-callable from any worker thread."""
        return self._prepare(model, self.sc.ladder.pad(g), tier=tier,
                             fusion=fusion, submitted_s=submitted_s,
                             deadline_ms=deadline_ms, tolerance=tolerance)

    def submit(self, g: Graph, *, model: str,
               tier: Optional[str] = None,
               fusion: Optional[str] = None,
               deadline_ms: Optional[float] = None,
               tolerance: Optional[float] = None) -> int:
        """One-shot inference request over a static graph. `deadline_ms`
        (relative to now) and `tolerance` (max accuracy points traded by
        the tier router) opt the request into the §14 SLO machinery."""
        return self._push(self.prepare_submit(g, model=model, tier=tier,
                                              fusion=fusion,
                                              deadline_ms=deadline_ms,
                                              tolerance=tolerance))

    def attach(self, g: Graph, *, model: str, calibrate: bool = True) -> int:
        """Register an evolving graph; returns a graph_id for update/query.

        Operands materialize lazily on the first `query()` and stay cached
        on device until `update()` changes the structure. The first attach
        to a model with uncalibrated non-fp32 tiers also runs the (model,
        tier) calibration on this graph (`calibrate=False` to defer to an
        explicit `calibrate()` call).

        A graph exceeding the TOP ladder bucket auto-shards (§12) when
        `shard_counts` is configured: `partition_for_ladder` picks the
        smallest configured shard count whose balanced per-shard load
        admits into the ladder, and every query over this graph_id
        dispatches through the sharded plan. Without `shard_counts` the
        oversized graph raises, exactly as before.

        With `device_cache_budget_bytes` set, attach() is the admission
        gate (§13): a graph whose projected primary operand entry can
        NEVER fit the budget raises `CacheAdmissionError` outright; under
        `admission="reject"` one that would overflow the CURRENT residency
        raises too, while the default `admission="evict"` admits it and
        lets insert-time eviction make room on first query."""
        part = None
        try:
            pg = self.sc.ladder.pad(g)
        except ValueError:
            if not self.sc.shard_counts:
                raise
            part = partition_for_ladder(g.edge_index, g.num_nodes,
                                        self.sc.ladder,
                                        self.sc.shard_counts,
                                        method=self.sc.partition_method)
            pg = pad_graph(g, capacity=part.full_rows)
        if self.sc.device_cache_budget_bytes is not None:
            projected = self._projected_primary_bytes(model, pg, part)
            with self._lock:
                reject = (not self._cache.fits(projected)
                          or (self.sc.admission == "reject"
                              and self._cache.would_overflow(projected)))
                if reject:
                    self.metrics["cache_admission_rejects"] += 1
            if reject:
                raise CacheAdmissionError(
                    f"graph with projected primary operand entry of "
                    f"{projected} bytes cannot be admitted under "
                    f"device_cache_budget_bytes="
                    f"{self.sc.device_cache_budget_bytes} "
                    f"(policy {self.sc.admission!r}, "
                    f"{self._cache.resident_bytes} resident)")
        if calibrate:
            self._calibrate(model, pg)      # no-op once (model, tier) is done
        with self._lock:
            gid = self._gid
            self._gid += 1
            self.graphs[gid] = (model, pg)
            self._graph_version[gid] = 0
            if part is not None:
                self._sharded[gid] = (part, g)
        return gid

    def detach(self, graph_id: int) -> None:
        """Release an attached graph and its device-resident operands.

        The cache pins O(cap²) float32 per attached graph in device memory
        (~32 MB for GAT at cap=2048), plus O(cap²) int8 per graph that took
        a QuantGr GCN tier. Without a `device_cache_budget_bytes` the
        manager never evicts, so long-running unbudgeted multi-tenant
        servers must detach graphs they stop serving; WITH a budget (§13)
        cost-aware LRU eviction bounds residency instead, and detach is
        how a tenant's spilled host-RAM form is released too. Lifecycle
        removal is not an eviction: detaching touches no eviction/spill
        counter.
        """
        with self._lock:
            key = (graph_id, self._graph_version.pop(graph_id, -1))
            self._cache.invalidate(key)
            self._sharded.pop(graph_id, None)
            self.graphs.pop(graph_id, None)

    def update(self, graph_id: int, edge_index: np.ndarray, num_nodes: int,
               features: np.ndarray) -> bool:
        """GrAd update of an attached graph; True if it climbed the ladder.

        Bumps the structure version, which invalidates the CacheG operand
        cache — the next `query()` re-materializes exactly once.

        Sharded graphs (§12) re-partition on every structure update (the
        edge-cut depends on the edges): an unchanged (shard count, shard
        bucket) pair is a pure value update like the unsharded case, a
        changed one counts as a rebucket. A graph that shrinks back into
        the ladder leaves the sharded path; an unsharded graph that grows
        past the top bucket enters it (rebucket either way)."""
        with self._lock:
            model, pg = self.graphs[graph_id]
            sharded = self._sharded.get(graph_id)
        new_sharded = None
        if sharded is not None:
            part, g_old = sharded

            # carry supervision arrays across the size change (same policy
            # as BucketLadder.grow): new nodes are unlabeled, shrinks
            # truncate — a stale (old-length) labels array would break
            # padding the first time a sharded graph changes size
            def _resized(arr, fill, dtype):
                if arr is None:
                    return None
                out = np.full((num_nodes,), fill, dtype=dtype)
                m = min(num_nodes, len(arr))
                out[:m] = arr[:m]
                return out

            g2 = Graph(edge_index=edge_index, num_nodes=num_nodes,
                       features=features,
                       labels=_resized(g_old.labels, -1, np.int32),
                       train_mask=_resized(g_old.train_mask, False, bool),
                       test_mask=_resized(g_old.test_mask, False, bool))
            try:
                pg = self.sc.ladder.pad(g2)
                rebucketed = True           # shrank back into the ladder
            except ValueError:
                part2 = partition_for_ladder(g2.edge_index, g2.num_nodes,
                                             self.sc.ladder,
                                             self.sc.shard_counts,
                                             method=self.sc.partition_method)
                pg = pad_graph(g2, capacity=part2.full_rows)
                new_sharded = (part2, g2)
                rebucketed = ((part2.shards, part2.shard_cap)
                              != (part.shards, part.shard_cap))
        else:
            try:
                pg, rebucketed = self.sc.ladder.grow(pg, edge_index,
                                                     num_nodes, features)
            except ValueError:
                if not self.sc.shard_counts:
                    raise
                # grew off the top of the ladder: enter the sharded path
                g2 = Graph(edge_index=edge_index, num_nodes=num_nodes,
                           features=features)
                part2 = partition_for_ladder(g2.edge_index, g2.num_nodes,
                                             self.sc.ladder,
                                             self.sc.shard_counts,
                                             method=self.sc.partition_method)
                pg = pad_graph(g2, capacity=part2.full_rows)
                new_sharded = (part2, g2)
                rebucketed = True
        with self._lock:
            self.graphs[graph_id] = (model, pg)
            ver = self._graph_version[graph_id]
            # lifecycle invalidation, not eviction: a no-op on keys the
            # graph never populated (attach-then-update before any query),
            # and never counted in the §13 eviction/spill metrics
            self._cache.invalidate((graph_id, ver))
            if new_sharded is not None:
                self._sharded[graph_id] = new_sharded
            else:
                self._sharded.pop(graph_id, None)
            self._graph_version[graph_id] = ver + 1
            if rebucketed:
                self.metrics["rebucket_events"] += 1
        return rebucketed

    # ---------------------------------------------------- GrAd delta updates
    def _delta_spec(self, cap: int, fields: Tuple[str, ...], flip_i, flip_j,
                    flip_v, touched, dis, dirty=None) -> DeltaSpec:
        """Pad one host-computed edge delta to the engine's static patcher
        widths (§13): flips to K_e, touched rows to K_t, both by REPEATING
        the first entry — duplicate-index scatters write identical values
        and duplicate row renorms recompute the same bits, so the pads are
        numerically inert and the trace count stays bounded. `dirty` (§15)
        is the boundary-dirty subset a sharded halo-delta exchange must
        move; it pads to K_t repeating a touched row (the patch math never
        reads it, and a duplicate dirty row re-sends the same bits), and
        an unsharded delta — or one confined to shard interiors — carries
        the inert all-touched[0] pad."""
        kt, ke = self._delta_pads(cap)

        def _pad(a, k, dtype):
            out = np.full((k,), a[0], dtype=dtype)
            out[:len(a)] = a
            return jnp.asarray(out)

        d = np.asarray(dirty if dirty is not None and len(dirty)
                       else touched[:1])
        return DeltaSpec(flip_i=_pad(flip_i, ke, np.int32),
                         flip_j=_pad(flip_j, ke, np.int32),
                         flip_v=_pad(flip_v, ke, np.float32),
                         touched=_pad(touched, kt, np.int32),
                         dirty=_pad(d, kt, np.int32),
                         dis=jnp.asarray(dis.astype(np.float32)),
                         fields=fields)

    def _requant_rows(self, delta, cap: int):
        """Rows of the int8 Â a delta forces through re-quantization:
        touched rows themselves plus every row adjacent (new structure) to
        a touched node — their entries rescale with the touched dis even
        though their own degree is unchanged. Returns the padded row index
        vector, or None when the set exceeds the warmed width K_r (the
        caller re-quantizes the full matrix through the per-bucket
        `_agg_quantizer` instead — also warm)."""
        kr = min(2 * self.sc.delta_pad_rows, cap)
        neigh = np.flatnonzero(delta.adj[:, delta.touched].any(axis=1))
        rows = np.union1d(delta.touched, neigh).astype(np.int64)
        if len(rows) > kr:
            return None
        out = np.full((kr,), rows[0], np.int32)
        out[:len(rows)] = rows
        return jnp.asarray(out)

    def _patch_shard_slices(self, e: _ModelEntry, part: GraphShards,
                            slices: Tuple[ShardSlice, ...], delta,
                            dirty: Optional[np.ndarray] = None
                            ) -> Tuple[ShardSlice, ...]:
        """Device-patch a sharded slice tuple (§13): concatenate the shard
        row blocks back into the (full, full) permuted operand matrices,
        run the SAME warm patch trace in SLOT coordinates (flip/touched
        indices through the inverse permutation, dis permuted), re-slice.
        Features and node masks are untouched — an edge delta moves no
        nodes and the partition is deliberately KEPT (a fresh partition
        would reshuffle slots and force a full rebuild, defeating the
        patch). `dirty` (§15) is the boundary-dirty row set in ORIGINAL
        node ids; it rides the spec in slot coordinates — the set a
        distributed deployment would push through
        `dist.compress.compressed_psum_delta` instead of re-exchanging
        full halos, and what the engine's `delta_halo_bytes_*` counters
        price."""
        full, c = part.full_rows, part.shard_cap
        invperm = np.empty((full,), np.int64)
        invperm[part.perm] = np.arange(full)
        fields = OPERAND_FIELDS[e.cfg.kind]
        spec = self._delta_spec(full, fields,
                                invperm[delta.flip_i].astype(np.int64),
                                invperm[delta.flip_j].astype(np.int64),
                                delta.flip_v,
                                np.sort(invperm[delta.touched]),
                                delta.dis[part.perm],
                                dirty=(np.sort(invperm[dirty])
                                       if dirty is not None and len(dirty)
                                       else None))
        hole = jnp.zeros((1, 1), jnp.float32)
        cat = {f: jnp.concatenate([getattr(s.ops, f) for s in slices],
                                  axis=0) for f in fields}
        full_ops = GranniteOperands(**{
            f: cat.get(f, hole) for f in ("norm_adj", "mask_mult",
                                          "bias_add", "sample_mask",
                                          "mean_mask")})
        patched = self._delta_patcher(full_ops, spec)
        out = []
        for idx, s in enumerate(slices):
            blk = {f: getattr(patched, f)[idx * c:(idx + 1) * c]
                   for f in fields}
            out.append(dataclasses.replace(
                s, ops=dataclasses.replace(s.ops, **blk)))
        return tuple(out)

    def update_delta(self, graph_id: int, add_edges=None,
                     remove_edges=None) -> bool:
        """GrAd INCREMENTAL structure update (§13): patch, don't rebuild.

        `add_edges` / `remove_edges` are (k, 2) arrays of UNDIRECTED node
        pairs (directed graphs raise — take the full `update()` path).
        The host patches the packed adjacency and renormalizes only the
        touched rows/cols of Â (`core.graph.apply_edge_delta`); every
        device-resident cached form of the graph is then patched IN PLACE
        of a rebuild through the warm `DeltaPatcher` traces — fp32 Â
        row/col renorm, GAT mask/bias rescatter, int8 Â re-quantization of
        exactly the rows whose fp32 values changed, grasp block-list
        re-derivation from the patched Â, and on sharded graphs the
        concatenated permuted row blocks with the partition (and halo
        observability via `core.partition.patch_halo`) carried forward.
        The patched entries land under the NEW (graph_id, version+1) key —
        cached arrays are never mutated, so a request racing this update
        serves its snapshot unharmed, and the per-key lifecycle contract
        holds unchanged.

        Falls back to the full `update()` rebuild — counted in
        `delta_fallbacks` — when the delta exceeds the warmed patch widths
        (more than `delta_pad_rows` touched nodes or 2x that many edge
        flips), the kind is SAGE (its sampled mask is not incrementally
        patchable), or `delta_pad_rows=0` disabled patching. Ineffective
        deltas (all edges already present/absent) return True without
        bumping the version: every cache entry is still exact.

        Returns True when the structure was patched incrementally (or the
        delta was a no-op), False when it fell back to `update()`.
        """
        with self._lock:
            model, pg = self.graphs[graph_id]
            ver = self._graph_version[graph_id]
            sharded = self._sharded.get(graph_id)
        e = self.models[model]
        if not is_symmetric_adjacency(pg.adj):
            raise ValueError(
                "update_delta edits undirected edge pairs; directed "
                "graphs must take the full update() path")
        delta = apply_edge_delta(pg.adj, pg.norm_adj, pg.num_nodes,
                                 add_edges, remove_edges)
        if delta is None:
            return True          # nothing effective changed: caches stand
        kt, ke = self._delta_pads(pg.capacity)
        patchable = (self.sc.delta_pad_rows > 0
                     and e.cfg.kind in ("gcn", "gat")
                     and len(delta.touched) <= kt
                     and len(delta.flip_i) <= ke)
        if not patchable:
            # §13 delta-vs-rebuild threshold: past the warmed patch widths
            # (or for SAGE's sampled mask) a rebuild is both simpler and
            # cheaper than a cascade of patches — reuse update() verbatim
            self._count("delta_fallbacks")
            edge_index = edge_index_from_adjacency(delta.adj, pg.num_nodes)
            feats = (sharded[1].features if sharded is not None
                     else pg.features[:pg.num_nodes])
            self.update(graph_id, edge_index, pg.num_nodes, feats)
            return False
        pg2 = dataclasses.replace(pg, adj=delta.adj, norm_adj=delta.norm_adj)
        old_key, new_key = (graph_id, ver), (graph_id, ver + 1)
        if sharded is not None:
            part, g = sharded
            edge_index = edge_index_from_adjacency(delta.adj, pg.num_nodes)
            g2 = dataclasses.replace(g, edge_index=edge_index)
            part2 = patch_halo(part, edge_index)
            # §15 halo-delta: only the touched rows with a cross-shard
            # neighbor in the patched structure have remote copies to
            # refresh — that set (not the full halo) is what crosses the
            # wire, priced at the exact fp32 rate the operand patch
            # requires (a compressed dirty exchange would break the
            # patched-equals-rebuilt bit contract)
            dirty = delta.boundary_rows(part.assignment, pg.num_nodes)
            fields = OPERAND_FIELDS[e.cfg.kind]
            full = part.full_rows
            # each dirty row ships its operand rows plus its D^-1/2 entry;
            # an interior delta (no dirty rows) is wire-FREE — no remote
            # shard holds a copy of a non-boundary row
            delta_elems = len(dirty) * (full * len(fields) + 1)
            full_elems = len(fields) * full * full + full
            delta_bytes = int(ring_psum_nbytes(part.shards, delta_elems,
                                               bytes_per_elt=4))
            full_bytes = int(ring_psum_nbytes(part.shards, full_elems,
                                              bytes_per_elt=4))
            with self._lock:
                slices = self._cache.get("shard", old_key)
            new_slices = None
            if slices is not None:
                new_slices = self._patch_shard_slices(e, part, slices,
                                                      delta, dirty=dirty)
            with self._lock:
                if self._graph_version.get(graph_id) != ver:
                    return False          # a racing update/detach won
                self.graphs[graph_id] = (model, pg2)
                self._sharded[graph_id] = (part2, g2)
                self._cache.invalidate(old_key)
                self._graph_version[graph_id] = ver + 1
                if new_slices is not None:
                    self._cache.put(
                        "shard", new_key, new_slices,
                        nbytes=self._shard_entry_nbytes(new_slices),
                        remat_s=transfer_cost(
                            self._shard_entry_nbytes(new_slices)))
                self.metrics["delta_updates"] += 1
                self.metrics["delta_halo_bytes_exchanged"] += delta_bytes
                self.metrics["delta_halo_bytes_full"] += full_bytes
                self.metrics["delta_dirty_rows"] += len(dirty)
            return True
        with self._lock:
            ops_old = self._cache.get("operand", old_key)
            tops_old = self._cache.get("tier", old_key)
            had_grasp = self._cache.get("grasp", old_key) is not None
        new_ops = new_tops = new_grasp = None
        if self.sc.use_cacheg and ops_old is not None:
            fields = OPERAND_FIELDS[e.cfg.kind]
            spec = self._delta_spec(pg.capacity, fields, delta.flip_i,
                                    delta.flip_j, delta.flip_v,
                                    delta.touched, delta.dis)
            new_ops = self._delta_patcher(ops_old, spec)
            if tops_old is not None:
                rows = self._requant_rows(delta, pg.capacity)
                if rows is None:
                    new_tops = self._agg_quantizer(new_ops.norm_adj)
                else:
                    new_tops = self._delta_patcher.patch_tier(
                        tops_old, new_ops.norm_adj, rows)
            if had_grasp and self._grasp_capable(e):
                # the block structure cannot be patched sparsely (a flip
                # moves rows between blocks) but re-deriving from the
                # PATCHED device Â is still zero host bytes and warm
                new_grasp = self._derive_grasp(e, pg.capacity,
                                               new_ops.norm_adj)
        with self._lock:
            if self._graph_version.get(graph_id) != ver:
                return False              # a racing update/detach won
            self.graphs[graph_id] = (model, pg2)
            self._cache.invalidate(old_key)
            self._graph_version[graph_id] = ver + 1
            if new_ops is not None:
                nb = pytree_nbytes(new_ops)
                self._cache.put(
                    "operand", new_key, new_ops, nbytes=nb,
                    remat_s=transfer_cost(nb),
                    spill_fn=self._operand_spill_fn(graph_id, ver + 1,
                                                    model))
            if new_tops is not None:
                self._cache.put("tier", new_key, new_tops,
                                nbytes=pytree_nbytes(new_tops))
            if new_grasp is not None:
                self._cache.put("grasp", new_key, new_grasp,
                                nbytes=pytree_nbytes(new_grasp))
            self.metrics["delta_updates"] += 1
        return True

    def prepare_query(self, graph_id: int, *, tier: Optional[str] = None,
                      fusion: Optional[str] = None,
                      submitted_s: Optional[float] = None,
                      deadline_ms: Optional[float] = None,
                      tolerance: Optional[float] = None) -> GNNRequest:
        """HOST stage of a query over an attached graph's current snapshot,
        optionally pinning a quality tier and/or fusion mode (model
        defaults otherwise).

        CacheG hit path: an unchanged structure serves straight from the
        device-resident cache — zero host-side operand construction, zero
        operand bytes over the link. The cache keys carry NO tier: the
        same fp32 operands feed every tier's plan, and the int8 Â that
        QuantGr GCN tiers read is quantized from them once per structure
        version into the tier cache below — so mixed-tier traffic over one
        graph shares one entry of each. The GraSp structure is the third
        derived form (DESIGN.md §10): the backend rule runs once per
        (graph, version) over counts the block compactor derives from the
        CACHED Â — device-side, zero extra host→device bytes — and both
        the decision and (when grasp) the budget-padded structure are
        cached under the same key, invalidated by the same `update()`
        bump, released by the same `detach()`.

        Thread discipline (the scheduler calls this from host workers while
        `update()` may arrive concurrently): the (model, pg, version)
        triple is snapshotted under the engine lock, operands are built
        OUTSIDE it, and a built entry is inserted only if the version is
        still current — a request racing an update serves the snapshot it
        read, and a stale build can never pin dead device memory under an
        unreachable key. Two workers missing the same key may both build
        (both counted as misses); last insert wins, values are identical.
        """
        with self._lock:
            model, pg = self.graphs[graph_id]
            ver = self._graph_version[graph_id]
            sharded = self._sharded.get(graph_id)
        if sharded is not None:
            if fusion not in (None, "none"):
                raise ValueError(
                    "sharded graphs serve fusion='none' only — the shard "
                    "axis occupies the plan dimension fused layers batch "
                    "over (DESIGN.md §12)")
            return self._prepare_sharded(graph_id, model, pg, sharded,
                                         ver, tier=tier,
                                         submitted_s=submitted_s,
                                         deadline_ms=deadline_ms,
                                         tolerance=tolerance)
        if not self.sc.use_cacheg:
            return self._prepare(model, pg, tier=tier, fusion=fusion,
                                 submitted_s=submitted_s,
                                 deadline_ms=deadline_ms,
                                 tolerance=tolerance)
        key = (graph_id, ver)
        with self._lock:
            ops = self._cache.get("operand", key)
        if ops is None:
            with self._lock:
                spilled = self._cache.spill_get("operand", key)
            if spilled is not None:
                # §13 spill fault: the evicted primary re-materializes from
                # its host-RAM compact form — compact bytes cross the link
                # again, but zero host packing work runs, and it is NOT an
                # operand_cache_miss (this version's structure work is done)
                self._count("cache_spill_hits")
                self._count("operand_bytes_h2d", spilled.nbytes)
                ops = realize_operands(spilled, self._materializer)
            else:
                self._count("operand_cache_misses")
                ops = self._device_operands(model, pg)
            nb = pytree_nbytes(ops)
            with self._lock:
                if self._graph_version.get(graph_id) == ver:
                    self._cache.put(
                        "operand", key, ops, nbytes=nb,
                        remat_s=transfer_cost(nb),
                        spill_fn=self._operand_spill_fn(graph_id, ver,
                                                        model))
        else:
            self._count("operand_cache_hits")
        tops = None
        resolved = self._route_tier(model, tier, tolerance, pg.capacity)
        e = self.models[model]
        if self._needs_tier_ops(e, resolved):
            # derived-form hit path: the int8 Â is structure work too —
            # once per (graph, version), never per query
            with self._lock:
                tops = self._cache.get("tier", key)
            if tops is None:
                tops = self._agg_quantizer(ops.norm_adj)
                with self._lock:
                    # a derived insert can never evict an entry at its own
                    # key — the manager protects the inserted key, which is
                    # exactly the primary this form hangs off
                    if self._graph_version.get(graph_id) == ver:
                        self._cache.put("tier", key, tops,
                                        nbytes=pytree_nbytes(tops))
        backend = "dense"
        if self._grasp_capable(e) and not e.tiers[resolved].quantgr:
            # derived-form hit path for the block structure: rule + compact
            # once per (graph, version) from the device-resident Â
            with self._lock:
                cached = self._cache.get("grasp", key)
            if cached is None:
                cached = self._derive_grasp(e, pg.capacity, ops.norm_adj)
                with self._lock:
                    if self._graph_version.get(graph_id) == ver:
                        self._cache.put("grasp", key, cached,
                                        nbytes=pytree_nbytes(cached))
            backend, bsp = cached
            self._count_forced_fallback(e, backend)   # per request, cached
            if backend == "grasp":                    # decision or not
                ops = dataclasses.replace(ops, block_sparse=bsp)
        return self._prepare(model, pg, ops, tier=resolved, tier_ops=tops,
                             tier_resolved=True, backend=backend,
                             fusion=fusion, submitted_s=submitted_s,
                             deadline_ms=deadline_ms, tolerance=tolerance)

    def _prepare_sharded(self, graph_id: int, model: str, pg: PaddedGraph,
                         sharded: Tuple[GraphShards, Graph], ver: int, *,
                         tier: Optional[str],
                         submitted_s: Optional[float],
                         deadline_ms: Optional[float] = None,
                         tolerance: Optional[float] = None) -> GNNRequest:
        """HOST stage of a query over an auto-sharded graph (§12).

        The CacheG unit here is the tuple of per-shard `ShardSlice`s —
        built once per (graph_id, structure_version) by
        `build_sharded_operands` (full-capacity operands permuted into
        slot layout and sliced into rectangular row blocks), cached and
        invalidated exactly like the dense operand cache (same
        hit/miss accounting, same version-checked insert against racing
        updates). Tier resolution is unchanged — QuantGr tiers serve
        through the model calibration, uncalibrated ones fall back to
        fp32; the sharded GCN int8 path re-derives the int8 Â in-trace
        from its complete row block, so no sharded tier-operand cache
        exists. Backend is always dense and fusion always "none": the
        batch key's shard element is what keeps these dispatches from
        mixing with unsharded ones."""
        part, g = sharded
        e = self.models[model]
        resolved = self._route_tier(model, tier, tolerance, part.shard_cap)
        key = (graph_id, ver)
        with self._lock:
            slices = self._cache.get("shard", key)
        if slices is None:
            self._count("operand_cache_misses")
            slices = build_sharded_operands(g, part, e.cfg)
            nb = self._shard_entry_nbytes(slices)
            with self._lock:
                # no spill_fn: the slice tuple re-derives from the engine's
                # own (partition, Graph) registry snapshot — a host-RAM
                # spill would duplicate state the engine already holds
                if self._graph_version.get(graph_id) == ver:
                    self._cache.put("shard", key, slices, nbytes=nb,
                                    remat_s=transfer_cost(nb))
        else:
            self._count("operand_cache_hits")
        x, ops, mask = stack_shard_slices(slices)
        now = self.clock.now()
        submitted_s = submitted_s if submitted_s is not None else now
        with self._lock:
            uid = self._uid
            self._uid += 1
            if self.metrics["first_submit_s"] is None:
                self.metrics["first_submit_s"] = submitted_s
        deadline_s = (submitted_s + deadline_ms * 1e-3
                      if deadline_ms is not None else None)
        return GNNRequest(uid=uid, model=model, pg=pg, ops=ops,
                          bucket=part.shard_cap, submitted_s=submitted_s,
                          tier=resolved, backend="dense", fusion="none",
                          shards=part.shards, part=part, shard_x=x,
                          shard_mask=mask, deadline_s=deadline_s,
                          tolerance=tolerance)

    def query(self, graph_id: int, *, tier: Optional[str] = None,
              fusion: Optional[str] = None,
              deadline_ms: Optional[float] = None,
              tolerance: Optional[float] = None) -> int:
        """Enqueue inference over an attached graph (see `prepare_query`)."""
        return self._push(self.prepare_query(graph_id, tier=tier,
                                             fusion=fusion,
                                             deadline_ms=deadline_ms,
                                             tolerance=tolerance))

    # --------------------------------------------------------------- execution
    def run(self) -> List[GNNRequest]:
        while self.queue:
            self._run_batch()
        return self.finished

    def _complete_expired(self, expired: List[GNNRequest],
                          now: float) -> None:
        """Finish requests whose deadline passed BEFORE dispatch (§14):
        they complete immediately with `deadline_missed=True` and no
        predictions — an answer the caller can no longer use must not
        occupy batch slots ahead of ones that still can. Counted per
        request in `deadline_misses`; their (submit → expiry) latency
        still feeds the metrics and the governor, because an expired
        request IS the overload signal the governor exists to see."""
        for r in expired:
            r.done = True
            r.deadline_missed = True
            r.finished_s = now
        with self._lock:
            for r in expired:
                self.metrics["latency_s"].append(now - r.submitted_s)
                self.metrics["deadline_misses"] += 1
                self.finished.append(r)
                if self.governor is not None:
                    self.governor.observe(now - r.submitted_s)
            self.metrics["last_finish_s"] = now

    def _run_batch(self) -> None:
        # expiry sweep first (§14): requests already past their deadline
        # complete flagged instead of wasting a dispatch
        now = self.clock.now()
        expired = [r for r in self.queue
                   if r.deadline_s is not None and r.deadline_s <= now]
        if expired:
            gone = {r.uid for r in expired}
            self.queue = [r for r in self.queue if r.uid not in gone]
            self._complete_expired(expired, now)
            if not self.queue:
                return
        # best-filling key first (not queue[0]'s — see best_fill_key), with
        # slack as the fill tie-break (edf_best_fill_key): a lone odd
        # request at the head no longer forces a 1-of-N dispatch
        # while fully-fillable keys wait behind it. Tier, agg backend AND
        # fusion mode are part of the batch key: all three select
        # different compiled plans, so a slot can never mix execution
        # variants.
        key = edf_best_fill_key(edf_pending_stats(self.queue, now),
                                self.sc.batch_slots, self._last_dispatch,
                                replica_slots=self.sc.replica_groups)
        # sharded: one request per replica row (§15; width-1 when R == 1)
        take = self.sc.replica_groups if key[5] else self.sc.batch_slots
        batch = [r for r in self.queue
                 if (r.model, r.bucket, r.tier, r.backend, r.fusion,
                     r.shards) == key][:take]
        taken = {r.uid for r in batch}
        self.queue = [r for r in self.queue if r.uid not in taken]
        self._execute_batch(batch)

    def _execute_batch(self, batch: List[GNNRequest]) -> None:
        """DEVICE stage: one fixed-width dispatch of same-key requests.

        Called with 1..batch_slots requests sharing one (model, bucket,
        tier, backend, fusion) key, from exactly ONE thread at a time (the sync
        `run()` loop, or the pipeline scheduler's dispatcher). Junk slots
        repeat a real request so batch width never changes shape; their
        outputs are dropped. `device_busy_s` accumulates the wall-clock of
        this stage — the pipeline's device-idle fraction is measured
        against it. A grasp dispatch whose plan was TRACED through the
        `ref` kernel routing ran the aggregation dense (plain XLA over the
        block form, no skip grid) — every request in it is counted as
        `backend_fallbacks` so the degradation is observable, never
        invisible.

        A SHARDED request (shards > 0) routes to `_execute_sharded`
        instead: its dispatch width is `replica_groups` (§15; the shard
        axis occupies the dim a batched plan would use, and the replica
        axis — when configured — is the sharded batch dim), and both
        drivers — the sync `run()` loop and the pipeline scheduler, whose
        `_take_locked` takes the same width for a sharded key — arrive
        here with 1..replica_groups same-key requests.
        """
        head = batch[0]
        if head.shards:
            self._execute_sharded(batch)
            return
        b = self.sc.batch_slots
        bkey = (head.model, head.bucket, head.tier, head.backend,
                head.fusion, 0)
        t0 = self.clock.now()
        # fixed batch width: junk slots repeat a real request, outputs dropped
        slots = batch + [batch[-1]] * (b - len(batch))
        e = self.models[head.model]
        x = jnp.asarray(stack_padded([r.pg for r in slots]).features)
        # CacheG: r.ops are device-resident (materialized or cached), so this
        # stack is a device-side concat — only the activations `x` crossed
        # the host→device link for this dispatch (DESIGN.md §7).
        ops = stack_operands([r.ops for r in slots])
        tops = (stack_tier_operands([r.tier_ops for r in slots])
                if slots[0].tier_ops is not None else None)
        plan = self.plan_for(head.model, head.bucket, head.tier,
                             head.backend, head.fusion)
        logits = plan(e.params, x, ops, e.calibrations.get(head.tier), tops)
        logits.block_until_ready()
        # trace-time capture, not a dispatch-time env read: the compiled
        # blob keeps whatever lowering it was traced with
        ran_dense_fallback = plan.grasp_ref_fallback

        # §14: fake clocks advance scripted per-key latency here — between
        # the dispatch timestamps — so batch cost is a test input
        self.clock.on_batch(bkey)
        now = self.clock.now()
        host_logits = np.asarray(logits)
        for i, r in enumerate(batch):
            lg = host_logits[i, : r.pg.num_nodes]
            r.preds = lg.argmax(axis=-1).astype(np.int32)
            if self.sc.return_logits:
                r.logits = lg
            r.done = True
            r.finished_s = now
            if r.deadline_s is not None and now > r.deadline_s:
                # executed but late (§14): the answer is delivered, the
                # breach is flagged — distinct from pre-dispatch expiry,
                # where preds stay None
                r.deadline_missed = True
        with self._lock:
            self.bank.observe(bkey, now - t0)
            for r in batch:
                lat = now - r.submitted_s
                self.metrics["latency_s"].append(lat)
                self.finished.append(r)
                if r.deadline_missed:
                    self.metrics["deadline_misses"] += 1
                if self.governor is not None:
                    self.governor.observe(lat)
            self.metrics["batches"] += 1
            self.metrics["slots_filled"] += len(batch)
            self.metrics["slots_total"] += b
            if head.backend == "grasp":
                self.metrics["grasp_batches"] += 1
                if ran_dense_fallback:
                    # per REQUEST (same unit as tier_fallbacks and the
                    # forced-but-ineligible count): every request in this
                    # dispatch ran its aggregation dense under ref routing
                    self.metrics["backend_fallbacks"] += len(batch)
            self.metrics["device_busy_s"] += now - t0
            self.metrics["last_finish_s"] = now
            self._last_dispatch[head.model] = self._dispatch_serial
            self._dispatch_serial += 1

    def _halo_bytes(self, cfg: GNNConfig, part: GraphShards
                    ) -> Tuple[int, int]:
        """(compressed, exact) collective bytes one sharded forward moves:
        ring-psum traffic is priced through the single owner of the ring
        factor (`dist.compress.ring_psum_nbytes` — also what
        `core.partition.modelled_sharded_latency` uses, so metric and
        model cannot drift), int8 (1 B/elt) on the compressed wire vs fp32
        (4 B/elt) exact, over the kind's actual exchange schedule
        (`sharded_exchange_widths`)."""
        elems = sum(part.full_rows * w for w in sharded_exchange_widths(cfg))
        comp = ring_psum_nbytes(part.shards, elems, bytes_per_elt=1)
        return int(comp), int(4 * comp)

    def _execute_sharded(self, batch: List[GNNRequest]) -> None:
        """DEVICE stage of one sharded dispatch (§12, §15): the plan runs
        every shard's aggregate+combine under the shard axis (shard_map
        when the host exposes enough devices, vmap-simulated otherwise —
        identical collective math), the halo crossing as a compressed
        psum; the slot-ordered logits are unpermuted back to node order on
        the host (`unshard_logits`). With `replica_groups > 1` the batch
        carries up to R same-key requests, one per replica row of the
        R x S mesh — junk rows repeat a real request exactly like junk
        batch slots, outputs dropped. Each replica row exchanges halos
        within itself (the psum names only the shard axis), so collective
        bytes are accounted per REAL request — both what the compressed
        wire moved and what exact fp32 would have, so the compression win
        is a metric, not a claim."""
        head = batch[0]
        R = self.sc.replica_groups
        bkey = (head.model, head.bucket, head.tier, "dense", "none",
                head.shards)
        t0 = self.clock.now()
        e = self.models[head.model]
        plan = self.plan_for(head.model, head.bucket, head.tier,
                             shards=head.shards)
        quant = e.calibrations.get(head.tier)
        if R == 1:
            logits = plan(e.params, head.shard_x, head.ops, quant,
                          node_mask=head.shard_mask)
        else:
            slots = batch + [batch[-1]] * (R - len(batch))
            logits = plan(e.params,
                          jnp.stack([r.shard_x for r in slots]),
                          stack_operands([r.ops for r in slots]), quant,
                          node_mask=jnp.stack([r.shard_mask for r in slots]))
        logits.block_until_ready()
        self.clock.on_batch(bkey)
        now = self.clock.now()
        host_logits = np.asarray(logits)
        comp_total = exact_total = 0
        for i, r in enumerate(batch):
            lg = unshard_logits(host_logits[i] if R > 1 else host_logits,
                                r.part)
            r.preds = lg.argmax(axis=-1).astype(np.int32)
            if self.sc.return_logits:
                r.logits = lg
            r.done = True
            r.finished_s = now
            if r.deadline_s is not None and now > r.deadline_s:
                r.deadline_missed = True
            comp, exact = self._halo_bytes(e.cfg, r.part)
            comp_total += comp
            exact_total += exact
        with self._lock:
            self.bank.observe(bkey, now - t0)
            for r in batch:
                lat = now - r.submitted_s
                self.metrics["latency_s"].append(lat)
                self.finished.append(r)
                if r.deadline_missed:
                    self.metrics["deadline_misses"] += 1
                if self.governor is not None:
                    self.governor.observe(lat)
            self.metrics["batches"] += 1
            self.metrics["slots_filled"] += len(batch)
            self.metrics["slots_total"] += R
            self.metrics["sharded_batches"] += 1
            self.metrics["halo_bytes_exchanged"] += (
                comp_total if self.sc.halo_compress else exact_total)
            self.metrics["collective_bytes_compressed"] += comp_total
            self.metrics["collective_bytes_exact"] += exact_total
            self.metrics["device_busy_s"] += now - t0
            self.metrics["last_finish_s"] = now
            self._last_dispatch[head.model] = self._dispatch_serial
            self._dispatch_serial += 1

    # -------------------------------------------------------------- pipeline
    def scheduler(self, pc=None):
        """Attach an async two-stage pipeline scheduler (DESIGN.md §9).

        Returns a `runtime.scheduler.PipelineScheduler` whose host workers
        run this engine's `prepare_submit`/`prepare_query` stages while its
        dispatcher drives `_execute_batch` — host preprocessing for request
        N+1 overlaps device execution of request N. Use as a context
        manager; the sync `submit`/`query` + `run()` path stays available
        on the bare engine."""
        from .scheduler import PipelineScheduler
        return PipelineScheduler(self, pc)

    # ---------------------------------------------------------------- metrics
    def tier_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-tier serving stats, derived from the finished requests (each
        carries its RESOLVED tier, so fp32 fallbacks count as fp32 here and
        as `tier_fallbacks` in the top-level metrics)."""
        by_tier: Dict[str, List[GNNRequest]] = {}
        for r in self.finished:
            by_tier.setdefault(r.tier, []).append(r)
        out: Dict[str, Dict[str, float]] = {}
        for tn, reqs in sorted(by_tier.items()):
            lat = np.asarray([r.finished_s - r.submitted_s for r in reqs])
            span = (max(r.finished_s for r in reqs)
                    - min(r.submitted_s for r in reqs))
            out[tn] = {
                "requests": len(reqs),
                "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
                "throughput_rps": (len(reqs) / span) if span > 0 else 0.0,
            }
        return out

    def summary(self) -> Dict[str, object]:
        lat = np.asarray(self.metrics["latency_s"], np.float64)
        t0, t1 = self.metrics["first_submit_s"], self.metrics["last_finish_s"]
        span = (t1 - t0) if (t0 is not None and t1 is not None) else 0.0
        return {
            "requests": len(self.finished),
            "compiled_blobs": self.compiled_blobs,
            "batches": self.metrics["batches"],
            "batch_occupancy": (self.metrics["slots_filled"]
                                / max(self.metrics["slots_total"], 1)),
            "device_busy_s": self.metrics["device_busy_s"],
            # fraction of the serving span the device stage sat idle —
            # the pipeline scheduler's overlap claim is judged on this
            # (DESIGN.md §9); 1 - busy/span, 0 when nothing ran
            "device_idle_fraction": (
                max(0.0, 1.0 - self.metrics["device_busy_s"] / span)
                if span > 0 else 0.0),
            "rebucket_events": self.metrics["rebucket_events"],
            "operand_bytes_h2d": self.metrics["operand_bytes_h2d"],
            "operand_cache_hits": self.metrics["operand_cache_hits"],
            "operand_cache_misses": self.metrics["operand_cache_misses"],
            "cacheg_fallbacks": self.metrics["cacheg_fallbacks"],
            "tier_fallbacks": self.metrics["tier_fallbacks"],
            # GraSp backend dispatch (DESIGN.md §10): per-model serving
            # mode, how many batches took the sparse path, and how many
            # REQUESTS with grasp intent quietly ran dense — forced-mode
            # ineligible structure or ref-routing dispatch (same
            # per-request unit as tier_fallbacks)
            "agg_backends": {name: e.agg_backend
                             for name, e in self.models.items()},
            "grasp_batches": self.metrics["grasp_batches"],
            "backend_fallbacks": self.metrics["backend_fallbacks"],
            # sharded serving (DESIGN.md §12): which attached graphs run
            # partitioned (and across how many shards), how many width-1
            # sharded dispatches ran, and the collective traffic — actual
            # bytes on the halo wire plus both counterfactual framings
            # (compressed vs exact), so the int8-wire win is inspectable
            "shard_counts": {gid: p.shards
                             for gid, (p, _) in self._sharded.items()},
            "sharded_batches": self.metrics["sharded_batches"],
            "halo_bytes_exchanged": self.metrics["halo_bytes_exchanged"],
            "collective_bytes_compressed":
                self.metrics["collective_bytes_compressed"],
            "collective_bytes_exact":
                self.metrics["collective_bytes_exact"],
            # §13 bounded cache hierarchy: residency vs budget, capacity
            # evictions split by outcome (spilled to host-RAM compact form
            # vs dropped — conservation: evictions == spilled + dropped),
            # second-level hits served from the spill store, admission
            # rejections, and the GrAd incremental-update counters
            "cache_resident_bytes": self._cache.resident_bytes,
            "cache_budget_bytes": self.sc.device_cache_budget_bytes,
            "cache_evictions": self._cache.evictions,
            "cache_spilled": self._cache.spilled,
            "cache_dropped": self._cache.dropped,
            "cache_spill_entries": self._cache.spill_entries,
            "cache_spill_hits": self.metrics["cache_spill_hits"],
            "cache_admission_rejects":
                self.metrics["cache_admission_rejects"],
            "delta_updates": self.metrics["delta_updates"],
            "delta_fallbacks": self.metrics["delta_fallbacks"],
            # §15 halo-delta exchange: exact wire bytes the dirty-
            # boundary-row exchange moved for sharded deltas vs what
            # re-exchanging the full halos would have — the wire
            # reduction is a counter, not a claim
            "delta_halo_bytes_exchanged":
                self.metrics["delta_halo_bytes_exchanged"],
            "delta_halo_bytes_full": self.metrics["delta_halo_bytes_full"],
            "delta_dirty_rows": self.metrics["delta_dirty_rows"],
            # §14 SLO loop: deadline outcomes, governor decisions, and the
            # measured-vs-modelled drift of the latency bank (mean
            # EWMA/seed ratio over keys with both — the signal that the
            # roofline mispriced a path, e.g. the BENCH grasp inversion)
            "deadline_misses": self.metrics["deadline_misses"],
            "shed_requests": self.metrics["shed_requests"],
            "slo_downgrades": (self.governor.downgrades
                               if self.governor is not None else 0),
            "slo_upgrades": (self.governor.upgrades
                             if self.governor is not None else 0),
            "slo_level": (self.governor.level
                          if self.governor is not None else 0),
            "ewma_vs_model": self.bank.ewma_vs_model(),
            "tiers": self.tier_summary(),
            "accuracy_delta_vs_fp32": {
                name: dict(e.accuracy_delta)
                for name, e in self.models.items() if e.accuracy_delta},
            "throughput_rps": (len(self.finished) / span if span > 0 else 0.0),
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else 0.0,
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else 0.0,
            "mean_latency_s": float(lat.mean()) if lat.size else 0.0,
        }
