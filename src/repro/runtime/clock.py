"""Injectable time source for the serving stack (DESIGN.md §14).

Every latency measurement, deadline comparison, and batching-window wait
in the serving path flows through a `Clock` instead of calling
`time.perf_counter()` directly.  The production `WallClock` is a thin
veneer over `perf_counter`; tests inject a fake clock
(`tests/clockwork.py`) that only moves when told to, which makes the
whole SLO control loop — EDF dispatch, expiry sweeps, EWMA updates,
governor hysteresis — drivable deterministically with zero real sleeps.

The one non-obvious member is `on_batch(key, span)`: `_execute_batch`
calls it between taking its start and end timestamps.  The wall clock
ignores it (real time already passed); a fake clock uses it to advance
virtual time by a scripted per-key latency, so "the batch took 3 ms"
becomes a test input instead of a machine-load artifact.
"""

from __future__ import annotations

import time


class Clock:
    """Time-source interface. Subclass and override for virtual time."""

    def now(self) -> float:
        """Current time in seconds (monotonic, arbitrary epoch)."""
        raise NotImplementedError

    def on_batch(self, key, span=None) -> None:
        """Hook invoked once per executed batch, between the dispatch
        timestamps.  `key` is the BatchKey; `span` is the measured wall
        span so far (None before execution finishes).  No-op by default.
        """

    def sleep(self, seconds: float) -> None:
        """Advance time by `seconds` (real for WallClock, virtual for fakes)."""
        raise NotImplementedError


class WallClock(Clock):
    """Production clock: `time.perf_counter` + real `time.sleep`."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


WALL = WallClock()
