"""Async two-stage pipeline scheduler for GraphServe (DESIGN.md §9).

GraphSplit at serving scale: the paper puts control-heavy graph work on the
host and dense compute on the accelerator, but the engine's sync path runs
both phases serially — `run()` only starts after every submit finished its
host work, so the device idles exactly during the preprocessing the split
exists to hide. The scheduler overlaps them as a two-stage pipeline:

  intake ──▶ HOST stage                 ──▶ ready ──▶ DEVICE stage
  bounded    worker threads running         bounded   one dispatcher thread
  queue      engine.prepare_submit /        buffer    grouping ready requests
             prepare_query (ladder.pad,     (per      by (model, bucket,
             operand build, CompactOperands batch     tier, agg backend,
             packing, CacheG lookups)       key)      fusion, shard count)
                                                      and driving
                                                      engine._execute_batch

Policies (all per `PipelineConfig`):

  * Batch window — the dispatcher prefers full batches: a key with fewer
    than `batch_slots` ready requests waits up to `window_ms` (measured
    from its OLDEST ready request) for stragglers while host work is still
    in flight, then dispatches partial. `window_ms=0` dispatches whatever
    is ready immediately.
  * Best-fill + fairness — key selection is `gnn_server.best_fill_key`:
    fullest key first, least-recently-dispatched model on ties, FIFO last
    (shared with the sync path, so both drivers batch identically).
  * Backpressure — both queues are bounded. A full intake queue makes
    `submit`/`query` either block (`backpressure="block"`, counted in
    `metrics["blocked"]`) or raise `QueueFull` (`"reject"`, counted in
    `metrics["rejected"]`); a full ready buffer blocks host workers, which
    in turn fills intake — pressure propagates to the caller instead of
    growing unbounded request state.
  * Determinism — `deterministic=True` forces one host worker and
    `window_ms=0` and runs the whole pipeline inline on the caller's
    thread (no threads at all): identical submission order then yields
    identical batch composition, which is what the differential test
    suites diff against the sequential path. Backpressure stays live —
    "block" drains inline instead of waiting on a thread.

Every engine contract survives the scheduler: plans/materializers are only
ever REPLAYED (zero recompiles, `assert_warm`), CacheG hit/miss accounting
is unchanged (worker races on a cold key may double-build; both count as
misses and the insert is version-checked), and tier fallback happens in the
host stage exactly as in the sync path. Under a §13 cache budget, HOST-stage
workers are also where spill faults surface: a `prepare_query` that misses
the device cache but hits the host-RAM spill store re-materializes the
compact form inside the host stage (counted `cache_spill_hits`, never an
`operand_cache_miss`), so eviction pressure converts into host-stage
latency, never device-stage stalls — and a budget-evicted entry re-inserted
by one worker may evict another graph mid-flight, which is safe for the
same reason racing double-builds are: requests carry their operand
snapshot, cache state only gates REUSE. The engine summary the scheduler
re-exports includes the cache residency/eviction/spill counters.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.graph import Graph

from .gnn_server import (BatchKey, GNNRequest, GraphServe, edf_best_fill_key)


class QueueFull(RuntimeError):
    """Raised by submit/query under `backpressure="reject"` when the intake
    queue is at `max_pending` — the caller sheds load instead of queueing."""


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    host_workers: int = 2          # threads running the engine's host stage
    window_ms: float = 2.0         # max wait to fill a partial batch
    max_pending: int = 64          # intake queue bound (host stage input)
    max_ready: int = 64            # ready buffer bound (device stage input)
    backpressure: str = "block"    # "block" | "reject" on a full intake
    deterministic: bool = False    # single worker, window=0, inline drive

    def __post_init__(self):
        if self.backpressure not in ("block", "reject"):
            raise ValueError("backpressure must be 'block' or 'reject', "
                             f"got {self.backpressure!r}")
        if self.host_workers < 1:
            raise ValueError("host_workers must be >= 1")
        if self.max_pending < 1 or self.max_ready < 1:
            raise ValueError("queue bounds must be >= 1")


@dataclasses.dataclass
class _Work:
    """One accepted intake item, before its host stage ran."""
    ticket: int
    kind: str                      # "submit" | "query"
    submitted_s: float             # intake time (latency includes queue wait)
    model: Optional[str] = None
    graph: Optional[Graph] = None
    graph_id: Optional[int] = None
    tier: Optional[str] = None
    fusion: Optional[str] = None
    deadline_ms: Optional[float] = None   # §14: relative to submitted_s
    tolerance: Optional[float] = None     # §14: tier-router budget (points)


# One ready-buffer entry: (arrival serial, arrival time, request). The
# serial is the FIFO tie-break best_fill_key sees; the arrival time anchors
# the key's batch window.
_Ready = Tuple[int, float, GNNRequest]


class PipelineScheduler:
    """Drives one GraphServe engine as a host/device pipeline.

    Use as a context manager (`with eng.scheduler(pc) as sched:`) or call
    `close()` explicitly; `drain()` blocks until every accepted request
    completed and returns them in ticket order. The sync engine API stays
    usable on the side — the scheduler only ever adds requests through the
    engine's prepare/_execute_batch stages, never through `engine.queue`.
    """

    def __init__(self, engine: GraphServe, pc: Optional[PipelineConfig] = None):
        pc = pc or PipelineConfig()
        if pc.deterministic:
            # reproducible batch composition: one worker (host order =
            # submission order) and no window (dispatch is a pure function
            # of the ready set, never of thread timing)
            pc = dataclasses.replace(pc, host_workers=1, window_ms=0.0)
        self.engine = engine
        self.pc = pc
        self.metrics = {"accepted": 0, "rejected": 0, "blocked": 0,
                        "completed": 0, "host_busy_s": 0.0}
        self._cond = threading.Condition()
        self._pending: Deque[_Work] = deque()
        self._ready: Dict[BatchKey, Deque[_Ready]] = {}
        self._ready_count = 0
        self._inflight_host = 0        # popped from intake, not yet ready
        self._arrival_serial = 0
        self._next_ticket = 0
        self._results: Dict[int, GNNRequest] = {}
        self._errors: Dict[int, BaseException] = {}
        self._closed = False
        self._threads: List[threading.Thread] = []
        if not pc.deterministic:
            for i in range(pc.host_workers):
                t = threading.Thread(target=self._host_loop,
                                     name=f"graphserve-host-{i}", daemon=True)
                t.start()
                self._threads.append(t)
            t = threading.Thread(target=self._dispatch_loop,
                                 name="graphserve-dispatch", daemon=True)
            t.start()
            self._threads.append(t)

    # ------------------------------------------------------------- intake
    def submit(self, g: Graph, *, model: str,
               tier: Optional[str] = None,
               fusion: Optional[str] = None,
               deadline_ms: Optional[float] = None,
               tolerance: Optional[float] = None) -> int:
        """Enqueue a one-shot request; returns a ticket (see `drain`).
        `deadline_ms`/`tolerance` opt into the §14 SLO machinery — the
        deadline budget starts HERE, so intake queue wait spends it."""
        return self._accept(_Work(ticket=-1, kind="submit",
                                  submitted_s=self.engine.clock.now(),
                                  model=model, graph=g, tier=tier,
                                  fusion=fusion, deadline_ms=deadline_ms,
                                  tolerance=tolerance))

    def query(self, graph_id: int, *, tier: Optional[str] = None,
              fusion: Optional[str] = None,
              deadline_ms: Optional[float] = None,
              tolerance: Optional[float] = None) -> int:
        """Enqueue a query over an attached graph; returns a ticket."""
        return self._accept(_Work(ticket=-1, kind="query",
                                  submitted_s=self.engine.clock.now(),
                                  graph_id=graph_id, tier=tier,
                                  fusion=fusion, deadline_ms=deadline_ms,
                                  tolerance=tolerance))

    def _accept(self, w: _Work) -> int:
        gov = self.engine.governor
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if gov is not None and gov.should_shed(len(self._pending)):
                # §14 governor shed: quality is already at the floor and
                # the queue keeps growing — drop through the existing
                # reject path regardless of the backpressure mode, counted
                # on both the scheduler and the engine
                self.metrics["rejected"] += 1
                self.engine._count("shed_requests")
                raise QueueFull(
                    f"SLO governor shedding at queue depth "
                    f"{len(self._pending)} (level {gov.level})")
            if len(self._pending) >= self.pc.max_pending:
                if self.pc.backpressure == "reject":
                    self.metrics["rejected"] += 1
                    raise QueueFull(
                        f"intake queue at max_pending={self.pc.max_pending}")
                self.metrics["blocked"] += 1
                if self.pc.deterministic:
                    # inline backpressure: advance the pipeline ourselves
                    # until intake has room (no threads to wait on)
                    while len(self._pending) >= self.pc.max_pending:
                        self._step_inline()
                else:
                    while (len(self._pending) >= self.pc.max_pending
                           and not self._closed):
                        self._cond.wait()
                    if self._closed:
                        raise RuntimeError("scheduler closed while blocked")
            w = dataclasses.replace(w, ticket=self._next_ticket)
            self._next_ticket += 1
            self._pending.append(w)
            self.metrics["accepted"] += 1
            self._cond.notify_all()
            return w.ticket

    # --------------------------------------------------------- host stage
    def _prepare(self, w: _Work) -> GNNRequest:
        if w.kind == "submit":
            return self.engine.prepare_submit(w.graph, model=w.model,
                                              tier=w.tier, fusion=w.fusion,
                                              submitted_s=w.submitted_s,
                                              deadline_ms=w.deadline_ms,
                                              tolerance=w.tolerance)
        return self.engine.prepare_query(w.graph_id, tier=w.tier,
                                         fusion=w.fusion,
                                         submitted_s=w.submitted_s,
                                         deadline_ms=w.deadline_ms,
                                         tolerance=w.tolerance)

    def _host_loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending:
                    return                       # closed and drained
                w = self._pending.popleft()
                self._inflight_host += 1
                self._cond.notify_all()          # intake space freed
            t0 = time.perf_counter()
            req = err = None
            try:
                req = self._prepare(w)
            except BaseException as exc:         # noqa: BLE001 — recorded,
                err = exc                        # re-raised by drain()
            dt = time.perf_counter() - t0
            with self._cond:
                self.metrics["host_busy_s"] += dt
                if err is not None:
                    self._errors[w.ticket] = err
                    self._inflight_host -= 1
                    self.metrics["completed"] += 1
                    self._cond.notify_all()
                    continue
                while self._ready_count >= self.pc.max_ready and not self._closed:
                    self._cond.wait()            # ready full: block intake
                self._push_ready_locked(w.ticket, req)
                self._inflight_host -= 1
                self._cond.notify_all()

    def _push_ready_locked(self, ticket: int, req: GNNRequest) -> None:
        key = (req.model, req.bucket, req.tier, req.backend, req.fusion,
               req.shards)
        self._ready.setdefault(key, deque()).append(
            (self._arrival_serial, self.engine.clock.now(), req))
        self._arrival_serial += 1
        self._ready_count += 1
        self._results[ticket] = req

    # ------------------------------------------------------- device stage
    def _expire_ready_locked(self) -> int:
        """§14 expiry sweep over the ready buffer: requests whose deadline
        already passed complete flagged (engine `_complete_expired` —
        `deadline_missed=True`, no preds) instead of occupying batch
        slots. Returns how many were swept; callers re-check the ready
        count afterwards. Runs under `_cond`; the engine call takes the
        engine lock, which is always safe in this order (never the
        reverse)."""
        now = self.engine.clock.now()
        expired: List[GNNRequest] = []
        for key in list(self._ready):
            q = self._ready[key]
            keep = deque(item for item in q
                         if not (item[2].deadline_s is not None
                                 and item[2].deadline_s <= now))
            if len(keep) != len(q):
                expired.extend(item[2] for item in q
                               if item[2].deadline_s is not None
                               and item[2].deadline_s <= now)
                if keep:
                    self._ready[key] = keep
                else:
                    del self._ready[key]
        if expired:
            self._ready_count -= len(expired)
            self.engine._complete_expired(expired, now)
            self.metrics["completed"] += len(expired)
        return len(expired)

    def _select_locked(self) -> BatchKey:
        now = self.engine.clock.now()
        stats = {}
        for k, q in self._ready.items():
            slack = min((item[2].deadline_s - now
                         if item[2].deadline_s is not None else float("inf"))
                        for item in q)
            stats[k] = (len(q), q[0][0], slack)
        return edf_best_fill_key(stats, self.engine.sc.batch_slots,
                                 self.engine._last_dispatch,
                                 replica_slots=self.engine.sc.replica_groups)

    def _width(self, key: BatchKey) -> int:
        """Dispatch width of one batch key: sharded keys (§12) fill the
        replica rows of the mesh (§15; width-1 when `replica_groups` is
        1 — the shard axis occupies the dim a batch would use), unsharded
        keys fill the batch slots."""
        return (self.engine.sc.replica_groups if key[5]
                else self.engine.sc.batch_slots)

    def _take_locked(self, key: BatchKey) -> List[GNNRequest]:
        q = self._ready[key]
        n = min(self._width(key), len(q))
        batch = [q.popleft()[2] for _ in range(n)]
        if not q:
            del self._ready[key]
        self._ready_count -= n
        return batch

    def _dispatch_loop(self) -> None:
        window_s = self.pc.window_ms * 1e-3
        while True:
            with self._cond:
                batch = None
                while batch is None:
                    if self._ready_count == 0:
                        if (self._closed and not self._pending
                                and self._inflight_host == 0):
                            return
                        self._cond.wait()        # device idle: nothing ready
                        continue
                    if self._expire_ready_locked():
                        # §14: expired requests completed without a
                        # dispatch — ready space freed, re-evaluate
                        self._cond.notify_all()
                        continue
                    key = self._select_locked()
                    fill = len(self._ready[key])
                    unready = len(self._pending) + self._inflight_host
                    if (fill < self._width(key) and unready > 0
                            and window_s > 0):
                        # batch window: stragglers are still in the host
                        # stage — wait (bounded by the key's oldest arrival
                        # + window) for a fuller batch before going partial
                        deadline = self._ready[key][0][1] + window_s
                        now = self.engine.clock.now()
                        if now < deadline:
                            self._cond.wait(deadline - now)
                            continue
                    batch = self._take_locked(key)
                    self._cond.notify_all()      # ready space freed
            self.engine._execute_batch(batch)
            with self._cond:
                self.metrics["completed"] += len(batch)
                self._cond.notify_all()

    # ------------------------------------------------- deterministic drive
    def _step_inline(self) -> None:
        """Advance the inline pipeline by one step: prefer host work (FIFO),
        dispatch one best-fill batch when the ready buffer is full (or when
        only ready work remains). Deterministic mode only."""
        if self._pending and self._ready_count < self.pc.max_ready:
            w = self._pending.popleft()
            t0 = time.perf_counter()
            req = self._prepare(w)               # inline: errors propagate
            self.metrics["host_busy_s"] += time.perf_counter() - t0
            self._push_ready_locked(w.ticket, req)
            return
        if self._ready_count:
            self._expire_ready_locked()          # §14 sweep before select
        if self._ready_count:
            batch = self._take_locked(self._select_locked())
            self.engine._execute_batch(batch)
            self.metrics["completed"] += len(batch)

    # ------------------------------------------------------------ lifecycle
    def drain(self, timeout: Optional[float] = None) -> List[GNNRequest]:
        """Run/wait until every accepted request completed; return them in
        ticket order. Host-stage errors (earliest ticket first) are
        re-raised — and CONSUMED, so a caller that catches the error can
        call `drain()` again to retrieve the successfully completed
        requests (an errored ticket simply has no result)."""
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        if self.pc.deterministic:
            while self._pending or self._ready_count:
                if deadline is not None and time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"{len(self._pending) + self._ready_count} "
                        "request(s) still undispatched")
                self._step_inline()
        else:
            with self._cond:
                while self.metrics["completed"] < self.metrics["accepted"]:
                    left = (deadline - time.perf_counter()
                            if deadline is not None else None)
                    if left is not None and left <= 0:
                        raise TimeoutError(
                            f"{self.metrics['accepted'] - self.metrics['completed']}"
                            " request(s) still in flight")
                    self._cond.wait(left)
        if self._errors:
            errors, self._errors = self._errors, {}
            raise errors[min(errors)]
        return [self._results[t] for t in sorted(self._results)]

    def close(self) -> None:
        """Stop accepting, finish outstanding work, join the threads.
        Idempotent; the engine stays usable afterwards."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self.pc.deterministic:
            while self._pending or self._ready_count:
                self._step_inline()
        for t in self._threads:
            t.join()
        self._threads = []

    def __enter__(self) -> "PipelineScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- metrics
    def summary(self) -> Dict[str, object]:
        """Engine summary (device_busy_s / device_idle_fraction included)
        plus the pipeline's own counters under `"pipeline"`."""
        s = self.engine.summary()
        s["pipeline"] = {
            "host_workers": self.pc.host_workers,
            "window_ms": self.pc.window_ms,
            "deterministic": self.pc.deterministic,
            "accepted": self.metrics["accepted"],
            "completed": self.metrics["completed"],
            "rejected": self.metrics["rejected"],
            "blocked": self.metrics["blocked"],
            "host_busy_s": self.metrics["host_busy_s"],
        }
        return s
