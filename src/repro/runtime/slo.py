"""SLO governor: rolling-p99 watcher with hysteresis (DESIGN.md §14).

The governor closes GraNNite's quality-for-latency dial from the serving
side: it watches the rolling request latency p99 (and the intake queue
depth) against a configured target and, when the target is breached for
`breach_checks` consecutive observations, steps the DEFAULT quality tier
one rung down the ladder (fp32 → int8 → int8+grax).  When the breach
clears for `clear_checks` consecutive observations it steps back up.
The asymmetric check counts are the hysteresis: a single slow batch
cannot flip the tier, and a single fast one cannot flip it back, so the
system never oscillates on measurement noise.

At the bottom rung the governor has no quality left to trade; when the
queue depth ALSO exceeds `max_queue_depth` it asks the intake path to
shed load (`should_shed`), which the pipeline scheduler turns into the
existing reject/QueueFull path.

The governor only steers requests that pinned NEITHER a tier NOR a
tolerance — an explicit request is a contract the governor never
overrides.  All of its state advances in `observe()`, which the engine
calls once per completed request under the engine lock with
clock-derived latencies, so a fake clock drives the whole cycle
deterministically.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SLOConfig:
    target_p99_ms: float = 50.0      # rolling-p99 latency target
    window: int = 64                 # rolling window size (requests)
    min_samples: int = 4             # no verdicts before this many samples
    breach_checks: int = 3           # consecutive breaches -> downgrade
    clear_checks: int = 6            # consecutive clears -> upgrade
    max_queue_depth: int = 64        # shed threshold at the bottom rung
    # quality-descending tier ladder the governor walks; intersected with
    # each model's registered tiers at override time
    ladder: Tuple[str, ...] = ("fp32", "int8", "int8+grax")


class SLOGovernor:
    """Hysteretic tier-downgrade controller over a rolling latency window."""

    def __init__(self, cfg: Optional[SLOConfig] = None):
        self.cfg = cfg or SLOConfig()
        self._lat: deque = deque(maxlen=self.cfg.window)
        self.level = 0                   # rungs below the default tier
        self.downgrades = 0              # level-raise transitions (counted)
        self.upgrades = 0                # level-drop transitions
        self._breach_streak = 0
        self._clear_streak = 0

    @property
    def max_level(self) -> int:
        return len(self.cfg.ladder) - 1

    def p99_ms(self) -> Optional[float]:
        if len(self._lat) < self.cfg.min_samples:
            return None
        return float(np.percentile(np.asarray(self._lat), 99) * 1e3)

    def observe(self, latency_s: float) -> None:
        """Feed one completed-request latency; run the hysteresis step."""
        self._lat.append(float(latency_s))
        p99 = self.p99_ms()
        if p99 is None:
            return
        if p99 > self.cfg.target_p99_ms:
            self._breach_streak += 1
            self._clear_streak = 0
            if (self._breach_streak >= self.cfg.breach_checks
                    and self.level < self.max_level):
                self.level += 1
                self.downgrades += 1
                self._breach_streak = 0
        else:
            self._clear_streak += 1
            self._breach_streak = 0
            if (self._clear_streak >= self.cfg.clear_checks
                    and self.level > 0):
                self.level -= 1
                self.upgrades += 1
                self._clear_streak = 0

    def tier_override(self, default_tier: str,
                      registered: Sequence[str]) -> Optional[str]:
        """Tier to serve a no-preference request at the current level.

        None at level 0 (serve the model default).  Otherwise walk the
        configured ladder, restricted to tiers the model actually
        registered, `level` rungs below the default.  Saturates at the
        bottom rung — beyond that the only lever left is shedding.
        """
        if self.level == 0:
            return None
        ladder: List[str] = [t for t in self.cfg.ladder if t in registered]
        if not ladder:
            return None
        start = ladder.index(default_tier) if default_tier in ladder else 0
        return ladder[min(start + self.level, len(ladder) - 1)]

    def should_shed(self, queue_depth: int) -> bool:
        """True when quality is exhausted AND the queue keeps growing."""
        return (self.level >= self.max_level
                and queue_depth >= self.cfg.max_queue_depth)
