"""Shared EWMA machinery: trainer straggler gate + serving latency bank.

One owner for every exponentially-weighted average in the runtime
(DESIGN.md §14).  Two consumers:

* `StragglerGate` — the trainer's per-step straggler detector
  (previously inlined in `runtime/trainer.py`).  The old inline code
  seeded the EWMA with the FIRST sample at weight 1.0
  (``ewma = wall if ewma is None else 0.9*ewma + 0.1*wall``), so the
  compile-heavy first step dominated the baseline for dozens of steps
  and masked real stragglers.  `Ewma` fixes that with standard bias
  correction: early samples share weight symmetrically, so the estimate
  after k samples is a proper weighted mean of all k, not 90% first
  sample.

* `LatencyBank` — the serving cost oracle.  Per BatchKey-shaped key it
  keeps a bias-corrected EWMA of measured `_execute_batch` wall spans,
  seeded (for prediction only — the seed never blends into the average)
  from the analytic roofline model.  Routing decisions
  (`select_agg_backend` measured override, tolerance tier router,
  governor p99) read predictions from here, so the model supplies the
  cold-start ordering and measurement takes over as soon as samples
  exist.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Hashable, Optional


class Ewma:
    """Bias-corrected exponential moving average.

    Maintains ``s = (1-a)*s + a*x`` and ``den = (1-a)*den + a`` with
    ``value = s/den`` — after one sample the value IS that sample, after
    k samples it is the bias-corrected weighted mean (geometric weights
    renormalized over the samples actually seen).  This removes the
    first-sample asymmetry of the naive ``ewma or x`` seeding: a single
    outlier first observation decays at the same rate as any other
    sample instead of anchoring the series.
    """

    __slots__ = ("alpha", "_s", "_den", "count", "min", "max")

    def __init__(self, alpha: float = 0.1):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._s = 0.0
        self._den = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, x: float) -> float:
        a = self.alpha
        self._s = (1.0 - a) * self._s + a * float(x)
        self._den = (1.0 - a) * self._den + a
        self.count += 1
        if x < self.min:
            self.min = float(x)
        if x > self.max:
            self.max = float(x)
        return self.value

    @property
    def value(self) -> Optional[float]:
        if self.count == 0:
            return None
        return self._s / self._den


class StragglerGate:
    """Trainer straggler detector over a bias-corrected EWMA baseline.

    A step is a straggler when ``wall > factor * baseline``; straggler
    samples are excluded from the baseline (they are what the baseline
    exists to detect).  The first sample always trains the baseline —
    with bias correction it no longer anchors it.
    """

    def __init__(self, factor: float, alpha: float = 0.1):
        self.factor = float(factor)
        self._ewma = Ewma(alpha)

    @property
    def baseline(self) -> Optional[float]:
        return self._ewma.value

    def check(self, wall: float) -> bool:
        """Record one step wall-time; return True when it straggled."""
        base = self._ewma.value
        straggler = base is not None and wall > self.factor * base
        if not straggler:
            self._ewma.observe(wall)
        return straggler


@dataclass
class _BankEntry:
    ewma: Ewma
    seed: Optional[float] = None  # roofline-modelled seconds, prediction-only


class LatencyBank:
    """Per-key measured-latency oracle with model-seeded cold start.

    Keys are whatever tuple the caller routes on — GraphServe uses
    ``(kind, bucket, tier, backend, fusion, shards)``.  `predict` returns
    the measured EWMA when samples exist, else the seed registered by
    `seed` (typically the analytic roofline figure), else None.  The seed
    intentionally never mixes into the average: predictions stay inside
    ``[min, max]`` of the observed samples once any exist, which is the
    invariant the hypothesis suite pins.
    """

    def __init__(self, alpha: float = 0.2):
        self.alpha = float(alpha)
        self._entries: Dict[Hashable, _BankEntry] = {}

    def _entry(self, key: Hashable) -> _BankEntry:
        e = self._entries.get(key)
        if e is None:
            e = self._entries[key] = _BankEntry(Ewma(self.alpha))
        return e

    def seed(self, key: Hashable, modelled_s: float) -> None:
        """Register the model-predicted latency for a cold key."""
        self._entry(key).seed = float(modelled_s)

    def observe(self, key: Hashable, seconds: float) -> None:
        self._entry(key).ewma.observe(float(seconds))

    def predict(self, key: Hashable) -> Optional[float]:
        e = self._entries.get(key)
        if e is None:
            return None
        if e.ewma.count > 0:
            return e.ewma.value
        return e.seed

    def measured(self, key: Hashable) -> Optional[float]:
        """Measured EWMA only — None until a real sample lands."""
        e = self._entries.get(key)
        if e is None or e.ewma.count == 0:
            return None
        return e.ewma.value

    def samples(self, key: Hashable) -> int:
        e = self._entries.get(key)
        return 0 if e is None else e.ewma.count

    def measured_pair(
        self,
        match: Callable[[Hashable], bool],
        backend_of: Callable[[Hashable], str],
    ) -> Dict[str, float]:
        """Best (minimum) measured latency per backend over matching keys.

        Used by the backend router: for a given (kind, bucket) it asks
        "what is the cheapest measured latency we have seen through each
        aggregation backend?" — the override only fires when BOTH
        backends have real samples, so an unmeasured path can never be
        condemned by the model alone.
        """
        best: Dict[str, float] = {}
        for key, e in self._entries.items():
            if e.ewma.count == 0 or not match(key):
                continue
            b = backend_of(key)
            v = e.ewma.value
            if b not in best or v < best[b]:
                best[b] = v
        return best

    def ewma_vs_model(self) -> Optional[float]:
        """Mean measured/modelled ratio over keys holding both figures.

        The serving summary exposes this as the drift signal between the
        roofline seed and reality — 1.0 means the model prices batches
        exactly; the BENCH grasp inversion shows up as a ratio far from 1
        on the grasp keys.
        """
        ratios = [
            e.ewma.value / e.seed
            for e in self._entries.values()
            if e.ewma.count > 0 and e.seed and e.seed > 0
        ]
        if not ratios:
            return None
        return sum(ratios) / len(ratios)

    def keys(self):
        return list(self._entries.keys())
