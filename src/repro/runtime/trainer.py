"""Training runtime: microbatched pjit trainer with fault tolerance.

Large-scale features (design target: 1000+ nodes; everything below runs
identically on 1 CPU device and the 512-way dry-run mesh):

  * MICROBATCHING — the global batch is split into `microbatches` slices;
    grads accumulate in a lax.scan. XLA keeps the gradient all-reduce off
    the critical path until the last microbatch (compute/comm overlap: each
    microbatch's backward overlaps the previous accumulation arithmetic).
  * FAULT TOLERANCE — steps run under a supervisor loop: any exception
    triggers restore-from-latest-checkpoint and a deterministic data-stream
    rewind (TokenStream.batch_at(step) is stateless in `step`). A failure
    injector is wired for tests/chaos drills.
  * STRAGGLER MITIGATION — per-step wall-clock EWMA; steps slower than
    `straggler_factor`× the EWMA are logged with their step index. On real
    multi-host deployments this signal feeds the pod-manager's
    replace-or-reshard decision; here it drives metrics + an optional hook.
  * ELASTIC RESTART — checkpoints are topology-free (ckpt module); `restore`
    accepts a different mesh and reshards (tested by tests/test_ckpt.py).
  * GRAD COMPRESSION — optional int8 gradient all-reduce (dist.compress)
    on the explicit-DDP path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.data.synthetic import TokenStream
from repro.dist import sharding as shd
from repro.nn import lm
from repro.nn.config import ArchConfig
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               linear_warmup_cosine)
from repro.runtime.ewma import StragglerGate


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    microbatches: int = 1
    lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


def make_train_step(cfg: ArchConfig, tc: TrainConfig,
                    mesh=None) -> Callable:
    """Build the jitted (params, opt, batch, step) -> (params, opt, metrics).

    Microbatch accumulation happens inside one jit so the compiler can
    overlap the per-microbatch backward with the running accumulation and
    defer the cross-data-axis all-reduce to the last slice.
    """

    def loss_fn(params, batch):
        loss, metrics = lm.lm_loss(params, cfg, batch)
        return loss, metrics

    def step_fn(params, opt, batch, step):
        n_micro = tc.microbatches

        if n_micro > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            def reshape(x):
                b = x.shape[0]
                y = x.reshape((n_micro, b // n_micro) + x.shape[1:])
                return shd.constrain_scan_slices(y)

            mbs = jax.tree_util.tree_map(reshape, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)

        grads, gnorm = clip_by_global_norm(grads, tc.clip_norm)
        # Degenerate configs (warmup >= total steps, e.g. smoke runs that
        # shrink steps but keep the default warmup) would otherwise spend the
        # whole run inside the ramp; explicit sane warmups are untouched.
        warmup = (tc.warmup_steps if tc.warmup_steps < tc.steps
                  else max(1, tc.steps // 4))
        lr = linear_warmup_cosine(step, base_lr=tc.lr,
                                  warmup_steps=warmup,
                                  total_steps=tc.steps)
        new_params, new_opt = adamw_update(params, grads, opt, lr=lr,
                                           weight_decay=tc.weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, metrics

    if mesh is None:
        return jax.jit(step_fn)

    # pjit: params sharded by logical axes, batch by data axes.
    def jit_with_shardings(params_example):
        pspec = shd.param_shardings(params_example, mesh)
        ospec = {"m": pspec, "v": pspec, "count": shd.scalar_sharding(mesh)}
        return jax.jit(
            step_fn,
            in_shardings=(pspec, ospec, None, None),
            out_shardings=(pspec, ospec, None),
        )
    return jit_with_shardings


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    wall_s: float
    straggler: bool


class Trainer:
    """Supervised training loop with restart-on-failure."""

    def __init__(self, cfg: ArchConfig, tc: TrainConfig, *,
                 params=None, failure_injector: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.tc = tc
        key = jax.random.PRNGKey(tc.seed)
        self.params = params if params is not None else lm.lm_init(key, cfg)
        self.opt = adamw_init(self.params)
        self.step = 0
        self.stream = TokenStream(vocab_size=cfg.vocab_size,
                                  seq_len=tc.seq_len,
                                  global_batch=tc.global_batch, seed=tc.seed)
        self.train_step = make_train_step(cfg, tc)
        self.ckpt = (CheckpointManager(tc.ckpt_dir, keep=tc.ckpt_keep,
                                       every=tc.ckpt_every)
                     if tc.ckpt_dir else None)
        self.failure_injector = failure_injector
        self.history: List[StepRecord] = []
        self.restarts = 0
        # Shared bias-corrected EWMA owner (runtime/ewma.py): the old
        # inline `ewma = wall if ewma is None else ...` seeded the
        # baseline with the compile-heavy first step at weight 1.0.
        self._straggler = StragglerGate(tc.straggler_factor, alpha=0.1)

    # -- fault-tolerance plumbing ------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt,
                "step": jnp.asarray(self.step, jnp.int32)}

    def _save(self, force: bool = False):
        if self.ckpt:
            self.ckpt.maybe_save(self.step, self._state_tree(), force=force)

    def _restore(self):
        if not self.ckpt:
            raise
        restored_step, tree = self.ckpt.restore_latest(self._state_tree())
        if restored_step is None:
            # no checkpoint yet: restart from scratch (step 0)
            key = jax.random.PRNGKey(self.tc.seed)
            self.params = lm.lm_init(key, self.cfg)
            self.opt = adamw_init(self.params)
            self.step = 0
        else:
            self.params, self.opt = tree["params"], tree["opt"]
            self.step = int(tree["step"])
        self.restarts += 1

    # -- main loop -----------------------------------------------------------
    def run(self, *, max_failures: int = 3) -> List[StepRecord]:
        failures = 0
        while self.step < self.tc.steps:
            try:
                self._run_until_done()
                break
            except Exception:
                failures += 1
                if failures > max_failures:
                    raise
                self._restore()
        if self.ckpt:
            self._save(force=True)
            self.ckpt.wait()
        return self.history

    def _run_until_done(self):
        while self.step < self.tc.steps:
            if self.failure_injector is not None:
                self.failure_injector(self.step)
            batch = {k: jnp.asarray(v)
                     for k, v in self.stream.batch_at(self.step).items()}
            t0 = time.perf_counter()
            self.params, self.opt, metrics = self.train_step(
                self.params, self.opt, batch, jnp.asarray(self.step))
            loss = float(metrics["loss"])
            wall = time.perf_counter() - t0
            # on a pod: a straggler step is reported to the job manager.
            # The gate excludes stragglers from its own baseline.
            straggler = self._straggler.check(wall)
            self.history.append(StepRecord(self.step, loss, wall, straggler))
            self.step += 1
            self._save()

    # -- metrics -------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        losses = [r.loss for r in self.history]
        return {
            "steps": self.step,
            "restarts": self.restarts,
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "stragglers": sum(r.straggler for r in self.history),
            "mean_step_s": float(np.mean([r.wall_s for r in self.history]))
            if self.history else None,
        }
