from . import gnn_server, scheduler, server, trainer  # noqa: F401
