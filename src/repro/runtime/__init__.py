from . import gnn_server, server, trainer  # noqa: F401
