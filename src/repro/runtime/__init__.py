from . import trainer, server  # noqa: F401
