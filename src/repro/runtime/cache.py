"""Byte-budgeted device cache for GraphServe's operand hierarchy (§13).

CacheG (DESIGN.md §7) keeps four device-resident forms per attached graph
— the fp32 operand set, the derived int8 Â, the derived GraSp structure,
and the sharded slice tuple — all keyed by (graph_id, structure_version)
and NOTHING else. Unbounded, that pins O(cap²) device bytes per graph and
OOMs long before production graph counts. This module bounds it:

  * every entry carries its MEASURED device-byte cost (`pytree_nbytes` of
    the actual leaves, not an estimate) and a re-materialization cost
    estimate (`remat_s`);
  * eviction is cost-aware LRU against `budget_bytes`: victims are picked
    least-recently-used GRAPH first (group recency — the max `last_use`
    across a key's entries — so a hot derived form keeps its primary
    resident), derived entries before the primary they hang off
    (`KIND_RANK`), cheapest re-materialization first among peers;
  * evicted primaries optionally spill to a host-RAM compact form (the
    SymG bit-packed `HostOperands`, ~64x smaller than the dense operand)
    produced by the entry's `spill_fn` at eviction time; a later fault
    re-materializes from the spilled form instead of re-running the host
    build. Entries whose `spill_fn` is None or declines (directed graphs,
    sharded slices — their host source survives in the engine registry)
    are dropped instead. Conservation: evictions == spilled + dropped.

Lifecycle vs capacity: `invalidate()` (update/detach removing a dead
version) is NOT an eviction — it touches no counter, so the eviction
metrics measure memory pressure, never graph churn.

NOT thread-safe by itself: GraphServe calls every method under its own
`_lock`, the same lock that already guards the caches this replaces.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax

Key = Tuple[int, int]                    # (graph_id, structure_version)

# derived forms (rank 0) evict before the primary they hang off (rank 1)
KIND_RANK = {"tier": 0, "grasp": 0, "operand": 1, "shard": 1}
PRIMARY_KINDS = ("operand", "shard")


class CacheAdmissionError(RuntimeError):
    """attach() admission control rejected a graph: its primary operand
    entry cannot fit the configured `device_cache_budget_bytes` (or the
    policy is "reject" and the budget is full)."""


def pytree_nbytes(tree) -> int:
    """Measured device bytes of a cached value: the sum of its leaves'
    buffer sizes (jnp and np both expose `.nbytes`; non-array leaves,
    e.g. the grasp backend string, cost nothing device-side)."""
    return int(sum(getattr(leaf, "nbytes", 0)
                   for leaf in jax.tree_util.tree_leaves(tree)))


def estimate_dense_entry_bytes(num_fields: int, capacity: int) -> int:
    """Projected device cost of one unsharded fp32 operand entry: the
    kind's populated (cap, cap) fields plus the (1, 1) placeholder holes
    (`materialize_operands` / `build_operands(lean=True)` layout)."""
    return num_fields * capacity * capacity * 4 + (5 - num_fields) * 4


def estimate_shard_entry_bytes(shards: int, shard_cap: int, full_rows: int,
                               num_fields: int, in_feats: int) -> int:
    """Projected device cost of one sharded slice-tuple entry: per shard,
    the kind's (shard_cap, full_rows) operand row blocks plus holes, the
    (shard_cap, F) feature block, and the (shard_cap,) node mask."""
    per = (num_fields * shard_cap * full_rows * 4 + (5 - num_fields) * 4
           + shard_cap * in_feats * 4 + shard_cap * 4)
    return shards * per


@dataclasses.dataclass
class CacheEntry:
    kind: str
    key: Key
    value: object
    nbytes: int
    remat_s: float = 0.0
    spill_fn: Optional[Callable[[], Optional[object]]] = None
    last_use: int = 0


class DeviceCacheManager:
    """The four operand caches behind one byte budget (DESIGN.md §13)."""

    def __init__(self, *, budget_bytes: Optional[int] = None,
                 spill_to_host: bool = True):
        if budget_bytes is not None and budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be positive or None, "
                             f"got {budget_bytes}")
        self.budget_bytes = budget_bytes
        self.spill_to_host = spill_to_host
        self._entries: Dict[Tuple[str, Key], CacheEntry] = {}
        self._spill: Dict[Tuple[str, Key], object] = {}
        self._clock = 0
        self._resident = 0
        self.evictions = 0
        self.spilled = 0
        self.dropped = 0
        self.spill_hits = 0

    # ------------------------------------------------------------- accounting
    @property
    def resident_bytes(self) -> int:
        return self._resident

    @property
    def spill_entries(self) -> int:
        return len(self._spill)

    def entry_sizes(self) -> Dict[Tuple[str, Key], int]:
        """Per-entry measured costs (tests assert their sum equals
        `resident_bytes` — the byte-accounting invariant)."""
        return {k: e.nbytes for k, e in self._entries.items()}

    def view(self, kind: str) -> Dict[Key, object]:
        """Snapshot of one kind's entries as a plain {key: value} dict —
        the shape the four caches had before the manager existed."""
        return {e.key: e.value for e in self._entries.values()
                if e.kind == kind}

    # -------------------------------------------------------------- hit paths
    def get(self, kind: str, key: Key):
        e = self._entries.get((kind, key))
        if e is None:
            return None
        self._clock += 1
        e.last_use = self._clock
        return e.value

    def spill_get(self, kind: str, key: Key):
        """Second-level hit: the host-RAM compact form of an evicted
        primary, if one was spilled. Non-destructive — the spilled form
        stays valid for the key's whole lifetime (structure is immutable
        per version), so a re-eviction never re-packs."""
        payload = self._spill.get((kind, key))
        if payload is not None:
            self.spill_hits += 1
        return payload

    # ------------------------------------------------------------ admission
    def fits(self, nbytes: int) -> bool:
        """Can an entry of this size EVER be resident (evicting everything
        else)? attach() admission control asks this before registering."""
        return self.budget_bytes is None or nbytes <= self.budget_bytes

    def would_overflow(self, nbytes: int) -> bool:
        """Would inserting this size require eviction right now? The
        "reject" admission policy refuses attach() in that case."""
        return (self.budget_bytes is not None
                and self._resident + nbytes > self.budget_bytes)

    # --------------------------------------------------------------- mutation
    def put(self, kind: str, key: Key, value, *, nbytes: int,
            remat_s: float = 0.0,
            spill_fn: Optional[Callable[[], Optional[object]]] = None,
            protect: Iterable[Key] = ()) -> bool:
        """Insert (or refresh — racing double-builds produce identical
        values) one entry, evicting until it fits. Returns False when the
        entry alone exceeds the whole budget: the value is NOT cached (the
        caller serves it transiently; the next query rebuilds) — a single
        oversized entry must never break the resident<=budget invariant.
        `protect` keys (plus the inserted key) are never victims, so a
        derived insert cannot evict the primary it derives from."""
        if self.budget_bytes is not None and nbytes > self.budget_bytes:
            return False
        old = self._entries.get((kind, key))
        if old is not None:
            self._resident -= old.nbytes
        self._evict_until(nbytes, protect=set(protect) | {key})
        self._clock += 1
        self._entries[(kind, key)] = CacheEntry(
            kind=kind, key=key, value=value, nbytes=nbytes,
            remat_s=remat_s, spill_fn=spill_fn, last_use=self._clock)
        self._resident += nbytes
        return True

    def invalidate(self, key: Key) -> int:
        """Lifecycle removal (update()/detach() retiring a version): drop
        every kind's entry AND any spilled form at this key. No-op on
        never-populated keys; never counted as an eviction."""
        removed = 0
        for kind in KIND_RANK:
            e = self._entries.pop((kind, key), None)
            if e is not None:
                self._resident -= e.nbytes
                removed += 1
            if self._spill.pop((kind, key), None) is not None:
                removed += 1
        return removed

    # --------------------------------------------------------------- eviction
    def _evict_until(self, need: int, protect: set) -> None:
        if self.budget_bytes is None:
            return
        while self._resident + need > self.budget_bytes:
            victim = self._pick_victim(protect)
            if victim is None:
                return               # everything left is protected
            self._evict(victim)

    def _pick_victim(self, protect: set) -> Optional[CacheEntry]:
        candidates = [e for e in self._entries.values()
                      if e.key not in protect]
        if not candidates:
            return None
        recency: Dict[Key, int] = {}
        for e in candidates:
            recency[e.key] = max(recency.get(e.key, 0), e.last_use)
        return min(candidates,
                   key=lambda e: (recency[e.key], KIND_RANK[e.kind],
                                  e.remat_s, e.last_use))

    def _evict(self, e: CacheEntry) -> None:
        del self._entries[(e.kind, e.key)]
        self._resident -= e.nbytes
        self.evictions += 1
        spill_key = (e.kind, e.key)
        if (self.spill_to_host and e.kind in PRIMARY_KINDS
                and spill_key in self._spill):
            self.spilled += 1        # re-eviction: the packed form persists
            return
        payload = None
        if self.spill_to_host and e.kind in PRIMARY_KINDS \
                and e.spill_fn is not None:
            payload = e.spill_fn()
        if payload is not None:
            self._spill[spill_key] = payload
            self.spilled += 1
        else:
            self.dropped += 1
