"""Serving runtime: NodePad-bucketed prefill + GrAd-cursor batched decode.

The paper's Step-1 enablement maps directly onto LM serving:

  * NodePad  — prompts are padded to one of a fixed set of BUCKET lengths and
    the KV cache to one max_len, so the jit cache holds exactly
    len(buckets)+1 compiled blobs, independent of request shapes. The server
    asserts zero recompiles after warmup.
  * GrAd     — per-slot cache cursors are runtime *inputs* (pos vector), so
    evolving sequence state never triggers recompilation — the same
    mask-as-argument discipline as dynamic graphs.
  * GraphSplit — tokenization/queueing/detokenization (control-heavy) stay on
    the host; the device executes only the dense compiled steps.

Two scheduling modes:
  * "continuous" — per-slot positions; finished slots are refilled from the
    queue every decode step (right-padded prefill; attention archs).
  * "wave"       — lockstep batches (SSM/hybrid archs: the recurrent state
    has no per-slot rewind, so waves keep prefill exact).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import lm
from repro.nn.config import ArchConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int = 16
    done: bool = False
    output: Optional[np.ndarray] = None
    submitted_s: float = 0.0
    finished_s: float = 0.0


@dataclasses.dataclass
class ServeConfig:
    buckets: tuple = (64, 128, 256)     # NodePad prompt buckets
    max_len: int = 512                  # cache capacity (prompt + decode)
    batch_slots: int = 4                # decode batch width
    mode: str = "continuous"            # continuous | wave
    eos_token: int = -1                 # <0: run until max_new_tokens


class Server:
    def __init__(self, cfg: ArchConfig, sc: ServeConfig, params=None, *,
                 seed: int = 0):
        self.cfg = cfg
        self.sc = sc
        if cfg.attention_free or cfg.layer_pattern in ("ssm", "jamba"):
            # recurrent state integrates right-padding junk; use exact waves
            self.sc = dataclasses.replace(sc, mode="wave")
        self.params = params if params is not None else lm.lm_init(
            jax.random.PRNGKey(seed), cfg)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.compile_count = 0
        self._compiled: Dict[Any, Callable] = {}
        self.metrics = {"prefills": 0, "decode_steps": 0, "tokens_out": 0,
                        "queue_wait_s": []}

    # ------------------------------------------------------------------ API
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 16) -> int:
        uid = len(self.queue) + len(self.finished)
        self.queue.append(Request(uid=uid, prompt=np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new_tokens,
                                  submitted_s=time.perf_counter()))
        return uid

    def bucket_for(self, length: int) -> int:
        for b in self.sc.buckets:
            if length <= b:
                return b
        raise ValueError(f"prompt length {length} exceeds largest bucket "
                         f"{self.sc.buckets[-1]}")

    # ----------------------------------------------------------- compiled fns
    def _prefill_fn(self, bucket: int) -> Callable:
        key = ("prefill", bucket)
        if key not in self._compiled:
            cfg, sc = self.cfg, self.sc

            @jax.jit
            def fn(params, tokens, prompt_lens):
                logits, state = lm.lm_prefill(params, cfg, tokens,
                                              max_len=sc.max_len)
                # per-slot last REAL token logits (right-padded prompts)
                h_pos = prompt_lens - 1
                return logits, state, h_pos
            self._compiled[key] = fn
            self.compile_count += 1
        return self._compiled[key]

    def _decode_fn(self) -> Callable:
        key = ("decode",)
        if key not in self._compiled:
            cfg = self.cfg

            # donate caches: single resident cache copy (GrAd in-place cursor)
            @functools.partial(jax.jit, donate_argnums=(2,))
            def fn(params, token, caches, pos, enc_kv):
                state = lm.ServeState(caches=caches, pos=pos, enc_kv=enc_kv)
                logits, state = lm.lm_decode_step(params, cfg, token, state)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return nxt, state.caches, state.pos
            self._compiled[key] = fn
            self.compile_count += 1
        return self._compiled[key]

    # ------------------------------------------------------------- scheduling
    def run(self) -> List[Request]:
        while self.queue:
            self._run_wave()
        return self.finished

    def _take_batch(self) -> List[Request]:
        batch = self.queue[: self.sc.batch_slots]
        self.queue = self.queue[self.sc.batch_slots:]
        return batch

    def _run_wave(self):
        """One wave: pad a batch of prompts to a common bucket, prefill,
        decode lockstep (per-slot GrAd cursors still honored)."""
        batch = self._take_batch()
        if not batch:
            return
        b = self.sc.batch_slots
        lens = [len(r.prompt) for r in batch]
        bucket = self.bucket_for(max(lens))
        toks = np.zeros((b, bucket), np.int32)
        plens = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            toks[i, : lens[i]] = r.prompt
            plens[i] = lens[i]
        for i in range(len(batch), b):      # empty slots decode junk, dropped
            plens[i] = 1

        prefill = self._prefill_fn(bucket)
        logits, state, h_pos = prefill(self.params, jnp.asarray(toks),
                                       jnp.asarray(plens))
        self.metrics["prefills"] += 1
        for r in batch:
            self.metrics["queue_wait_s"].append(
                time.perf_counter() - r.submitted_s)

        # wave decode starts at per-slot prompt length (GrAd cursor vector);
        # prefill wrote cache rows [0, bucket), real content [0, plen).
        if self.sc.mode == "continuous":
            pos = jnp.asarray(plens, jnp.int32)
        else:
            pos = jnp.asarray(int(max(lens)), jnp.int32)

        # first token: greedy from the last real prompt position.
        # lm_prefill returned last-PADDED-position logits; for exactness we
        # re-decode from per-slot cursors, so only seed tokens differ for
        # padded slots — wave mode uses max-len (exact), continuous re-reads.
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        decode = self._decode_fn()
        steps = max(r.max_new_tokens for r in batch)
        outs = np.zeros((b, steps), np.int32)
        outs[:, 0] = np.asarray(tok)     # first token comes from prefill
        caches = state.caches
        enc_kv = state.enc_kv
        for t in range(1, steps):
            tok, caches, pos = decode(self.params, tok, caches, pos, enc_kv)
            outs[:, t] = np.asarray(tok)
            self.metrics["decode_steps"] += 1

        now = time.perf_counter()
        for i, r in enumerate(batch):
            n = r.max_new_tokens
            r.output = outs[i, :n]
            r.done = True
            r.finished_s = now
            self.metrics["tokens_out"] += int(n)
            self.finished.append(r)

    # ---------------------------------------------------------------- metrics
    def summary(self) -> Dict[str, Any]:
        waits = self.metrics["queue_wait_s"]
        return {
            "requests": len(self.finished),
            "compiled_blobs": self.compile_count,
            "prefills": self.metrics["prefills"],
            "decode_steps": self.metrics["decode_steps"],
            "tokens_out": self.metrics["tokens_out"],
            "mean_queue_wait_s": float(np.mean(waits)) if waits else 0.0,
        }
