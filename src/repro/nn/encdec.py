"""Encoder–decoder support (whisper-base backbone).

The modality frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (B, frames, d_model) — the conv1d×2 + sinusoid
frontend is replaced by those embeddings directly. The encoder backbone is
real: `encoder.num_layers` bidirectional attention layers with MLPs.

Cross-attention K/V for the decoder are precomputed once from the encoder
output (per decoder superblock, stacked along the scan axis) — during decode
they are static operands, the enc-dec analogue of CacheG's "compute the
shared operand once, reuse it across every layer/step".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import transformer as tfm
from .config import ArchConfig


def encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    """Derive the encoder stack's config from the decoder's."""
    return dataclasses.replace(cfg, num_layers=cfg.encoder.num_layers,
                               layer_pattern="global", moe=None, encoder=None)


def encoder_init(key, cfg: ArchConfig) -> Dict[str, Any]:
    ecfg = encoder_cfg(cfg)
    return {"stack": tfm.stack_init(key, ecfg),
            "final_norm": tfm.norm_init(ecfg)}


def encoder_forward(enc_params: Dict[str, Any], cfg: ArchConfig,
                    frame_embeds: jnp.ndarray) -> jnp.ndarray:
    """frame_embeds: (B, frames, d) stub output -> encoder hidden states."""
    ecfg = encoder_cfg(cfg)
    s = frame_embeds.shape[1]
    positions = jnp.arange(s)

    def block_fn(carry, blk_params):
        h, aux = carry
        for pos in range(len(ecfg.superblock)):
            h, a = tfm._layer_forward(blk_params[pos], ecfg, h,
                                      kind="attn_bidir", positions=positions)
            aux = aux + a
        return (h, aux), None

    if ecfg.remat:
        block_fn = jax.checkpoint(block_fn)
    (h, _), _ = jax.lax.scan(block_fn, (frame_embeds,
                                        jnp.zeros((), jnp.float32)),
                             enc_params["stack"],
                             unroll=ecfg.num_superblocks if ecfg.unroll_scans
                             else 1)
    return tfm.apply_norm(enc_params["final_norm"], ecfg, h)


def cross_kv(stacked: List[Dict[str, Any]], cfg: ArchConfig,
             enc_out: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute decoder cross-attention K/V, stacked over superblocks.

    Returns (k, v): each (nsb, B, enc_len, KV, hd). The whisper decoder
    superblock is ('attn',), so position 0 holds the cross params.
    """
    dt = cfg.dtype
    wk = stacked[0]["cross"].wk.value.astype(dt)   # (nsb, d, KV, hd)
    wv = stacked[0]["cross"].wv.value.astype(dt)
    k = jnp.einsum("bsd,ldhk->lbshk", enc_out, wk)
    v = jnp.einsum("bsd,ldhk->lbshk", enc_out, wv)
    return k, v
