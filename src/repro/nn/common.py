"""Shared building blocks for the LM substrate.

Parameters carry *logical axis names* (a hand-rolled version of flax's
logical-axis machinery): every leaf is created as a `Param(value, axes)` and
`split_params` separates the value tree from the axes tree. The distribution
layer (`repro.dist.sharding`) maps logical axes -> mesh axes, checking
divisibility, so the same model definition runs on 1 CPU device, a 16x16 pod,
or the 2x16x16 multi-pod mesh without edits — the NodePad philosophy (one
artifact, many deployments) applied to distribution.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Param with logical axes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Param:
    value: Any                 # jnp.ndarray (or ShapeDtypeStruct under eval_shape)
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), tuple(p.axes)),
    lambda axes, children: Param(children[0], axes))


def _is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """Param tree -> (values tree, axes tree), same structure."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=_is_param)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=_is_param)
    return values, axes


def dense_param(key, shape, axes, *, scale: Optional[float] = None,
                dtype=jnp.float32) -> Param:
    """Truncated-normal fan-in init (the standard LM init)."""
    fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    v = jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * scale
    return Param(v, axes)


def zeros_param(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones_param(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


def stack_params(trees):
    """Stack per-layer Param trees along a leading 'layers' axis (for scan)."""
    def stack(*ps):
        return Param(jnp.stack([p.value for p in ps]),
                     ("layers",) + ps[0].axes)
    return jax.tree_util.tree_map(stack, *trees, is_leaf=_is_param)


# ---------------------------------------------------------------------------
# Norms / activations (computed in fp32, cast back — TPU numerics practice)
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
             zero_centered: bool = False) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    g = scale.astype(jnp.float32)
    y = y * (1.0 + g) if zero_centered else y * g
    return y.astype(dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               *, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


# ---------------------------------------------------------------------------
# Rotary embeddings (standard / partial "2d"-style)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, *, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (S,) or (B, S) absolute positions.

    Rotates the first `fraction * D` dims (chatglm's partial/'2d' RoPE is
    fraction=0.5; standard llama-family is 1.0), NeoX half-split layout.
    """
    b, s, h, d = x.shape
    inv, rot = rope_frequencies(d, theta=theta, fraction=fraction)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(jnp.float32) * inv[None, None, :]  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)   # (B,S,1,rot/2)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def take_embedding(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Token embedding lookup.

    NOTE (EffOp applicability): the paper rewrites gathers as one-hot matmuls
    when the gather index set is small and reused (graph neighborhoods). A
    vocab-size one-hot here would cost B*S*V*d FLOPs — catastrophically more
    than the gather; XLA lowers this take to an efficient dynamic-gather on
    TPU. Documented in DESIGN.md §Arch-applicability as a case where the
    technique does NOT transfer.
    """
    return jnp.take(table, tokens, axis=0)
