"""Top-level language model: embeddings → superblock stack → chunked loss.

Covers every assigned family through config alone:
  dense / moe / ssm / hybrid  — `stack_forward` handles layer heterogeneity;
  vlm                         — optional `prefix_embeds` (stub patch
                                embeddings) are prepended to token embeddings,
                                loss is computed on token positions only;
  audio (enc-dec)             — optional `enc_embeds` (stub frame embeddings)
                                run the real encoder; decoder cross-attends.

The vocab-dim loss never materializes (B, S, V) for large V: log-softmax
cross-entropy runs over `cfg.loss_chunk`-sized sequence chunks under
jax.checkpoint (GraphSplit thinking: the huge tensor is the 'transfer' we
design away).
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer as tfm
from .common import Param, dense_param, take_embedding
from .config import ArchConfig


class LMParams(NamedTuple):
    embed: Param                         # (V, d)
    stack: List[Dict[str, Any]]
    final_norm: Dict[str, Param]
    unembed: Optional[Param] = None      # (d, V) when not tied
    encoder: Optional[Dict[str, Any]] = None


def lm_init(key, cfg: ArchConfig) -> LMParams:
    ks = jax.random.split(key, 4)
    embed = dense_param(ks[0], (cfg.vocab_size, cfg.d_model),
                        ("vocab", "embed"), scale=1.0)
    return LMParams(
        embed=embed,
        stack=tfm.stack_init(ks[1], cfg, cross=cfg.is_encdec),
        final_norm=tfm.norm_init(cfg),
        unembed=(None if cfg.tie_embeddings else
                 dense_param(ks[2], (cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"))),
        encoder=encdec.encoder_init(ks[3], cfg) if cfg.is_encdec else None,
    )


def embed_tokens(p: LMParams, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = take_embedding(p.embed.value, tokens).astype(cfg.dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    return x


def hidden_to_logits(p: LMParams, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    w = (p.embed.value.T if p.unembed is None else p.unembed.value).astype(cfg.dtype)
    logits = jnp.einsum("...d,dv->...v", h, w, preferred_element_type=jnp.float32)
    from .common import softcap
    return softcap(logits, cfg.final_softcap)


def _encode(p: LMParams, cfg: ArchConfig, enc_embeds: jnp.ndarray):
    enc_out = encdec.encoder_forward(p.encoder, cfg, enc_embeds.astype(cfg.dtype))
    return encdec.cross_kv(p.stack, cfg, enc_out)


def lm_hidden(p: LMParams, cfg: ArchConfig, tokens: jnp.ndarray, *,
              prefix_embeds: Optional[jnp.ndarray] = None,
              enc_embeds: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Returns (hidden (B, P+S, d), moe_aux, prefix_len)."""
    x = embed_tokens(p, cfg, tokens)
    plen = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
        plen = prefix_embeds.shape[1]
    positions = jnp.arange(x.shape[1])
    enc_kv = _encode(p, cfg, enc_embeds) if enc_embeds is not None else None
    h, aux = tfm.stack_forward(p.stack, cfg, x, positions=positions,
                               enc_kv_stacked=enc_kv)
    h = tfm.apply_norm(p.final_norm, cfg, h)
    return h, aux, plen


def chunked_xent(p: LMParams, cfg: ArchConfig, h: jnp.ndarray,
                 labels: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Per-token CE over seq chunks; never materializes (B, S, V)."""
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c

    def chunk_loss(args):
        hc, yc, mc = args
        logits = hidden_to_logits(p, cfg, hc)           # (B, c, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return nll.sum(), mc.sum()

    chunk_loss = jax.checkpoint(chunk_loss)
    hs = jnp.moveaxis(h.reshape(b, nc, c, d), 1, 0)
    ys = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nc, c).astype(jnp.float32), 1, 0)
    if nc == 1:
        tot, cnt = chunk_loss((hs[0], ys[0], ms[0]))
    elif cfg.unroll_scans:   # cost-exact mode: no while loop
        outs = [chunk_loss((hs[i], ys[i], ms[i])) for i in range(nc)]
        tot = sum(o[0] for o in outs)
        cnt = sum(o[1] for o in outs)
    else:
        tots, cnts = jax.lax.map(chunk_loss, (hs, ys, ms))
        tot, cnt = tots.sum(), cnts.sum()
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(p: LMParams, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens/labels/mask (B, S) (+ patches / frames for vlm/audio)."""
    h, aux, plen = lm_hidden(
        p, cfg, batch["tokens"],
        prefix_embeds=batch.get("patches"),
        enc_embeds=batch.get("frames"))
    h = h[:, plen:]                       # loss over token positions only
    ce = chunked_xent(p, cfg, h, batch["labels"], batch["mask"])
    return ce + aux, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode (NodePad'ded caches)
# ---------------------------------------------------------------------------


class ServeState(NamedTuple):
    caches: List[Any]
    pos: jnp.ndarray                      # scalar int32 — next write position
    enc_kv: Optional[Tuple] = None        # whisper cross K/V


def lm_prefill(p: LMParams, cfg: ArchConfig, tokens: jnp.ndarray, *,
               max_len: int,
               prefix_embeds: Optional[jnp.ndarray] = None,
               enc_embeds: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, ServeState]:
    """Run the prompt, build caches. Returns (last-token logits, state)."""
    x = embed_tokens(p, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    enc_kv = _encode(p, cfg, enc_embeds) if enc_embeds is not None else None
    h, caches = tfm.stack_prefill(p.stack, cfg, x, positions=positions,
                                  max_len=max_len, enc_kv_stacked=enc_kv)
    h = tfm.apply_norm(p.final_norm, cfg, h)
    logits = hidden_to_logits(p, cfg, h[:, -1:])
    return logits[:, 0], ServeState(caches=caches,
                                    pos=jnp.asarray(x.shape[1], jnp.int32),
                                    enc_kv=enc_kv)


def lm_decode_step(p: LMParams, cfg: ArchConfig, token: jnp.ndarray,
                   state: ServeState) -> Tuple[jnp.ndarray, ServeState]:
    """token: (B,) int32. One step; cache write at state.pos (GrAd cursor)."""
    x = embed_tokens(p, cfg, token[:, None])
    h, new_caches = tfm.stack_decode(p.stack, cfg, x, state.caches, state.pos,
                                     enc_kv_stacked=state.enc_kv)
    h = tfm.apply_norm(p.final_norm, cfg, h)
    logits = hidden_to_logits(p, cfg, h[:, 0:1])[:, 0]
    return logits, ServeState(caches=new_caches, pos=state.pos + 1,
                              enc_kv=state.enc_kv)


def greedy_generate(p: LMParams, cfg: ArchConfig, prompt: jnp.ndarray, *,
                    steps: int, max_len: int) -> jnp.ndarray:
    """Reference sampler for the examples: prefill + `steps` greedy tokens."""
    logits, state = lm_prefill(p, cfg, prompt, max_len=max_len)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def body(carry, _):
        tok, state = carry
        logits, state = lm_decode_step(p, cfg, tok, state)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, state), nxt

    (_, _), toks = jax.lax.scan(body, (tok, state), None, length=steps)
    return jnp.concatenate([tok[None], toks], axis=0).T  # (B, steps+1)
