"""Architecture + shape configuration for the LM substrate.

One `ArchConfig` covers all 10 assigned families (dense / ssm / moe / hybrid
/ vlm / audio). Layer heterogeneity (gemma2 local-global alternation, jamba's
1:7 mamba:attention interleave with MoE every other layer) is expressed as a
*superblock*: the smallest repeating pattern of layers. The model scans over
superblocks, so the HLO is O(superblock), not O(num_layers) — this is what
makes 46-layer x 512-device dry-run compiles tractable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_k_layers: int = 1          # MoE replaces the MLP every k-th layer
    shared_expert_ff: int = 0        # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    group_size: int = 1024           # EffOp dense-dispatch token group
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_kernel: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (frontend is a stub: precomputed embeddings)."""
    num_layers: int
    frames: int                      # encoder sequence length at decode time


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | ssm | moe | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # --- attention flavor ---
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # chatglm partial ('2d') rope = 0.5
    qk_norm: bool = False            # qwen3
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    local_window: Optional[int] = None      # gemma2 sliding window
    layer_pattern: str = "global"    # global | local_global | jamba | ssm
    attn_logits_f32: bool = True
    # --- mixtures / ssm / enc-dec / frontends ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None   # vision_stub | audio_stub
    num_patches: int = 1024          # vlm: patch-embedding positions
    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    zero_centered_norm: bool = False  # gemma2 (1 + g) rmsnorm
    post_norms: bool = False         # gemma2 sandwich norms
    scale_embeddings: bool = False   # gemma2: x *= sqrt(d_model)
    act: str = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = True
    compute_dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512            # vocab-loss sequence chunking
    q_chunk: int = 2048              # pure-JAX flash attention block sizes
    kv_chunk: int = 2048
    # Cost-exact lowering mode (launch/dryrun two-point measurement): unroll
    # every scan/map so XLA's HLO cost analysis (which counts while bodies
    # ONCE, not × trip count) reports exact FLOPs/bytes/collective totals.
    unroll_scans: bool = False
    # §Perf hillclimb knobs (baseline = paper-faithful = all off):
    attn_block_skip: bool = False    # skip fully-masked causal/window blocks
    logits_bf16: bool = False        # attention scores in bf16 (2x less HBM)
    # Flash-kernel HBM model (dry-run MEASUREMENT aid only, never executed
    # for real outputs): replaces attention score math with a bytes-
    # equivalent Q/K/V->O stream, modelling the Pallas flash kernel whose
    # score tiles live in VMEM. XLA-CPU HLO cannot express VMEM residency
    # (it legalizes bf16 math via f32 materializations), so the kernel's
    # memory term is measured through this stub; compute/collective terms
    # are taken from the non-stub variant.
    attn_flash_stub: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def superblock(self) -> Tuple[str, ...]:
        """Per-layer kinds inside the smallest repeating block.

        kinds: 'attn' (global), 'attn_local', 'ssm' — each is followed by its
        MLP/MoE as dictated by `moe.every_k_layers` (position parity within
        the superblock).
        """
        if self.layer_pattern == "global":
            return ("attn",)
        if self.layer_pattern == "local_global":
            return ("attn_local", "attn")
        if self.layer_pattern == "ssm":
            return ("ssm",)
        if self.layer_pattern == "jamba":
            # Jamba block: 8 layers, attention at index 4 (1:7 ratio),
            # MoE on odd layers (every_k_layers=2 handled by position).
            return ("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm")
        raise ValueError(self.layer_pattern)

    @property
    def num_superblocks(self) -> int:
        sb = len(self.superblock)
        assert self.num_layers % sb == 0, (self.num_layers, sb)
        return self.num_layers // sb

    def layer_uses_moe(self, pos_in_superblock: int, kind: str) -> bool:
        del kind  # MoE placement depends only on position (jamba: odd layers)
        if self.moe is None:
            return False
        return pos_in_superblock % self.moe.every_k_layers == (
            self.moe.every_k_layers - 1)

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def attention_free(self) -> bool:
        return self.layer_pattern == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid with O(1)-state majority)."""
        return self.layer_pattern in ("ssm", "jamba")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.num_layers):
            kind = self.superblock[i % len(self.superblock)]
            if kind.startswith("attn"):
                total += d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            else:  # ssm
                s = self.ssm
                d_in = s.expand * d
                total += d * 2 * d_in                      # w_zx
                total += d * 2 * s.n_groups * s.d_state    # w_bc
                total += d * (d_in // s.headdim)           # w_dt
                total += d_in * d                          # out_proj
                total += s.conv_kernel * (d_in + 2 * s.n_groups * s.d_state)
            if self.layer_uses_moe(i % len(self.superblock), kind):
                m = self.moe
                mult = 3 if self.gated_mlp else 2
                total += m.num_experts * mult * d * m.d_ff_expert
                total += d * m.num_experts  # router
                if m.shared_expert_ff:
                    total += mult * d * m.shared_expert_ff
            elif ff > 0:  # mamba2 sets d_ff=0 (no MLP); jamba ssm layers keep theirs
                mult = 3 if self.gated_mlp else 2
                total += mult * d * ff
        if self.encoder is not None:
            # encoder layers: self-attn + mlp; decoder cross-attn extra
            enc = self.encoder.num_layers * (
                (2 * d * n_q * hd + 2 * d * n_kv * hd)
                + (3 if self.gated_mlp else 2) * d * ff)
            cross = self.num_layers * (d * n_q * hd + 2 * d * n_kv * hd
                                       + n_q * hd * d)
            total += enc + cross
        return int(total)

    def active_param_count(self) -> int:
        """MoE: params touched per token (6*N_active*D roofline convention)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        mult = 3 if self.gated_mlp else 2
        total = self.param_count()
        per_moe_layer = m.num_experts * mult * self.d_model * m.d_ff_expert
        active_per_layer = m.top_k * mult * self.d_model * m.d_ff_expert
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if self.layer_uses_moe(i % len(self.superblock),
                                   self.superblock[i % len(self.superblock)]))
        return int(total - n_moe_layers * (per_moe_layer - active_per_layer))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
