"""Decoder stack: scan over superblocks of heterogeneous layers.

The stack is organized around `cfg.superblock` — the smallest repeating
pattern of layer kinds ('attn' | 'attn_local' | 'ssm'). Parameters are built
per superblock *position* and stacked along a leading `num_superblocks` axis,
then the forward is a `lax.scan` over superblocks (with `jax.checkpoint` per
block when cfg.remat): the compiled HLO is O(superblock), not O(num_layers),
which keeps 46-layer × 512-device dry-run compiles tractable.

Decode threads per-layer caches (KV for attention positions, SSMCache for
ssm positions) through the same scan — caches are scanned-over xs/ys, the
(B, 1, d) hidden state is the carry. All cache shapes are NodePad'ded
(static S_max), GrAd-updated in place via dynamic_update_slice.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import (Param, layer_norm, ones_param, rms_norm, stack_params,
                     zeros_param)
from .config import ArchConfig
from .mlp import mlp_forward, mlp_init


# ---------------------------------------------------------------------------
# Norm params / application (rmsnorm or layernorm with bias)
# ---------------------------------------------------------------------------


def norm_init(cfg: ArchConfig) -> Dict[str, Param]:
    p = {"scale": ones_param((cfg.d_model,), (None,))}
    if cfg.norm == "layernorm":
        p["bias"] = zeros_param((cfg.d_model,), (None,))
    return p


def apply_norm(p: Dict[str, Param], cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"].value, p["bias"].value)
    return rms_norm(x, p["scale"].value, zero_centered=cfg.zero_centered_norm)


# ---------------------------------------------------------------------------
# Per-position layer params
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ArchConfig, pos: int, *, cross: bool = False) -> Dict[str, Any]:
    """One layer at superblock position `pos`: mixer + (mlp|moe) + norms."""
    kind = cfg.superblock[pos]
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"pre_norm": norm_init(cfg)}
    if kind.startswith("attn"):
        p["mixer"] = attn_mod.attn_init(ks[0], cfg)
    else:
        p["mixer"] = ssm_mod.ssm_init(ks[0], cfg)
    if cfg.post_norms:
        p["post_norm"] = norm_init(cfg)
    if cross:
        p["pre_cross_norm"] = norm_init(cfg)
        p["cross"] = attn_mod.attn_init(ks[3], cfg, cross=True)
    # mamba2 has no MLP at all (d_ff == 0 and no MoE)
    use_moe = cfg.layer_uses_moe(pos, kind)
    if use_moe:
        p["pre_mlp_norm"] = norm_init(cfg)
        p["mlp"] = moe_mod.moe_init(ks[1], cfg)
    elif cfg.d_ff > 0:
        p["pre_mlp_norm"] = norm_init(cfg)
        p["mlp"] = mlp_init(ks[2], cfg)
    if cfg.post_norms and "mlp" in p:
        p["post_mlp_norm"] = norm_init(cfg)
    return p


def stack_init(key, cfg: ArchConfig, *, cross: bool = False) -> List[Dict[str, Any]]:
    """Stacked params: list over superblock positions; each leaf has leading
    num_superblocks axis (the scan axis)."""
    sb = len(cfg.superblock)
    out = []
    for pos in range(sb):
        trees = [layer_init(jax.random.fold_in(key, blk * sb + pos), cfg, pos,
                            cross=cross)
                 for blk in range(cfg.num_superblocks)]
        out.append(stack_params(trees))
    return out


def _is_param(x):
    return isinstance(x, Param)


def slice_block(stacked: List[Dict[str, Any]], blk: int) -> List[Dict[str, Any]]:
    """Materialize one superblock's params (used by non-scan reference path)."""
    def take(p: Param) -> Param:
        return Param(p.value[blk], p.axes[1:])
    return jax.tree_util.tree_map(take, stacked, is_leaf=_is_param)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _layer_forward(p: Dict[str, Any], cfg: ArchConfig, x: jnp.ndarray, *,
                   kind: str, positions: jnp.ndarray,
                   enc_kv: Optional[Tuple] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm residual layer. Returns (x, moe_aux)."""
    h = apply_norm(p["pre_norm"], cfg, x)
    if kind.startswith("attn"):
        h = attn_mod.attn_forward(p["mixer"], cfg, h, kind=kind,
                                  positions=positions)
    else:
        h = ssm_mod.ssm_forward(p["mixer"], cfg, h)
    if cfg.post_norms:
        h = apply_norm(p["post_norm"], cfg, h)
    x = x + h
    if "cross" in p:
        h = apply_norm(p["pre_cross_norm"], cfg, x)
        h = attn_mod.attn_forward(p["cross"], cfg, h, kind="attn",
                                  positions=positions, cross_kv=enc_kv)
        x = x + h
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in p:
        h = apply_norm(p["pre_mlp_norm"], cfg, x)
        if isinstance(p["mlp"], moe_mod.MoEParams) or (
                isinstance(p["mlp"], dict) and "w_router" in p["mlp"]):
            h, aux = moe_mod.moe_forward(p["mlp"], cfg, h)
        else:
            h = mlp_forward(p["mlp"], cfg, h)
        if cfg.post_norms:
            h = apply_norm(p["post_mlp_norm"], cfg, h)
        x = x + h
    return x, aux


def stack_forward(stacked: List[Dict[str, Any]], cfg: ArchConfig,
                  x: jnp.ndarray, *, positions: jnp.ndarray,
                  enc_kv_stacked: Optional[Tuple] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d). Scan over superblocks. Returns (hidden, moe_aux_sum)."""

    def block_fn(carry, xs):
        h, aux = carry
        blk_params = xs["params"]
        enc_kv = xs.get("enc_kv")
        for pos, kind in enumerate(cfg.superblock):
            h, a = _layer_forward(blk_params[pos], cfg, h, kind=kind,
                                  positions=positions, enc_kv=enc_kv)
            aux = aux + a
        return (h, aux), None

    if cfg.remat:
        block_fn = jax.checkpoint(block_fn)

    xs: Dict[str, Any] = {"params": stacked}
    if enc_kv_stacked is not None:
        xs["enc_kv"] = enc_kv_stacked
    (h, aux), _ = jax.lax.scan(block_fn, (x, jnp.zeros((), jnp.float32)), xs,
                               unroll=cfg.num_superblocks if cfg.unroll_scans
                               else 1)
    return h, aux


# ---------------------------------------------------------------------------
# Caches (decode)
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_len: int) -> List[Any]:
    """Per-position stacked caches: KV (nsb, B, S_max, KV, hd) or SSMCache."""
    nsb = cfg.num_superblocks
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    out: List[Any] = []
    for kind in cfg.superblock:
        if kind.startswith("attn"):
            shape = (nsb, batch, max_len, kvh, hd)
            out.append({"k": jnp.zeros(shape, cfg.dtype),
                        "v": jnp.zeros(shape, cfg.dtype)})
        else:
            c = ssm_mod.ssm_init_cache(cfg, batch)
            out.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (nsb,) + a.shape), c))
    return out


def stack_prefill(stacked: List[Dict[str, Any]], cfg: ArchConfig,
                  x: jnp.ndarray, *, positions: jnp.ndarray,
                  max_len: int,
                  enc_kv_stacked: Optional[Tuple] = None
                  ) -> Tuple[jnp.ndarray, List[Any]]:
    """Prefill: forward + build decode caches. x: (B, S, d)."""
    b, s, _ = x.shape
    assert max_len >= s, (
        f"cache capacity {max_len} < prompt length {s} (NodePad: include "
        f"multimodal prefix positions in max_len)")

    def block_fn(h, xs):
        blk_params = xs["params"]
        enc_kv = xs.get("enc_kv")
        caches_out = []
        for pos, kind in enumerate(cfg.superblock):
            p = blk_params[pos]
            hn = apply_norm(p["pre_norm"], cfg, h)
            if kind.startswith("attn"):
                k, v = attn_mod.attn_prefill_kv(p["mixer"], cfg, hn, positions)
                pad = max_len - s
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                caches_out.append({"k": kc, "v": vc})
                hn = attn_mod.attn_forward(p["mixer"], cfg, hn, kind=kind,
                                           positions=positions)
            else:
                hn, ssm_cache = ssm_mod.ssm_forward(p["mixer"], cfg, hn,
                                                    return_state=True)
                caches_out.append(ssm_cache)
            if cfg.post_norms:
                hn = apply_norm(p["post_norm"], cfg, hn)
            h = h + hn
            if "cross" in p:
                hn = apply_norm(p["pre_cross_norm"], cfg, h)
                hn = attn_mod.attn_forward(p["cross"], cfg, hn, kind="attn",
                                           positions=positions, cross_kv=enc_kv)
                h = h + hn
            if "mlp" in p:
                hn = apply_norm(p["pre_mlp_norm"], cfg, h)
                if isinstance(p["mlp"], moe_mod.MoEParams):
                    hn, _ = moe_mod.moe_forward(p["mlp"], cfg, hn)
                else:
                    hn = mlp_forward(p["mlp"], cfg, hn)
                if cfg.post_norms:
                    hn = apply_norm(p["post_mlp_norm"], cfg, hn)
                h = h + hn
        return h, caches_out

    if cfg.remat:
        block_fn = jax.checkpoint(block_fn)
    xs: Dict[str, Any] = {"params": stacked}
    if enc_kv_stacked is not None:
        xs["enc_kv"] = enc_kv_stacked
    h, caches = jax.lax.scan(block_fn, x, xs,
                             unroll=cfg.num_superblocks if cfg.unroll_scans
                             else 1)
    return h, caches


def stack_decode(stacked: List[Dict[str, Any]], cfg: ArchConfig,
                 x: jnp.ndarray, caches: List[Any], pos: jnp.ndarray,
                 enc_kv_stacked: Optional[Tuple] = None
                 ) -> Tuple[jnp.ndarray, List[Any]]:
    """One-token decode. x: (B, 1, d); pos: scalar or (B,) write cursors.

    Caches ride the scan CARRY (updated in place with a dynamic index per
    superblock), NOT xs->ys: while-loop carries alias in place, so decode
    holds exactly ONE cache copy in HBM (with donated inputs the step is
    fully in-place — xs/ys stacking would double cache memory)."""

    def block_fn(carry, xs):
        h, caches_all = carry
        blk_params = xs["params"]
        idx = xs["idx"]
        enc_kv = xs.get("enc_kv")
        blk_caches = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
            caches_all)
        new_caches = []
        for i, kind in enumerate(cfg.superblock):
            p = blk_params[i]
            hn = apply_norm(p["pre_norm"], cfg, h)
            if kind.startswith("attn"):
                c = blk_caches[i]
                hn, nk, nv = attn_mod.attn_decode(p["mixer"], cfg, hn,
                                                  c["k"], c["v"], pos, kind=kind)
                new_caches.append({"k": nk, "v": nv})
            else:
                hn, nc = ssm_mod.ssm_decode(p["mixer"], cfg, hn, blk_caches[i])
                new_caches.append(nc)
            if cfg.post_norms:
                hn = apply_norm(p["post_norm"], cfg, hn)
            h = h + hn
            if "cross" in p:
                hn = apply_norm(p["pre_cross_norm"], cfg, h)
                ek, ev = enc_kv
                hn, _, _ = attn_mod.attn_decode(p["cross"], cfg, hn, ek, ev,
                                                pos, kind="attn", cross=True)
                h = h + hn
            if "mlp" in p:
                hn = apply_norm(p["pre_mlp_norm"], cfg, h)
                if isinstance(p["mlp"], moe_mod.MoEParams):
                    hn, _ = moe_mod.moe_forward(p["mlp"], cfg, hn)
                else:
                    hn = mlp_forward(p["mlp"], cfg, hn)
                if cfg.post_norms:
                    hn = apply_norm(p["post_mlp_norm"], cfg, hn)
                h = h + hn
        caches_all = jax.tree_util.tree_map(
            lambda all_, new: jax.lax.dynamic_update_index_in_dim(
                all_, new.astype(all_.dtype), idx, 0),
            caches_all, new_caches)
        return (h, caches_all), None

    xs: Dict[str, Any] = {"params": stacked,
                          "idx": jnp.arange(cfg.num_superblocks)}
    if enc_kv_stacked is not None:
        xs["enc_kv"] = enc_kv_stacked
    (h, new_caches), _ = jax.lax.scan(
        block_fn, (x, caches), xs,
        unroll=cfg.num_superblocks if cfg.unroll_scans else 1)
    return h, new_caches
