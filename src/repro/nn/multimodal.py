"""Modality frontend STUBS (per the assignment brief).

phi-3-vision and whisper-base specify the transformer BACKBONE only; the
CLIP / conv-mel frontends are stubs whose `input_specs()` provide
*precomputed* patch / frame embeddings. These helpers generate deterministic
synthetic embeddings with the right shapes & dtypes for smoke tests, and the
ShapeDtypeStructs for the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig


def vision_patch_embeddings(cfg: ArchConfig, batch: int, *,
                            seed: int = 0) -> jnp.ndarray:
    """Stub CLIP output: (B, num_patches, d_model), unit-scale."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (batch, cfg.num_patches, cfg.d_model),
                          jnp.float32)
    return (x / jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32))).astype(cfg.dtype)


def audio_frame_embeddings(cfg: ArchConfig, batch: int, frames: int, *,
                           seed: int = 0) -> jnp.ndarray:
    """Stub conv-frontend output: (B, frames, d_model)."""
    key = jax.random.PRNGKey(seed + 1)
    x = jax.random.normal(key, (batch, frames, cfg.d_model), jnp.float32)
    return (x / jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32))).astype(cfg.dtype)


def vision_spec(cfg: ArchConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.num_patches, cfg.d_model), cfg.dtype)


def audio_spec(cfg: ArchConfig, batch: int, frames: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, frames, cfg.d_model), cfg.dtype)
