"""GQA attention for the LM substrate.

Two execution paths, mirroring the kernel routing policy:

  * `chunked_attention` — pure-JAX online-softmax over KV blocks (lax.map
    over query blocks, lax.scan over KV blocks). O(S * chunk) memory, never
    materializes (Sq, Skv). This is what the multi-pod dry-run compiles
    (works on every backend) and the oracle the Pallas flash kernel is tested
    against. Masks (causal / sliding window) are *computed from positions*
    inside each block — the GrAd discipline: no precomputed O(S^2) operand.
  * `repro.kernels.ops.flash_attention` — the Pallas TPU kernel, selected on
    TPU backends for the same math.

Decode (Sq == 1) uses a direct einsum over the cache: logits are (B, H, Skv),
already linear in S.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import common
from .common import Param, dense_param, ones_param
from .config import ArchConfig

NEG_INF = -1e9


class AttnParams(NamedTuple):
    wq: Param        # (d, H, hd)
    wk: Param        # (d, KV, hd)
    wv: Param        # (d, KV, hd)
    wo: Param        # (H, hd, d)
    q_norm: Optional[Param] = None   # (hd,) qwen3 qk-norm
    k_norm: Optional[Param] = None


def attn_init(key, cfg: ArchConfig, *, cross: bool = False) -> AttnParams:
    d, hh, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    return AttnParams(
        wq=dense_param(ks[0], (d, hh, hd), ("embed", "heads", None)),
        wk=dense_param(ks[1], (d, kv, hd), ("embed", "kv", None)),
        wv=dense_param(ks[2], (d, kv, hd), ("embed", "kv", None)),
        wo=dense_param(ks[3], (hh, hd, d), ("heads", None, "embed")),
        q_norm=ones_param((hd,), (None,)) if cfg.qk_norm else None,
        k_norm=ones_param((hd,), (None,)) if cfg.qk_norm else None,
    )


def _block_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=jnp.bool_)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, window: Optional[int] = None,
                      attn_softcap: Optional[float] = None,
                      scale: Optional[float] = None, q_offset: int = 0,
                      q_chunk: int = 2048, kv_chunk: int = 2048,
                      kv_len: Optional[jnp.ndarray] = None,
                      unroll: bool = False,
                      block_skip: bool = False,
                      logits_bf16: bool = False,
                      flash_stub: bool = False) -> jnp.ndarray:
    """Online-softmax attention. q: (B,Sq,H,D), k/v: (B,Skv,KV,D).

    `kv_len`: optional scalar — keys at positions >= kv_len are masked
    (decode with a partially-filled NodePad'ded cache).
    """
    b, sq, hh, d = q.shape
    _, skv, kvh, _ = k.shape
    group = hh // kvh
    scale = scale if scale is not None else d ** -0.5
    bq = min(q_chunk, sq)
    bk = min(kv_chunk, skv)
    # NodePad-pad ragged sequences to chunk multiples (vlm: patches+tokens).
    # Padded queries are discarded below; padded keys are masked via kv_len.
    qpad, kpad = (-sq) % bq, (-skv) % bk
    sq_orig = sq
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        sq += qpad
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        kv_len = jnp.asarray(skv) if kv_len is None else jnp.minimum(kv_len, skv)
        skv += kpad
    nq, nk = sq // bq, skv // bk

    # (B, Sq, H, D) -> (nq, B, bq, H, D): chunked along sequence
    qc = jnp.moveaxis(q.reshape(b, nq, bq, hh, d), 1, 0) * scale
    kc = jnp.moveaxis(k.reshape(b, nk, bk, kvh, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, bk, kvh, d), 1, 0)

    score_dt = jnp.bfloat16 if logits_bf16 else jnp.float32

    if flash_stub:
        # bytes-equivalent stand-in for the Pallas flash kernel: reads Q, K,
        # V once, writes O once — no score-sized HBM buffer exists (VMEM
        # residency). Output values are NOT attention (measurement only).
        kmix = jnp.mean(k, axis=(1, 2)) + jnp.mean(v, axis=(1, 2))  # (B, D)
        out = q * kmix[:, None, None, :].astype(q.dtype)
        return out[:, :sq_orig]

    def q_block(args):
        iq, qb = args                                   # qb: (B, bq, H, D)
        q_pos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, jk, kb, vb):
            m_run, l_run, acc = carry
            k_pos = jk * bk + jnp.arange(bk)
            # logits: (B, KV, g, bq, bk) — grouped GQA einsum, no KV repeat.
            # logits_bf16 (QuantGr-on-scores, §Perf): halves the dominant
            # S^2 HBM term; softmax stats still accumulate in fp32.
            qg = qb.reshape(b, bq, kvh, group, d)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                           preferred_element_type=score_dt)
            s = common.softcap(s, attn_softcap)
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
            if kv_len is not None:
                mask &= (k_pos < kv_len)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1).astype(jnp.float32))
            p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]) \
                if not logits_bf16 else \
                jnp.exp((s - m_new[..., None].astype(score_dt)))
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + p.sum(axis=-1, dtype=jnp.float32)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc)

        m0 = jnp.full((b, kvh, group, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, group, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, group, bq, d), jnp.float32)
        carry0 = (m0, l0, a0)

        if not block_skip:
            (m_f, l_f, acc), _ = jax.lax.scan(
                lambda c, xs: (kv_step(c, *xs), None), carry0,
                (jnp.arange(nk), kc, vc), unroll=nk if unroll else 1)
        else:
            # static block-skip (§Perf): iq is a python int (block_skip
            # forces the unrolled q loop below), so the not-fully-masked
            # block range resolves at trace time — the skipped blocks are
            # simply absent from the HLO (differentiable, exactly costed).
            # Causal halves the S^2 work; sliding-window layers drop from
            # S^2 to S*window (gemma2 local: 8x at 32k prefill).
            iq_s = int(iq)
            hi = min(nk, (q_offset + (iq_s + 1) * bq - 1) // bk + 1) \
                if causal else nk
            lo = max(0, (q_offset + iq_s * bq - window + 1) // bk) \
                if window is not None else 0
            carry = carry0
            for j in range(lo, hi):
                carry = kv_step(carry, jnp.asarray(j), kc[j], vc[j])
            m_f, l_f, acc = carry

        out = acc / jnp.maximum(l_f, 1e-12)[..., None]  # (B, KV, g, bq, D)
        out = jnp.moveaxis(out, 3, 1).reshape(b, bq, hh, d)
        return out.astype(q.dtype)

    if nq == 1:
        out = q_block((0, qc[0]))[None]
    elif unroll or block_skip:   # cost-exact mode / static block-skip
        out = jnp.stack([q_block((i, qc[i])) for i in range(nq)])
    else:
        out = jax.lax.map(q_block, (jnp.arange(nq), qc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hh, d)
    return out[:, :sq_orig]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     *, window: Optional[int], attn_softcap: Optional[float],
                     pos: jnp.ndarray, scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token attention over a NodePad'ded cache.

    q: (B, 1, H, D); caches: (B, S, KV, D); pos: scalar current position, or
    (B,) per-slot positions (continuous batching). Cache slots > pos are
    masked additively (GrAx1: add NEG_INF, no Select on the data path).
    """
    b, _, hh, d = q.shape
    _, s, kvh, _ = k_cache.shape
    group = hh // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kvh, group, d) * scale
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    logits = common.softcap(logits, attn_softcap)
    k_pos = jnp.arange(s)
    posb = pos if pos.ndim == 1 else jnp.full((b,), pos)   # (B,)
    valid = k_pos[None, :] <= posb[:, None]                # (B, S)
    if window is not None:
        valid &= k_pos[None, :] > posb[:, None] - window
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # GrAx1 additive
    logits = logits + bias[:, None, None, :]
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", attn.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hh, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + norm + attention + out-proj)
# ---------------------------------------------------------------------------


def _project_qkv(p: AttnParams, cfg: ArchConfig, x: jnp.ndarray,
                 kv_src: Optional[jnp.ndarray] = None):
    dt = cfg.dtype
    kv_in = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq.value.astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p.wk.value.astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p.wv.value.astype(dt))
    if p.q_norm is not None:
        q = common.rms_norm(q, p.q_norm.value)
        k = common.rms_norm(k, p.k_norm.value)
    return q, k, v


def attn_forward(p: AttnParams, cfg: ArchConfig, x: jnp.ndarray, *,
                 kind: str, positions: jnp.ndarray,
                 cross_kv: Optional[tuple] = None) -> jnp.ndarray:
    """Training/prefill attention. x: (B, S, d) in compute dtype."""
    dt = cfg.dtype
    if cross_kv is not None:
        # Cross-attention (whisper decoder->encoder): no rope — relative
        # position between text and audio frames is not meaningful.
        k, v = cross_kv
        q = jnp.einsum("bsd,dhk->bshk", x, p.wq.value.astype(dt))
        if p.q_norm is not None:
            q = common.rms_norm(q, p.q_norm.value)
        out = chunked_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                unroll=cfg.unroll_scans,
                                logits_bf16=cfg.logits_bf16)
    else:
        q, k, v = _project_qkv(p, cfg, x)
        q = common.apply_rope(q, positions, theta=cfg.rope_theta,
                              fraction=cfg.rope_fraction)
        k = common.apply_rope(k, positions, theta=cfg.rope_theta,
                              fraction=cfg.rope_fraction)
        causal = kind != "attn_bidir"
        window = cfg.local_window if kind == "attn_local" else None
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                unroll=cfg.unroll_scans,
                                block_skip=cfg.attn_block_skip,
                                logits_bf16=cfg.logits_bf16,
                                flash_stub=cfg.attn_flash_stub)
    return jnp.einsum("bshk,hkd->bsd", out, p.wo.value.astype(dt))


def attn_prefill_kv(p: AttnParams, cfg: ArchConfig, x: jnp.ndarray,
                    positions: jnp.ndarray):
    """Compute rope'd K/V for cache initialization (prefill)."""
    dt = cfg.dtype
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk.value.astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv.value.astype(dt))
    if p.k_norm is not None:
        k = common.rms_norm(k, p.k_norm.value)
    k = common.apply_rope(k, positions, theta=cfg.rope_theta,
                          fraction=cfg.rope_fraction)
    return k, v


def attn_decode(p: AttnParams, cfg: ArchConfig, x: jnp.ndarray,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray, pos: jnp.ndarray,
                *, kind: str, cross: bool = False):
    """One-token decode. x: (B, 1, d). Returns (out, new_k, new_v).

    The cache is a NodePad bucket: statically (B, S_max, KV, D); `pos` is the
    write cursor. GrAd discipline — same compiled blob for every position.
    """
    dt = cfg.dtype
    if cross:
        q = jnp.einsum("bsd,dhk->bshk", x, p.wq.value.astype(dt))
        if p.q_norm is not None:
            q = common.rms_norm(q, p.q_norm.value)
        out = decode_attention(q, k_cache, v_cache, window=None,
                               attn_softcap=None,
                               pos=jnp.asarray(k_cache.shape[1] - 1))
        new_k, new_v = k_cache, v_cache
    else:
        q, k, v = _project_qkv(p, cfg, x)
        posv = pos[None] if pos.ndim == 0 else pos[:, None]  # (1,) or (B,1)
        q = common.apply_rope(q, posv, theta=cfg.rope_theta,
                              fraction=cfg.rope_fraction)
        k = common.apply_rope(k, posv, theta=cfg.rope_theta,
                              fraction=cfg.rope_fraction)
        if pos.ndim == 1:
            # per-slot write cursors (continuous batching): vmapped update
            upd = jax.vmap(
                lambda c, kk, pp: jax.lax.dynamic_update_slice_in_dim(
                    c, kk, pp, axis=0))
            new_k = upd(k_cache, k.astype(k_cache.dtype), pos)
            new_v = upd(v_cache, v.astype(v_cache.dtype), pos)
        else:
            new_k = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), pos, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), pos, axis=1)
        window = cfg.local_window if kind == "attn_local" else None
        out = decode_attention(q, new_k, new_v, window=window,
                               attn_softcap=cfg.attn_softcap, pos=pos)
    return jnp.einsum("bshk,hkd->bsd", out, p.wo.value.astype(dt)), new_k, new_v
