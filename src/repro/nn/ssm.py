"""Mamba2 SSD (state-space duality) layer — chunked dense-matmul scan.

The SSD algorithm is itself a GraNNite-spirit rewrite (DESIGN.md §4): the
recurrence  s_t = a_t s_{t-1} + b_t x_t  is control-heavy/sequential (the
NPU-DSP analogue); SSD re-expresses length-L chunks as dense masked matmuls
(the attention-like  C (L ∘ decay) B^T  form) that run on the MXU, with only
an O(S/chunk) scan carrying the inter-chunk state. We implement exactly that
structure:

  * intra-chunk: (l, l) decay-masked C·B^T matmul — MXU work, chunk=256
    keeps the (l, l) tile VMEM-resident;
  * inter-chunk: lax.scan over chunk states (b, h, n, p) — the only
    sequential dependency, S/chunk steps;
  * decode: O(1) single-token state update (einsum, no scan).

Shapes follow the Mamba2 paper: d_in = expand * d_model, heads = d_in /
headdim, groups share B/C across heads (n_groups).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Param, dense_param, ones_param, zeros_param
from .config import ArchConfig


class SSMParams(NamedTuple):
    w_zx: Param        # (d, 2*d_in) — z (gate) and x (ssm input) projections
    w_bc: Param        # (d, 2*g*n)  — B and C projections
    w_dt: Param        # (d, H)      — per-head timestep projection
    conv_w: Param      # (k, d_in + 2*g*n) depthwise causal conv
    conv_b: Param      # (d_in + 2*g*n,)
    a_log: Param       # (H,)  A = -exp(a_log)
    d_skip: Param      # (H,)  skip connection ("D" in mamba)
    dt_bias: Param     # (H,)
    norm: Param        # (d_in,) gated RMSNorm scale
    w_out: Param       # (d_in, d)


class SSMCache(NamedTuple):
    """Decode-time state: NodePad'ded static shapes, GrAd-updated in place."""
    conv: jnp.ndarray   # (B, k-1, d_in + 2*g*n) last conv inputs
    state: jnp.ndarray  # (B, H, n, p) SSD recurrent state (fp32)


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.headdim
    return d_in, n_heads, s.n_groups, s.d_state


def ssm_init(key, cfg: ArchConfig) -> SSMParams:
    s = cfg.ssm
    d = cfg.d_model
    d_in, n_heads, g, n = ssm_dims(cfg)
    conv_ch = d_in + 2 * g * n
    ks = jax.random.split(key, 5)
    # dt bias: init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[3], (n_heads,))
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return SSMParams(
        w_zx=dense_param(ks[0], (d, 2 * d_in), ("embed", "ssm_in")),
        w_bc=dense_param(ks[1], (d, 2 * g * n), ("embed", None)),
        w_dt=dense_param(ks[2], (d, n_heads), ("embed", "ssm_heads")),
        conv_w=dense_param(ks[4], (s.conv_kernel, conv_ch), (None, None),
                           scale=1.0 / s.conv_kernel),
        conv_b=zeros_param((conv_ch,), (None,)),
        a_log=Param(jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
                    ("ssm_heads",)),
        d_skip=ones_param((n_heads,), ("ssm_heads",)),
        dt_bias=Param(dt_bias, ("ssm_heads",)),
        norm=ones_param((d_in,), ("ssm_in",)),
        w_out=dense_param(ks[4], (d_in, d), ("ssm_in", "embed")),
    )


def _gated_rms_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                    eps: float = 1e-6) -> jnp.ndarray:
    """Mamba2's RMSNormGated: norm(y * silu(z)) * scale, fp32 internals."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d. x: (B, S, C), w: (k, C). O(k) shifted adds —
    dense elementwise work (no conv HLO needed; k=4)."""
    k = w.shape[0]
    pads = x if init is None else jnp.concatenate([init, x], axis=1)
    if init is None:
        pads = jnp.pad(pads, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # k=4: unrolled shifted adds fuse into one kernel
        out = out + pads[:, i:i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _segsum_decay(da_cum: jnp.ndarray) -> jnp.ndarray:
    """L[i, j] = exp(cum_i - cum_j) for j <= i else 0. da_cum: (..., l)."""
    diff = da_cum[..., :, None] - da_cum[..., None, :]
    mask = jnp.tril(jnp.ones(diff.shape[-2:], dtype=bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_scan(xh: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, bmat: jnp.ndarray,
             cmat: jnp.ndarray, *, chunk: int,
             init_state: Optional[jnp.ndarray] = None,
             unroll: bool = False
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. xh: (B,S,H,P), dt: (B,S,H) post-softplus, a: (H,) negative,
    bmat/cmat: (B,S,G,N). Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    b, s, h, p = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hg = h // g
    l = min(chunk, s)
    s_orig = s
    pad = (-s) % l
    if pad:
        # NodePad: dt=0 on padded steps => decay=1 and zero state update, so
        # padding is semantically inert for the recurrence (outputs sliced).
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
    nc = s // l

    # chunk: (B, nc, l, ...)
    xc = xh.reshape(b, nc, l, h, p)
    dtc = dt.reshape(b, nc, l, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, l, g, n)
    cc = cmat.reshape(b, nc, l, g, n)

    da = dtc * a.astype(jnp.float32)                     # (B,nc,l,H)
    da_cum = jnp.cumsum(da, axis=2)                      # (B,nc,l,H)
    da_total = da_cum[:, :, -1]                          # (B,nc,H)

    # ---- intra-chunk (dense masked matmul — the MXU form) -----------------
    # scores[b,c,h,i,j] = C_i·B_j * L[i,j] ; y_diag = scores @ (dt*x)
    cb = jnp.einsum("bclgn,bcsgn->bcgls", cc, bc,
                    preferred_element_type=jnp.float32)   # (B,nc,G,l,l)
    lmat = _segsum_decay(jnp.moveaxis(da_cum, -1, -2))    # (B,nc,H,l,l)
    lmat = lmat.reshape(b, nc, g, hg, l, l)
    scores = cb[:, :, :, None] * lmat                     # (B,nc,G,hg,l,l)
    xdt = xc.astype(jnp.float32) * dtc[..., None]         # (B,nc,l,H,P)
    xdt_g = xdt.reshape(b, nc, l, g, hg, p)
    y_diag = jnp.einsum("bcghls,bcsghp->bclghp", scores, jnp.moveaxis(
        xdt_g, 3, 3), preferred_element_type=jnp.float32)

    # ---- chunk states ------------------------------------------------------
    # S_c = sum_j exp(da_total - da_cum_j) * B_j ⊗ (dt_j x_j)   (B,nc,H,N,P)
    decay_to_end = jnp.exp(da_total[:, :, None] - da_cum)  # (B,nc,l,H)
    bw = bc[:, :, :, :, None, :] * decay_to_end.reshape(b, nc, l, g, hg)[..., None]
    states = jnp.einsum("bclghn,bclghp->bcghnp",
                        bw, xdt_g, preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence (the only scan) ---------------------------
    s0 = (jnp.zeros((b, g, hg, n, p), jnp.float32) if init_state is None
          else init_state.reshape(b, g, hg, n, p).astype(jnp.float32))
    chunk_decay = jnp.exp(da_total).reshape(b, nc, g, hg)  # (B,nc,G,hg)

    def step(carry, inp):
        st, dec = inp                                      # (B,G,hg,N,P), (B,G,hg)
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=nc if unroll else 1)
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (B,nc,G,hg,N,P)

    # ---- inter-chunk output: C_i · S_prev * exp(da_cum_i) ------------------
    cdec = cc[:, :, :, :, None, :] * jnp.exp(da_cum).reshape(
        b, nc, l, g, hg)[..., None]                        # (B,nc,l,G,hg,N)
    y_off = jnp.einsum("bclghn,bcghnp->bclghp", cdec, prev_states,
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(xh.dtype), final.reshape(b, h, n, p)


def ssm_forward(p: SSMParams, cfg: ArchConfig, x: jnp.ndarray,
                *, return_state: bool = False):
    """Train/prefill forward. x: (B, S, d) -> (B, S, d)."""
    s_cfg = cfg.ssm
    dt_ = cfg.dtype
    d_in, n_heads, g, n = ssm_dims(cfg)
    b, s, _ = x.shape

    zx = jnp.einsum("bsd,de->bse", x, p.w_zx.value.astype(dt_))
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bcx = jnp.einsum("bsd,de->bse", x, p.w_bc.value.astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p.w_dt.value.astype(dt_))

    conv_in = jnp.concatenate([xin, bcx], axis=-1)
    conv_out = _causal_conv(conv_in, p.conv_w.value, p.conv_b.value)
    xin = conv_out[..., :d_in]
    bmat = conv_out[..., d_in:d_in + g * n].reshape(b, s, g, n)
    cmat = conv_out[..., d_in + g * n:].reshape(b, s, g, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p.dt_bias.value.astype(jnp.float32))
    a = -jnp.exp(p.a_log.value.astype(jnp.float32))
    xh = xin.reshape(b, s, n_heads, s_cfg.headdim)
    y, state = ssd_scan(xh, dt, a, bmat, cmat, chunk=s_cfg.chunk,
                        unroll=cfg.unroll_scans)
    y = y + xh * p.d_skip.value.astype(dt_)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = _gated_rms_norm(y, z, p.norm.value)
    out = jnp.einsum("bse,ed->bsd", y, p.w_out.value.astype(dt_))
    if return_state:
        k = s_cfg.conv_kernel
        cache = SSMCache(conv=conv_in[:, s - (k - 1):, :], state=state)
        return out, cache
    return out


def ssm_decode(p: SSMParams, cfg: ArchConfig, x: jnp.ndarray,
               cache: SSMCache) -> Tuple[jnp.ndarray, SSMCache]:
    """One-token decode: O(1) state update. x: (B, 1, d)."""
    s_cfg = cfg.ssm
    dt_ = cfg.dtype
    d_in, n_heads, g, n = ssm_dims(cfg)
    b = x.shape[0]

    zx = jnp.einsum("bsd,de->bse", x, p.w_zx.value.astype(dt_))
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bcx = jnp.einsum("bsd,de->bse", x, p.w_bc.value.astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p.w_dt.value.astype(dt_))

    conv_in = jnp.concatenate([xin, bcx], axis=-1)        # (B, 1, C)
    window = jnp.concatenate([cache.conv, conv_in], axis=1)  # (B, k, C)
    w = p.conv_w.value.astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
    conv_out = jax.nn.silu(conv_out + p.conv_b.value.astype(jnp.float32))
    conv_out = conv_out.astype(dt_)[:, None, :]           # (B, 1, C)

    xin = conv_out[..., :d_in]
    bmat = conv_out[..., d_in:d_in + g * n].reshape(b, g, n)
    cmat = conv_out[..., d_in + g * n:].reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p.dt_bias.value.astype(jnp.float32))  # (B, H)
    a = -jnp.exp(p.a_log.value.astype(jnp.float32))
    da = jnp.exp(dt * a)                                   # (B, H)

    xh = xin.reshape(b, n_heads, s_cfg.headdim).astype(jnp.float32)
    hg = n_heads // g
    bfull = jnp.repeat(bmat, hg, axis=1).astype(jnp.float32)   # (B, H, N)
    cfull = jnp.repeat(cmat, hg, axis=1).astype(jnp.float32)
    # s' = exp(dt a) s + dt * B ⊗ x ; y = C · s'
    new_state = (cache.state * da[..., None, None]
                 + dt[..., None, None] * bfull[..., None] * xh[:, :, None, :])
    y = jnp.einsum("bhn,bhnp->bhp", cfull, new_state)
    y = y + xh * p.d_skip.value.astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in).astype(dt_)
    y = _gated_rms_norm(y, z, p.norm.value)
    out = jnp.einsum("bse,ed->bsd", y, p.w_out.value.astype(dt_))
    return out, SSMCache(conv=window[:, 1:, :], state=new_state)


def ssm_init_cache(cfg: ArchConfig, batch: int, dtype=None) -> SSMCache:
    s = cfg.ssm
    d_in, n_heads, g, n = ssm_dims(cfg)
    dt_ = dtype or cfg.dtype
    return SSMCache(
        conv=jnp.zeros((batch, s.conv_kernel - 1, d_in + 2 * g * n), dt_),
        state=jnp.zeros((batch, n_heads, n, s.headdim), jnp.float32))


def ssm_reference(p: SSMParams, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle: sequential per-token recurrence (the pre-SSD 'DSP form').

    Used by tests to validate the chunked MXU form; also the baseline the
    benchmark harness times to show SSD's dense-rewrite win (paper Fig. 20
    analogue for the SSM family).
    """
    b, s, _ = x.shape
    cache = ssm_init_cache(cfg, b)
    # replicate conv exactly: run full conv then sequential SSD
    s_cfg = cfg.ssm
    dt_ = cfg.dtype
    d_in, n_heads, g, n = ssm_dims(cfg)
    zx = jnp.einsum("bsd,de->bse", x, p.w_zx.value.astype(dt_))
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bcx = jnp.einsum("bsd,de->bse", x, p.w_bc.value.astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p.w_dt.value.astype(dt_))
    conv_in = jnp.concatenate([xin, bcx], axis=-1)
    conv_out = _causal_conv(conv_in, p.conv_w.value, p.conv_b.value)
    xin = conv_out[..., :d_in]
    bmat = conv_out[..., d_in:d_in + g * n].reshape(b, s, g, n)
    cmat = conv_out[..., d_in + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p.dt_bias.value.astype(jnp.float32))
    a = -jnp.exp(p.a_log.value.astype(jnp.float32))
    xh = xin.reshape(b, s, n_heads, s_cfg.headdim).astype(jnp.float32)
    hg = n_heads // g
    bfull = jnp.repeat(bmat, hg, axis=2).astype(jnp.float32)
    cfull = jnp.repeat(cmat, hg, axis=2).astype(jnp.float32)

    def step(state, t):
        da = jnp.exp(dt[:, t] * a)
        state = (state * da[..., None, None]
                 + dt[:, t][..., None, None] * bfull[:, t][..., None]
                 * xh[:, t][:, :, None, :])
        y = jnp.einsum("bhn,bhnp->bhp", cfull[:, t], state)
        return state, y

    _, ys = jax.lax.scan(step, jnp.zeros((b, n_heads, n, s_cfg.headdim),
                                         jnp.float32), jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1) + xh * p.d_skip.value[None, None, :, None]
    y = y.reshape(b, s, d_in).astype(dt_)
    y = _gated_rms_norm(y, z, p.norm.value)
    return jnp.einsum("bse,ed->bsd", y, p.w_out.value.astype(dt_))
