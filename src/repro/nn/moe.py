"""Mixture-of-Experts with EffOp dense one-hot dispatch + NodePad capacity.

This is the strongest transfer of the paper's ideas to the LM families
(DESIGN.md §4): token->expert routing is a gather/scatter problem — exactly
the control-heavy op class GraNNite rewrites. We implement dispatch and
combine as *dense masked matmuls*:

  * EffOp:   dispatch = one_hot(position_in_expert) masked matmul; combine =
             gate-weighted transpose of the same mask. No gather, no scatter,
             no sort — MXU-only data movement.
  * NodePad: every expert buffer is padded to a fixed capacity
             C = ceil(G * top_k * capacity_factor / E) per token-group;
             overflow tokens drop (standard capacity-factor semantics),
             underflow slots are zero — "0 = no edge" reused verbatim.
  * GrAd:    the dispatch mask is a runtime tensor derived from router
             outputs — never baked into the compiled blob.

Grouped dispatch bounds the one-hot cost: tokens are processed in groups of
`group_size` G, so dispatch FLOPs are T*G*k*cf*d instead of T^2*k*cf*d.
Experts are sharded over the "model" mesh axis (EP); each device builds its
local experts' buffers from the all-gathered group — XLA SPMD turns the
dispatch einsum into an all-to-all-like exchange.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Param, activation, dense_param
from .config import ArchConfig, MoEConfig
from .mlp import MLPParams, mlp_forward, mlp_init


class MoEParams(NamedTuple):
    w_router: Param              # (d, E)
    w_in: Param                  # (E, d, ff)
    w_up: Optional[Param]        # (E, d, ff)
    w_out: Param                 # (E, ff, d)
    shared: Optional[MLPParams]  # llama4 always-on shared expert


def moe_init(key, cfg: ArchConfig) -> MoEParams:
    m = cfg.moe
    d, e, ff = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    return MoEParams(
        w_router=dense_param(ks[0], (d, e), ("embed", None)),
        w_in=dense_param(ks[1], (e, d, ff), ("experts", "embed", "ff")),
        w_up=(dense_param(ks[2], (e, d, ff), ("experts", "embed", "ff"))
              if cfg.gated_mlp else None),
        w_out=dense_param(ks[3], (e, ff, d), ("experts", "ff", "embed")),
        shared=(mlp_init(ks[4], cfg, d_ff=m.shared_expert_ff)
                if m.shared_expert_ff else None),
    )


def capacity(m: MoEConfig, group: int) -> int:
    c = int(group * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # pad to sublane multiple


def _route(m: MoEConfig, logits: jnp.ndarray):
    """logits: (G, E) -> (gates (G,k), idx (G,k), probs (G,E))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _dispatch_masks(m: MoEConfig, gates: jnp.ndarray, idx: jnp.ndarray,
                    cap: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build (G, E, C) dispatch 0/1 and combine (gate-weighted) tensors.

    Pure masked-dense arithmetic (EffOp): one_hot + cumsum position
    assignment, capacity overflow drops via a comparison mask.
    """
    g, k = idx.shape
    e = m.num_experts
    sel = jax.nn.one_hot(idx, e, dtype=jnp.float32)          # (G, k, E)
    # position of each (token, slot) within its expert queue: count earlier
    # assignments. Priority: slot-major then token order (standard).
    flat = sel.transpose(1, 0, 2).reshape(k * g, e)           # slot-major
    pos_flat = jnp.cumsum(flat, axis=0) - flat                # (k*G, E)
    pos = pos_flat.reshape(k, g, e).transpose(1, 0, 2)        # (G, k, E)
    within = (pos < cap) * sel                                # keep under capacity
    pos_cap = jnp.sum(pos * within, axis=-1)                  # (G, k)
    slot_oh = jax.nn.one_hot(pos_cap, cap, dtype=jnp.float32)  # (G, k, C)
    keep = jnp.sum(within, axis=-1)                           # (G, k) 0/1
    dispatch = jnp.einsum("gke,gkc->gec", within, slot_oh)     # (G, E, C)
    combine = jnp.einsum("gke,gkc,gk->gec", within, slot_oh,
                         gates * keep)
    return dispatch, combine


def _aux_losses(m: MoEConfig, probs: jnp.ndarray, idx: jnp.ndarray,
                logits: jnp.ndarray) -> jnp.ndarray:
    """Load-balance + router-z losses (standard Switch/OLMoE auxiliaries)."""
    e = m.num_experts
    density = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32),
                       axis=(0, 1))                           # fraction routed
    density_probs = jnp.mean(probs, axis=0)                   # router mass
    lb = e * jnp.sum(density * density_probs)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32),
                                             axis=-1)))
    return m.router_aux_weight * lb + m.router_z_weight * z


def moe_forward(p: MoEParams, cfg: ArchConfig, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss). Grouped EffOp dispatch."""
    m = cfg.moe
    dt = cfg.dtype
    act = activation(cfg.act)
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    g = min(m.group_size, t)
    assert t % g == 0, (t, g)
    ng = t // g
    cap = capacity(m, g)
    xg = tokens.reshape(ng, g, d)
    # under the distribution context: keep the token dim of each group
    # sharded over the data axes (see dist.sharding.constrain_scan_slices)
    from repro.dist.sharding import constrain_scan_slices
    xg = constrain_scan_slices(xg)

    def group_fn(xt):
        logits = jnp.einsum("gd,de->ge", xt, p.w_router.value.astype(dt))
        gates, idx, probs = _route(m, logits)
        dispatch, combine = _dispatch_masks(m, gates, idx, cap)
        # EffOp dispatch: (G,E,C)^T @ (G,d) -> (E,C,d) on the MXU
        buf = jnp.einsum("gec,gd->ecd", dispatch.astype(dt), xt)
        h = jnp.einsum("ecd,edf->ecf", buf, p.w_in.value.astype(dt))
        if p.w_up is not None:
            h = act(h) * jnp.einsum("ecd,edf->ecf", buf, p.w_up.value.astype(dt))
        else:
            h = act(h)
        out = jnp.einsum("ecf,efd->ecd", h, p.w_out.value.astype(dt))
        # combine: gate-weighted un-dispatch, same mask transposed
        y = jnp.einsum("gec,ecd->gd", combine.astype(dt), out)
        aux = _aux_losses(m, probs, idx, logits)
        return y, aux

    if ng == 1:
        y, aux = group_fn(xg[0])
        y = y[None]
    else:
        # vmap (NOT lax.map): groups are independent — parallel hardware
        # should process them concurrently, and an unrolled/vmapped form is
        # exactly costed by HLO cost analysis (a scanned form is not).
        y, aux = jax.vmap(group_fn)(xg)
    out = y.reshape(b, s, d)
    if p.shared is not None:
        out = out + mlp_forward(p.shared, cfg, x)
    return out, jnp.mean(aux)
