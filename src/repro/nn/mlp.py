"""Dense MLP blocks (gated SwiGLU/GeGLU or plain)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import Param, activation, dense_param
from .config import ArchConfig


class MLPParams(NamedTuple):
    w_in: Param                 # (d, ff) — gate proj when gated
    w_up: Optional[Param]       # (d, ff) — up proj (gated only)
    w_out: Param                # (ff, d)


def mlp_init(key, cfg: ArchConfig, *, d_ff: Optional[int] = None) -> MLPParams:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return MLPParams(
        w_in=dense_param(k1, (d, ff), ("embed", "ff")),
        w_up=dense_param(k2, (d, ff), ("embed", "ff")) if cfg.gated_mlp else None,
        w_out=dense_param(k3, (ff, d), ("ff", "embed")),
    )


def mlp_forward(p: MLPParams, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    dt = cfg.dtype
    act = activation(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, p.w_in.value.astype(dt))
    if p.w_up is not None:
        h = act(h) * jnp.einsum("bsd,df->bsf", x, p.w_up.value.astype(dt))
    else:
        h = act(h)
    return jnp.einsum("bsf,fd->bsd", h, p.w_out.value.astype(dt))
