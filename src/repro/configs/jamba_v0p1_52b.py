"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 — Mamba:attention 1:7 interleave (one
attention layer per 8-layer Jamba block), MoE every other layer
[arXiv:2403.19887].

Hardware-adaptation note (DESIGN.md §2): Jamba v0.1 uses Mamba-1 selective
scan; we realize its ssm layers with the Mamba2 SSD chunked-matmul form —
same state size (16), same interleave — because SSD is the TPU-native
(MXU-friendly) expression of the same recurrence class.
"""
from repro.nn.config import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern="jamba",          # 8-layer superblock, attn at index 4
    ssm=SSMConfig(d_state=16, headdim=64, expand=2, chunk=256,
                  conv_kernel=4, n_groups=1),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  every_k_layers=2),  # MoE on odd superblock positions
    act="silu",
    gated_mlp=True,
    tie_embeddings=False,
)
