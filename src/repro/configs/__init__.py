"""Architecture registry: --arch <id> lookup for every assigned config.

Each `<arch>.py` exports `CONFIG` (the exact published dims) and the registry
adds `reduced(cfg)` — a family-faithful shrink (few layers, small width, few
experts, tiny vocab) used by the per-arch CPU smoke tests. FULL configs are
only ever lowered via ShapeDtypeStructs (launch/dryrun.py), never allocated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.nn.config import ArchConfig, MoEConfig, SSMConfig, EncoderConfig, SHAPES

from . import (chatglm3_6b, gemma2_27b, jamba_v0p1_52b, llama4_scout_17b_a16e,
               mamba2_2p7b, olmoe_1b_7b, phi3_vision_4p2b, qwen3_4b,
               smollm_135m, whisper_base)

ARCHS: Dict[str, ArchConfig] = {
    "gemma2-27b": gemma2_27b.CONFIG,
    "chatglm3-6b": chatglm3_6b.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "smollm-135m": smollm_135m.CONFIG,
    "mamba2-2.7b": mamba2_2p7b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.CONFIG,
    "jamba-v0.1-52b": jamba_v0p1_52b.CONFIG,
    "phi-3-vision-4.2b": phi3_vision_4p2b.CONFIG,
    "whisper-base": whisper_base.CONFIG,
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ArchConfig, *, layers: int | None = None) -> ArchConfig:
    """Family-faithful smoke-test shrink: same layer pattern / feature flags,
    tiny dims. Keeps every structural knob (GQA ratio, qk-norm, softcaps,
    MoE top-k, SSD grouping, enc-dec) exercised on CPU."""
    sb = len(cfg.superblock)
    nl = layers if layers is not None else 2 * sb
    nl = max(sb, (nl // sb) * sb)
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = max(kv, 4 if cfg.num_heads >= 4 else cfg.num_heads)
    heads = (heads // kv) * kv
    changes = dict(
        num_layers=nl, d_model=128, num_heads=heads, num_kv_heads=kv,
        head_dim=32, d_ff=(256 if cfg.d_ff > 0 else 0), vocab_size=512,
        local_window=(64 if cfg.local_window else None),
        num_patches=16, loss_chunk=64, q_chunk=64, kv_chunk=64, remat=False,
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=128,
            shared_expert_ff=(128 if cfg.moe.shared_expert_ff else 0),
            group_size=64,
            # no-drop capacity: routing becomes independent of the grouping
            # context (prefill group vs decode group), so serve == forward
            # exactly — the standard inference-MoE setting
            capacity_factor=4.0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, headdim=16, chunk=32)
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(num_layers=2, frames=64)
    return dataclasses.replace(cfg, **changes)


__all__ = ["ARCHS", "SHAPES", "get_config", "reduced", "ArchConfig",
           "MoEConfig", "SSMConfig", "EncoderConfig"]
