"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 with an always-on shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E]. The multimodal early-fusion frontend
is out of scope for this entry (text backbone; phi-3-vision covers the vlm
frontend-stub pattern)."""
from repro.nn.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                  every_k_layers=1, shared_expert_ff=8192),
    rope_theta=500000.0,
    act="silu",
    gated_mlp=True,
    tie_embeddings=False,
)
