"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8 [arXiv:2409.02060]. Every layer is MoE; OLMoE uses
qk-norm."""
from repro.nn.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,                   # per-expert width
    vocab_size=50304,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                  every_k_layers=1),
    act="silu",
    gated_mlp=True,
    tie_embeddings=False,
)
