"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — partial ('2d') RoPE over half the head dim, multi-query-style
GQA [arXiv:2406.12793]."""
from repro.nn.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,               # chatglm rotary on half the dims
    act="silu",
    gated_mlp=True,
    tie_embeddings=False,            # separate output head
)
