"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating attention, logit softcapping
[arXiv:2408.00118]. head_dim is an explicit 128 (32·128 ≠ 4608)."""
from repro.nn.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    layer_pattern="local_global",     # alternating sliding-window / global
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,                  # sandwich norms
    zero_centered_norm=True,          # (1 + g) RMSNorm
    scale_embeddings=True,            # x *= sqrt(d_model)
    act="gelu_tanh",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=10000.0,
)
