"""The paper's own model configs (Section V): 2-layer GCN / GAT / GraphSAGE
on Cora/Citeseer-shaped graphs, hidden width 64, GAT 8 heads, SAGE fan-out 10.
"""
from __future__ import annotations

from repro.core.models import GNNConfig

CORA_FEATS, CORA_CLASSES = 1433, 7
CITESEER_FEATS, CITESEER_CLASSES = 3703, 6


def gcn(dataset: str = "cora") -> GNNConfig:
    f, c = ((CORA_FEATS, CORA_CLASSES) if dataset == "cora"
            else (CITESEER_FEATS, CITESEER_CLASSES))
    return GNNConfig(kind="gcn", in_feats=f, hidden=64, num_classes=c)


def gat(dataset: str = "cora") -> GNNConfig:
    f, c = ((CORA_FEATS, CORA_CLASSES) if dataset == "cora"
            else (CITESEER_FEATS, CITESEER_CLASSES))
    return GNNConfig(kind="gat", in_feats=f, hidden=64, num_classes=c, heads=8)


def sage(dataset: str = "cora", aggregator: str = "mean") -> GNNConfig:
    f, c = ((CORA_FEATS, CORA_CLASSES) if dataset == "cora"
            else (CITESEER_FEATS, CITESEER_CLASSES))
    return GNNConfig(kind="sage", in_feats=f, hidden=64, num_classes=c,
                     aggregator=aggregator, max_neighbors=10)


GNN_MODELS = {
    "gcn": gcn, "gat": gat,
    "sage-mean": lambda d="cora": sage(d, "mean"),
    "sage-max": lambda d="cora": sage(d, "max"),
}
