"""whisper-base [audio]: 6L d_model=512 8H (MHA kv=8) d_ff=2048 vocab=51865
— encoder-decoder; conv-mel frontend is a STUB (precomputed frame
embeddings) [arXiv:2212.04356].

Backbone-only per the assignment: 6 encoder + 6 decoder layers, layernorm,
GELU, non-gated MLP. Positions use RoPE (our substrate's scheme) instead of
whisper's learned absolute embeddings — a backbone-equivalent substitution
recorded in DESIGN.md §Arch-applicability.
"""
from repro.nn.config import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                   # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encoder=EncoderConfig(num_layers=6, frames=1500),
    frontend="audio_stub",
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,
)
