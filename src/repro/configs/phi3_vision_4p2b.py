"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (MHA, kv=32) d_ff=8192
vocab=32064 — phi3-mini text backbone + CLIP vision frontend
[hf:microsoft/Phi-3-vision-128k-instruct].

The CLIP frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, num_patches, d_model) which the LM prepends
to the token embeddings (NodePad thinking: a fixed patch budget keeps the
compiled blob static across image resolutions).
"""
from repro.nn.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision_stub",
    num_patches=1024,
    act="silu",
    gated_mlp=True,
    tie_embeddings=False,
)
