"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free, d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060].

Mamba2 blocks have no separate MLP (d_ff=0): the expand-2 in-projection is
the block's full width. num_heads is vestigial for the attention-free path
(kept >0 so generic shape code works); heads = d_in/headdim = 80.
"""
from repro.nn.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=8,                 # unused (attention-free)
    num_kv_heads=8,
    d_ff=0,                      # no MLP — the SSM block is the layer
    vocab_size=50280,
    layer_pattern="ssm",
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, chunk=256,
                  conv_kernel=4, n_groups=1),
    norm="rmsnorm",
    tie_embeddings=True,
)
