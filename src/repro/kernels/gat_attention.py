"""Fused GAT attention — the paper's EffOp + GrAx1 + GrAx2 pipeline, one pass.

Per (head, row-block): scores = leaky_relu(alpha_dst ⊕ alpha_src) + bias
(GrAx2 fused broadcast-add; GrAx1 additive mask — no Select, no multiply),
row softmax, then attn @ H aggregation on the MXU. The entire score matrix
row-strip (bm, N) stays in VMEM — it is produced, normalized, and consumed
without ever round-tripping to HBM, which is the Pallas analogue of keeping
the intermediate attention map out of DRAM (the paper's DSP<->DRAM traffic).

Grid: (H, N/bm). NodePad guarantees N % 128 == 0; F (per-head feature dim)
is zero-padded to the lane width by `ops.gat_attention` when needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128


def _gat_kernel(ad_ref, as_ref, bias_ref, h_ref, o_ref, *, negative_slope: float):
    # ad: (bm, 1) this row-block's dst terms for this head
    # as: (N, 1) all src terms for this head; bias: (bm, N); h: (N, 1, F)
    ad = ad_ref[...]                      # (bm, 1)
    a_src = as_ref[...][:, 0]             # (N,)
    e = ad + a_src[None, :]               # GrAx2: single fused broadcast-add
    e = jnp.where(e >= 0, e, negative_slope * e)          # leaky_relu
    e = e + bias_ref[...]                 # GrAx1: additive mask, no Select
    e = e - jnp.max(e, axis=1, keepdims=True)
    p = jnp.exp(e)
    attn = p / jnp.maximum(p.sum(axis=1, keepdims=True), 1e-12)
    h = h_ref[...][:, 0, :]               # (N, F)
    o_ref[...] = jnp.dot(attn.astype(h.dtype), h,
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)[:, None, :]


@functools.partial(jax.jit, static_argnames=("bm", "negative_slope", "interpret"))
def gat_attention(h: jnp.ndarray, alpha_dst: jnp.ndarray, alpha_src: jnp.ndarray,
                  bias_add: jnp.ndarray, *, bm: int = DEFAULT_BM,
                  negative_slope: float = 0.2,
                  interpret: bool = False) -> jnp.ndarray:
    """h: (N, H, F), alpha_*: (N, H), bias_add: (N, N) -> out (N, H, F)."""
    n, heads, f = h.shape
    assert alpha_dst.shape == (n, heads) and bias_add.shape == (n, n)
    bm = min(bm, n)
    assert n % bm == 0, (n, bm)
    grid = (heads, n // bm)
    return pl.pallas_call(
        functools.partial(_gat_kernel, negative_slope=negative_slope),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda hd, i: (i, hd)),       # alpha_dst
            pl.BlockSpec((n, 1), lambda hd, i: (0, hd)),        # alpha_src (all)
            pl.BlockSpec((bm, n), lambda hd, i: (i, 0)),        # bias row strip
            pl.BlockSpec((n, 1, f), lambda hd, i: (0, hd, 0)),  # h, this head
        ],
        out_specs=pl.BlockSpec((bm, 1, f), lambda hd, i: (i, hd, 0)),
        out_shape=jax.ShapeDtypeStruct((n, heads, f), h.dtype),
        interpret=interpret,
    )(alpha_dst, alpha_src, bias_add, h)
