"""MXU-tiled dense matmul — the StaGr aggregation backbone.

StaGr's whole point is that aggregation becomes `Â @ H`: a plain matmul the
systolic array executes at peak. This kernel is the TPU-native form: grid
(M/bm, N/bn, K/bk) with the K dimension innermost (output-block revisiting),
fp32 VMEM accumulator, blocks aligned to the 128x128 MXU tile (NodePad
guarantees M, K are 128-multiples for graph operands).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk)


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype"))
def block_matmul(a: jnp.ndarray, b: jnp.ndarray, *,
                 block: tuple = DEFAULT_BLOCK, interpret: bool = False,
                 out_dtype=None) -> jnp.ndarray:
    """C = A @ B with explicit VMEM tiling. Shapes must divide the blocks."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = block
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape ({m},{k})x({k},{n}) not divisible by block {(bm, bn, bk)}"
    out_dtype = out_dtype or a.dtype
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
