"""GrAx3: SAGE-max aggregation as masked multiply + max-pool on the MXU host.

out[i, f] = max_j mask[i, j] * h[j, f]   (features assumed >= 0 post-ReLU;
isolated nodes produce 0 — the paper's stated semantics, Fig. 18).

The sequential per-neighborhood DSP selection becomes a data-parallel
broadcast-multiply + max reduction. Grid: (N/bm, F/bf, N/bk) with a running
max accumulator in VMEM; the (rows, bk, bf) product is materialized in small
row slabs to bound VMEM (rows*bk*bf*4B <= ~2 MiB per slab).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bf, bk)
_ROW_SLAB = 32                   # rows per inner slab: 32*128*128*4B = 2 MiB


def _sage_max_kernel(mask_ref, h_ref, o_ref, acc_ref, *, k_steps: int, slab: int,
                     n_slabs: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)  # identity: mask*h >= 0

    h = h_ref[...].astype(jnp.float32)          # (bk, bf)

    def body(r, _):
        sl = pl.ds(r * slab, slab)
        mask = mask_ref[sl, :]                            # (slab, bk)
        prod = mask[:, :, None] * h[None, :, :]           # (slab, bk, bf)
        acc_ref[sl, :] = jnp.maximum(acc_ref[sl, :], jnp.max(prod, axis=1))
        return 0

    jax.lax.fori_loop(0, n_slabs, body, 0)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sage_max(mask01: jnp.ndarray, h: jnp.ndarray, *,
             block: tuple = DEFAULT_BLOCK, interpret: bool = False) -> jnp.ndarray:
    """mask01: (N, N) 0/1 sampled adjacency; h: (N, F) non-negative."""
    n, n2 = mask01.shape
    _, f = h.shape
    assert n == n2 and h.shape[0] == n
    bm, bf, bk = block
    bm, bf, bk = min(bm, n), min(bf, f), min(bk, n)
    assert n % bm == 0 and f % bf == 0 and n % bk == 0, (mask01.shape, h.shape, block)
    slab = min(bm, _ROW_SLAB)
    assert bm % slab == 0, (bm, slab)
    k_steps = n // bk
    return pl.pallas_call(
        functools.partial(_sage_max_kernel, k_steps=k_steps, slab=slab,
                          n_slabs=bm // slab),
        grid=(n // bm, f // bf, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bf), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), h.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bf), jnp.float32)],
        interpret=interpret,
    )(mask01, h)
