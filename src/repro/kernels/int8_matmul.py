"""QuantGr INT8 datapath: int8 x int8 -> int32 MXU matmul with static scales.

The NPU's INT8 path gives 2x TOPs / 4x TOPs-per-watt over FP16; the TPU MXU
likewise doubles int8 throughput. The kernel accumulates in int32 in VMEM
(never narrower — QuantGr is *symmetric static*, so overflow is bounded by
bk*127*127 per partial, well inside int32 for bk <= 2^16) and applies the
per-tensor activation scale x per-output-channel weight scale at the final
store, fusing dequantization into the matmul epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)


def _int8_kernel(x_ref, w_ref, sw_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        # epilogue: int32 -> fp32 dequant; sw already folds x_scale*w_scale
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sw_ref[...]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def int8_matmul(xq: jnp.ndarray, wq: jnp.ndarray, x_scale: jnp.ndarray,
                w_scale: jnp.ndarray, *, block: tuple = DEFAULT_BLOCK,
                interpret: bool = False) -> jnp.ndarray:
    """(M,K)int8 @ (K,N)int8 * x_scale * w_scale[N] -> (M,N)float32."""
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2 and xq.dtype == jnp.int8 and wq.dtype == jnp.int8
    bm, bn, bk = block
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"int8_matmul: ({m},{k})x({k},{n}) vs block {(bm, bn, bk)}"
    k_steps = k // bk
    # Fuse the per-tensor activation scale into the per-channel weight scales
    # so the epilogue is one multiply. Scales remain runtime *inputs* (GrAd
    # spirit: values never baked into the trace; QuantGr's "static" refers to
    # calibration time, not compile-time constants).
    sw = (jnp.asarray(w_scale).reshape(1, n)
          * jnp.asarray(x_scale)).astype(jnp.float32)
    kernel = functools.partial(_int8_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, bn), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, wq, sw)
