"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` is the semantic ground truth: simple, obviously-correct jnp.
Kernel tests sweep shapes/dtypes and `assert_allclose(kernel, ref)`; `ops.py`
also uses these as the CPU fallback path (the dry-run compiles these — same
FLOPs, no TPU-only lowering).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, *, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def int8_matmul_ref(xq: jnp.ndarray, wq: jnp.ndarray, x_scale: jnp.ndarray,
                    w_scale: jnp.ndarray) -> jnp.ndarray:
    """INT8 x INT8 -> INT32 accumulate -> FP32 rescale."""
    acc = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (x_scale * w_scale)


def bitmap_spmm_ref(dense_a: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GraSp oracle: the block-compacted form must equal the dense matmul."""
    return (dense_a @ h).astype(h.dtype)


def bitmap_spmm_block_ref(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                          counts: jnp.ndarray, h: jnp.ndarray, *,
                          block_size: int) -> jnp.ndarray:
    """GraSp ref path ON the compacted form — pure jnp, so it traces under
    jit/vmap with the block structure as a runtime argument (the serving
    plans need exactly that; the old ref densified on the HOST and could
    not see tracers). Same math as the kernel: gather the H row-blocks each
    bitmap entry names, MAC the real ones, mask the padded tail.

    This is still a dense-XLA fallback, not a skip win: every padded list
    entry is fetched and multiplied-by-zero rather than skipped — callers
    that must observe a GraSp dispatch running without the skip grid check
    `ops.bitmap_spmm_mode()` (GraphServe counts it as `backend_fallbacks`).
    """
    rb, max_nnz = block_cols.shape
    bs = block_size
    f = h.shape[1]
    hb = h.reshape(h.shape[0] // bs, bs, f)
    gathered = hb[block_cols]                           # (rb, max_nnz, bs, f)
    blk = blocks.reshape(rb, max_nnz, bs, bs)
    mask = (jnp.arange(max_nnz)[None, :] < counts[:, None]).astype(blocks.dtype)
    return jnp.einsum("rk,rkij,rkjf->rif", mask, blk, gathered
                      ).reshape(rb * bs, f).astype(h.dtype)


def gat_attention_ref(h: jnp.ndarray, alpha_dst: jnp.ndarray,
                      alpha_src: jnp.ndarray, bias_add: jnp.ndarray,
                      *, negative_slope: float = 0.2) -> jnp.ndarray:
    """Fused GAT oracle (EffOp + GrAx1 + GrAx2 dense formulation).

    h: (N, H, F); alpha_dst/alpha_src: (N, H); bias_add: (N, N) 0 / -1e9.
    out[i, hd] = sum_j softmax_j(leaky(ad[i,hd]+as[j,hd]) + bias[i,j]) h[j,hd].
    """
    e = alpha_dst[:, None, :] + alpha_src[None, :, :]            # (N, N, H)
    e = jax.nn.leaky_relu(e, negative_slope=negative_slope)
    e = e + bias_add[:, :, None]
    e = e - jnp.max(e, axis=1, keepdims=True)
    p = jnp.exp(e)
    attn = p / jnp.maximum(p.sum(axis=1, keepdims=True), 1e-12)  # (N, N, H)
    return jnp.einsum("ijh,jhf->ihf", attn, h)


def sage_max_ref(mask01: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GrAx3 oracle: out[i,f] = max_j mask[i,j] * h[j,f] (h assumed >= 0;
    isolated rows -> 0, matching the paper's DPU max-pool semantics)."""
    prod = mask01[:, :, None] * h[None, :, :]
    return jnp.max(prod, axis=1)


# ------------------------------------------------- fused per-layer twins
#
# Exact jnp ground truth for `fused_layers.py` — one twin per fused kernel,
# composed from the per-op refs above plus the EffOp catalogue
# (`repro.core.effop`), which makes EffOp the semantic spec for the fused
# epilogues on every backend (the ref path IS what serves on CPU).
# `repro.core.effop` is imported lazily inside each twin: ref.py loads with
# the kernels package, before repro.core exists.


def _act_ref(z: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "relu":
        return jax.nn.relu(z)
    if activation == "elu":
        return jax.nn.elu(z)
    if activation == "none":
        return z
    raise ValueError(f"unknown activation {activation!r}")


def fused_gcn_layer_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                        norm_adj: Optional[jnp.ndarray] = None,
                        quant=None, activation: str = "none") -> jnp.ndarray:
    """act(Â @ (X @ W) + b) — dense GCN layer twin.

    quant: optional (wq, w_scale, x_scale, h_scale, aq, a_scale) for the
    QuantGr tier; then the combine is the int8 chain (quantize X, s8 dot,
    dequant, re-quantize H) and the aggregate is Âq @ Hq — the exact unfused
    `apply_quantized_linear` + `apply_quantized_agg` math, inlined so the
    twin has no dependency on repro.core.
    """
    if quant is not None:
        wq, w_scale, x_scale, h_scale, aq, a_scale = quant
        xq = jnp.clip(jnp.round(x / x_scale), -127.0, 127.0).astype(jnp.int8)
        h = int8_matmul_ref(xq, wq, x_scale, w_scale)
        hq = jnp.clip(jnp.round(h / h_scale), -127.0, 127.0).astype(jnp.int8)
        acc = jnp.matmul(aq.astype(jnp.int32), hq.astype(jnp.int32),
                         preferred_element_type=jnp.int32)
        z = acc.astype(jnp.float32) * (a_scale * h_scale) + b
        return _act_ref(z, activation)
    h = matmul_ref(x, w, out_dtype=jnp.float32)
    return _act_ref(norm_adj @ h + b, activation).astype(x.dtype)


def fused_gcn_grasp_layer_ref(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                              counts: jnp.ndarray, x: jnp.ndarray,
                              w: jnp.ndarray, b: jnp.ndarray, *,
                              block_size: int,
                              activation: str = "none") -> jnp.ndarray:
    """GraSp GCN layer twin: combine then block-compacted aggregate."""
    h = matmul_ref(x, w, out_dtype=jnp.float32)
    agg = bitmap_spmm_block_ref(blocks, block_cols, counts, h,
                                block_size=block_size)
    return _act_ref(agg + b, activation).astype(x.dtype)


def fused_gat_layer_ref(x: Optional[jnp.ndarray], w: Optional[jnp.ndarray],
                        a_src: jnp.ndarray, a_dst: jnp.ndarray,
                        bias_add: jnp.ndarray, b: jnp.ndarray, *,
                        negative_slope: float = 0.2,
                        activation: str = "none",
                        precombined=None) -> jnp.ndarray:
    """Whole-GAT-layer twin via the EffOp catalogue (GrAx1 + GrAx2).

    x: (N, Fin); w: (Fin, H, F); a_src/a_dst: (H, F); b: (H, F) -> (N, H, F).
    precombined: optional (h, alpha_dst, alpha_src) — the QuantGr tiers
    compute the combine outside (int8) and only attention + epilogue fuse.
    """
    from repro.core import effop
    if precombined is not None:
        h, alpha_dst, alpha_src = precombined
    else:
        h = jnp.einsum("nf,fhd->nhd", x, w)
        alpha_src = jnp.einsum("nhf,hf->nh", h, a_src)
        alpha_dst = jnp.einsum("nhf,hf->nh", h, a_dst)
    outs = []
    for hd in range(h.shape[1]):
        e = effop.broadcast_add_scores(alpha_src[:, hd], alpha_dst[:, hd],
                                       grax2=True)
        e = jax.nn.leaky_relu(e, negative_slope=negative_slope)
        attn = effop.segment_softmax_dense(e, bias_add)       # GrAx1 mask
        outs.append(attn @ h[:, hd, :] + b[hd][None, :])
    return _act_ref(jnp.stack(outs, axis=1), activation)


def fused_sage_layer_ref(mask: jnp.ndarray, xk: jnp.ndarray, x: jnp.ndarray,
                         w_self: jnp.ndarray, w_neigh: jnp.ndarray,
                         b: jnp.ndarray, *, aggregator: str = "mean",
                         activation: str = "none") -> jnp.ndarray:
    """SAGE layer twin: mean (M @ X) or GrAx3 masked-max aggregation plus
    both combines and the epilogue. xk is X (mean) or pooled >= 0 (max)."""
    from repro.core import effop
    if aggregator == "mean":
        agg = mask @ xk
    else:
        agg = effop.masked_max_aggregate(xk, mask, grax3=True)
    return _act_ref(x @ w_self + agg @ w_neigh + b, activation)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        q_offset: int = 0) -> jnp.ndarray:
    """Exact GQA attention oracle.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H % KV == 0.
    `q_offset`: absolute position of q[0] (decode: Skv-1 typically).
    `window`: sliding-window size (attend to keys within `window` positions).
    `softcap`: gemma2-style tanh logit soft capping.
    """
    b, sq, hh, d = q.shape
    _, skv, kv, _ = k.shape
    group = hh // kv
    scale = scale if scale is not None else d ** -0.5
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", attn.astype(vr.dtype), vr)
    return out.astype(q.dtype)
